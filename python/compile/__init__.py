"""Build-time Python package: Layer-2 JAX model + Layer-1 Pallas kernels and
the AOT lowering driver. Never imported at runtime — the Rust binary consumes
only the HLO text artifacts this package emits (``make artifacts``)."""
