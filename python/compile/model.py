"""Layer-2 JAX model: the Llama-family transformer, numerically identical to
the Rust native engine (rust/src/model/llama.rs).

Parameter layout (must match the Rust ordering exactly — the PJRT engine
feeds parameters positionally):
  [embed (v,h)] +
  per layer: [attn_norm (h,), wq (h,h), wk, wv, wo, mlp_norm (h,),
              w_gate (f,h), w_up (f,h), w_down (h,f)] +
  [final_norm (h,), lm_head (v,h)]

Linears compute y = x @ W.T (weights stored (out, in), as in Rust).
`train_step` returns (loss, *grads) — the artifact the Rust trainer executes.
"""

import jax
import jax.numpy as jnp

RMS_EPS = 1e-5

# Scaled-down presets mirrored from rust/src/model/config.rs.
PRESETS = {
    "nano": dict(hidden=16, intermediate=44, heads=2, layers=1, vocab=29, seq_len=8),
    "tiny": dict(hidden=64, intermediate=172, heads=4, layers=2, vocab=512, seq_len=32),
    "small": dict(hidden=128, intermediate=344, heads=4, layers=4, vocab=1024, seq_len=64),
    "med": dict(hidden=256, intermediate=688, heads=8, layers=6, vocab=2048, seq_len=128),
}
ROPE_THETA = 10_000.0


def param_shapes(cfg):
    """Shapes in the canonical order (tuples; 1-D params as (h,))."""
    h, f, v = cfg["hidden"], cfg["intermediate"], cfg["vocab"]
    shapes = [("embed", (v, h))]
    for l in range(cfg["layers"]):
        shapes += [
            (f"layer{l}.attn_norm", (h,)),
            (f"layer{l}.wq", (h, h)),
            (f"layer{l}.wk", (h, h)),
            (f"layer{l}.wv", (h, h)),
            (f"layer{l}.wo", (h, h)),
            (f"layer{l}.mlp_norm", (h,)),
            (f"layer{l}.w_gate", (f, h)),
            (f"layer{l}.w_up", (f, h)),
            (f"layer{l}.w_down", (h, f)),
        ]
    shapes += [("final_norm", (h,)), ("lm_head", (v, h))]
    return shapes


def init_params(cfg, key):
    """Random init (for python-side tests; real runs feed Rust params)."""
    params = []
    std = 0.02
    resid_std = std / (2.0 * cfg["layers"]) ** 0.5
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("wo", "w_down")):
            params.append(resid_std * jax.random.normal(sub, shape, jnp.float32))
        else:
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def _rmsnorm(x, gain):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + RMS_EPS) * gain


def _rope(x, heads, head_dim):
    """x: (b, t, h). Rotate pairs (2i, 2i+1) per head, matching Rust."""
    b, t, h = x.shape
    x = x.reshape(b, t, heads, head_dim // 2, 2)
    pos = jnp.arange(t, dtype=jnp.float32)[None, :, None, None]
    i = jnp.arange(head_dim // 2, dtype=jnp.float32)[None, None, None, :]
    freq = 1.0 / ROPE_THETA ** (2.0 * i / head_dim)
    angle = pos * freq  # (1, t, 1, d/2)
    cos, sin = jnp.cos(angle), jnp.sin(angle)
    a = x[..., 0]
    bb = x[..., 1]
    rot = jnp.stack([a * cos - bb * sin, a * sin + bb * cos], axis=-1)
    return rot.reshape(b, t, h)


def forward_hidden(cfg, params, tokens):
    """Transformer body → final normed hidden states (b, t, h)."""
    heads = cfg["heads"]
    hd = cfg["hidden"] // heads
    b, t = tokens.shape
    it = iter(range(len(params)))
    embed = params[next(it)]
    x = embed[tokens]  # (b, t, h)
    for _ in range(cfg["layers"]):
        attn_norm = params[next(it)]
        wq = params[next(it)]
        wk = params[next(it)]
        wv = params[next(it)]
        wo = params[next(it)]
        mlp_norm = params[next(it)]
        w_gate = params[next(it)]
        w_up = params[next(it)]
        w_down = params[next(it)]

        n1 = _rmsnorm(x, attn_norm)
        q = _rope(n1 @ wq.T, heads, hd)
        k = _rope(n1 @ wk.T, heads, hd)
        v = n1 @ wv.T
        # (b, heads, t, hd)
        qh = q.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
        kh = k.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
        vh = v.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(jnp.float32(hd))
        mask = jnp.tril(jnp.ones((t, t), bool))
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, t, cfg["hidden"])
        x = x + attn @ wo.T

        n2 = _rmsnorm(x, mlp_norm)
        gate = n2 @ w_gate.T
        up = n2 @ w_up.T
        hact = jax.nn.silu(gate) * up
        x = x + hact @ w_down.T
    final_norm = params[next(it)]
    return _rmsnorm(x, final_norm)


def loss_fn(cfg, params, tokens, targets):
    """Mean next-token cross-entropy (identical to the Rust engine)."""
    hidden = forward_hidden(cfg, params, tokens)
    lm_head = params[-1]
    logits = hidden @ lm_head.T  # (b, t, v)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def make_train_step(cfg):
    """Build train_step(params..., tokens, targets) → (loss, *grads)."""

    def train_step(*args):
        n_params = len(param_shapes(cfg))
        params = list(args[:n_params])
        tokens, targets = args[n_params], args[n_params + 1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(cfg, ps, tokens, targets)
        )(params)
        # Rust expects 1-D grads as 1-row matrices — shapes already match
        # ((h,) flattens identically), so return as-is.
        return (loss, *grads)

    return train_step
