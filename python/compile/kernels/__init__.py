"""Layer-1 Pallas kernels (build-time only; lowered into the AOT artifacts).

All kernels run with ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute (see DESIGN.md
§Hardware-Adaptation). Block shapes are still chosen for TPU VMEM/MXU:
128-multiples on the lane dimension, fp32 accumulation.
"""

from .adam_update import adam_update
from .geodesic import geodesic_step
from .project import project, project_back
from .recovery import recovery_scale

__all__ = [
    "adam_update",
    "geodesic_step",
    "project",
    "project_back",
    "recovery_scale",
]
