"""Pallas kernel: recovery scaling Λ = φ ⊙ (G − S·G̃)  (Eqs. 10–11).

Column block layout: for each 128-wide lane block the kernel reduces the
column norms of the optimizer direction and the raw low-rank gradient
(both r×block), forms φ_j = ‖dir_j‖/‖g̃_j‖, and scales the residual block
(m×block) — a single fused pass instead of two reductions plus a broadcast
multiply over HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_BLOCK = 128


def _recovery_kernel(dir_ref, gl_ref, res_ref, o_ref):
    d = dir_ref[...]
    g = gl_ref[...]
    num = jnp.sqrt(jnp.sum(d * d, axis=0))  # (block,)
    den = jnp.sqrt(jnp.sum(g * g, axis=0))
    phi = jnp.where(den > 1e-30, num / den, 0.0)
    o_ref[...] = res_ref[...] * phi[None, :]


@functools.partial(jax.jit, static_argnames=())
def recovery_scale(direction, g_low, resid):
    """Λ = φ·resid. direction, g_low: (r, n); resid: (m, n) → (m, n)."""
    r, n = direction.shape
    m = resid.shape[0]
    pad = (-n) % LANE_BLOCK
    if pad:
        direction_p = jnp.pad(direction, ((0, 0), (0, pad)))
        # Pad g_low with ones so φ's denominator stays non-zero in padding.
        g_low_p = jnp.pad(g_low, ((0, 0), (0, pad)), constant_values=1.0)
        resid_p = jnp.pad(resid, ((0, 0), (0, pad)))
    else:
        direction_p, g_low_p, resid_p = direction, g_low, resid
    n_pad = direction_p.shape[1]
    grid = (n_pad // LANE_BLOCK,)
    out = pl.pallas_call(
        _recovery_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, LANE_BLOCK), lambda i: (0, i)),
            pl.BlockSpec((r, LANE_BLOCK), lambda i: (0, i)),
            pl.BlockSpec((m, LANE_BLOCK), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, LANE_BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n_pad), resid.dtype),
        interpret=True,
    )(direction_p, g_low_p, resid_p)
    return out[:, :n]
