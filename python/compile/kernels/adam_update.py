"""Pallas kernel: fused Adam moment update + preconditioned direction.

One pass over the low-rank moment tensors computes
  m′ = β₁m + (1−β₁)g,   v′ = β₂v + (1−β₂)g²,
  dir = (m′/d₁) / (√(v′/d₂) + ε)
without materializing intermediates in HBM — three reads, three writes
(vs. 5 reads/3 writes + 2 temporaries for the unfused jnp chain). This is
the optimizer's element-wise hot loop (Algorithm 1's G̃ᴼ computation).

The debias factors d₁ = 1−β₁ᵗ and d₂ = 1−β₂ᵗ depend on the step count, so
they arrive as (1,1) arrays rather than being baked into the HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8
LANE_BLOCK = 128


def _adam_kernel(beta1, beta2, eps, m_ref, v_ref, g_ref, d1_ref, d2_ref, mo_ref, vo_ref, do_ref):
    g = g_ref[...]
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mo_ref[...] = m_new
    vo_ref[...] = v_new
    d1 = d1_ref[0, 0]
    d2 = d2_ref[0, 0]
    do_ref[...] = (m_new / d1) / (jnp.sqrt(v_new / d2) + eps)


@functools.partial(jax.jit, static_argnames=("beta1", "beta2", "eps"))
def adam_update(m, v, g, debias1, debias2, beta1=0.9, beta2=0.999, eps=1e-8):
    """Fused moment update. m, v, g: (r, n); debias1/2: () or (1,1) arrays.

    Returns (m′, v′, dir), all (r, n).
    """
    r, n = m.shape
    pad_r = (-r) % ROW_BLOCK
    pad_n = (-n) % LANE_BLOCK
    if pad_r or pad_n:
        padcfg = ((0, pad_r), (0, pad_n))
        m_p = jnp.pad(m, padcfg)
        v_p = jnp.pad(v, padcfg)
        g_p = jnp.pad(g, padcfg)
    else:
        m_p, v_p, g_p = m, v, g
    rp, np_ = m_p.shape
    d1 = jnp.asarray(debias1, jnp.float32).reshape(1, 1)
    d2 = jnp.asarray(debias2, jnp.float32).reshape(1, 1)
    grid = (rp // ROW_BLOCK, np_ // LANE_BLOCK)
    kernel = functools.partial(_adam_kernel, beta1, beta2, eps)
    block = pl.BlockSpec((ROW_BLOCK, LANE_BLOCK), lambda i, j: (i, j))
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    m_new, v_new, direction = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[block, block, block, scalar, scalar],
        out_specs=[block, block, block],
        out_shape=[
            jax.ShapeDtypeStruct((rp, np_), m.dtype),
            jax.ShapeDtypeStruct((rp, np_), v.dtype),
            jax.ShapeDtypeStruct((rp, np_), m.dtype),
        ],
        interpret=True,
    )(m_p, v_p, g_p, d1, d2)
    return m_new[:r, :n], v_new[:r, :n], direction[:r, :n]
