"""Pallas kernel: low-rank projection G̃ = SᵀG and back-projection Ĝ = S·G̃.

The projection is the per-step hot-spot of every low-rank optimizer
(executed for every 2-D parameter on every iteration, O(mnr)), so it gets
the MXU treatment: the n (lane) dimension is tiled in 128-wide blocks, the
m (sublane) contraction stays resident in VMEM, accumulation is fp32.

VMEM budget per grid step (TPU estimate, DESIGN.md §Perf-L1):
  S block m×r + G block m×128 + out block r×128, all fp32
  e.g. m=2048, r=512: 2048·512·4 + 2048·128·4 + 512·128·4 ≈ 5.3 MiB — fits
  a 16 MiB VMEM core with double-buffering headroom on the G stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_BLOCK = 128


def _project_kernel(s_ref, g_ref, o_ref):
    # o = Sᵀ·G for one lane block; fp32 accumulate on the MXU.
    o_ref[...] = jnp.dot(
        s_ref[...].T, g_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _project_back_kernel(s_ref, gl_ref, o_ref):
    o_ref[...] = jnp.dot(
        s_ref[...], gl_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_lanes(x, block):
    n = x.shape[-1]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x, n


@functools.partial(jax.jit, static_argnames=())
def project(s, g):
    """G̃ = SᵀG.  s: (m, r), g: (m, n) → (r, n)."""
    m, r = s.shape
    g_p, n = _pad_lanes(g, LANE_BLOCK)
    n_pad = g_p.shape[1]
    grid = (n_pad // LANE_BLOCK,)
    out = pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((m, LANE_BLOCK), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((r, LANE_BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, n_pad), g.dtype),
        interpret=True,
    )(s, g_p)
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=())
def project_back(s, g_low):
    """Ĝ = S·G̃.  s: (m, r), g_low: (r, n) → (m, n)."""
    m, r = s.shape
    gl_p, n = _pad_lanes(g_low, LANE_BLOCK)
    n_pad = gl_p.shape[1]
    grid = (n_pad // LANE_BLOCK,)
    out = pl.pallas_call(
        _project_back_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((r, LANE_BLOCK), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, LANE_BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n_pad), g_low.dtype),
        interpret=True,
    )(s, gl_p)
    return out[:, :n]
