"""Pure-jnp reference oracles for every Pallas kernel (Layer 1).

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these to fp tolerance.
"""

import jax.numpy as jnp


def project_ref(s, g):
    """Low-rank projection G̃ = SᵀG.  s: (m, r), g: (m, n) -> (r, n)."""
    return s.T @ g


def project_back_ref(s, g_low):
    """Ĝ = S·G̃.  s: (m, r), g_low: (r, n) -> (m, n)."""
    return s @ g_low


def adam_update_ref(m, v, g, beta1, beta2, eps, debias1, debias2):
    """Fused Adam moment update + preconditioned direction.

    m, v, g: same shape. Returns (m', v', dir) with
      m' = β₁m + (1−β₁)g,  v' = β₂v + (1−β₂)g²,
      dir = (m'/debias1) / (sqrt(v'/debias2) + ε).
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    direction = (m_new / debias1) / (jnp.sqrt(v_new / debias2) + eps)
    return m_new, v_new, direction


def geodesic_ref(s, u, v, sigma, eta):
    """Rank-1 Grassmann geodesic step (Eq. 5, descent orientation).

    s: (m, r) orthonormal, u: (m,) left singular vector of ∇F (⊥ span S),
    v: (r,), sigma scalar. Returns S′ = S + (S·v·(cosθ−1) − u·sinθ)vᵀ with
    θ = σ·η clamped to π/2 (stability guard, matching the Rust engine).
    """
    theta = jnp.minimum(sigma * eta, jnp.float32(jnp.pi / 2))
    sv = s @ v  # (m,)
    w = sv * (jnp.cos(theta) - 1.0) - u * jnp.sin(theta)
    return s + jnp.outer(w, v)


def recovery_scale_ref(direction, g_low, resid):
    """Recovery scaling Λ = φ·resid (Eq. 10-11, Left-projection layout).

    direction, g_low: (r, n); resid: (m, n). φ_j = ‖dir[:,j]‖/‖g_low[:,j]‖.
    """
    num = jnp.linalg.norm(direction, axis=0)
    den = jnp.linalg.norm(g_low, axis=0)
    phi = jnp.where(den > 1e-30, num / den, 0.0)
    return resid * phi[None, :]


def tangent_ref(s, g):
    """Tangent ∇F = −2·R·Aᵀ with A = SᵀG, R = G − SA (Eqs. 2–4)."""
    a = s.T @ g
    r = g - s @ a
    return -2.0 * (r @ a.T)
