"""Pallas kernel: rank-1 Grassmann geodesic step (Eq. 5, descent form).

Given the basis S (m×r), the top singular triplet (σ, u, v) of the tangent
∇F and step size η, computes

    S′ = S + (S·v·(cos θ − 1) − u·sin θ)·vᵀ,   θ = min(σ·η, π/2)

in a single VMEM-resident kernel: one matvec (S·v), one outer-product
accumulate. O(m·r) — the cheapness that lets SubTrack++ update the subspace
as often as GaLore pays O(nm²) for.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _geodesic_kernel(eta, s_ref, u_ref, v_ref, sig_ref, o_ref):
    s = s_ref[...]
    u = u_ref[...]  # (m, 1)
    v = v_ref[...]  # (1, r)
    sigma = sig_ref[0, 0]
    theta = jnp.minimum(sigma * eta, jnp.float32(jnp.pi / 2))
    cos_t = jnp.cos(theta)
    sin_t = jnp.sin(theta)
    sv = jnp.dot(s, v[0, :], preferred_element_type=jnp.float32)  # (m,)
    w = sv * (cos_t - 1.0) - u[:, 0] * sin_t
    o_ref[...] = s + w[:, None] * v


@functools.partial(jax.jit, static_argnames=("eta",))
def geodesic_step(s, u, v, sigma, eta=10.0):
    """S′ from the rank-1 geodesic. s: (m, r); u: (m,); v: (r,); sigma: ()."""
    m, r = s.shape
    u2 = u.reshape(m, 1)
    v2 = v.reshape(1, r)
    sig = jnp.asarray(sigma, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_geodesic_kernel, eta),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, r), s.dtype),
        interpret=True,
    )(s, u2, v2, sig)
