"""AOT lowering driver: JAX (Layer 2 + Layer 1) → HLO **text** artifacts for
the Rust (Layer 3) runtime.

Interchange is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the `xla` crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md and DESIGN.md §AOT).

Artifacts written (defaults; see --help):
  train_step_<preset>_b<B>_t<T>.hlo.txt     model fwd+bwd → (loss, grads…)
  subtrack_adam_<m>x<n>_r<r>.hlo.txt        every-step optimizer math
  subtrack_update_<m>x<n>_r<r>.hlo.txt      every-k subspace update
  manifest.json                             shapes + provenance

Run once via `make artifacts`; Python never runs at training time.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model as model_lib  # noqa: E402
from compile import optim as optim_lib  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(preset: str, batch: int, out_dir: str) -> dict:
    cfg = model_lib.PRESETS[preset]
    t = cfg["seq_len"]
    shapes = model_lib.param_shapes(cfg)
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes]
    specs.append(jax.ShapeDtypeStruct((batch, t), jnp.int32))  # tokens
    specs.append(jax.ShapeDtypeStruct((batch, t), jnp.int32))  # targets
    step = model_lib.make_train_step(cfg)
    lowered = jax.jit(step).lower(*specs)
    name = f"train_step_{preset}_b{batch}_t{t}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  {name}: {len(text)} chars")
    return {
        "name": name,
        "kind": "train_step",
        "preset": preset,
        "batch": batch,
        "seq_len": t,
        "n_params": len(shapes),
    }


def lower_subtrack(m: int, n: int, r: int, out_dir: str, eta: float) -> list:
    """Lower both optimizer artifacts for one (m, n, r) bucket."""
    written = []
    f32 = jnp.float32
    adam_fn = optim_lib.make_subtrack_adam()
    lowered = jax.jit(adam_fn).lower(
        jax.ShapeDtypeStruct((m, r), f32),  # S
        jax.ShapeDtypeStruct((r, n), f32),  # M
        jax.ShapeDtypeStruct((r, n), f32),  # V
        jax.ShapeDtypeStruct((m, n), f32),  # G
        jax.ShapeDtypeStruct((), f32),      # debias1
        jax.ShapeDtypeStruct((), f32),      # debias2
    )
    name = f"subtrack_adam_{m}x{n}_r{r}"
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"  {name}")
    written.append({"name": name, "kind": "subtrack_adam", "m": m, "n": n, "r": r})

    upd_fn = optim_lib.make_subspace_update(eta=eta)
    lowered = jax.jit(upd_fn).lower(
        jax.ShapeDtypeStruct((m, r), f32),  # S
        jax.ShapeDtypeStruct((r, n), f32),  # M
        jax.ShapeDtypeStruct((r, n), f32),  # V
        jax.ShapeDtypeStruct((m, n), f32),  # G
        jax.ShapeDtypeStruct((), f32),      # debias2_prev
    )
    name = f"subtrack_update_{m}x{n}_r{r}"
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"  {name}")
    written.append({"name": name, "kind": "subtrack_update", "m": m, "n": n, "r": r, "eta": eta})
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--presets", default="nano,tiny", help="train_step presets (comma-sep)")
    ap.add_argument("--batch", type=int, default=4, help="train_step batch size")
    ap.add_argument(
        "--subtrack-shapes",
        default="16x16_4,64x172_8",
        help="optimizer buckets as mxn_r, comma-sep",
    )
    ap.add_argument("--eta", type=float, default=10.0)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"jax": jax.__version__, "artifacts": []}
    print("lowering train_step artifacts:")
    for preset in [p for p in args.presets.split(",") if p]:
        manifest["artifacts"].append(lower_train_step(preset, args.batch, args.out))
    print("lowering subtrack optimizer artifacts:")
    for spec in [s for s in args.subtrack_shapes.split(",") if s]:
        dims, r = spec.split("_")
        m, n = dims.split("x")
        manifest["artifacts"].extend(
            lower_subtrack(int(m), int(n), int(r), args.out, args.eta)
        )
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
