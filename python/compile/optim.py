"""Layer-2 composition of the SubTrack++ optimizer step from the Layer-1
Pallas kernels — lowered as standalone artifacts so the Rust coordinator can
run the paper's update on the PJRT path.

Two artifacts per (m, n, r) shape bucket:

* ``subtrack_adam``  — the every-step path: project → fused Adam → back-
  project → recovery scaling. Inputs (S, M, V, G, d1, d2) → (M′, V′, ΔW).
* ``subtrack_update`` — the every-k-steps path: least-squares residual →
  tangent → rank-1 (power iteration unrolled) → geodesic kernel → rotated
  moments (projection-aware, Eqs. 8–9). Inputs (S, M, V, G, t_debias) →
  (S′, M′, V′).

The orientation convention matches the Rust engine's Left side (m ≤ n);
the Rust caller transposes Right-side gradients before dispatch.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import adam_update, geodesic_step, project, project_back, recovery_scale

POWER_ITERS = 8


def subtrack_adam_step(s, m, v, g, debias1, debias2, beta1=0.9, beta2=0.999, eps=1e-8):
    """Every-step SubTrack++ math (no subspace motion): returns (m', v', dw).

    dw is the full-size weight delta Ĝ + Λ (recovery scaling included);
    the caller applies W ← W − lr·scale·dw.
    """
    g_low = project(s, g)
    m_new, v_new, direction = adam_update(
        m, v, g_low, debias1, debias2, beta1=beta1, beta2=beta2, eps=eps
    )
    back = project_back(s, direction)
    resid = g - project_back(s, g_low)
    lam = recovery_scale(direction, g_low, resid)
    return m_new, v_new, back + lam


def _power_top1(a, iters=POWER_ITERS):
    """Top singular triplet of a (m, r) matrix via unrolled power iteration.
    Deterministic init (column of ones) — adequate because the tangent is
    strongly rank-1 dominated; mirrors the Rust implementation's role."""
    m, r = a.shape
    v = jnp.ones((r,), a.dtype) / jnp.sqrt(jnp.float32(r))
    u = jnp.zeros((m,), a.dtype)
    sigma = jnp.float32(0.0)
    for _ in range(iters):
        u = a @ v
        un = jnp.linalg.norm(u)
        u = jnp.where(un > 1e-30, u / un, u)
        v = a.T @ u
        sigma = jnp.linalg.norm(v)
        v = jnp.where(sigma > 1e-30, v / sigma, v)
    return sigma, u, v


def subtrack_subspace_update(s, m, v, g, debias2_prev, eta=10.0, beta2=0.999):
    """Every-k-steps Grassmannian update + projection-aware moment rotation.

    s: (dim, r); m, v: (r, n); g: (dim, n) oriented Left.
    debias2_prev = 1 − β₂^(t−1) (scalar array).
    Returns (s', m', v').
    """
    a = project(s, g)  # r×n least-squares solution (S orthonormal)
    resid = g - project_back(s, a)
    tangent = -2.0 * (resid @ a.T)  # (dim, r)
    sigma, u_vec, v_vec = _power_top1(tangent)
    # geodesic_step already encodes the descent orientation (−u·sinθ for the
    # SVD factors of ∇F), matching rust/src/optim/subtrack.rs.
    s_new = geodesic_step(s, u_vec, v_vec, sigma, eta=eta)
    # Projection-aware rotation (Eqs. 8–9).
    q = s_new.T @ s  # (r, r)
    rot_m = q @ m
    var = jnp.maximum(v - m * m, 0.0)
    rot_v = jnp.abs(debias2_prev * ((q * q) @ var + (q @ m) ** 2))
    return s_new, rot_m, rot_v


def make_subtrack_adam(beta1=0.9, beta2=0.999, eps=1e-8):
    return functools.partial(subtrack_adam_step, beta1=beta1, beta2=beta2, eps=eps)


def make_subspace_update(eta=10.0, beta2=0.999):
    return functools.partial(subtrack_subspace_update, eta=eta, beta2=beta2)
