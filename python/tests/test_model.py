"""Layer-2 model correctness: shapes, init loss, gradients, and the
train_step artifact contract."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as model_lib


CFG = model_lib.PRESETS["nano"]


def _random_params(seed=0):
    return model_lib.init_params(CFG, jax.random.PRNGKey(seed))


def _random_batch(b=2, seed=1):
    key = jax.random.PRNGKey(seed)
    t = CFG["seq_len"]
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (b, t), 0, CFG["vocab"], jnp.int32)
    targets = jax.random.randint(k2, (b, t), 0, CFG["vocab"], jnp.int32)
    return tokens, targets


def test_param_shapes_match_rust_layout():
    shapes = model_lib.param_shapes(CFG)
    # embed + 9 per layer + final_norm + lm_head
    assert len(shapes) == 1 + 9 * CFG["layers"] + 2
    assert shapes[0] == ("embed", (CFG["vocab"], CFG["hidden"]))
    assert shapes[-1] == ("lm_head", (CFG["vocab"], CFG["hidden"]))
    assert shapes[1] == ("layer0.attn_norm", (CFG["hidden"],))
    assert shapes[7] == ("layer0.w_gate", (CFG["intermediate"], CFG["hidden"]))


def test_init_loss_near_log_vocab():
    params = _random_params()
    tokens, targets = _random_batch()
    loss = model_lib.loss_fn(CFG, params, tokens, targets)
    expect = np.log(CFG["vocab"])
    assert abs(float(loss) - expect) < 0.5, (float(loss), expect)


def test_causality():
    params = _random_params()
    tokens, targets = _random_batch()
    h1 = model_lib.forward_hidden(CFG, params, tokens)
    # Perturb the last position; earlier positions must be unchanged.
    tokens2 = tokens.at[0, -1].set((tokens[0, -1] + 1) % CFG["vocab"])
    h2 = model_lib.forward_hidden(CFG, params, tokens2)
    np.testing.assert_allclose(h1[0, 0], h2[0, 0], atol=1e-6)
    np.testing.assert_allclose(h1[0, :-1], h2[0, :-1], atol=1e-6)


def test_train_step_returns_loss_and_grads():
    step = model_lib.make_train_step(CFG)
    params = _random_params()
    tokens, targets = _random_batch()
    out = step(*params, tokens, targets)
    assert len(out) == len(params) + 1
    loss = out[0]
    assert loss.shape == ()
    for p, g in zip(params, out[1:]):
        assert p.shape == g.shape
    # Gradients are finite and non-trivial.
    total = sum(float(jnp.sum(jnp.abs(g))) for g in out[1:])
    assert np.isfinite(total) and total > 0


def test_grad_matches_finite_difference():
    params = _random_params()
    tokens, targets = _random_batch(b=1)
    loss, grads = jax.value_and_grad(
        lambda ps: model_lib.loss_fn(CFG, ps, tokens, targets)
    )(params)
    # Check one entry of wq in layer 0 (index 2).
    idx, i, j = 2, 1, 3
    eps = 1e-3
    pp = [p for p in params]
    pp[idx] = params[idx].at[i, j].add(eps)
    lp = model_lib.loss_fn(CFG, pp, tokens, targets)
    pp[idx] = params[idx].at[i, j].add(-eps)
    lm = model_lib.loss_fn(CFG, pp, tokens, targets)
    numeric = (lp - lm) / (2 * eps)
    assert abs(float(numeric) - float(grads[idx][i, j])) < 5e-3


def test_training_overfits_one_batch():
    params = _random_params()
    tokens, targets = _random_batch(b=2)
    val_and_grad = jax.jit(
        jax.value_and_grad(lambda ps: model_lib.loss_fn(CFG, ps, tokens, targets))
    )
    loss0 = None
    for _ in range(40):
        loss, grads = val_and_grad(params)
        if loss0 is None:
            loss0 = loss
        params = [p - 0.05 * g for p, g in zip(params, grads)]
    assert float(loss) < float(loss0) * 0.9, (float(loss0), float(loss))
