"""Layer-1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes; fixed-seed numpy draws the values. This is the
CORE correctness signal for everything the AOT artifacts embed.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp


from compile.kernels import (
    adam_update,
    geodesic_step,
    project,
    project_back,
    recovery_scale,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(**SETTINGS)
@given(
    m=st.integers(2, 48),
    n=st.integers(1, 200),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_project_matches_ref(m, n, r, seed):
    r = min(r, m)
    rng = np.random.default_rng(seed)
    s = rand(rng, m, r)
    g = rand(rng, m, n)
    got = project(s, g)
    want = ref.project_ref(s, g)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    m=st.integers(2, 48),
    n=st.integers(1, 200),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_project_back_matches_ref(m, n, r, seed):
    r = min(r, m)
    rng = np.random.default_rng(seed)
    s = rand(rng, m, r)
    g_low = rand(rng, r, n)
    got = project_back(s, g_low)
    want = ref.project_back_ref(s, g_low)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    r=st.integers(1, 24),
    n=st.integers(1, 300),
    t=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_adam_update_matches_ref(r, n, t, seed):
    rng = np.random.default_rng(seed)
    m = rand(rng, r, n)
    v = jnp.abs(rand(rng, r, n))
    g = rand(rng, r, n)
    b1, b2, eps = 0.9, 0.999, 1e-8
    d1 = 1.0 - b1**t
    d2 = 1.0 - b2**t
    got_m, got_v, got_d = adam_update(m, v, g, d1, d2, beta1=b1, beta2=b2, eps=eps)
    want_m, want_v, want_d = ref.adam_update_ref(m, v, g, b1, b2, eps, d1, d2)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    m=st.integers(2, 64),
    r=st.integers(1, 8),
    sigma=st.floats(0.0, 50.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_geodesic_matches_ref(m, r, sigma, seed):
    r = min(r, m)
    rng = np.random.default_rng(seed)
    # Orthonormal S via QR.
    raw = rng.standard_normal((m, r))
    q, _ = np.linalg.qr(raw)
    s = jnp.asarray(q, jnp.float32)
    u = rand(rng, m)
    u = u / (jnp.linalg.norm(u) + 1e-30)
    v = rand(rng, r)
    v = v / (jnp.linalg.norm(v) + 1e-30)
    eta = 0.37
    got = geodesic_step(s, u, v, jnp.float32(sigma), eta=eta)
    want = ref.geodesic_ref(s, u, v, jnp.float32(sigma), eta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_geodesic_preserves_orthonormality():
    rng = np.random.default_rng(7)
    m, r = 32, 4
    q, _ = np.linalg.qr(rng.standard_normal((m, r)))
    s = jnp.asarray(q, jnp.float32)
    g = jnp.asarray(rng.standard_normal((m, 64)), jnp.float32)
    # u must be orthogonal to span(S) for exact orthonormality — build it
    # from the projection residual, as the algorithm does.
    t = ref.tangent_ref(s, g)
    u, sv, vt = np.linalg.svd(np.asarray(t), full_matrices=False)
    s_new = geodesic_step(
        s,
        jnp.asarray(u[:, 0]),
        jnp.asarray(vt[0]),
        jnp.float32(sv[0]),
        eta=1e-3,
    )
    gram = np.asarray(s_new).T @ np.asarray(s_new)
    np.testing.assert_allclose(gram, np.eye(r), atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(2, 40),
    n=st.integers(1, 200),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_recovery_scale_matches_ref(m, n, r, seed):
    rng = np.random.default_rng(seed)
    direction = rand(rng, r, n)
    g_low = rand(rng, r, n)
    resid = rand(rng, m, n)
    got = recovery_scale(direction, g_low, resid)
    want = ref.recovery_scale_ref(direction, g_low, resid)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_recovery_scale_zero_denominator():
    # Columns with zero low-rank gradient must get φ = 0, not inf/nan.
    direction = jnp.ones((2, 3), jnp.float32)
    g_low = jnp.zeros((2, 3), jnp.float32)
    resid = jnp.ones((4, 3), jnp.float32)
    out = recovery_scale(direction, g_low, resid)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(out, 0.0)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_project_exact_on_lane_boundary(dtype):
    # n an exact multiple of the 128 lane block (no padding path).
    rng = np.random.default_rng(3)
    s = jnp.asarray(rng.standard_normal((16, 4)), dtype)
    g = jnp.asarray(rng.standard_normal((16, 256)), dtype)
    np.testing.assert_allclose(project(s, g), ref.project_ref(s, g), rtol=1e-5, atol=1e-5)
