"""pytest conftest: make `compile` importable from any invocation directory."""

import os
import sys

_here = os.path.dirname(os.path.abspath(__file__))
_python_dir = os.path.dirname(_here)
if _python_dir not in sys.path:
    sys.path.insert(0, _python_dir)
