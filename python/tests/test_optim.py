"""SubTrack++ optimizer composition (Layer 2 over Layer 1): the lowered
artifacts must implement exactly Algorithm 1's step math."""

import numpy as np

import jax.numpy as jnp

from compile import optim as optim_lib
from compile.kernels import ref


def _setup(m=12, n=40, r=4, seed=0):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((m, r)))
    s = jnp.asarray(q, jnp.float32)
    g = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    mm = jnp.asarray(0.01 * rng.standard_normal((r, n)), jnp.float32)
    vv = jnp.asarray(np.abs(0.01 * rng.standard_normal((r, n))), jnp.float32)
    return s, mm, vv, g


def test_adam_step_composition_matches_manual():
    s, m, v, g = _setup()
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = 5
    d1, d2 = 1 - b1**t, 1 - b2**t
    m_new, v_new, dw = optim_lib.subtrack_adam_step(s, m, v, g, d1, d2)
    # Manual composition with the jnp oracles.
    g_low = ref.project_ref(s, g)
    em, ev, ed = ref.adam_update_ref(m, v, g_low, b1, b2, eps, d1, d2)
    back = ref.project_back_ref(s, ed)
    resid = g - ref.project_back_ref(s, g_low)
    lam = ref.recovery_scale_ref(ed, g_low, resid)
    np.testing.assert_allclose(m_new, em, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(v_new, ev, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dw, back + lam, rtol=1e-4, atol=1e-5)


def test_subspace_update_preserves_orthonormality():
    s, m, v, g = _setup(m=24, n=64, r=5, seed=3)
    s_new, m_new, v_new = optim_lib.subtrack_subspace_update(
        s, m, v, g, jnp.float32(1 - 0.999**4), eta=1e-3
    )
    gram = np.asarray(s_new).T @ np.asarray(s_new)
    np.testing.assert_allclose(gram, np.eye(5), atol=1e-3)
    assert np.all(np.asarray(v_new) >= 0)


def test_subspace_update_reduces_estimation_error():
    s, m, v, g = _setup(m=24, n=64, r=5, seed=4)

    def cost(ss):
        a = np.asarray(ss).T @ np.asarray(g)
        return float(np.linalg.norm(np.asarray(g) - np.asarray(ss) @ a))

    before = cost(s)
    s_new, _, _ = optim_lib.subtrack_subspace_update(
        s, m, v, g, jnp.float32(0.5), eta=1e-4
    )
    after = cost(s_new)
    assert after < before, (before, after)


def test_moment_rotation_identity_when_subspace_static():
    # If the gradient already lies in span(S), the tangent vanishes and the
    # rotation matrix is I ⇒ moments unchanged (up to the debias factor).
    s, m, v, _ = _setup(m=16, n=32, r=4, seed=5)
    coeff = jnp.asarray(np.random.default_rng(6).standard_normal((4, 32)), jnp.float32)
    g_in_span = s @ coeff
    t = 10_000  # debias2_prev ≈ 1 at large t
    s_new, m_new, v_new = optim_lib.subtrack_subspace_update(
        s, m, v, g_in_span, jnp.float32(1 - 0.999 ** (t - 1)), eta=10.0
    )
    np.testing.assert_allclose(s_new, s, atol=1e-4)
    np.testing.assert_allclose(m_new, m, rtol=1e-3, atol=1e-4)
