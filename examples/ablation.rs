//! Figure 3/6 ablation walkthrough: pure Grassmannian tracking, +PA, +RS and
//! full SubTrack++ (plus GaLore for reference) on one model, reporting loss
//! and wall-time.
//!
//!     cargo run --release --example ablation

use subtrack::experiments::pretrain::{run_method, SweepOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = SweepOpts::new("tiny", 150);
    opts.batch_size = 8;
    opts.lr = 2e-3;
    let variants = [
        ("subtrack-pure", "Grassmannian tracking only"),
        ("subtrack-pa", "+ projection-aware optimizer"),
        ("subtrack-rs", "+ recovery scaling"),
        ("subtrack++", "full SubTrack++"),
        ("galore", "GaLore reference"),
    ];
    println!("{:<16} {:<32} {:>10} {:>10}", "variant", "description", "loss", "time (s)");
    for (method, desc) in variants {
        let r = run_method(&opts, method);
        println!(
            "{:<16} {:<32} {:>10.4} {:>10.1}",
            method, desc, r.final_eval_loss, r.wall_time_secs
        );
    }
    Ok(())
}
