//! Raw GEMM throughput probe (see EXPERIMENTS.md §Perf).
//!
//! Prints human-readable GFLOPS and merges a machine-readable record into
//! `BENCH_gemm.json` (shared with `examples/profile_step.rs`, which adds
//! steps/sec) so the perf trajectory is tracked across PRs:
//!
//! ```text
//! cargo run --release --example gemmbench
//! SUBTRACK_BENCH_OUT=path.json cargo run --release --example gemmbench
//! ```

use std::collections::BTreeMap;
use subtrack::model::{Batch, Llama, ModelConfig, StepState};
use subtrack::optim::subtrack::grassmannian_step_ws;
use subtrack::tensor::{gemm, microkernel, ops, pool, qr, svd, Dtype, Matrix, MatrixB, Workspace};
use subtrack::util::json::{merge_into_file, Json};
use subtrack::util::rng::Rng;

/// Measure mean seconds/op over ~`budget` seconds of repetitions.
fn time_op(budget: f64, mut op: impl FnMut()) -> f64 {
    // One untimed warmup rep.
    op();
    let t0 = std::time::Instant::now();
    let mut reps = 0u32;
    while t0.elapsed().as_secs_f64() < budget {
        op();
        reps += 1;
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let out_path =
        std::env::var("SUBTRACK_BENCH_OUT").unwrap_or_else(|_| "BENCH_gemm.json".to_string());
    let budget = 0.3f64;
    let mut rng = Rng::new(1);
    let mut ws = Workspace::new();
    let mut cases = BTreeMap::new();
    let auto_threads = gemm::gemm_threads();

    for n in [128usize, 256, 512] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        let mut c = ws.take(n, n);

        // (label, thread count, op) triples measured identically.
        let variants: Vec<(&str, usize)> = vec![
            ("matmul_1t", 1),
            ("matmul", 0),
            ("matmul_nt", 0),
            ("matmul_tn", 0),
            ("matmul_into", 0),
            ("matmul_nt_into", 0),
            ("matmul_tn_into", 0),
        ];
        for (label, forced) in variants {
            gemm::set_gemm_threads(forced);
            let secs = match label {
                "matmul" | "matmul_1t" => {
                    time_op(budget, || {
                        std::hint::black_box(gemm::matmul(&a, &b));
                    })
                }
                "matmul_nt" => time_op(budget, || {
                    std::hint::black_box(gemm::matmul_nt(&a, &b));
                }),
                "matmul_tn" => time_op(budget, || {
                    std::hint::black_box(gemm::matmul_tn(&a, &b));
                }),
                "matmul_into" => time_op(budget, || {
                    gemm::matmul_into(&mut c, &a, &b);
                    std::hint::black_box(&c);
                }),
                "matmul_nt_into" => time_op(budget, || {
                    gemm::matmul_nt_into(&mut c, &a, &b, &mut ws);
                    std::hint::black_box(&c);
                }),
                "matmul_tn_into" => time_op(budget, || {
                    gemm::matmul_tn_into(&mut c, &a, &b, &mut ws);
                    std::hint::black_box(&c);
                }),
                _ => unreachable!(),
            };
            gemm::set_gemm_threads(0);
            let gflops = flops / secs / 1e9;
            println!("{label:<16} {n}: {:8.2} ms  {gflops:7.2} GFLOPS", secs * 1e3);
            cases.insert(
                format!("{label}_{n}"),
                Json::obj(vec![
                    ("ms", Json::Num(secs * 1e3)),
                    ("gflops", Json::Num(gflops)),
                ]),
            );
        }
        // Packed-vs-legacy route sweep at the auto plan: the two routes are
        // bit-identical by contract, so the delta is pure kernel speed
        // (panel packing + register tiling + the active micro-kernel vs the
        // streaming row kernel).
        for (label, mode) in [("matmul_legacy", 1usize), ("matmul_packed", 2usize)] {
            gemm::set_gemm_pack(mode);
            let secs = time_op(budget, || {
                gemm::matmul_into(&mut c, &a, &b);
                std::hint::black_box(&c);
            });
            gemm::set_gemm_pack(0);
            let gflops = flops / secs / 1e9;
            println!("{label:<16} {n}: {:8.2} ms  {gflops:7.2} GFLOPS", secs * 1e3);
            cases.insert(
                format!("{label}_{n}"),
                Json::obj(vec![
                    ("ms", Json::Num(secs * 1e3)),
                    ("gflops", Json::Num(gflops)),
                ]),
            );
        }
        ws.give(c);
    }

    // ---- widening kernels: packed 16-bit operands, f32 accumulation ----
    // The default route fuses decode into B-panel packing (no full-matrix
    // f32 image of B); the `_legacy` rows pin `GEMM_PACK=1`, which decodes
    // into leased scratch and runs the streaming row kernel — so the ledger
    // tracks both the decode-fusion win and the historical baseline.
    println!("\nwidening GEMM (packed B, f32 accumulation):");
    let mut dtype_ms = BTreeMap::new();
    for n in [128usize, 256, 512] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let mut c = ws.take(n, n);
        let f32_secs = time_op(budget, || {
            gemm::matmul_into(&mut c, &a, &b);
            std::hint::black_box(&c);
        });
        println!("matmul_f32       {n}: {:8.2} ms", f32_secs * 1e3);
        dtype_ms.insert(format!("matmul_f32_{n}"), Json::Num(f32_secs * 1e3));
        for dt in [Dtype::Bf16, Dtype::F16] {
            let packed = MatrixB::encode(&b, dt);
            let secs = time_op(budget, || {
                gemm::matmul_wide_into(&mut c, &a, &packed, &mut ws);
                std::hint::black_box(&c);
            });
            let label = dt.as_str();
            println!("matmul_wide_{label:<4} {n}: {:8.2} ms", secs * 1e3);
            dtype_ms.insert(format!("matmul_wide_{label}_{n}"), Json::Num(secs * 1e3));
            gemm::set_gemm_pack(1);
            let legacy_secs = time_op(budget, || {
                gemm::matmul_wide_into(&mut c, &a, &packed, &mut ws);
                std::hint::black_box(&c);
            });
            gemm::set_gemm_pack(0);
            println!("matmul_wide_{label}_legacy {n}: {:8.2} ms", legacy_secs * 1e3);
            dtype_ms.insert(
                format!("matmul_wide_{label}_legacy_{n}"),
                Json::Num(legacy_secs * 1e3),
            );
        }
        ws.give(c);
    }
    // Model-level per-dtype step cost: quantized activations + widened
    // weights against the plain f32 path at one fixed tiny-family shape.
    for dt in [Dtype::F32, Dtype::Bf16, Dtype::F16] {
        let mut cfg = ModelConfig::preset("tiny");
        cfg.seq_len = 64;
        cfg.dtype = dt;
        let t = cfg.seq_len;
        let model = Llama::new(cfg.clone(), 5);
        let b = 4usize;
        let mut brng = Rng::new(6);
        let inputs: Vec<u32> = (0..b * t).map(|_| brng.below(cfg.vocab) as u32).collect();
        let targets: Vec<u32> = (0..b * t).map(|_| brng.below(cfg.vocab) as u32).collect();
        let batch = Batch { inputs: inputs.clone(), targets, b, t };
        let mut state = StepState::new();
        let mut grads = model.zero_grads();
        let fwd = time_op(budget, || {
            let cache = model.forward_hidden_ws(&inputs, b, t, &mut state);
            cache.recycle(&mut state.ws);
        });
        let fwdbwd = time_op(budget, || {
            std::hint::black_box(model.loss_and_grad_into(&batch, &mut grads, &mut state));
        });
        let label = dt.as_str();
        println!("model_fwd    [{label:<4}]: {:8.3} ms", fwd * 1e3);
        println!("model_fwdbwd [{label:<4}]: {:8.3} ms", fwdbwd * 1e3);
        dtype_ms.insert(format!("model_fwd_{label}"), Json::Num(fwd * 1e3));
        dtype_ms.insert(format!("model_fwdbwd_{label}"), Json::Num(fwdbwd * 1e3));
    }

    // ---- refresh-path kernels (QR / SVD / power iteration / geodesic) ----
    // Timed at 1 worker and at the auto plan so the ledger tracks the
    // threaded-refresh win across PRs (ROADMAP "refresh wall-time" item).
    println!("\nrefresh-path kernels (m=256, n=256, r=16):");
    let (m, n, r) = (256usize, 256usize, 16usize);
    let g = Matrix::randn(m, n, 1.0, &mut rng);
    let base = Matrix::randn(m, r, 1.0, &mut rng);
    let (s_basis, _) = qr::thin_qr(&base);
    let mut refresh = BTreeMap::new();
    for (label, forced) in [("1t", 1usize), ("auto", 0usize)] {
        gemm::set_gemm_threads(forced);
        let mut q = ws.take(m, r);
        let mut rr = ws.take(r, r);
        let tall = Matrix::randn(m, r, 1.0, &mut rng);
        let qr_secs = time_op(budget, || {
            qr::thin_qr_into(&tall, &mut q, &mut rr, &mut ws);
            std::hint::black_box(&q);
        });
        ws.give(q);
        ws.give(rr);
        let mut basis = ws.take(m, r);
        let svd_secs = time_op(budget, || {
            svd::truncated_basis_into(&g, false, &mut basis, &mut ws);
            std::hint::black_box(&basis);
        });
        ws.give(basis);
        let mut rng_pi = Rng::new(7);
        let mut u = vec![0.0f32; m];
        let mut v = vec![0.0f32; n];
        let power_secs = time_op(budget, || {
            let sigma = svd::power_iteration_top1_ws(&g, 8, &mut rng_pi, &mut u, &mut v);
            std::hint::black_box(sigma);
        });
        let mut rng_gs = Rng::new(8);
        let mut s_work = s_basis.clone();
        let geo_secs = time_op(budget, || {
            s_work.copy_from(&s_basis);
            std::hint::black_box(grassmannian_step_ws(
                &mut s_work,
                &g,
                1e-3,
                8,
                &mut rng_gs,
                &mut ws,
            ));
        });
        gemm::set_gemm_threads(0);
        for (kernel, secs) in [
            ("thin_qr", qr_secs),
            ("truncated_svd", svd_secs),
            ("power_top1", power_secs),
            ("grassmannian", geo_secs),
        ] {
            println!("{kernel:<16} [{label:<4}]: {:8.3} ms", secs * 1e3);
            refresh.insert(format!("{kernel}_{label}"), Json::Num(secs * 1e3));
        }
    }
    // ---- WY-blocked QR block-size sweep (GEMM_QR_BLOCK tuning data) ----
    // nb = 1 is the per-column reflector fan; larger panels route the
    // trailing update and Q formation through the GEMM kernels. Timed at a
    // wider shape (n = 64) where the trailing matrix is big enough for the
    // compute-over-bandwidth trade to show.
    println!("\nWY-blocked QR sweep (m=256, n=64):");
    let (qm, qn) = (256usize, 64usize);
    let tall_wide = Matrix::randn(qm, qn, 1.0, &mut rng);
    for (label, forced) in [("1t", 1usize), ("auto", 0usize)] {
        gemm::set_gemm_threads(forced);
        for nb in [1usize, 2, 4, 8, 16, 32] {
            let mut q = ws.take(qm, qn);
            let mut rr = ws.take(qn, qn);
            let secs = time_op(budget, || {
                qr::thin_qr_into_blocked(&tall_wide, &mut q, &mut rr, &mut ws, nb);
                std::hint::black_box(&q);
            });
            ws.give(q);
            ws.give(rr);
            println!("thin_qr nb={nb:<3} [{label:<4}]: {:8.3} ms", secs * 1e3);
            refresh.insert(format!("thin_qr_n{qn}_nb{nb}_{label}"), Json::Num(secs * 1e3));
        }
        gemm::set_gemm_threads(0);
    }

    // ---- attention kernels + head fan-out (gemm.attn_ms) ----
    // Two layers: (a) the per-head kernel pipeline — fused triangular
    // scores/causal-softmax/apply against the historical three-pass
    // scale→mask→softmax with dense GEMMs (the FLOP/traffic halving); (b)
    // the model-level attention fwd/bwd at 1 worker vs the auto plan across
    // a seq-len sweep — the per-(batch, head) pool fan-out win. The model
    // timings are full forward / forward+backward passes (attention-
    // dominated as T grows).
    println!("\nattention kernels (d=64) + head fan-out:");
    let mut attn = BTreeMap::new();
    let d = 64usize;
    for t in [64usize, 128, 256] {
        let q = Matrix::randn(t, d, 1.0, &mut rng);
        let k = Matrix::randn(t, d, 1.0, &mut rng);
        let v = Matrix::randn(t, d, 1.0, &mut rng);
        let scale = 1.0 / (d as f32).sqrt();
        let mut scores = ws.take(t, t);
        let mut out = ws.take(t, d);
        // Both legs pinned to one thread: the triangular kernels are
        // sequential by design (the model threads a level up, per head),
        // while the dense GEMMs of the three-pass leg would clear the
        // PAR_FLOPS gate at these shapes — letting them fan out would
        // measure threading, not the FLOP/traffic halving this section
        // records.
        let fused_fwd = time_op(budget, || {
            gemm::run_single_threaded(|| {
                gemm::attn_scores_into(&mut scores, &q, &k, 1.0, &mut ws);
                ops::causal_softmax_rows(&mut scores, scale);
                gemm::attn_apply_into(&mut out, &scores, &v);
            });
            std::hint::black_box(&out);
        });
        // Keep the fused probabilities for the backward timing below.
        let p_fused = scores.clone();
        let threepass_fwd = time_op(budget, || {
            gemm::run_single_threaded(|| {
                gemm::matmul_nt_into(&mut scores, &q, &k, &mut ws);
                scores.scale_mut(scale);
                for i in 0..t {
                    for j in (i + 1)..t {
                        scores.set(i, j, f32::NEG_INFINITY);
                    }
                }
                ops::softmax_rows(&mut scores);
                gemm::matmul_into(&mut out, &scores, &v);
            });
            std::hint::black_box(&out);
        });
        let dout = Matrix::randn(t, d, 1.0, &mut rng);
        let mut dvs = ws.take(t, d);
        let mut dqs = ws.take(t, d);
        let mut dks = ws.take(t, d);
        let mut dp = ws.take(t, t);
        let fused_bwd = time_op(budget, || {
            gemm::run_single_threaded(|| {
                gemm::attn_apply_tn_into(&mut dvs, &p_fused, &dout);
                gemm::attn_scores_into(&mut dp, &dout, &v, 1.0, &mut ws);
                ops::causal_softmax_grad(&p_fused, &mut dp, scale);
                gemm::attn_apply_into(&mut dqs, &dp, &k);
                gemm::attn_apply_tn_into(&mut dks, &dp, &q);
            });
            std::hint::black_box((&dvs, &dqs, &dks));
        });
        for (kernel, secs) in [
            ("fused_fwd", fused_fwd),
            ("threepass_fwd", threepass_fwd),
            ("fused_bwd", fused_bwd),
        ] {
            println!("{kernel:<16} T={t:<4}: {:8.3} ms", secs * 1e3);
            attn.insert(format!("{kernel}_T{t}"), Json::Num(secs * 1e3));
        }
        ws.give(scores);
        ws.give(out);
        ws.give(dvs);
        ws.give(dqs);
        ws.give(dks);
        ws.give(dp);
    }
    // Model-level head fan-out: tiny-family config, seq-len sweep, 1 worker
    // vs the auto plan.
    for t in [32usize, 64, 128] {
        let mut cfg = ModelConfig::preset("tiny");
        cfg.seq_len = t;
        let model = Llama::new(cfg.clone(), 3);
        let b = 4usize;
        let mut brng = Rng::new(4);
        let inputs: Vec<u32> = (0..b * t).map(|_| brng.below(cfg.vocab) as u32).collect();
        let targets: Vec<u32> = (0..b * t).map(|_| brng.below(cfg.vocab) as u32).collect();
        let batch = Batch { inputs: inputs.clone(), targets, b, t };
        for (label, forced) in [("1t", 1usize), ("auto", 0usize)] {
            gemm::set_gemm_threads(forced);
            let mut state = StepState::new();
            let mut grads = model.zero_grads();
            let fwd = time_op(budget, || {
                let cache = model.forward_hidden_ws(&inputs, b, t, &mut state);
                cache.recycle(&mut state.ws);
            });
            let fwdbwd = time_op(budget, || {
                std::hint::black_box(model.loss_and_grad_into(&batch, &mut grads, &mut state));
            });
            gemm::set_gemm_threads(0);
            println!("model_fwd  T={t:<4} [{label:<4}]: {:8.3} ms", fwd * 1e3);
            println!("model_step T={t:<4} [{label:<4}]: {:8.3} ms", fwdbwd * 1e3);
            attn.insert(format!("model_fwd_T{t}_{label}"), Json::Num(fwd * 1e3));
            attn.insert(format!("model_fwdbwd_T{t}_{label}"), Json::Num(fwdbwd * 1e3));
        }
    }

    // ---- scheduler sweep (counter-vs-deque dispatch, chunk sizing) ----
    // Raw pool dispatch of 4096 trivial tasks and of a skewed-cost task set
    // under both schedulers: Counter is the pre-deque shared-counter
    // baseline, Steal the per-participant deques with half-stealing. At
    // 1 worker both inline (the no-scheduler floor); at the full
    // participant budget the gap is pure claim/hand-off contention. The
    // chunk sweep times the 256³ GEMM at forced row-chunk sizes against the
    // L2-target auto sizing.
    println!("\nscheduler sweep ({} participants):", pool::max_participants());
    let mut sched = BTreeMap::new();
    for (mlabel, mode) in [("counter", pool::Sched::Counter), ("steal", pool::Sched::Steal)] {
        for (wlabel, w) in [("1w", 1usize), ("auto", pool::max_participants())] {
            let secs = time_op(budget, || {
                pool::run_mode(w, 4096, mode, &|i| {
                    std::hint::black_box(i);
                });
            });
            println!("dispatch4096 {mlabel:<8} [{wlabel:<4}]: {:8.3} ms", secs * 1e3);
            sched.insert(format!("dispatch4096_{mlabel}_{wlabel}"), Json::Num(secs * 1e3));
            // Skewed cost: every 16th task does ~64× the work — the
            // rebalancing case the deques exist for.
            let secs = time_op(budget, || {
                pool::run_mode(w, 512, mode, &|i| {
                    let reps = if i % 16 == 0 { 4096u64 } else { 64 };
                    let mut acc = 0u64;
                    for r in 0..reps {
                        acc = acc.wrapping_mul(6364136223846793005).wrapping_add(r);
                    }
                    std::hint::black_box(acc);
                });
            });
            println!("skewed512    {mlabel:<8} [{wlabel:<4}]: {:8.3} ms", secs * 1e3);
            sched.insert(format!("skewed512_{mlabel}_{wlabel}"), Json::Num(secs * 1e3));
        }
    }
    let sa = Matrix::randn(256, 256, 1.0, &mut rng);
    let sb = Matrix::randn(256, 256, 1.0, &mut rng);
    let mut sc = ws.take(256, 256);
    for chunk in [4usize, 16, 64, 0] {
        gemm::set_gemm_chunk(chunk);
        let secs = time_op(budget, || {
            gemm::matmul_into(&mut sc, &sa, &sb);
            std::hint::black_box(&sc);
        });
        let label = if chunk == 0 { "auto".to_string() } else { chunk.to_string() };
        println!("matmul256 chunk={label:<4}: {:8.3} ms", secs * 1e3);
        sched.insert(format!("matmul256_chunk_{label}"), Json::Num(secs * 1e3));
    }
    gemm::set_gemm_chunk(0);
    ws.give(sc);

    let record = Json::obj(vec![
        ("threads", Json::Num(auto_threads as f64)),
        ("microkernel", Json::Str(microkernel::active_name().to_string())),
        ("workspace_misses", Json::Num(ws.misses() as f64)),
        ("cases", Json::Obj(cases)),
        ("dtype_ms", Json::Obj(dtype_ms)),
        ("refresh_ms", Json::Obj(refresh)),
        ("attn_ms", Json::Obj(attn)),
        ("sched_ms", Json::Obj(sched)),
    ]);
    merge_into_file(&out_path, "gemm", record).expect("write BENCH_gemm.json");
    println!("\n[data] gemm record -> {out_path} ({auto_threads} threads auto)");
}
