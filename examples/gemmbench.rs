//! Raw GEMM throughput probe (see EXPERIMENTS.md §Perf).
use subtrack::tensor::{gemm, Matrix};
use subtrack::util::rng::Rng;
fn main() {
    let mut rng = Rng::new(1);
    for n in [128usize, 256, 512] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let t0 = std::time::Instant::now();
        let mut reps = 0;
        while t0.elapsed().as_secs_f64() < 1.0 { std::hint::black_box(gemm::matmul(&a, &b)); reps += 1; }
        let secs = t0.elapsed().as_secs_f64() / reps as f64;
        let gf = 2.0 * (n as f64).powi(3) / secs / 1e9;
        println!("matmul {n}: {:.1} ms, {gf:.2} GFLOPS", secs*1e3);
        let t0 = std::time::Instant::now();
        let mut reps = 0;
        while t0.elapsed().as_secs_f64() < 1.0 { std::hint::black_box(gemm::matmul_nt(&a, &b)); reps += 1; }
        let secs = t0.elapsed().as_secs_f64() / reps as f64;
        let gf = 2.0 * (n as f64).powi(3) / secs / 1e9;
        println!("matmul_nt {n}: {:.1} ms, {gf:.2} GFLOPS", secs*1e3);
    }
}
