//! Raw GEMM throughput probe (see EXPERIMENTS.md §Perf).
//!
//! Prints human-readable GFLOPS and merges a machine-readable record into
//! `BENCH_gemm.json` (shared with `examples/profile_step.rs`, which adds
//! steps/sec) so the perf trajectory is tracked across PRs:
//!
//! ```text
//! cargo run --release --example gemmbench
//! SUBTRACK_BENCH_OUT=path.json cargo run --release --example gemmbench
//! ```

use std::collections::BTreeMap;
use subtrack::tensor::{gemm, Matrix, Workspace};
use subtrack::util::json::{merge_into_file, Json};
use subtrack::util::rng::Rng;

/// Measure mean seconds/op over ~`budget` seconds of repetitions.
fn time_op(budget: f64, mut op: impl FnMut()) -> f64 {
    // One untimed warmup rep.
    op();
    let t0 = std::time::Instant::now();
    let mut reps = 0u32;
    while t0.elapsed().as_secs_f64() < budget {
        op();
        reps += 1;
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let out_path =
        std::env::var("SUBTRACK_BENCH_OUT").unwrap_or_else(|_| "BENCH_gemm.json".to_string());
    let budget = 0.3f64;
    let mut rng = Rng::new(1);
    let mut ws = Workspace::new();
    let mut cases = BTreeMap::new();
    let auto_threads = gemm::gemm_threads();

    for n in [128usize, 256, 512] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        let mut c = ws.take(n, n);

        // (label, thread count, op) triples measured identically.
        let variants: Vec<(&str, usize)> = vec![
            ("matmul_1t", 1),
            ("matmul", 0),
            ("matmul_nt", 0),
            ("matmul_tn", 0),
            ("matmul_into", 0),
            ("matmul_nt_into", 0),
            ("matmul_tn_into", 0),
        ];
        for (label, forced) in variants {
            gemm::set_gemm_threads(forced);
            let secs = match label {
                "matmul" | "matmul_1t" => {
                    time_op(budget, || {
                        std::hint::black_box(gemm::matmul(&a, &b));
                    })
                }
                "matmul_nt" => time_op(budget, || {
                    std::hint::black_box(gemm::matmul_nt(&a, &b));
                }),
                "matmul_tn" => time_op(budget, || {
                    std::hint::black_box(gemm::matmul_tn(&a, &b));
                }),
                "matmul_into" => time_op(budget, || {
                    gemm::matmul_into(&mut c, &a, &b);
                    std::hint::black_box(&c);
                }),
                "matmul_nt_into" => time_op(budget, || {
                    gemm::matmul_nt_into(&mut c, &a, &b, &mut ws);
                    std::hint::black_box(&c);
                }),
                "matmul_tn_into" => time_op(budget, || {
                    gemm::matmul_tn_into(&mut c, &a, &b, &mut ws);
                    std::hint::black_box(&c);
                }),
                _ => unreachable!(),
            };
            gemm::set_gemm_threads(0);
            let gflops = flops / secs / 1e9;
            println!("{label:<16} {n}: {:8.2} ms  {gflops:7.2} GFLOPS", secs * 1e3);
            cases.insert(
                format!("{label}_{n}"),
                Json::obj(vec![
                    ("ms", Json::Num(secs * 1e3)),
                    ("gflops", Json::Num(gflops)),
                ]),
            );
        }
        ws.give(c);
    }

    let record = Json::obj(vec![
        ("threads", Json::Num(auto_threads as f64)),
        ("workspace_misses", Json::Num(ws.misses() as f64)),
        ("cases", Json::Obj(cases)),
    ]);
    merge_into_file(&out_path, "gemm", record).expect("write BENCH_gemm.json");
    println!("\n[data] gemm record -> {out_path} ({auto_threads} threads auto)");
}
