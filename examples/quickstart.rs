//! Quickstart: train a tiny Llama with SubTrack++ in ~30 seconds, then swap
//! the optimizer for GaLore with one line — the public API in a nutshell.
//!
//!     cargo run --release --example quickstart

use subtrack::train::{TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    // 1. Pick a model preset and an optimizer by name. `TrainConfig::preset`
    //    fills in the paper's hyperparameters (rank, update interval k,
    //    scale α, step size η, limiter ζ) scaled to the model size.
    let mut cfg = TrainConfig::preset("tiny", "subtrack++", 120);
    cfg.batch_size = 8;
    cfg.lr = 2e-3;

    // 2. Train. The trainer owns the synthetic corpus, the LR schedule
    //    (warmup + cosine), gradient clipping and metrics.
    let mut trainer = Trainer::new(cfg.clone());
    println!(
        "training {} ({} params) with {} ...",
        cfg.model.name,
        trainer.model.param_count(),
        cfg.method
    );
    let report = trainer.run()?;
    println!(
        "SubTrack++ : eval loss {:.4} in {:.1}s ({} subspace updates, {} optimizer state)",
        report.final_eval_loss,
        report.wall_time_secs,
        report.subspace_updates,
        subtrack::util::human_bytes(report.peak_state_bytes),
    );

    // 3. Swap the optimizer — every baseline in the paper is one string away.
    let mut cfg2 = cfg;
    cfg2.method = "galore".into();
    let report2 = Trainer::new(cfg2).run()?;
    println!(
        "GaLore     : eval loss {:.4} in {:.1}s",
        report2.final_eval_loss, report2.wall_time_secs
    );

    println!(
        "\nSubTrack++ vs GaLore: Δloss {:+.4}, speedup {:.2}x",
        report.final_eval_loss - report2.final_eval_loss,
        report2.wall_time_secs / report.wall_time_secs
    );
    Ok(())
}
