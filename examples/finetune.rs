//! Fine-tuning walkthrough (Tables 4–5 workflow): pre-train a tiny backbone,
//! then fine-tune it on the synthetic GLUE battery with three optimizers and
//! print the accuracy grid.
//!
//!     cargo run --release --example finetune

use subtrack::data::tasks::TaskKind;
use subtrack::experiments::finetune::{accuracy_grid, finetune, pretrain_backbone, FinetuneOpts};
use subtrack::model::ModelConfig;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::preset("tiny");
    println!("pre-training backbone ({} params) ...", cfg.param_count());
    let backbone = pretrain_backbone(&cfg, 60, 42);

    let methods = ["full-rank", "galore", "subtrack++"];
    let tasks = TaskKind::glue();
    let opts = FinetuneOpts { steps: 100, ..FinetuneOpts::default() };

    let mut results = Vec::new();
    for method in methods {
        for (name, kind) in &tasks {
            print!("fine-tuning {method} on {name} ... ");
            let res = finetune(&backbone, name, *kind, method, &opts);
            println!("acc {:.1}%", 100.0 * res.val_accuracy);
            results.push(res);
        }
    }
    let task_names: Vec<&str> = tasks.iter().map(|(n, _)| *n).collect();
    println!("\n{}", accuracy_grid(&results, &task_names, &methods));
    Ok(())
}
