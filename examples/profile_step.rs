//! Per-stage timing of one native train step (L3 profiling harness).
use subtrack::model::{Llama, ModelConfig, Batch};
use subtrack::util::rng::Rng;
use std::time::Instant;
fn main() {
    let preset = std::env::args().nth(1).unwrap_or("small".into());
    let cfg = ModelConfig::preset(&preset);
    let model = Llama::new(cfg.clone(), 1);
    let mut rng = Rng::new(2);
    let (b, t) = (8, cfg.seq_len);
    let inputs: Vec<u32> = (0..b*t).map(|_| rng.below(cfg.vocab) as u32).collect();
    let targets: Vec<u32> = (0..b*t).map(|_| rng.below(cfg.vocab) as u32).collect();
    let batch = Batch { inputs: inputs.clone(), targets, b, t };
    // forward only
    let t0 = Instant::now();
    let n = 5;
    for _ in 0..n { std::hint::black_box(model.forward_hidden(&inputs, b, t)); }
    println!("forward_hidden: {:.1} ms", t0.elapsed().as_secs_f64()/n as f64*1e3);
    let t0 = Instant::now();
    for _ in 0..n { std::hint::black_box(model.loss(&batch)); }
    println!("loss (fwd+head+CE): {:.1} ms", t0.elapsed().as_secs_f64()/n as f64*1e3);
    let t0 = Instant::now();
    for _ in 0..n { std::hint::black_box(model.loss_and_grad(&batch)); }
    println!("loss_and_grad: {:.1} ms", t0.elapsed().as_secs_f64()/n as f64*1e3);
}
