//! Per-stage timing of one native train step (L3 profiling harness).
//!
//! Prints forward / loss / loss+grad timings plus full-step throughput
//! (forward + backward + fused-Adam update through the persistent
//! workspace), and merges the numbers into `BENCH_gemm.json` next to the
//! GEMM record from `examples/gemmbench.rs`:
//!
//! ```text
//! cargo run --release --example profile_step [preset]
//! SUBTRACK_BENCH_OUT=path.json cargo run --release --example profile_step small
//! ```

use std::time::Instant;
use subtrack::model::{Batch, Llama, ModelConfig, StepState};
use subtrack::optim::{Adam, AdamCfg, Optimizer};
use subtrack::tensor::{dtype, gemm, ops};
use subtrack::train::{FaultPolicy, Sentinel, SentinelConfig};
use subtrack::util::json::{merge_section_into_file, Json};
use subtrack::util::rng::Rng;

fn main() {
    let preset = std::env::args().nth(1).unwrap_or("small".into());
    let out_path =
        std::env::var("SUBTRACK_BENCH_OUT").unwrap_or_else(|_| "BENCH_gemm.json".to_string());
    let mut cfg = ModelConfig::preset(&preset);
    // Honor the PALLAS_DTYPE knob (the same override TrainConfig::preset
    // applies) so the mixed-precision legs profile their true storage.
    if let Some(dt) = dtype::env_dtype() {
        cfg.dtype = dt;
    }
    let mut model = Llama::new(cfg.clone(), 1);
    // Storage footprint of the weights themselves: 4 B/param for f32, 2 for
    // the packed 16-bit dtypes (the paper's memory axis, parameter slice).
    let mut param_bytes = 0usize;
    let mut param_count = 0usize;
    for p in &model.params {
        param_bytes += p.storage_bytes();
        param_count += p.value.len();
    }
    let bytes_per_param = param_bytes as f64 / param_count as f64;
    println!(
        "param storage [{}]: {bytes_per_param:.1} B/param ({param_count} params)",
        cfg.dtype.as_str()
    );
    let mut rng = Rng::new(2);
    let (b, t) = (8, cfg.seq_len);
    let inputs: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
    let targets: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
    let batch = Batch { inputs: inputs.clone(), targets, b, t };
    let mut state = StepState::new();
    let mut grads = model.zero_grads();
    let n = 5;

    // forward only
    let t0 = Instant::now();
    for _ in 0..n {
        let cache = model.forward_hidden_ws(&inputs, b, t, &mut state);
        cache.recycle(&mut state.ws);
    }
    let forward_ms = t0.elapsed().as_secs_f64() / n as f64 * 1e3;
    println!("forward_hidden: {forward_ms:.1} ms");

    // Forward + step at a forced single worker: the gap to the auto numbers
    // below is the pool win (GEMM row chunks + the per-(batch, head)
    // attention fan-out). Complements the T sweep gemmbench records under
    // gemm.attn_ms.
    gemm::set_gemm_threads(1);
    let t0 = Instant::now();
    for _ in 0..n {
        let cache = model.forward_hidden_ws(&inputs, b, t, &mut state);
        cache.recycle(&mut state.ws);
    }
    let forward_1t_ms = t0.elapsed().as_secs_f64() / n as f64 * 1e3;
    let t0 = Instant::now();
    for _ in 0..n {
        std::hint::black_box(model.loss_and_grad_into(&batch, &mut grads, &mut state));
    }
    let grad_1t_ms = t0.elapsed().as_secs_f64() / n as f64 * 1e3;
    gemm::set_gemm_threads(0);
    println!("forward_hidden [1t]: {forward_1t_ms:.1} ms");
    println!("loss_and_grad  [1t]: {grad_1t_ms:.1} ms");

    let t0 = Instant::now();
    for _ in 0..n {
        std::hint::black_box(model.loss_ws(&batch, &mut state));
    }
    let loss_ms = t0.elapsed().as_secs_f64() / n as f64 * 1e3;
    println!("loss (fwd+head+CE): {loss_ms:.1} ms");

    let t0 = Instant::now();
    for _ in 0..n {
        std::hint::black_box(model.loss_and_grad_into(&batch, &mut grads, &mut state));
    }
    let grad_ms = t0.elapsed().as_secs_f64() / n as f64 * 1e3;
    println!("loss_and_grad: {grad_ms:.1} ms");

    // Full training step: fwd + bwd + fused Adam, steady-state workspace.
    let mut opt = Adam::new(AdamCfg::default());
    // Warmup populates the buffer pool and the optimizer state.
    let _ = model.loss_and_grad_into(&batch, &mut grads, &mut state);
    opt.step(1e-4, &mut model.params, &grads);
    state.ws.reset_counters();
    let steps = 10usize;
    let t0 = Instant::now();
    for _ in 0..steps {
        let _ = model.loss_and_grad_into(&batch, &mut grads, &mut state);
        opt.step(1e-4, &mut model.params, &grads);
    }
    let step_secs = t0.elapsed().as_secs_f64() / steps as f64;
    let steps_per_sec = 1.0 / step_secs;
    println!(
        "full step (fwd+bwd+adam): {:.1} ms  ({steps_per_sec:.2} steps/sec, \
         {} ws misses over {steps} steps)",
        step_secs * 1e3,
        state.ws.misses(),
    );

    // Data-parallel step with ZeRO-partitioned optimizer state (2 shards):
    // shard gradients reduce through the persistent DpContext, then each
    // shard updates only its own partition. Also records the per-shard vs
    // replicated state footprint (the paper's memory axis).
    let state_bytes_replicated = opt.state_bytes();
    let dp_shards = 2usize;
    let mut dp = subtrack::train::parallel::DpContext::new(dp_shards);
    let mut sharded =
        subtrack::optim::sharded_by_name("full-rank", Default::default(), dp_shards);
    let _ = dp.loss_grad_into(&model, &batch, &mut grads);
    sharded.step(1e-4, &mut model.params, &grads);
    let t0 = Instant::now();
    for _ in 0..steps {
        let _ = dp.loss_grad_into(&model, &batch, &mut grads);
        sharded.step(1e-4, &mut model.params, &grads);
    }
    let dp_step_secs = t0.elapsed().as_secs_f64() / steps as f64;
    println!(
        "full step (dp={dp_shards}, sharded adam): {:.1} ms  \
         (state/shard {} B vs replicated {} B)",
        dp_step_secs * 1e3,
        sharded.state_bytes(),
        state_bytes_replicated,
    );
    let dp_state_bytes = sharded.state_bytes();

    // Fault-tolerance overhead: the per-step sentinel check (norm read +
    // window fold) and a full rollback snapshot (param deep-copy + packed
    // optimizer state), timed against the same model.
    let mut sentinel = Sentinel::new(SentinelConfig {
        policy: FaultPolicy::Rollback,
        ..SentinelConfig::default()
    });
    let reps = 50usize;
    let t0 = Instant::now();
    for s in 0..reps {
        let norm = ops::global_norm_slice(&grads);
        std::hint::black_box(sentinel.check(s, 1.0, norm));
    }
    let sentinel_ms = t0.elapsed().as_secs_f64() / reps as f64 * 1e3;
    let t0 = Instant::now();
    let mut saved: Vec<subtrack::tensor::Matrix> = Vec::new();
    for _ in 0..reps {
        saved.clear();
        saved.extend(model.params.iter().map(|p| p.value.clone()));
        std::hint::black_box(opt.snapshot());
    }
    let snapshot_ms = t0.elapsed().as_secs_f64() / reps as f64 * 1e3;
    println!("sentinel check (norm + window): {sentinel_ms:.3} ms");
    println!("rollback snapshot (params + opt): {snapshot_ms:.3} ms");

    let record = Json::obj(vec![(
        preset.as_str(),
        Json::obj(vec![
            ("forward_ms", Json::Num(forward_ms)),
            ("forward_1t_ms", Json::Num(forward_1t_ms)),
            ("loss_ms", Json::Num(loss_ms)),
            ("loss_and_grad_ms", Json::Num(grad_ms)),
            ("loss_and_grad_1t_ms", Json::Num(grad_1t_ms)),
            ("step_ms", Json::Num(step_secs * 1e3)),
            ("steps_per_sec", Json::Num(steps_per_sec)),
            ("dp2.step_ms", Json::Num(dp_step_secs * 1e3)),
            ("dp2.state_bytes_per_shard", Json::Num(dp_state_bytes as f64)),
            ("dp2.state_bytes_replicated", Json::Num(state_bytes_replicated as f64)),
            ("steady_state_ws_misses", Json::Num(state.ws.misses() as f64)),
            ("train.sentinel_ms", Json::Num(sentinel_ms)),
            ("train.snapshot_ms", Json::Num(snapshot_ms)),
            ("train.bytes_per_param", Json::Num(bytes_per_param)),
            ("train.storage_dtype", Json::Str(cfg.dtype.as_str().to_string())),
            ("batch", Json::Num(b as f64)),
            ("seq_len", Json::Num(t as f64)),
        ]),
    )]);
    // Nested under "profile_step", merging any presets recorded earlier.
    merge_section_into_file(&out_path, "profile_step", record).expect("write BENCH_gemm.json");
    println!("[data] profile_step record -> {out_path}");
}
