//! End-to-end validation driver (DESIGN.md §End-to-end validation).
//!
//! 1. Pre-trains the `small` Llama (~1.9M params — the 1-core substitute for
//!    the paper-scale run) for several hundred steps with SubTrack++ on the
//!    synthetic corpus, logging the loss curve to `results/e2e_loss.csv`.
//! 2. Verifies the loss actually converges (>25% drop from the ln(V) init).
//! 3. If `make artifacts` has produced the tiny-preset train_step module,
//!    re-runs a short segment through the **PJRT engine** (JAX-lowered
//!    Layer 2 + Pallas Layer 1 executed from Rust) and cross-checks the two
//!    engines' losses step by step — proving all three layers compose.
//!
//!     make artifacts && cargo run --release --example pretrain_e2e

use subtrack::runtime::PjrtEngine;
use subtrack::train::{TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    // ---- phase 1: native-engine pre-training run ----
    let steps = env_usize("E2E_STEPS", 300);
    let mut cfg = TrainConfig::preset("small", "subtrack++", steps);
    cfg.batch_size = 8;
    cfg.lr = 1e-3;
    let mut trainer = Trainer::new(cfg.clone());
    println!(
        "[e2e] pre-training {} ({} params) with SubTrack++ for {} steps ...",
        cfg.model.name,
        trainer.model.param_count(),
        steps
    );
    let report = trainer.run()?;
    report.curve_csv().save("results/e2e_loss.csv")?;
    let init_loss = (cfg.model.vocab as f32).ln();
    println!(
        "[e2e] loss {:.4} -> {:.4} (init ≈ ln V = {:.4}) in {:.1}s; curve -> results/e2e_loss.csv",
        report.steps.first().map(|s| s.loss).unwrap_or(f32::NAN),
        report.final_eval_loss,
        init_loss,
        report.wall_time_secs
    );
    anyhow::ensure!(
        report.final_eval_loss < init_loss * 0.75,
        "e2e convergence check failed: {} !< {}",
        report.final_eval_loss,
        init_loss * 0.75
    );
    println!("[e2e] convergence check PASSED (>25% below unigram init)");

    // ---- phase 2: three-layer cross-check via PJRT ----
    let artifact_preset = "tiny";
    let (b, t) = (2usize, 32usize);
    match PjrtEngine::new("artifacts", artifact_preset, b, t) {
        Err(e) => {
            println!("[e2e] PJRT phase skipped ({e}); run `make artifacts` to enable");
        }
        Ok(mut engine) => {
            println!("[e2e] PJRT cross-check: artifact {}", engine.artifact_name());
            let mut cfg = TrainConfig::preset(artifact_preset, "subtrack++", 20);
            cfg.batch_size = b;
            cfg.hp.interval = 5;
            let mut native = Trainer::new(cfg);
            let mut worst_rel = 0.0f32;
            for step in 0..10 {
                let batch = native.corpus.sample_batch(b, t);
                let (nat_loss, nat_grads) = native.model.loss_and_grad(&batch);
                let (pj_loss, _) = engine.loss_and_grad(&native.model.params, &batch)?;
                let rel = (nat_loss - pj_loss).abs() / nat_loss.max(1e-6);
                worst_rel = worst_rel.max(rel);
                native.opt.step(1e-3, &mut native.model.params, &nat_grads);
                println!(
                    "[e2e]   step {step}: native {nat_loss:.5} vs pjrt {pj_loss:.5} (rel {rel:.2e})"
                );
            }
            anyhow::ensure!(worst_rel < 1e-3, "engine divergence: {worst_rel}");
            println!("[e2e] three-layer cross-check PASSED (max rel diff {worst_rel:.2e})");
        }
    }
    println!("[e2e] OK");
    Ok(())
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}
