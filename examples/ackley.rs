//! Figure 5 — Grassmannian subspace tracking vs GaLore's SVD on the Ackley
//! function. Writes the four trajectory panels to `results/fig5_ackley.csv`.
//!
//!     cargo run --release --example ackley

use subtrack::experiments::ackley::figure5_panels;
use subtrack::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let runs = figure5_panels(1);
    let mut csv = CsvWriter::new(&["tracker", "scale_factor", "step", "x", "y", "f"]);
    println!("{:<14} {:>4} {:>10} {:>10} {:>10}  reached?", "tracker", "SF", "final f", "max jump", "mean jump");
    for run in &runs {
        for (i, (x, y, f)) in run.trajectory.iter().enumerate() {
            csv.row(&[
                format!("{:?}", run.tracker),
                format!("{}", run.scale_factor),
                i.to_string(),
                format!("{x:.6}"),
                format!("{y:.6}"),
                format!("{f:.6}"),
            ]);
        }
        println!(
            "{:<14} {:>4} {:>10.4} {:>10.4} {:>10.4}  {}",
            format!("{:?}", run.tracker),
            run.scale_factor,
            run.final_value,
            run.max_jump,
            run.mean_jump,
            if run.reached_minimum { "yes" } else { "no" }
        );
    }
    csv.save("results/fig5_ackley.csv")?;
    println!("\ntrajectories -> results/fig5_ackley.csv");
    Ok(())
}
