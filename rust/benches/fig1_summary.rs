//! Figure 1 — the headline three-panel comparison on the largest testbed
//! model: (a) eval loss, (b) peak memory, (c) wall-time, across all methods.
//!
//!     cargo bench --bench fig1_summary
//!     SUBTRACK_SIZES=small SUBTRACK_STEPS=300 cargo bench --bench fig1_summary

mod common;

use subtrack::experiments::pretrain::{self, SweepOpts};
use subtrack::optim::PRETRAIN_METHODS;

fn main() {
    common::banner("Figure 1", "loss / memory / wall-time bars");
    let size = common::env_str("SUBTRACK_SIZES", "tiny");
    let steps = common::env_usize("SUBTRACK_STEPS", 250);
    let mut opts = SweepOpts::new(&size, steps);
    opts.batch_size = 8;
    let reports = pretrain::sweep(&opts, PRETRAIN_METHODS);

    // Bars rendered as aligned text (the CSV feeds real plotting).
    let max_loss = reports.iter().map(|r| r.final_eval_loss).fold(0.0f32, f32::max);
    let max_mem = reports.iter().map(|r| r.peak_state_bytes).max().unwrap_or(1) as f32;
    let max_time = reports.iter().map(|r| r.wall_time_secs).fold(0.0f64, f64::max);
    println!("\n(a) eval loss          (b) optimizer memory    (c) wall-time");
    for r in &reports {
        let bar = |f: f32| "#".repeat((f * 20.0) as usize);
        println!(
            "{:<18} {:>7.3} {:<20} {:>9} {:<20} {:>7.1}s {}",
            r.method,
            r.final_eval_loss,
            bar(r.final_eval_loss / max_loss),
            subtrack::util::human_bytes(r.peak_state_bytes),
            bar(r.peak_state_bytes as f32 / max_mem),
            r.wall_time_secs,
            bar((r.wall_time_secs / max_time) as f32),
        );
    }
    let sub = reports.iter().find(|r| r.method == "SubTrack++").unwrap();
    let best_other = reports
        .iter()
        .filter(|r| r.method != "SubTrack++")
        .map(|r| r.final_eval_loss)
        .fold(f32::INFINITY, f32::min);
    println!(
        "\nSubTrack++ loss {:.4} vs best baseline {:.4} (paper Fig 1a: SubTrack++ lowest)",
        sub.final_eval_loss, best_other
    );
    common::save_csv(&pretrain::summary_csv(&reports), "fig1_summary.csv");
}
