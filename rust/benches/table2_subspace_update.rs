//! Table 2 + Table 3 (Appendix D) — optimizer state counts and subspace
//! update time complexity.
//!
//! Prints (a) the analytic optimizer-state table for the paper's six model
//! sizes, (b) measured subspace-update times across a shape grid with fitted
//! scaling exponents (SubTrack++/LDAdam O(mnr) vs GaLore/Fira O(nm²)), and
//! (c) the Appendix-D stage breakdown of the Grassmannian update.
//!
//!     cargo bench --bench table2_subspace_update
//!     SUBTRACK_GRID="64,128,256,384" cargo bench --bench table2_subspace_update

mod common;

use subtrack::experiments::complexity;
use subtrack::model::ModelConfig;
use subtrack::util::csv::CsvWriter;

fn main() {
    common::banner("Table 2", "optimizer memory & subspace update complexity");

    // ---- (a) optimizer state parameter counts (analytic, paper sizes) ----
    println!("\noptimizer state parameters (analytic; Table 2 formulas):");
    println!(
        "{:<8} {:>16} {:>16} {:>8}",
        "size", "Adam (2mn)", "low-rank (mr+2nr)", "ratio"
    );
    for cfg in ModelConfig::paper_sizes() {
        let adam = cfg.adam_state_params();
        let lowrank = cfg.lowrank_state_params(cfg.rank);
        println!(
            "{:<8} {:>16} {:>16} {:>7.2}x",
            cfg.name,
            adam,
            lowrank,
            adam as f64 / lowrank as f64
        );
    }

    // ---- (b) measured subspace update times + scaling fit ----
    let grid: Vec<usize> = common::env_str("SUBTRACK_GRID", "48,96,192,320")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let rank = common::env_usize("SUBTRACK_RANK", 16);
    let reps = common::env_usize("SUBTRACK_REPS", 5);
    println!("\nmeasured single-update times (square m×m gradients, rank {rank}):");
    println!(
        "{:<6} {:>14} {:>14} {:>14}",
        "m", "subtrack (s)", "svd (s)", "power (s)"
    );
    let samples = complexity::measure_grid(&grid, rank, reps);
    let mut csv = CsvWriter::new(&["mechanism", "m", "n", "r", "seconds"]);
    for &m in &grid {
        let find = |mech: &str| {
            samples
                .iter()
                .find(|s| s.mechanism == mech && s.m == m)
                .map(|s| s.seconds)
                .unwrap_or(f64::NAN)
        };
        println!(
            "{:<6} {:>14.6} {:>14.6} {:>14.6}",
            m,
            find("subtrack"),
            find("svd"),
            find("power")
        );
    }
    for s in &samples {
        csv.rowv(&[
            s.mechanism.to_string(),
            s.m.to_string(),
            s.n.to_string(),
            s.r.to_string(),
            format!("{:.9}", s.seconds),
        ]);
    }
    println!("\nfitted scaling exponents (log-time vs log-m; square slice):");
    for mech in ["subtrack", "svd", "power"] {
        println!(
            "  {:<10} m^{:.2}   (paper: subtrack/power O(mnr) -> ~2 at fixed r; svd O(nm²) -> ~3)",
            mech,
            complexity::scaling_exponent(&samples, mech)
        );
    }

    // ---- (c) Appendix-D stage breakdown ----
    let (m, n, r) = (
        common::env_usize("SUBTRACK_BD_M", 256),
        common::env_usize("SUBTRACK_BD_N", 256),
        rank,
    );
    let mut agg = subtrack::optim::subtrack::UpdateBreakdown::default();
    for i in 0..reps {
        let (_, bd) = complexity::time_grassmannian(m, n, r, 7 + i as u64);
        agg.lstsq += bd.lstsq;
        agg.residual += bd.residual;
        agg.tangent += bd.tangent;
        agg.rank1 += bd.rank1;
        agg.geodesic += bd.geodesic;
    }
    let total = agg.total();
    println!("\nAppendix D stage breakdown ({m}x{n}, r={r}, mean of {reps}):");
    for (name, secs, paper) in [
        ("least squares (SᵀG)", agg.lstsq, "O(mr²)→O(mnr)"),
        ("residual", agg.residual, "O(mrn)"),
        ("tangent −2RAᵀ", agg.tangent, "O(mnr)"),
        ("rank-1 approx", agg.rank1, "O(mr²)"),
        ("geodesic update", agg.geodesic, "O(mr²)"),
    ] {
        println!(
            "  {:<22} {:>10.3} ms  ({:>4.1}%)  paper: {}",
            name,
            secs / reps as f64 * 1e3,
            100.0 * secs / total,
            paper
        );
    }
    common::save_csv(&csv, "table2_subspace_update.csv");
}
