//! Figure 5 — Grassmannian tracking vs GaLore's SVD on the Ackley function
//! (rank-1 subspace, interval 10, 100 steps, scale factors 1 and 3).
//!
//!     cargo bench --bench fig5_ackley

mod common;

use subtrack::experiments::ackley::figure5_panels;
use subtrack::util::csv::CsvWriter;

fn main() {
    common::banner("Figure 5", "subspace tracking robustness on Ackley");
    let runs = figure5_panels(common::env_usize("SUBTRACK_SEED", 1) as u64);
    let mut csv = CsvWriter::new(&["tracker", "scale_factor", "step", "x", "y", "f"]);
    println!(
        "\n{:<14} {:>4} {:>10} {:>10} {:>10}  reached min?",
        "tracker", "SF", "final f", "max jump", "mean jump"
    );
    for run in &runs {
        for (i, (x, y, f)) in run.trajectory.iter().enumerate() {
            csv.row(&[
                format!("{:?}", run.tracker),
                format!("{}", run.scale_factor),
                i.to_string(),
                format!("{x:.6}"),
                format!("{y:.6}"),
                format!("{f:.6}"),
            ]);
        }
        println!(
            "{:<14} {:>4} {:>10.4} {:>10.4} {:>10.4}  {}",
            format!("{:?}", run.tracker),
            run.scale_factor,
            run.final_value,
            run.max_jump,
            run.mean_jump,
            run.reached_minimum
        );
    }
    // Paper Figure 5 shape: SVD's jumps grow with SF; tracking stays smooth.
    let svd1 = &runs[1];
    let svd3 = &runs[3];
    let grass1 = &runs[0];
    println!("\nshape checks vs paper Fig 5:");
    println!(
        "  SVD max jump grows with SF: {:.4} (SF1) -> {:.4} (SF3): {}",
        svd1.max_jump,
        svd3.max_jump,
        svd3.max_jump > svd1.max_jump
    );
    println!(
        "  tracking keeps smaller jumps than SVD@SF3: {:.4} vs {:.4}: {}",
        grass1.max_jump,
        svd3.max_jump,
        grass1.max_jump <= svd3.max_jump
    );
    common::save_csv(&csv, "fig5_ackley.csv");
}
