//! Figure 4 — training loss vs steps (a) and vs wall-time (b) for the
//! headline model. Writes the full per-step curves for both axes.
//!
//!     cargo bench --bench fig4_convergence
//!     SUBTRACK_SIZES=small SUBTRACK_STEPS=400 cargo bench --bench fig4_convergence

mod common;

use subtrack::experiments::pretrain::{self, SweepOpts};

const METHODS: &[&str] = &["full-rank", "galore", "ldadam", "fira", "subtrack++"];

fn main() {
    common::banner("Figure 4", "loss vs steps and vs wall-time");
    let size = common::env_str("SUBTRACK_SIZES", "tiny");
    let steps = common::env_usize("SUBTRACK_STEPS", 300);
    let mut opts = SweepOpts::new(&size, steps);
    opts.batch_size = 8;
    let reports = pretrain::sweep(&opts, METHODS);

    println!("\nfinal smoothed train loss / wall-time ({size}, {steps} steps):");
    println!("{:<28} {:>10} {:>12}", "method", "loss", "wall (s)");
    for r in &reports {
        let tail: f32 = {
            let n = r.steps.len();
            let lo = n.saturating_sub(20);
            r.steps[lo..].iter().map(|s| s.loss).sum::<f32>() / (n - lo) as f32
        };
        println!("{:<28} {:>10.4} {:>12.1}", r.method, tail, r.wall_time_secs);
    }
    // Figure-4 shape: SubTrack++ reaches the lowest loss in the least
    // wall-time among the low-rank methods.
    let sub = reports.iter().find(|r| r.method == "SubTrack++").unwrap();
    let ld = reports.iter().find(|r| r.method == "LDAdam").unwrap();
    println!(
        "\nSubTrack++ {:.4} in {:.1}s vs LDAdam {:.4} in {:.1}s",
        sub.final_eval_loss, sub.wall_time_secs, ld.final_eval_loss, ld.wall_time_secs
    );
    common::save_csv(&pretrain::curves_csv(&reports), "fig4_convergence.csv");
    common::save_csv(&pretrain::summary_csv(&reports), "fig4_summary.csv");
}
