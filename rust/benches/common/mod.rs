//! Shared plumbing for the per-table/figure bench harnesses.

use std::path::Path;

/// Read an env knob with a default (benches are parameterized through env
/// vars because `cargo bench` owns the CLI).
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_str(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

/// Save a CSV under results/ and announce it.
pub fn save_csv(csv: &subtrack::util::csv::CsvWriter, name: &str) {
    let path = Path::new("results").join(name);
    csv.save(&path).expect("write results csv");
    println!("\n[data] {} rows -> {}", csv.len(), path.display());
}

pub fn banner(id: &str, what: &str) {
    println!("================================================================");
    println!("{id} — {what}");
    println!("================================================================");
}
