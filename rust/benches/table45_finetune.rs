//! Tables 4 & 5 — fine-tuning on the synthetic GLUE / SuperGLUE batteries
//! at rank 8 (DESIGN.md §Substitutions), across the paper's fine-tuning
//! method set.
//!
//!     cargo bench --bench table45_finetune
//!     SUBTRACK_STEPS=200 SUBTRACK_SUITE=superglue cargo bench --bench table45_finetune

mod common;

use subtrack::data::tasks::TaskKind;
use subtrack::experiments::finetune::{accuracy_grid, finetune, pretrain_backbone, FinetuneOpts};
use subtrack::model::ModelConfig;
use subtrack::util::csv::CsvWriter;

const METHODS: &[&str] = &["full-rank", "badam", "galore", "ldadam", "subtrack++"];

fn main() {
    common::banner("Tables 4/5", "fine-tuning accuracy (GLUE/SuperGLUE stand-ins)");
    let suite = common::env_str("SUBTRACK_SUITE", "glue");
    let steps = common::env_usize("SUBTRACK_STEPS", 120);
    let cfg = ModelConfig::preset(&common::env_str("SUBTRACK_MODEL", "tiny"));
    println!("\npre-training {} backbone ...", cfg.name);
    let backbone = pretrain_backbone(&cfg, common::env_usize("SUBTRACK_PRETRAIN", 60), 42);

    let tasks = if suite == "superglue" { TaskKind::superglue() } else { TaskKind::glue() };
    let opts = FinetuneOpts { steps, rank: 8, ..FinetuneOpts::default() };

    let mut results = Vec::new();
    let mut csv = CsvWriter::new(&["suite", "task", "method", "val_accuracy", "wall_s"]);
    for method in METHODS {
        for (name, kind) in &tasks {
            let res = finetune(&backbone, name, *kind, method, &opts);
            println!(
                "  {method:<12} {name:<10} acc {:>5.1}%  ({:.1}s)",
                100.0 * res.val_accuracy,
                res.wall_time_secs
            );
            csv.rowv(&[
                suite.clone(),
                name.to_string(),
                method.to_string(),
                format!("{:.4}", res.val_accuracy),
                format!("{:.2}", res.wall_time_secs),
            ]);
            results.push(res);
        }
    }
    let task_names: Vec<&str> = tasks.iter().map(|(n, _)| *n).collect();
    println!("\n{}", accuracy_grid(&results, &task_names, METHODS));
    // Shape check (paper Tables 4/5): the low-rank methods land close to
    // full-rank; BAdam trails on the harder tasks.
    let mean = |m: &str| {
        let xs: Vec<f32> =
            results.iter().filter(|r| r.method == m).map(|r| r.val_accuracy).collect();
        xs.iter().sum::<f32>() / xs.len() as f32
    };
    println!(
        "mean accuracy — full-rank {:.3}, subtrack++ {:.3}, galore {:.3}, badam {:.3}",
        mean("full-rank"),
        mean("subtrack++"),
        mean("galore"),
        mean("badam")
    );
    common::save_csv(&csv, "table45_finetune.csv");
}
