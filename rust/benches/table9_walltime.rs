//! Table 9 — wall-time comparison under the paper's protocol: step count
//! sized so every periodic-subspace method performs exactly 10 subspace
//! updates.
//!
//!     cargo bench --bench table9_walltime
//!     SUBTRACK_SIZES=tiny,small SUBTRACK_STEPS=200 cargo bench --bench table9_walltime

mod common;

use subtrack::experiments::pretrain::{self, SweepOpts};
use subtrack::optim::PRETRAIN_METHODS;

fn main() {
    common::banner("Table 9", "wall-time, 10 subspace updates per run");
    let sizes = common::env_str("SUBTRACK_SIZES", "tiny");
    let steps = common::env_usize("SUBTRACK_STEPS", 200);

    let mut all = Vec::new();
    for size in sizes.split(',') {
        let mut opts = SweepOpts::new(size.trim(), steps);
        opts.batch_size = 8;
        opts.target_subspace_updates = 10;
        println!("\n--- {} / {} steps (interval {}) ---", size.trim(), steps, steps / 10);
        let reports = pretrain::sweep(&opts, PRETRAIN_METHODS);
        print!("{}", pretrain::walltime_table(&reports));
        // Shape checks mirroring the paper's Table 9 ordering.
        let get = |m: &str| reports.iter().find(|r| r.method == m).unwrap();
        let sub = get("SubTrack++");
        let ld = get("LDAdam");
        println!(
            "SubTrack++ vs LDAdam wall-time: {:.1}s vs {:.1}s ({:.0}% saved; paper: 43% on 1B)",
            sub.wall_time_secs,
            ld.wall_time_secs,
            100.0 * (1.0 - sub.wall_time_secs / ld.wall_time_secs)
        );
        all.extend(reports);
    }
    common::save_csv(&pretrain::summary_csv(&all), "table9_walltime.csv");
}
