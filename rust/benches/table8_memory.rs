//! Table 8 — peak memory comparison. Reports (a) measured optimizer-state
//! bytes + process peak RSS on short scaled runs, and (b) the analytic
//! per-size optimizer-state table for the paper's six sizes.
//!
//!     cargo bench --bench table8_memory

mod common;

use subtrack::experiments::pretrain::{self, SweepOpts};
use subtrack::model::ModelConfig;
use subtrack::optim::PRETRAIN_METHODS;

fn main() {
    common::banner("Table 8", "peak memory across methods");
    let size = common::env_str("SUBTRACK_SIZES", "tiny");
    let steps = common::env_usize("SUBTRACK_STEPS", 60);

    let mut opts = SweepOpts::new(&size, steps);
    opts.batch_size = 8;
    println!("\nmeasured ({size}, {steps} steps):");
    let reports = pretrain::sweep(&opts, PRETRAIN_METHODS);
    print!("{}", pretrain::memory_table(&reports));

    // Shape checks (paper Table 8): every reduced-state method well below
    // Adam; LDAdam above GaLore (error-feedback buffer). Note: at paper
    // scale BAdam is the smallest row; at this tiny scale its single active
    // block (the embedding) can exceed the low-rank methods' total — the
    // analytic table below shows the paper-scale ordering.
    let get = |m: &str| reports.iter().find(|r| r.method == m).unwrap();
    assert!(get("BAdam").peak_state_bytes < get("Adam").peak_state_bytes);
    assert!(get("SubTrack++").optimizer_state_params < get("Adam").optimizer_state_params);
    assert!(get("LDAdam").peak_state_bytes > get("GaLore").peak_state_bytes);
    println!("\nshape checks vs paper Table 8: reduced-state < Adam ✓, LDAdam > GaLore (EF buffer) ✓");

    println!("\nanalytic optimizer-state memory at paper sizes (fp32 bytes):");
    println!("{:<8} {:>14} {:>14}", "size", "Adam", "GaLore-class");
    for cfg in ModelConfig::paper_sizes() {
        println!(
            "{:<8} {:>14} {:>14}",
            cfg.name,
            subtrack::util::human_bytes(cfg.adam_state_params() * 4),
            subtrack::util::human_bytes(cfg.lowrank_state_params(cfg.rank) * 4),
        );
    }
    common::save_csv(&pretrain::summary_csv(&reports), "table8_memory.csv");
}
