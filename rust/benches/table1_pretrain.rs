//! Table 1 — evaluation loss pre-training Llama-family models across the
//! full method battery (scaled testbed; DESIGN.md §Substitutions).
//!
//!     cargo bench --bench table1_pretrain
//!     SUBTRACK_SIZES=tiny,small SUBTRACK_STEPS=400 cargo bench --bench table1_pretrain

mod common;

use subtrack::experiments::pretrain::{self, SweepOpts};
use subtrack::optim::PRETRAIN_METHODS;

fn main() {
    common::banner("Table 1", "pre-training eval loss across methods & sizes");
    let sizes = common::env_str("SUBTRACK_SIZES", "tiny");
    let steps = common::env_usize("SUBTRACK_STEPS", 250);

    let mut all = Vec::new();
    for size in sizes.split(',') {
        let mut opts = SweepOpts::new(size.trim(), steps);
        opts.batch_size = 8;
        opts.lr = if size.trim() == "med" { 1e-3 } else { 2e-3 };
        println!("\n--- {} / {} steps ---", size.trim(), steps);
        let reports = pretrain::sweep(&opts, PRETRAIN_METHODS);
        print!("{}", pretrain::loss_table(&reports));
        all.extend(reports);
    }
    // Headline check (the paper's Table 1 shape): SubTrack++ within the top
    // two methods per size.
    for size in sizes.split(',') {
        let mut rows: Vec<_> = all.iter().filter(|r| r.model == size.trim()).collect();
        rows.sort_by(|a, b| a.final_eval_loss.partial_cmp(&b.final_eval_loss).unwrap());
        if let Some(pos) = rows.iter().position(|r| r.method == "SubTrack++") {
            println!(
                "\n[{}] SubTrack++ rank among {} methods: #{}",
                size.trim(),
                rows.len(),
                pos + 1
            );
        }
    }
    common::save_csv(&pretrain::summary_csv(&all), "table1_pretrain.csv");
}
