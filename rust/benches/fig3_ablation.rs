//! Figures 3 & 6 — component ablation: pure Grassmannian tracking, +PA,
//! +RS, full SubTrack++, with GaLore as the step-wise reference. Reports
//! loss (Fig 3) and wall-time (Fig 6).
//!
//!     cargo bench --bench fig3_ablation

mod common;

use subtrack::experiments::pretrain::{self, SweepOpts};

const VARIANTS: &[&str] =
    &["galore", "subtrack-pure", "subtrack-pa", "subtrack-rs", "subtrack++"];

fn main() {
    common::banner("Figures 3/6", "SubTrack++ component ablation");
    let size = common::env_str("SUBTRACK_SIZES", "tiny");
    let steps = common::env_usize("SUBTRACK_STEPS", 250);
    let mut opts = SweepOpts::new(&size, steps);
    opts.batch_size = 8;
    let reports = pretrain::sweep(&opts, VARIANTS);

    println!("\n{:<22} {:>10} {:>12}", "variant", "loss", "wall (s)");
    for r in &reports {
        println!("{:<22} {:>10.4} {:>12.1}", r.method, r.final_eval_loss, r.wall_time_secs);
    }
    let get = |m: &str| reports.iter().find(|r| r.method == m).unwrap();
    let pure = get("SubTrack (pure)");
    let full = get("SubTrack++");
    let galore = get("GaLore");
    println!("\nshape checks vs paper Fig 3/6:");
    println!(
        "  full ({:.4}) ≤ pure ({:.4}): {}",
        full.final_eval_loss,
        pure.final_eval_loss,
        full.final_eval_loss <= pure.final_eval_loss
    );
    println!(
        "  pure tracking wall-time ({:.1}s) ≤ GaLore ({:.1}s): {}  (Fig 6: tracking avoids SVD)",
        pure.wall_time_secs,
        galore.wall_time_secs,
        pure.wall_time_secs <= galore.wall_time_secs
    );
    common::save_csv(&pretrain::summary_csv(&reports), "fig3_ablation.csv");
    common::save_csv(&pretrain::curves_csv(&reports), "fig3_curves.csv");
}
