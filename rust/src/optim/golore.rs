//! GoLore (He et al., 2025) — GaLore's convergence fix: use SVD projections
//! early in training (when gradients carry strong signal) and switch to
//! *random orthonormal* projections late in training, where gradients are
//! noise-dominated and SVD locks onto noise directions.

use super::adam::{AdamCfg, Moments};
use super::projector::{self, Projector};
use super::{HyperParams, Optimizer, OptimizerSnapshot, Param, ParamKind, SnapshotReader};
use crate::tensor::{Matrix, Workspace};
use crate::util::rng::Rng;

struct MatState {
    proj: Projector,
    moments: Moments,
    /// Late-phase random-projector stream, keyed on the parameter name so
    /// draws are independent of slot order / shard membership (see
    /// [`super::param_stream_rng`]).
    rng: Rng,
}

/// GoLore optimizer.
pub struct GoLore {
    hp: HyperParams,
    adam: AdamCfg,
    mats: Vec<Option<MatState>>,
    vecs: Vec<Option<Moments>>,
    step_no: usize,
    n_subspace_updates: usize,
    n_refresh_rejections: usize,
    poison_refresh: bool,
    /// Switch from SVD to random projections after this many steps. The
    /// reference recipe switches in the last third of training; the trainer
    /// sets this from the configured total step budget.
    pub switch_after: usize,
    /// Per-step projection + refresh scratch (zero steady-state allocation;
    /// refresh steps miss only on their first occurrence).
    ws: Workspace,
}

impl GoLore {
    pub fn new(hp: HyperParams) -> GoLore {
        GoLore {
            hp,
            adam: AdamCfg::from(hp),
            mats: Vec::new(),
            vecs: Vec::new(),
            step_no: 0,
            n_subspace_updates: 0,
            n_refresh_rejections: 0,
            poison_refresh: false,
            switch_after: 1000,
            ws: Workspace::new(),
        }
    }

    fn ensure_slots(&mut self, n: usize) {
        if self.mats.len() != n {
            self.mats = (0..n).map(|_| None).collect();
            self.vecs = (0..n).map(|_| None).collect();
        }
    }
}

impl Optimizer for GoLore {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_slots(params.len());
        let refresh = self.hp.interval > 0 && self.step_no % self.hp.interval == 0;
        let late_phase = self.step_no >= self.switch_after;
        for i in 0..params.len() {
            let g = &grads[i];
            match params[i].kind {
                ParamKind::Matrix2D if g.rows() > 1 && g.cols() > 1 => {
                    let (m, n) = g.shape();
                    let needs_init = self.mats[i].is_none();
                    if needs_init {
                        let mut rng =
                            super::param_stream_rng(self.hp.seed, 0x601e, &params[i].name);
                        let proj = if late_phase {
                            Projector::init_random_orthonormal(m, n, self.hp.rank, &mut rng)
                        } else {
                            Projector::init_svd(g, self.hp.rank)
                        };
                        let (lm, ln) = proj.lowrank_shape(m, n);
                        self.mats[i] =
                            Some(MatState { proj, moments: Moments::new(lm, ln), rng });
                    } else if refresh {
                        // In-place refresh with workspace-leased scratch,
                        // behind the health guard: a degenerate (or
                        // fault-injected) candidate basis is rejected and the
                        // previous projector kept until the next interval.
                        let GoLore {
                            ws,
                            mats,
                            n_subspace_updates,
                            n_refresh_rejections,
                            poison_refresh,
                            ..
                        } = &mut *self;
                        let st = mats[i].as_mut().expect("initialized above");
                        let (sr, sc) = st.proj.s.shape();
                        let mut old_s = ws.take_dirty(sr, sc);
                        old_s.copy_from(&st.proj.s);
                        if late_phase {
                            st.proj.refresh_random_orthonormal_into(&mut st.rng, ws);
                        } else {
                            st.proj.refresh_svd_into(g, ws);
                        }
                        if std::mem::take(poison_refresh) {
                            projector::poison_basis(&mut st.proj.s);
                        }
                        if projector::basis_acceptable(&st.proj.s, projector::REFRESH_DEFECT_TOL)
                        {
                            *n_subspace_updates += 1;
                        } else {
                            st.proj.s.copy_from(&old_s);
                            *n_refresh_rejections += 1;
                        }
                        ws.give(old_s);
                    }
                    let adam = self.adam;
                    let scale = self.hp.scale;
                    // Disjoint borrows: scratch pool vs per-matrix state.
                    let GoLore { ws, mats, .. } = &mut *self;
                    let st = mats[i].as_mut().expect("initialized above");
                    let (lm, ln) = st.proj.lowrank_shape(m, n);
                    let mut g_low = ws.take_dirty(lm, ln);
                    st.proj.project_into(g, &mut g_low, ws);
                    let mut dir = ws.take_dirty(lm, ln);
                    st.moments.update_into(&adam, &g_low, &mut dir);
                    let mut delta = ws.take_dirty(m, n);
                    st.proj.project_back_into(&dir, &mut delta, ws);
                    params[i].axpy_update(-lr * scale, &delta);
                    ws.give(delta);
                    ws.give(dir);
                    ws.give(g_low);
                }
                _ => {
                    if self.vecs[i].is_none() {
                        self.vecs[i] = Some(Moments::new(g.rows(), g.cols()));
                    }
                    let adam = self.adam;
                    let st = self.vecs[i].as_mut().unwrap();
                    st.fused_step(&adam, lr, 0.0, &mut params[i].value, g);
                    params[i].mark_dirty();
                }
            }
        }
        self.step_no += 1;
    }

    fn state_bytes(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.bytes() + s.proj.bytes()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.bytes()).sum();
        mats + vecs
    }

    fn state_params(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.params() + s.proj.params()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.params()).sum();
        mats + vecs
    }

    fn subspace_updates(&self) -> usize {
        self.n_subspace_updates
    }

    fn workspace_misses(&self) -> usize {
        self.ws.misses()
    }

    fn projector_defect(&self) -> Option<f32> {
        Some(self.mats.iter().flatten().map(|s| s.proj.defect()).fold(0.0f32, f32::max))
    }

    fn poison_next_refresh(&mut self) {
        self.poison_refresh = true;
    }

    fn refresh_rejections(&self) -> usize {
        self.n_refresh_rejections
    }

    // Pack order: step_no, n_subspace_updates, n_refresh_rejections, matrix
    // slots (presence + projector + moments + the slot's name-keyed rng),
    // vector moment slots.
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = OptimizerSnapshot::new();
        snap.push_int(self.step_no as u64);
        snap.push_int(self.n_subspace_updates as u64);
        snap.push_int(self.n_refresh_rejections as u64);
        snap.push_int(self.mats.len() as u64);
        for slot in &self.mats {
            match slot {
                Some(st) => {
                    snap.push_int(1);
                    st.proj.pack(&mut snap);
                    st.moments.pack(&mut snap);
                    snap.push_rng(&st.rng);
                }
                None => snap.push_int(0),
            }
        }
        super::pack_moment_slots(&mut snap, &self.vecs);
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let mut r = snap.reader();
        self.step_no = r.int() as usize;
        self.n_subspace_updates = r.int() as usize;
        self.n_refresh_rejections = r.int() as usize;
        let n_mats = r.int() as usize;
        self.mats.resize_with(n_mats, || None);
        for slot in &mut self.mats {
            if r.int() == 1 {
                match slot {
                    Some(st) => {
                        st.proj.unpack_into(&mut r);
                        st.moments.unpack_into(&mut r);
                        st.rng = r.rng();
                    }
                    None => {
                        *slot = Some(MatState {
                            proj: Projector::unpack(&mut r),
                            moments: Moments::unpack(&mut r),
                            rng: r.rng(),
                        });
                    }
                }
            } else {
                *slot = None;
            }
        }
        super::unpack_moment_slots(&mut r, &mut self.vecs);
    }

    fn restore_ranges(&mut self, parts: &[(&OptimizerSnapshot, usize, usize)]) -> bool {
        self.mats.clear();
        self.vecs.clear();
        self.step_no = 0;
        self.n_subspace_updates = 0;
        self.n_refresh_rejections = 0;
        for &(snap, lo, hi) in parts {
            let mut r = snap.reader();
            self.step_no = self.step_no.max(r.int() as usize);
            self.n_subspace_updates = self.n_subspace_updates.max(r.int() as usize);
            self.n_refresh_rejections = self.n_refresh_rejections.max(r.int() as usize);
            let n_mats = r.int() as usize;
            assert!(hi <= n_mats, "golore restore_ranges: slot range {lo}..{hi} out of {n_mats}");
            for i in 0..n_mats {
                if r.int() == 1 {
                    let st = MatState {
                        proj: Projector::unpack(&mut r),
                        moments: Moments::unpack(&mut r),
                        rng: r.rng(),
                    };
                    if i >= lo && i < hi {
                        self.mats.push(Some(st));
                    }
                } else if i >= lo && i < hi {
                    self.mats.push(None);
                }
            }
            super::keep_moment_slot_range(&mut r, &mut self.vecs, lo, hi);
        }
        true
    }

    fn name(&self) -> String {
        "GoLore".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};

    #[test]
    fn converges_on_lstsq() {
        let prob = LstsqProblem::new(64, 10, 14, 110);
        let mut opt = GoLore::new(HyperParams {
            rank: 4,
            interval: 20,
            scale: 1.0,
            ..HyperParams::default()
        });
        opt.switch_after = 200;
        let (init, fin) = run_lstsq(&mut opt, &prob, 400, 0.05);
        assert!(fin < init * 0.1, "init={init} final={fin}");
        assert!(opt.subspace_updates() > 0);
    }

    #[test]
    fn switches_projector_type() {
        // After `switch_after`, refreshed projectors must be random (they
        // can no longer equal the SVD basis of the same gradient).
        let prob = LstsqProblem::new(32, 8, 12, 111);
        let mut opt = GoLore::new(HyperParams {
            rank: 2,
            interval: 10,
            scale: 1.0,
            ..HyperParams::default()
        });
        opt.switch_after = 0; // random from the first refresh
        let (init, fin) = run_lstsq(&mut opt, &prob, 200, 0.05);
        assert!(fin < init, "still optimizes with pure random projections");
        // Random-orthonormal refreshes must keep the basis orthonormal.
        assert!(opt.projector_defect().unwrap() < 1e-4);
    }
}
