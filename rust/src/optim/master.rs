//! f32 master weights for mixed-precision storage.
//!
//! [`MixedPrecision`] wraps any base optimizer and owns the f32 **master
//! copy** of every parameter. Under a 16-bit storage dtype the visible
//! `Param.value` holds values rounded onto the storage grid (bf16/f16 —
//! see `tensor::dtype`), which is too coarse to integrate small updates:
//! an update below half a storage ulp re-rounds to the old value and the
//! parameter never moves. The classic fix, reproduced here, is:
//!
//! 1. the inner optimizer steps the f32 masters (full-precision math,
//!    moments, projectors — all untouched),
//! 2. the wrapper writes each master back through
//!    [`Param::quantize_store_from`], so storage is re-rounded **once per
//!    step** from the full-precision value and sub-ulp progress
//!    accumulates in the master.
//!
//! Masters are lazily initialized from the parameters' current (already
//! quantized) values on the first step, so a fresh run and a
//! checkpoint-resumed run start their masters from byte-identical storage.
//! Snapshots append the master matrices after the inner optimizer's
//! streams (count last), so rollback and format-3 checkpoints replay
//! bit-identically; the inner restore reads its own prefix and never sees
//! the tail.
//!
//! Under [`Dtype::F32`] the factory (`optim::mixed_by_name`) skips this
//! wrapper entirely — the f32 path stays byte-identical to earlier
//! revisions.

use super::{Optimizer, OptimizerSnapshot, Param, ParamKind};
use crate::tensor::{Dtype, Matrix};

/// Mixed-precision wrapper: inner optimizer over f32 masters, quantized
/// write-back into the visible storage-dtype parameters (module docs).
pub struct MixedPrecision {
    inner: Box<dyn Optimizer>,
    dtype: Dtype,
    /// f32 master copies, parallel to the trainer's parameter list. Empty
    /// until the first step (the wrapper has not seen the params yet).
    masters: Vec<Param>,
    /// Master values restored before the first step (checkpoint resume);
    /// applied once `masters` is built.
    pending: Option<Vec<Matrix>>,
}

impl MixedPrecision {
    pub fn new(inner: Box<dyn Optimizer>, dtype: Dtype) -> MixedPrecision {
        MixedPrecision { inner, dtype, masters: Vec::new(), pending: None }
    }

    /// The storage dtype write-backs round onto.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    fn ensure_masters(&mut self, params: &[Param]) {
        if self.masters.len() != params.len() {
            // Initialized from the *quantized* storage values, not some
            // pre-rounding original: a resumed run rebuilding masters from
            // a checkpoint must land on the same starting point.
            self.masters = params
                .iter()
                .map(|p| match p.kind {
                    ParamKind::Matrix2D => Param::matrix(&p.name, p.value.clone()),
                    ParamKind::Vector => Param::vector(&p.name, p.value.clone()),
                })
                .collect();
        }
        if let Some(pend) = self.pending.take() {
            assert_eq!(pend.len(), self.masters.len(), "mixed snapshot: master count mismatch");
            for (m, src) in self.masters.iter_mut().zip(&pend) {
                if m.value.shape() == src.shape() {
                    m.value.copy_from(src);
                } else {
                    m.value = src.clone();
                }
                m.mark_dirty();
            }
        }
    }
}

impl Optimizer for MixedPrecision {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_masters(params);
        self.inner.step(lr, &mut self.masters, grads);
        for (p, m) in params.iter_mut().zip(&self.masters) {
            p.quantize_store_from(&m.value);
        }
    }

    /// The wrapper holds the (global, unsharded) master copies itself;
    /// partitioning happens *inside* it, in the sharded inner optimizer.
    fn partitionable(&self) -> bool {
        false
    }

    /// Inner state plus the f32 masters (4 bytes per element — masters are
    /// always full precision regardless of the storage dtype).
    fn state_bytes(&self) -> usize {
        let master_bytes: usize =
            self.masters.iter().map(|m| m.numel() * std::mem::size_of::<f32>()).sum();
        self.inner.state_bytes() + master_bytes
    }

    /// Table-2 accounting stays the inner method's: masters are storage
    /// plumbing, not optimizer state parameters in the paper's sense (they
    /// show up in [`state_bytes`](Optimizer::state_bytes) instead).
    fn state_params(&self) -> usize {
        self.inner.state_params()
    }

    fn subspace_updates(&self) -> usize {
        self.inner.subspace_updates()
    }

    fn workspace_misses(&self) -> usize {
        self.inner.workspace_misses()
    }

    fn projector_defect(&self) -> Option<f32> {
        self.inner.projector_defect()
    }

    fn poison_next_refresh(&mut self) {
        self.inner.poison_next_refresh();
    }

    fn refresh_rejections(&self) -> usize {
        self.inner.refresh_rejections()
    }

    // Pack order: the inner snapshot's streams verbatim, then the master
    // matrices, then their count as the *last* int. The inner restore
    // consumes exactly its own prefix through the reader cursor, so the
    // appended tail is invisible to it; the wrapper peels the tail off by
    // reading the final count.
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = self.inner.snapshot();
        for m in &self.masters {
            snap.push_mat(&m.value);
        }
        snap.push_int(self.masters.len() as u64);
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let k = *snap.ints.last().expect("mixed snapshot: missing master count") as usize;
        assert!(k <= snap.mats.len(), "mixed snapshot: master tail larger than matrix stream");
        let tail = &snap.mats[snap.mats.len() - k..];
        // Hand the inner optimizer a snapshot holding exactly its own
        // streams, with the master tail peeled off: the sharded restore
        // classifies legacy layouts by checking that declared stream
        // lengths tile the snapshot exactly, so trailing master data must
        // not be visible to it.
        let inner_snap = OptimizerSnapshot {
            mats: snap.mats[..snap.mats.len() - k].to_vec(),
            ints: snap.ints[..snap.ints.len() - 1].to_vec(),
            floats: snap.floats.clone(),
            rngs: snap.rngs.clone(),
        };
        self.inner.restore(&inner_snap);
        if self.masters.len() == k {
            for (m, src) in self.masters.iter_mut().zip(tail) {
                if m.value.shape() == src.shape() {
                    m.value.copy_from(src);
                } else {
                    m.value = src.clone();
                }
                m.mark_dirty();
            }
            self.pending = None;
        } else {
            // Restore before the first step (resume path): the parameter
            // list has not been seen yet, so stash the masters until
            // `ensure_masters` builds the table.
            self.pending = Some(tail.to_vec());
        }
    }

    fn name(&self) -> String {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{by_name, mixed_by_name, HyperParams};
    use super::*;
    use crate::tensor::dtype;

    fn test_hp() -> HyperParams {
        HyperParams { rank: 3, interval: 4, scale: 1.0, seed: 7, ..HyperParams::default() }
    }

    fn bf16_params() -> Vec<Param> {
        let mut w = Param::matrix("w", Matrix::full(4, 4, 1.0));
        let mut b = Param::vector("b", Matrix::full(1, 4, 1.0));
        w.set_storage_dtype(Dtype::Bf16);
        b.set_storage_dtype(Dtype::Bf16);
        vec![w, b]
    }

    fn tiny_grads() -> Vec<Matrix> {
        vec![Matrix::full(4, 4, 1e-3), Matrix::full(1, 4, 1e-3)]
    }

    #[test]
    fn f32_dtype_is_a_passthrough() {
        // No wrapper under f32: same object as sharded_by_name, and one
        // step matches the plain optimizer bit for bit.
        let mut a = mixed_by_name("adam", test_hp(), 1, Dtype::F32);
        let mut b = by_name("adam", test_hp());
        let mut pa = vec![Param::matrix("w", Matrix::full(3, 3, 0.5))];
        let mut pb = vec![Param::matrix("w", Matrix::full(3, 3, 0.5))];
        let g = vec![Matrix::full(3, 3, 0.1)];
        a.step(0.01, &mut pa, &g);
        b.step(0.01, &mut pb, &g);
        assert_eq!(pa[0].value.data(), pb[0].value.data());
        assert_eq!(pa[0].dtype(), Dtype::F32);
    }

    #[test]
    fn masters_accumulate_sub_ulp_updates() {
        // Adam's normalized update at lr 1e-5 is far below the bf16 ulp at
        // 1.0 (2^-8): quantizing each step's result directly would never
        // move the weight. The master copy integrates the updates and the
        // storage eventually steps down to the next grid point.
        let mut opt = mixed_by_name("adam", test_hp(), 1, Dtype::Bf16);
        let mut params = bf16_params();
        let grads = tiny_grads();
        let naive = {
            // What storage-only integration would do: one step's update,
            // re-rounded — back on the starting grid point.
            let delta = 1e-5f32;
            dtype::bf16_to_f32(dtype::f32_to_bf16(1.0 - delta))
        };
        assert_eq!(naive, 1.0, "premise: one update is sub-ulp");
        for _ in 0..500 {
            opt.step(1e-5, &mut params, &grads);
        }
        assert!(
            params[0].value.get(0, 0) < 1.0,
            "storage never moved: {}",
            params[0].value.get(0, 0)
        );
        // Storage stays on the bf16 grid (quantize is idempotent).
        for p in &params {
            for &v in p.value.data() {
                assert_eq!(v, Dtype::Bf16.quantize(v), "off-grid storage value {v}");
            }
        }
    }

    #[test]
    fn state_accounts_masters_in_bytes_not_params() {
        let mut opt = mixed_by_name("adam", test_hp(), 1, Dtype::Bf16);
        let mut inner = by_name("adam", test_hp());
        let mut params = bf16_params();
        let mut iparams = bf16_params();
        let grads = tiny_grads();
        opt.step(0.01, &mut params, &grads);
        inner.step(0.01, &mut iparams, &grads);
        let master_bytes: usize = params.iter().map(|p| p.numel() * 4).sum();
        assert_eq!(opt.state_bytes(), inner.state_bytes() + master_bytes);
        assert_eq!(opt.state_params(), inner.state_params());
    }

    #[test]
    fn snapshot_restore_replays_bitexact() {
        let mut opt = mixed_by_name("subtrack++", test_hp(), 1, Dtype::Bf16);
        let mut params = bf16_params();
        let step = |opt: &mut Box<dyn Optimizer>, params: &mut Vec<Param>, s: usize| {
            let g = 1e-3 + s as f32 * 1e-4;
            let grads = vec![Matrix::full(4, 4, g), Matrix::full(1, 4, g)];
            opt.step(0.05, params, &grads);
        };
        for s in 0..6 {
            step(&mut opt, &mut params, s);
        }
        let snap = opt.snapshot();
        let saved: Vec<Matrix> = params.iter().map(|p| p.value.clone()).collect();
        let mut trace = Vec::new();
        for s in 6..10 {
            step(&mut opt, &mut params, s);
            trace.push(params.iter().map(|p| p.value.clone()).collect::<Vec<_>>());
        }
        opt.restore(&snap);
        for (p, v) in params.iter_mut().zip(&saved) {
            p.value.copy_from(v);
            p.mark_dirty();
        }
        for (i, want) in trace.iter().enumerate() {
            step(&mut opt, &mut params, 6 + i);
            for (p, w) in params.iter().zip(want) {
                assert_eq!(p.value.data(), w.data(), "replay diverged at {i}");
            }
        }
    }

    #[test]
    fn restore_into_fresh_wrapper_resumes_identically() {
        // The checkpoint-resume path: restore lands before the wrapper has
        // ever seen the parameter list, so masters arrive via `pending`.
        let mut opt = mixed_by_name("adam", test_hp(), 1, Dtype::Bf16);
        let mut params = bf16_params();
        let grads = tiny_grads();
        for _ in 0..300 {
            opt.step(1e-5, &mut params, &grads);
        }
        let snap = opt.snapshot();
        let saved = params.clone();
        // Continue the original.
        for _ in 0..300 {
            opt.step(1e-5, &mut params, &grads);
        }
        // Fresh wrapper + restored snapshot + saved (quantized) params.
        let mut opt2 = mixed_by_name("adam", test_hp(), 1, Dtype::Bf16);
        opt2.restore(&snap);
        let mut params2 = saved;
        for _ in 0..300 {
            opt2.step(1e-5, &mut params2, &grads);
        }
        for (a, b) in params.iter().zip(&params2) {
            assert_eq!(a.value.data(), b.value.data(), "resume diverged for {}", a.name);
        }
    }
}
