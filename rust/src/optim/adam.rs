//! Full-rank Adam / AdamW — the paper's "Full-Rank" baseline row, and the
//! shared per-matrix moment machinery that every low-rank method reuses in
//! its reduced space.

use super::{HyperParams, Optimizer, Param};
use crate::tensor::Matrix;

/// Adam configuration.
#[derive(Clone, Copy, Debug)]
pub struct AdamCfg {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// AdamW decoupled weight decay (0 disables).
    pub weight_decay: f32,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

impl From<HyperParams> for AdamCfg {
    fn from(hp: HyperParams) -> Self {
        AdamCfg { beta1: hp.beta1, beta2: hp.beta2, eps: hp.eps, weight_decay: hp.weight_decay }
    }
}

/// First/second moment state for one tensor (any shape).
#[derive(Clone, Debug)]
pub struct Moments {
    pub m: Matrix,
    pub v: Matrix,
    /// Per-tensor step count (for bias correction).
    pub t: usize,
}

impl Moments {
    pub fn new(rows: usize, cols: usize) -> Moments {
        Moments { m: Matrix::zeros(rows, cols), v: Matrix::zeros(rows, cols), t: 0 }
    }

    /// Standard Adam update: fold in `grad`, return the preconditioned update
    /// direction `m̂ ⊘ (√v̂ + ε)` (bias-corrected).
    pub fn update(&mut self, cfg: &AdamCfg, grad: &Matrix) -> Matrix {
        debug_assert_eq!(self.m.shape(), grad.shape());
        self.t += 1;
        let b1 = cfg.beta1;
        let b2 = cfg.beta2;
        let md = self.m.data_mut();
        let gd = grad.data();
        for (m, &g) in md.iter_mut().zip(gd) {
            *m = b1 * *m + (1.0 - b1) * g;
        }
        let vd = self.v.data_mut();
        for (v, &g) in vd.iter_mut().zip(gd) {
            *v = b2 * *v + (1.0 - b2) * g * g;
        }
        self.direction(cfg)
    }

    /// Preconditioned direction from the current moments (bias-corrected).
    pub fn direction(&self, cfg: &AdamCfg) -> Matrix {
        let bc1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bc2 = 1.0 - cfg.beta2.powi(self.t as i32);
        let (rows, cols) = self.m.shape();
        let mut out = Matrix::zeros(rows, cols);
        let od = out.data_mut();
        let md = self.m.data();
        let vd = self.v.data();
        for i in 0..od.len() {
            let mhat = md[i] / bc1;
            let vhat = vd[i] / bc2;
            od[i] = mhat / (vhat.sqrt() + cfg.eps);
        }
        out
    }

    /// Unbias-corrected raw output M ⊘ √(V+ε) as written in the paper's
    /// Algorithm 1 (used by recovery scaling's φ computation).
    pub fn raw_direction(&self, eps: f32) -> Matrix {
        self.m.zip(&self.v, |m, v| m / (v + eps).sqrt())
    }

    pub fn bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    pub fn params(&self) -> usize {
        self.m.len() + self.v.len()
    }
}

/// Full-rank Adam(W). Optimizer state is 2·mn per matrix — the paper's
/// Table 2 "Adam" row.
pub struct Adam {
    cfg: AdamCfg,
    states: Vec<Moments>,
}

impl Adam {
    pub fn new(cfg: AdamCfg) -> Adam {
        Adam { cfg, states: Vec::new() }
    }

    fn ensure_states(&mut self, params: &[Param]) {
        if self.states.len() != params.len() {
            self.states =
                params.iter().map(|p| Moments::new(p.value.rows(), p.value.cols())).collect();
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_states(params);
        for ((p, g), st) in params.iter_mut().zip(grads).zip(&mut self.states) {
            let dir = st.update(&self.cfg, g);
            if self.cfg.weight_decay > 0.0 {
                // Decoupled (AdamW) decay.
                let wd = self.cfg.weight_decay;
                p.value.apply(|w| w * (1.0 - lr * wd));
            }
            p.value.axpy(-lr, &dir);
        }
    }

    fn state_bytes(&self) -> usize {
        self.states.iter().map(|s| s.bytes()).sum()
    }

    fn state_params(&self) -> usize {
        self.states.iter().map(|s| s.params()).sum()
    }

    fn name(&self) -> String {
        if self.cfg.weight_decay > 0.0 {
            "AdamW".into()
        } else {
            "Adam".into()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};

    #[test]
    fn converges_on_lstsq() {
        let prob = LstsqProblem::new(64, 10, 6, 1);
        let mut opt = Adam::new(AdamCfg::default());
        let (init, fin) = run_lstsq(&mut opt, &prob, 400, 0.05);
        assert!(fin < init * 0.01, "init={init} final={fin}");
    }

    #[test]
    fn state_accounting_is_2mn() {
        let prob = LstsqProblem::new(8, 10, 6, 2);
        let mut opt = Adam::new(AdamCfg::default());
        let _ = run_lstsq(&mut opt, &prob, 1, 0.01);
        assert_eq!(opt.state_params(), 2 * 10 * 6);
        assert_eq!(opt.state_bytes(), 2 * 10 * 6 * 4);
    }

    #[test]
    fn bias_correction_first_step() {
        // After one step with grad g, direction ≈ sign-ish g/(|g|+eps) ≈ ±1.
        let mut st = Moments::new(1, 1);
        let cfg = AdamCfg::default();
        let g = Matrix::from_rows(&[&[0.5]]);
        let d = st.update(&cfg, &g);
        assert!((d.get(0, 0) - 1.0).abs() < 1e-3, "got {}", d.get(0, 0));
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let mut opt = Adam::new(AdamCfg { weight_decay: 0.1, ..AdamCfg::default() });
        let mut params = vec![Param::matrix("w", Matrix::full(2, 2, 1.0))];
        let zero_grad = Matrix::zeros(2, 2);
        opt.step(0.1, &mut params, std::slice::from_ref(&zero_grad));
        // Pure decay: w = 1 * (1 - 0.1*0.1) = 0.99
        assert!((params[0].value.get(0, 0) - 0.99).abs() < 1e-5);
        assert_eq!(opt.name(), "AdamW");
    }

    #[test]
    fn deterministic_across_runs() {
        let prob = LstsqProblem::new(16, 5, 4, 3);
        let mut a = Adam::new(AdamCfg::default());
        let mut b = Adam::new(AdamCfg::default());
        let ra = run_lstsq(&mut a, &prob, 50, 0.02);
        let rb = run_lstsq(&mut b, &prob, 50, 0.02);
        assert_eq!(ra, rb);
    }
}
