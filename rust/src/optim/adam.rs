//! Full-rank Adam / AdamW — the paper's "Full-Rank" baseline row, and the
//! shared per-matrix moment machinery that every low-rank method reuses in
//! its reduced space.

use super::{HyperParams, Optimizer, OptimizerSnapshot, Param, SnapshotReader};
use crate::tensor::Matrix;

/// Adam configuration.
#[derive(Clone, Copy, Debug)]
pub struct AdamCfg {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// AdamW decoupled weight decay (0 disables).
    pub weight_decay: f32,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

impl From<HyperParams> for AdamCfg {
    fn from(hp: HyperParams) -> Self {
        AdamCfg { beta1: hp.beta1, beta2: hp.beta2, eps: hp.eps, weight_decay: hp.weight_decay }
    }
}

/// First/second moment state for one tensor (any shape).
#[derive(Clone, Debug)]
pub struct Moments {
    pub m: Matrix,
    pub v: Matrix,
    /// Per-tensor step count (for bias correction).
    pub t: usize,
}

impl Moments {
    pub fn new(rows: usize, cols: usize) -> Moments {
        Moments { m: Matrix::zeros(rows, cols), v: Matrix::zeros(rows, cols), t: 0 }
    }

    /// Standard Adam update: fold in `grad`, return the preconditioned update
    /// direction `m̂ ⊘ (√v̂ + ε)` (bias-corrected).
    pub fn update(&mut self, cfg: &AdamCfg, grad: &Matrix) -> Matrix {
        let (rows, cols) = self.m.shape();
        let mut out = Matrix::zeros(rows, cols);
        self.update_into(cfg, grad, &mut out);
        out
    }

    /// Allocation-free [`update`]: fold in `grad`, write the bias-corrected
    /// direction into `out` (typically a workspace buffer).
    ///
    /// [`update`]: Moments::update
    pub fn update_into(&mut self, cfg: &AdamCfg, grad: &Matrix, out: &mut Matrix) {
        debug_assert_eq!(self.m.shape(), grad.shape());
        self.fold(cfg, grad);
        self.direction_into(cfg, out);
    }

    /// Fold `grad` into the first/second moments (no direction computed).
    fn fold(&mut self, cfg: &AdamCfg, grad: &Matrix) {
        self.t += 1;
        let b1 = cfg.beta1;
        let b2 = cfg.beta2;
        let md = self.m.data_mut();
        let gd = grad.data();
        for (m, &g) in md.iter_mut().zip(gd) {
            *m = b1 * *m + (1.0 - b1) * g;
        }
        let vd = self.v.data_mut();
        for (v, &g) in vd.iter_mut().zip(gd) {
            *v = b2 * *v + (1.0 - b2) * g * g;
        }
    }

    /// Preconditioned direction from the current moments (bias-corrected).
    pub fn direction(&self, cfg: &AdamCfg) -> Matrix {
        let (rows, cols) = self.m.shape();
        let mut out = Matrix::zeros(rows, cols);
        self.direction_into(cfg, &mut out);
        out
    }

    /// Allocation-free [`direction`].
    ///
    /// [`direction`]: Moments::direction
    pub fn direction_into(&self, cfg: &AdamCfg, out: &mut Matrix) {
        assert_eq!(out.shape(), self.m.shape(), "direction shape");
        let bc1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bc2 = 1.0 - cfg.beta2.powi(self.t as i32);
        let od = out.data_mut();
        let md = self.m.data();
        let vd = self.v.data();
        for i in 0..od.len() {
            let mhat = md[i] / bc1;
            let vhat = vd[i] / bc2;
            od[i] = mhat / (vhat.sqrt() + cfg.eps);
        }
    }

    /// Fused single-pass Adam(W) step: folds `grad` into m/v and applies the
    /// bias-corrected preconditioned update (and decoupled decay) directly to
    /// `param` — one sweep over memory, zero temporaries. Arithmetic is
    /// element-for-element identical to `update` + `decay` + `axpy(-lr, ·)`,
    /// so trajectories match the unfused path bit-for-bit.
    ///
    /// `weight_decay` is explicit (not read from `cfg`) because callers that
    /// apply their own decay elsewhere pass 0 here.
    pub fn fused_step(
        &mut self,
        cfg: &AdamCfg,
        lr: f32,
        weight_decay: f32,
        param: &mut Matrix,
        grad: &Matrix,
    ) {
        debug_assert_eq!(self.m.shape(), grad.shape());
        debug_assert_eq!(param.shape(), grad.shape());
        self.t += 1;
        let b1 = cfg.beta1;
        let b2 = cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let decay = 1.0 - lr * weight_decay;
        let md = self.m.data_mut();
        let vd = self.v.data_mut();
        let pd = param.data_mut();
        let gd = grad.data();
        for i in 0..gd.len() {
            let g = gd[i];
            let m = b1 * md[i] + (1.0 - b1) * g;
            let v = b2 * vd[i] + (1.0 - b2) * g * g;
            md[i] = m;
            vd[i] = v;
            let mhat = m / bc1;
            let vhat = v / bc2;
            let dir = mhat / (vhat.sqrt() + cfg.eps);
            let mut p = pd[i];
            if weight_decay > 0.0 {
                p *= decay;
            }
            pd[i] = p + (-lr) * dir;
        }
    }

    /// Unbias-corrected raw output M ⊘ √(V+ε) as written in the paper's
    /// Algorithm 1 (used by recovery scaling's φ computation).
    pub fn raw_direction(&self, eps: f32) -> Matrix {
        self.m.zip(&self.v, |m, v| m / (v + eps).sqrt())
    }

    pub fn bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    pub fn params(&self) -> usize {
        self.m.len() + self.v.len()
    }

    /// Pack m, v, t into a snapshot (see `Optimizer::snapshot`).
    pub fn pack(&self, snap: &mut OptimizerSnapshot) {
        snap.push_mat(&self.m);
        snap.push_mat(&self.v);
        snap.push_int(self.t as u64);
    }

    /// Rebuild moments from the stream produced by [`Moments::pack`].
    pub fn unpack(r: &mut SnapshotReader) -> Moments {
        let m = r.mat();
        let v = r.mat();
        Moments { m, v, t: r.int() as usize }
    }

    /// In-place [`Moments::unpack`] (no allocation when shapes match).
    pub fn unpack_into(&mut self, r: &mut SnapshotReader) {
        r.mat_into(&mut self.m);
        r.mat_into(&mut self.v);
        self.t = r.int() as usize;
    }
}

/// Full-rank Adam(W). Optimizer state is 2·mn per matrix — the paper's
/// Table 2 "Adam" row.
pub struct Adam {
    cfg: AdamCfg,
    states: Vec<Moments>,
}

impl Adam {
    pub fn new(cfg: AdamCfg) -> Adam {
        Adam { cfg, states: Vec::new() }
    }

    fn ensure_states(&mut self, params: &[Param]) {
        if self.states.len() != params.len() {
            self.states =
                params.iter().map(|p| Moments::new(p.value.rows(), p.value.cols())).collect();
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_states(params);
        for ((p, g), st) in params.iter_mut().zip(grads).zip(&mut self.states) {
            // Single fused m/v/param sweep (decoupled decay folded in).
            st.fused_step(&self.cfg, lr, self.cfg.weight_decay, &mut p.value, g);
            p.mark_dirty();
        }
    }

    fn state_bytes(&self) -> usize {
        self.states.iter().map(|s| s.bytes()).sum()
    }

    fn state_params(&self) -> usize {
        self.states.iter().map(|s| s.params()).sum()
    }

    // Pack order: state count, then each state's (m, v, t).
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = OptimizerSnapshot::new();
        snap.push_int(self.states.len() as u64);
        for st in &self.states {
            st.pack(&mut snap);
        }
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let mut r = snap.reader();
        let n = r.int() as usize;
        if self.states.len() != n {
            self.states = (0..n).map(|_| Moments::unpack(&mut r)).collect();
        } else {
            for st in &mut self.states {
                st.unpack_into(&mut r);
            }
        }
    }

    fn restore_ranges(&mut self, parts: &[(&OptimizerSnapshot, usize, usize)]) -> bool {
        self.states.clear();
        for &(snap, lo, hi) in parts {
            let mut r = snap.reader();
            let n = r.int() as usize;
            assert!(hi <= n, "adam restore_ranges: slot range {lo}..{hi} out of {n}");
            for i in 0..hi {
                let st = Moments::unpack(&mut r);
                if i >= lo {
                    self.states.push(st);
                }
            }
        }
        true
    }

    fn name(&self) -> String {
        if self.cfg.weight_decay > 0.0 {
            "AdamW".into()
        } else {
            "Adam".into()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};

    #[test]
    fn converges_on_lstsq() {
        let prob = LstsqProblem::new(64, 10, 6, 1);
        let mut opt = Adam::new(AdamCfg::default());
        let (init, fin) = run_lstsq(&mut opt, &prob, 400, 0.05);
        assert!(fin < init * 0.01, "init={init} final={fin}");
    }

    #[test]
    fn state_accounting_is_2mn() {
        let prob = LstsqProblem::new(8, 10, 6, 2);
        let mut opt = Adam::new(AdamCfg::default());
        let _ = run_lstsq(&mut opt, &prob, 1, 0.01);
        assert_eq!(opt.state_params(), 2 * 10 * 6);
        assert_eq!(opt.state_bytes(), 2 * 10 * 6 * 4);
    }

    #[test]
    fn bias_correction_first_step() {
        // After one step with grad g, direction ≈ sign-ish g/(|g|+eps) ≈ ±1.
        let mut st = Moments::new(1, 1);
        let cfg = AdamCfg::default();
        let g = Matrix::from_rows(&[&[0.5]]);
        let d = st.update(&cfg, &g);
        assert!((d.get(0, 0) - 1.0).abs() < 1e-3, "got {}", d.get(0, 0));
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let mut opt = Adam::new(AdamCfg { weight_decay: 0.1, ..AdamCfg::default() });
        let mut params = vec![Param::matrix("w", Matrix::full(2, 2, 1.0))];
        let zero_grad = Matrix::zeros(2, 2);
        opt.step(0.1, &mut params, std::slice::from_ref(&zero_grad));
        // Pure decay: w = 1 * (1 - 0.1*0.1) = 0.99
        assert!((params[0].value.get(0, 0) - 0.99).abs() < 1e-5);
        assert_eq!(opt.name(), "AdamW");
    }

    #[test]
    fn fused_step_matches_unfused() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(4);
        let cfg = AdamCfg { weight_decay: 0.05, ..AdamCfg::default() };
        let mut p_fused = Matrix::randn(6, 5, 1.0, &mut rng);
        let mut p_ref = p_fused.clone();
        let mut st_fused = Moments::new(6, 5);
        let mut st_ref = Moments::new(6, 5);
        let lr = 0.01;
        for step in 0..5u64 {
            let g = Matrix::randn(6, 5, 0.5, &mut Rng::new(100 + step));
            st_fused.fused_step(&cfg, lr, cfg.weight_decay, &mut p_fused, &g);
            // Reference: unfused update + decoupled decay + axpy.
            let dir = st_ref.update(&cfg, &g);
            p_ref.apply(|w| w * (1.0 - lr * cfg.weight_decay));
            p_ref.axpy(-lr, &dir);
        }
        assert_eq!(p_fused.data(), p_ref.data(), "fused path must be bit-identical");
        assert_eq!(st_fused.m.data(), st_ref.m.data());
        assert_eq!(st_fused.v.data(), st_ref.v.data());
    }

    #[test]
    fn deterministic_across_runs() {
        let prob = LstsqProblem::new(16, 5, 4, 3);
        let mut a = Adam::new(AdamCfg::default());
        let mut b = Adam::new(AdamCfg::default());
        let ra = run_lstsq(&mut a, &prob, 50, 0.02);
        let rb = run_lstsq(&mut b, &prob, 50, 0.02);
        assert_eq!(ra, rb);
    }
}
