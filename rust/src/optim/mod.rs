//! The optimizer family: SubTrack++ (the paper's contribution) and every
//! baseline it is evaluated against.
//!
//! | Module      | Method                          | Subspace mechanism                  |
//! |-------------|---------------------------------|-------------------------------------|
//! | [`adam`]    | Adam / AdamW (full-rank)        | —                                   |
//! | [`galore`]  | GaLore (Zhao et al. 2024)       | truncated SVD every k steps         |
//! | [`fira`]    | Fira (Chen et al. 2025)         | SVD every k + recovery scaling      |
//! | [`ldadam`]  | LDAdam (Robert et al. 2025)     | power iteration every step + PA + EF|
//! | [`osd`]     | Online Subspace Descent         | Oja online-PCA step per iteration   |
//! | [`badam`]   | BAdam (Luo et al. 2024)         | block coordinate descent            |
//! | [`apollo`]  | APOLLO (Zhu et al. 2025)        | random projection, channel scaling  |
//! | [`golore`]  | GoLore (He et al. 2025)         | SVD early, random projection late   |
//! | [`subtrack`]| **SubTrack++** (this paper)     | Grassmannian geodesic rank-1 update |
//!
//! All low-rank methods share the convention of the paper (and GaLore):
//! 2-D parameters are projected per-matrix with rank `r` on the *shorter*
//! side; 1-D parameters (norms, biases) always take the full-rank Adam path.

pub mod adam;
pub mod apollo;
pub mod badam;
pub mod fira;
pub mod galore;
pub mod golore;
pub mod ldadam;
pub mod master;
pub mod osd;
pub mod projector;
pub mod sharded;
pub mod subtrack;

pub use adam::{Adam, AdamCfg};
pub use apollo::Apollo;
pub use badam::BAdam;
pub use fira::Fira;
pub use galore::GaLore;
pub use golore::GoLore;
pub use ldadam::LdAdam;
pub use master::MixedPrecision;
pub use osd::OnlineSubspaceDescent;
pub use sharded::ShardedOptimizer;
pub use subtrack::{Components, SubTrack};

use crate::tensor::dtype::quantize_slice;
use crate::tensor::{Dtype, Matrix};
use crate::util::rng::Rng;

/// A deterministic RNG stream keyed on a parameter's *name* (FNV-1a hash)
/// rather than its slot index or draw order.
///
/// The stochastic optimizers (SubTrack's power-iteration init, GoLore's and
/// APOLLO's random projectors) used to draw from one instance-level stream
/// in parameter order, which made the stream a parameter drew depend on
/// *which other parameters the same instance had already touched*. Under
/// ZeRO-style state partitioning each shard's instance sees only its own
/// parameter slice, so order-dependent streams would diverge from the
/// single-shard run. Keying the stream on (seed, method tag, param name)
/// makes every parameter's randomness a pure function of its identity —
/// identical for any shard count or partition boundary. Parameter names are
/// unique within a model by construction (`model::llama` asserts nothing,
/// but the name list is a fixed schema).
pub fn param_stream_rng(seed: u64, method_tag: u64, name: &str) -> Rng {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3); // FNV prime
    }
    Rng::new(seed ^ method_tag ^ h)
}

/// Whether a parameter participates in low-rank projection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    /// 2-D weight matrix — eligible for low-rank projection.
    Matrix2D,
    /// 1-D parameter (norm gain, bias) — always full-rank Adam.
    Vector,
}

/// A named trainable parameter.
///
/// The `version` counter backs the [`TransposeCache`] invalidation contract:
/// every optimizer write must go through [`Param::axpy_update`] /
/// [`Param::decay`] (or call [`Param::mark_dirty`] after mutating `value`
/// directly) so cached `Wᵀ` copies are recomputed exactly when the weight
/// changed.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub value: Matrix,
    pub kind: ParamKind,
    version: u64,
    /// Storage dtype `value` is held in. `value` stays an f32 [`Matrix`]
    /// (compute reads it directly), but under a 16-bit dtype every element
    /// is kept *on the storage grid* — quantized through
    /// [`Param::quantize_store_from`] after each optimizer write-back — so
    /// the numerics are exactly those of packed storage while checkpoints
    /// and byte accounting use the true 2-byte element size.
    dtype: Dtype,
}

impl Param {
    pub fn matrix(name: &str, value: Matrix) -> Param {
        Param {
            name: name.to_string(),
            value,
            kind: ParamKind::Matrix2D,
            version: 0,
            dtype: Dtype::F32,
        }
    }

    pub fn vector(name: &str, value: Matrix) -> Param {
        Param {
            name: name.to_string(),
            value,
            kind: ParamKind::Vector,
            version: 0,
            dtype: Dtype::F32,
        }
    }

    pub fn numel(&self) -> usize {
        self.value.len()
    }

    /// The storage dtype (see the field docs).
    #[inline]
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Switch the parameter onto `dt` storage, rounding the current value
    /// onto the storage grid. Bumps the version (cached transposes of the
    /// unrounded value are stale).
    pub fn set_storage_dtype(&mut self, dt: Dtype) {
        self.dtype = dt;
        quantize_slice(dt, self.value.data_mut());
        self.version += 1;
    }

    /// Overwrite `value` with `master` rounded onto the storage grid — the
    /// master-weight write-back step. Bumps the version.
    pub fn quantize_store_from(&mut self, master: &Matrix) {
        self.value.copy_from(master);
        quantize_slice(self.dtype, self.value.data_mut());
        self.version += 1;
    }

    /// Bytes this parameter occupies in storage form (element-size-aware:
    /// 2 per element under bf16/f16, 4 under f32).
    pub fn storage_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }

    /// Monotone write counter (see [`TransposeCache`]).
    #[inline]
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Record an out-of-band mutation of `value`.
    #[inline]
    pub fn mark_dirty(&mut self) {
        self.version += 1;
    }

    /// `value += alpha · other`, bumping the version.
    pub fn axpy_update(&mut self, alpha: f32, other: &Matrix) {
        self.value.axpy(alpha, other);
        self.version += 1;
    }

    /// `value *= factor` (decoupled weight decay), bumping the version.
    pub fn decay(&mut self, factor: f32) {
        self.value.scale_mut(factor);
        self.version += 1;
    }
}

/// Cached `Wᵀ` per parameter, invalidated by [`Param::version`].
///
/// The model's linears compute `x·Wᵀ`; materializing the transpose once per
/// weight *update* instead of once per GEMM call removes an O(rows·cols)
/// transpose from every layer of every step. Entries rebuild in place (the
/// old buffer is reused when the shape matches), so steady-state steps with
/// unchanged or optimizer-updated weights never allocate here after warmup.
///
/// # Fused multi-parameter entries
///
/// Beyond the per-param table, the cache keeps **fused** entries that
/// concatenate several parameters into one operand so the model can issue
/// one large GEMM instead of several small ones (QKV as `x·[Wqᵀ|Wkᵀ|Wvᵀ]`,
/// SwiGLU gate/up as `x·[Wgᵀ|Wuᵀ]`, and the stacked `[Wq;Wk;Wv]` /
/// `[Wg;Wu]` the backward `dn1`/`dn2` accumulations multiply against).
/// Fused entries live in their own slot table
/// ([`get_fused_transpose`] / [`get_fused_stack`]) and are keyed on **all**
/// source versions: a rebuild happens iff any source parameter's version
/// moved (or the shape changed), so per-param optimizer updates invalidate
/// exactly the fused operands that contain them. Invalidation contract for
/// callers: a slot's (kind, parameter set) mapping must stay fixed for the
/// cache's lifetime — slots are not keyed on parameter identity, only on
/// their versions.
///
/// [`get_fused_transpose`]: TransposeCache::get_fused_transpose
/// [`get_fused_stack`]: TransposeCache::get_fused_stack
#[derive(Default)]
pub struct TransposeCache {
    /// Per-param entries keyed on (version, storage dtype): a dtype switch
    /// re-rounds the value without changing its identity, so the dtype is
    /// part of the freshness key even though [`Param::set_storage_dtype`]
    /// also bumps the version (belt-and-suspenders for any future path that
    /// swaps dtype on a restored parameter).
    entries: Vec<Option<(u64, Dtype, Matrix)>>,
    /// Fused multi-param entries, indexed by caller-owned slot ids.
    fused: Vec<Option<FusedEntry>>,
    /// Number of transpose recomputations performed (diagnostics/tests).
    recomputes: usize,
}

/// One fused entry: the concatenated operand plus the source versions and
/// storage dtypes it was built from (both parallel to the caller's param
/// list for its slot).
struct FusedEntry {
    versions: Vec<u64>,
    dtypes: Vec<Dtype>,
    mat: Matrix,
}

/// Write `w`ᵀ into the column block starting at `col_off` of `out`
/// (blocked like [`Matrix::transpose_into`]; every element of the block is
/// written).
fn transpose_into_cols(w: &Matrix, out: &mut Matrix, col_off: usize) {
    const B: usize = 32;
    let (r, c) = w.shape();
    debug_assert!(out.rows() == c && col_off + r <= out.cols());
    let oc = out.cols();
    let wd = w.data();
    let od = out.data_mut();
    for ib in (0..r).step_by(B) {
        for jb in (0..c).step_by(B) {
            for i in ib..(ib + B).min(r) {
                for j in jb..(jb + B).min(c) {
                    od[j * oc + col_off + i] = wd[i * c + j];
                }
            }
        }
    }
}

impl TransposeCache {
    pub fn new() -> TransposeCache {
        TransposeCache::default()
    }

    /// The cached transpose of `param.value`, recomputing iff the parameter
    /// version changed since the last call for this `idx`.
    pub fn get(&mut self, idx: usize, param: &Param) -> &Matrix {
        if self.entries.len() <= idx {
            self.entries.resize_with(idx + 1, || None);
        }
        let want_shape = (param.value.cols(), param.value.rows());
        let fresh = matches!(
            &self.entries[idx],
            Some((ver, dt, t))
                if *ver == param.version() && *dt == param.dtype() && t.shape() == want_shape
        );
        if !fresh {
            self.recomputes += 1;
            let mut buf = match self.entries[idx].take() {
                Some((_, _, old)) if old.shape() == want_shape => old,
                _ => Matrix::zeros(want_shape.0, want_shape.1),
            };
            param.value.transpose_into(&mut buf);
            self.entries[idx] = Some((param.version(), param.dtype(), buf));
        }
        match &self.entries[idx] {
            Some((_, _, t)) => t,
            None => unreachable!("entry populated above"),
        }
    }

    /// The cached horizontal concatenation `[W₀ᵀ | W₁ᵀ | …]` of several
    /// parameters' transposes (all sources share their column count — the
    /// fused linear's input dimension), recomputing iff any source version
    /// changed since the last call for this `slot`. See the type docs for
    /// the slot contract.
    pub fn get_fused_transpose(&mut self, slot: usize, params: &[&Param]) -> &Matrix {
        let c = params.first().map_or(0, |p| p.value.cols());
        let total: usize = params.iter().map(|p| p.value.rows()).sum();
        let want = (c, total);
        if !self.fused_fresh(slot, params, want) {
            self.recomputes += 1;
            let (mut buf, mut versions, mut dtypes) = self.take_fused_slot(slot, want);
            versions.clear();
            versions.extend(params.iter().map(|p| p.version()));
            dtypes.clear();
            dtypes.extend(params.iter().map(|p| p.dtype()));
            let mut off = 0usize;
            for p in params {
                debug_assert_eq!(p.value.cols(), c, "fused transpose: mismatched input dims");
                transpose_into_cols(&p.value, &mut buf, off);
                off += p.value.rows();
            }
            self.fused[slot] = Some(FusedEntry { versions, dtypes, mat: buf });
        }
        match &self.fused[slot] {
            Some(e) => &e.mat,
            None => unreachable!("entry populated above"),
        }
    }

    /// The cached vertical stack `[W₀; W₁; …]` of several parameters' raw
    /// values (all sources share their column count), recomputing iff any
    /// source version changed. Same slot contract as
    /// [`get_fused_transpose`] — and a slot must never be shared between
    /// the two kinds.
    ///
    /// [`get_fused_transpose`]: TransposeCache::get_fused_transpose
    pub fn get_fused_stack(&mut self, slot: usize, params: &[&Param]) -> &Matrix {
        let c = params.first().map_or(0, |p| p.value.cols());
        let total: usize = params.iter().map(|p| p.value.rows()).sum();
        let want = (total, c);
        if !self.fused_fresh(slot, params, want) {
            self.recomputes += 1;
            let (mut buf, mut versions, mut dtypes) = self.take_fused_slot(slot, want);
            versions.clear();
            versions.extend(params.iter().map(|p| p.version()));
            dtypes.clear();
            dtypes.extend(params.iter().map(|p| p.dtype()));
            let mut off = 0usize;
            for p in params {
                debug_assert_eq!(p.value.cols(), c, "fused stack: mismatched widths");
                let n = p.value.len();
                buf.data_mut()[off..off + n].copy_from_slice(p.value.data());
                off += n;
            }
            self.fused[slot] = Some(FusedEntry { versions, dtypes, mat: buf });
        }
        match &self.fused[slot] {
            Some(e) => &e.mat,
            None => unreachable!("entry populated above"),
        }
    }

    /// Whether a fused slot can be served as-is: right shape, same source
    /// count, no source version or storage dtype moved.
    fn fused_fresh(&self, slot: usize, params: &[&Param], want: (usize, usize)) -> bool {
        match self.fused.get(slot).and_then(|e| e.as_ref()) {
            Some(e) => {
                e.mat.shape() == want
                    && e.versions.len() == params.len()
                    && e.versions.iter().zip(params).all(|(&v, p)| v == p.version())
                    && e.dtypes.len() == params.len()
                    && e.dtypes.iter().zip(params).all(|(&d, p)| d == p.dtype())
            }
            None => false,
        }
    }

    /// Take the slot's buffer for an in-place rebuild (reused when the
    /// shape matches, so steady-state weight updates never allocate here).
    fn take_fused_slot(
        &mut self,
        slot: usize,
        want: (usize, usize),
    ) -> (Matrix, Vec<u64>, Vec<Dtype>) {
        if self.fused.len() <= slot {
            self.fused.resize_with(slot + 1, || None);
        }
        match self.fused[slot].take() {
            Some(e) if e.mat.shape() == want => (e.mat, e.versions, e.dtypes),
            Some(e) => (Matrix::zeros(want.0, want.1), e.versions, e.dtypes),
            None => (Matrix::zeros(want.0, want.1), Vec::new(), Vec::new()),
        }
    }

    /// Drop every cached transpose (use after wholesale parameter
    /// replacement, e.g. checkpoint load into a live trainer).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.fused.clear();
    }

    pub fn recomputes(&self) -> usize {
        self.recomputes
    }
}

/// Shared optimizer hyperparameters (paper Table 10 defaults).
#[derive(Clone, Copy, Debug)]
pub struct HyperParams {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Low-rank projection rank r.
    pub rank: usize,
    /// Subspace update interval k.
    pub interval: usize,
    /// GaLore-style scale factor α applied to the projected-back update.
    pub scale: f32,
    /// SubTrack++ geodesic step size η.
    pub eta: f32,
    /// Recovery-scaling growth limiter ζ.
    pub zeta: f32,
    /// Seed for any stochastic pieces (power iteration init, random proj).
    pub seed: u64,
}

impl Default for HyperParams {
    fn default() -> Self {
        HyperParams {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            rank: 16,
            interval: 200,
            scale: 0.25,
            eta: 10.0,
            zeta: 1.01,
            seed: 0,
        }
    }
}

/// A deep copy of an optimizer's mutable state (moments, projector bases,
/// step counters, RNG streams), produced by [`Optimizer::snapshot`] and
/// replayed by [`Optimizer::restore`].
///
/// The representation is a flat bag of typed streams rather than a
/// per-optimizer struct: each optimizer packs its fields in a fixed,
/// documented order and unpacks them in the same order through a
/// [`SnapshotReader`] cursor. `Option` slots are encoded as a presence
/// integer (0/1) followed by the slot's payload when present, so a snapshot
/// taken before a slot was initialized restores it back to uninitialized.
#[derive(Clone, Debug, Default)]
pub struct OptimizerSnapshot {
    mats: Vec<Matrix>,
    ints: Vec<u64>,
    floats: Vec<f64>,
    rngs: Vec<Rng>,
}

impl OptimizerSnapshot {
    pub fn new() -> OptimizerSnapshot {
        OptimizerSnapshot::default()
    }

    pub fn push_mat(&mut self, m: &Matrix) {
        self.mats.push(m.clone());
    }

    pub fn push_int(&mut self, v: u64) {
        self.ints.push(v);
    }

    pub fn push_float(&mut self, v: f64) {
        self.floats.push(v);
    }

    pub fn push_rng(&mut self, r: &Rng) {
        self.rngs.push(r.clone());
    }

    /// A cursor for unpacking in push order.
    pub fn reader(&self) -> SnapshotReader<'_> {
        SnapshotReader { snap: self, mat: 0, int: 0, float: 0, rng: 0 }
    }

    /// Approximate heap size — used to account rollback snapshots in the
    /// trainer's peak-memory bookkeeping.
    pub fn bytes(&self) -> usize {
        self.mats.iter().map(|m| m.len() * std::mem::size_of::<f32>()).sum::<usize>()
            + self.ints.len() * std::mem::size_of::<u64>()
            + self.floats.len() * std::mem::size_of::<f64>()
            + self.rngs.len() * std::mem::size_of::<Rng>()
    }

    /// Serialize to a little-endian byte stream so checkpoints can persist
    /// full optimizer state alongside the parameter blob.
    ///
    /// Layout: four u64 stream counts (mats, ints, floats, rngs), then each
    /// matrix as u32 rows + u32 cols + row-major f32 data, then the ints
    /// (u64), floats (f64 bit patterns), and RNGs (6 u64 state words each,
    /// see [`Rng::state_words`]).
    pub fn encode(&self) -> Vec<u8> {
        let mat_bytes: usize = self.mats.iter().map(|m| 8 + m.len() * 4).sum();
        let mut out =
            Vec::with_capacity(32 + mat_bytes + self.ints.len() * 8 + self.floats.len() * 8);
        for count in [self.mats.len(), self.ints.len(), self.floats.len(), self.rngs.len()] {
            out.extend_from_slice(&(count as u64).to_le_bytes());
        }
        for m in &self.mats {
            out.extend_from_slice(&(m.rows() as u32).to_le_bytes());
            out.extend_from_slice(&(m.cols() as u32).to_le_bytes());
            for &v in m.data() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        for &v in &self.ints {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &self.floats {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        for r in &self.rngs {
            for w in r.state_words() {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
        out
    }

    /// Inverse of [`encode`](OptimizerSnapshot::encode). Returns an error
    /// string (not a panic) on truncated or malformed input so checkpoint
    /// loading can classify it as corruption and fall back.
    pub fn decode(bytes: &[u8]) -> Result<OptimizerSnapshot, String> {
        struct Cursor<'a> {
            buf: &'a [u8],
            off: usize,
        }
        impl Cursor<'_> {
            fn take<const N: usize>(&mut self) -> Result<[u8; N], String> {
                let end = self.off.checked_add(N).ok_or("offset overflow")?;
                let chunk = self.buf.get(self.off..end).ok_or("truncated snapshot")?;
                self.off = end;
                Ok(chunk.try_into().expect("length checked"))
            }
            fn u64(&mut self) -> Result<u64, String> {
                Ok(u64::from_le_bytes(self.take::<8>()?))
            }
            fn u32(&mut self) -> Result<u32, String> {
                Ok(u32::from_le_bytes(self.take::<4>()?))
            }
            fn f32(&mut self) -> Result<f32, String> {
                Ok(f32::from_le_bytes(self.take::<4>()?))
            }
        }
        let mut c = Cursor { buf: bytes, off: 0 };
        let n_mats = c.u64()? as usize;
        let n_ints = c.u64()? as usize;
        let n_floats = c.u64()? as usize;
        let n_rngs = c.u64()? as usize;
        let mut snap = OptimizerSnapshot::new();
        for _ in 0..n_mats {
            let rows = c.u32()? as usize;
            let cols = c.u32()? as usize;
            let numel = rows.checked_mul(cols).ok_or("matrix shape overflow")?;
            if numel.checked_mul(4).ok_or("matrix size overflow")?
                > bytes.len().saturating_sub(c.off)
            {
                return Err("truncated snapshot matrix".into());
            }
            let mut m = Matrix::zeros(rows, cols);
            for v in m.data_mut() {
                *v = c.f32()?;
            }
            snap.mats.push(m);
        }
        for _ in 0..n_ints {
            snap.ints.push(c.u64()?);
        }
        for _ in 0..n_floats {
            snap.floats.push(f64::from_bits(c.u64()?));
        }
        for _ in 0..n_rngs {
            let mut w = [0u64; 6];
            for wi in &mut w {
                *wi = c.u64()?;
            }
            snap.rngs.push(Rng::from_state_words(w));
        }
        if c.off != bytes.len() {
            return Err(format!("trailing bytes in snapshot: {} past end", bytes.len() - c.off));
        }
        Ok(snap)
    }
}

/// Read cursor over an [`OptimizerSnapshot`], consuming each typed stream
/// in push order. Panics if an optimizer reads past what it packed — that
/// is a pack/unpack ordering bug, not a runtime condition.
pub struct SnapshotReader<'a> {
    snap: &'a OptimizerSnapshot,
    mat: usize,
    int: usize,
    float: usize,
    rng: usize,
}

impl SnapshotReader<'_> {
    fn next_mat(&mut self) -> &Matrix {
        let m = self.snap.mats.get(self.mat).expect("snapshot: matrix stream exhausted");
        self.mat += 1;
        m
    }

    /// Copy the next matrix into `out` (in place when shapes match, so a
    /// same-run restore does not allocate).
    pub fn mat_into(&mut self, out: &mut Matrix) {
        let src = self.next_mat();
        if out.shape() == src.shape() {
            out.copy_from(src);
        } else {
            *out = src.clone();
        }
    }

    /// Clone the next matrix out of the snapshot.
    pub fn mat(&mut self) -> Matrix {
        self.next_mat().clone()
    }

    pub fn int(&mut self) -> u64 {
        let v = *self.snap.ints.get(self.int).expect("snapshot: int stream exhausted");
        self.int += 1;
        v
    }

    pub fn float(&mut self) -> f64 {
        let v = *self.snap.floats.get(self.float).expect("snapshot: float stream exhausted");
        self.float += 1;
        v
    }

    pub fn rng(&mut self) -> Rng {
        let r = self.snap.rngs.get(self.rng).expect("snapshot: rng stream exhausted").clone();
        self.rng += 1;
        r
    }
}

/// Pack a `Vec<Option<Moments>>` slot table (count, then per-slot presence
/// flag + payload) — shared by the low-rank optimizers' vector-parameter
/// snapshot streams.
pub(crate) fn pack_moment_slots(snap: &mut OptimizerSnapshot, slots: &[Option<adam::Moments>]) {
    snap.push_int(slots.len() as u64);
    for slot in slots {
        match slot {
            Some(m) => {
                snap.push_int(1);
                m.pack(snap);
            }
            None => snap.push_int(0),
        }
    }
}

/// Inverse of [`pack_moment_slots`], restoring in place where shapes allow.
pub(crate) fn unpack_moment_slots(
    r: &mut SnapshotReader,
    slots: &mut Vec<Option<adam::Moments>>,
) {
    let n = r.int() as usize;
    slots.resize_with(n, || None);
    for slot in slots.iter_mut() {
        if r.int() == 1 {
            match slot {
                Some(m) => m.unpack_into(r),
                None => *slot = Some(adam::Moments::unpack(r)),
            }
        } else {
            *slot = None;
        }
    }
}

/// Range variant of [`unpack_moment_slots`] for elastic resharding
/// ([`Optimizer::restore_ranges`]): parse a packed slot table and append
/// only slots `lo..hi` to `out`. Slots outside the range must still be
/// decoded — payload lengths are data-dependent — and are dropped.
pub(crate) fn keep_moment_slot_range(
    r: &mut SnapshotReader,
    out: &mut Vec<Option<adam::Moments>>,
    lo: usize,
    hi: usize,
) {
    let n = r.int() as usize;
    assert!(hi <= n, "moment slot range {lo}..{hi} out of table of {n}");
    for i in 0..n {
        if r.int() == 1 {
            let m = adam::Moments::unpack(r);
            if i >= lo && i < hi {
                out.push(Some(m));
            }
        } else if i >= lo && i < hi {
            out.push(None);
        }
    }
}

/// A full-parameter optimizer over a set of named parameters.
///
/// `lr` is supplied per step so the trainer owns the schedule. `grads` is
/// parallel to `params`. Optimizers are `Send` so [`ShardedOptimizer`] can
/// drive per-shard instances from pool worker threads.
pub trait Optimizer: Send {
    /// Apply one update step in place.
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]);

    /// Apply one update step to a contiguous *partition* of the parameter
    /// list (ZeRO-1 semantics: this instance owns only these tensors' state
    /// and never sees the rest). `partition`/`grads` are the owned
    /// sub-slices, parallel to each other.
    ///
    /// The default delegates to [`step`](Optimizer::step): every per-tensor
    /// method (Adam moments, low-rank projector state keyed by slot) treats
    /// its parameter list as the whole world, so a partition behaves exactly
    /// like a small full run provided the method's cross-parameter coupling
    /// is nil and its randomness is keyed per parameter (see
    /// [`param_stream_rng`]). Methods with *global* state spanning all
    /// parameters (BAdam's single active block) must instead report
    /// [`partitionable`](Optimizer::partitionable) `= false`.
    fn step_partition(&mut self, lr: f32, partition: &mut [Param], grads: &[Matrix]) {
        self.step(lr, partition, grads)
    }

    /// Whether this method's state can be partitioned across DP shards via
    /// [`step_partition`] without changing the algorithm. `false` for
    /// methods whose update couples all parameters globally (BAdam's block
    /// switch draws one active block over the full list).
    ///
    /// [`step_partition`]: Optimizer::step_partition
    fn partitionable(&self) -> bool {
        true
    }

    /// Bytes of optimizer state currently held (moments + projectors +
    /// auxiliary buffers). Used for the paper's Table 8 accounting.
    fn state_bytes(&self) -> usize;

    /// Count of optimizer state *parameters* in the paper's Table 2 sense
    /// (moments + projector entries; excludes auxiliary buffers).
    fn state_params(&self) -> usize;

    /// How many subspace updates have been performed (diagnostics).
    fn subspace_updates(&self) -> usize {
        0
    }

    /// Misses of the optimizer's internal scratch [`Workspace`] (0 for
    /// optimizers that keep no per-step scratch). Steady-state steps must
    /// not grow this, and refresh steps only on their first occurrence —
    /// see `rust/tests/zero_alloc.rs`.
    ///
    /// [`Workspace`]: crate::tensor::Workspace
    fn workspace_misses(&self) -> usize {
        0
    }

    /// Worst orthonormality defect ‖SᵀS − I‖_max over the optimizer's
    /// current projector bases, or `None` for methods without orthonormal
    /// projectors (full-rank Adam, APOLLO's Gaussian sketch, BAdam's block
    /// masks). The property suite in `rust/tests/subspace_props.rs` gates
    /// every refresh mechanism on this staying small.
    fn projector_defect(&self) -> Option<f32> {
        None
    }

    /// Deep-copy every piece of mutable state into a snapshot the trainer
    /// can later [`restore`] for anomaly rollback. Includes RNG streams and
    /// step counters so a restored optimizer replays bit-identically.
    ///
    /// [`restore`]: Optimizer::restore
    fn snapshot(&self) -> OptimizerSnapshot;

    /// Rewind to a snapshot previously produced by [`snapshot`] on this
    /// optimizer over the same parameter set. Restoring a snapshot from a
    /// different optimizer or parameter set is a programming error and may
    /// panic.
    ///
    /// [`snapshot`]: Optimizer::snapshot
    fn restore(&mut self, snap: &OptimizerSnapshot);

    /// Elastic-reshard support: rebuild this instance's state from
    /// contiguous *slot sub-ranges* of same-method snapshots. Each part
    /// `(snap, lo, hi)` contributes slots `lo..hi` of `snap`'s local slot
    /// table, and the concatenation of all parts must be exactly this
    /// instance's parameter list, in order. [`ShardedOptimizer`] uses this
    /// to resume a checkpoint under a different shard count: every
    /// per-parameter state (moments, projector, per-slot RNG stream) moves
    /// wholesale, so the resumed trajectory is bit-identical to the
    /// uninterrupted one. Instance-wide diagnostic counters
    /// (`n_subspace_updates`-style tallies) are taken as the max over the
    /// contributing parts and may over-attribute after a reshard; nothing
    /// in any update path reads them.
    ///
    /// Returns `false` (the default) when the method's state cannot be
    /// re-split at parameter granularity — the sharded wrapper then refuses
    /// to resume at a different shard count.
    fn restore_ranges(&mut self, parts: &[(&OptimizerSnapshot, usize, usize)]) -> bool {
        let _ = parts;
        false
    }

    /// Fault injection: make the next subspace refresh produce a
    /// deliberately non-finite basis so the refresh guard's rejection path
    /// can be exercised end to end. No-op for methods without a guarded
    /// refresh (full-rank Adam, BAdam, APOLLO's Gaussian sketch).
    fn poison_next_refresh(&mut self) {}

    /// How many subspace refreshes the health guard rejected (kept the
    /// previous basis because the candidate was non-finite or far from
    /// orthonormal). Surfaced into `train::metrics`.
    fn refresh_rejections(&self) -> usize {
        0
    }

    /// Method name for logs and tables.
    fn name(&self) -> String;
}

/// Construct an optimizer by its table name. Panics on unknown names — the
/// accepted set is exactly the row labels used across the paper's tables.
pub fn by_name(name: &str, hp: HyperParams) -> Box<dyn Optimizer> {
    match name {
        "adam" | "full-rank" | "adamw" => Box::new(Adam::new(AdamCfg {
            beta1: hp.beta1,
            beta2: hp.beta2,
            eps: hp.eps,
            weight_decay: hp.weight_decay,
        })),
        "galore" => Box::new(GaLore::new(hp)),
        "fira" => Box::new(Fira::new(hp)),
        "ldadam" => Box::new(LdAdam::new(hp)),
        "osd" | "online-subspace-descent" => Box::new(OnlineSubspaceDescent::new(hp)),
        "badam" => Box::new(BAdam::new(hp)),
        "apollo" => Box::new(Apollo::new(hp)),
        "golore" => Box::new(GoLore::new(hp)),
        "subtrack" | "subtrack++" => Box::new(SubTrack::new(hp, Components::full())),
        "subtrack-pure" => Box::new(SubTrack::new(hp, Components::pure())),
        "subtrack-pa" => Box::new(SubTrack::new(hp, Components::pa_only())),
        "subtrack-rs" => Box::new(SubTrack::new(hp, Components::rs_only())),
        other => panic!("unknown optimizer: {other}"),
    }
}

/// Construct an optimizer whose state is partitioned across `shards`
/// ZeRO-1 shards. Methods that are not
/// [`partitionable`](Optimizer::partitionable), and `shards <= 1`,
/// collapse to a single inner instance — the single-shard wrapper
/// delegates [`step`](Optimizer::step) directly, so trajectories are
/// bit-identical to the plain optimizer. Always returning the wrapper
/// (rather than the bare method at `shards <= 1`) keeps every checkpoint's
/// optimizer blob in the elastic sharded layout, so a run can be resumed
/// under any `train.workers` regardless of the count that wrote it.
pub fn sharded_by_name(name: &str, hp: HyperParams, shards: usize) -> Box<dyn Optimizer> {
    Box::new(ShardedOptimizer::new(name, hp, shards))
}

/// [`sharded_by_name`] wrapped for mixed-precision storage: under a 16-bit
/// `dtype` the inner optimizer is driven over f32 master weights and every
/// update is written back through [`Param::quantize_store_from`]; under
/// `Dtype::F32` this is exactly `sharded_by_name` (no wrapper, byte-identical
/// trajectories).
pub fn mixed_by_name(
    name: &str,
    hp: HyperParams,
    shards: usize,
    dtype: Dtype,
) -> Box<dyn Optimizer> {
    let inner = sharded_by_name(name, hp, shards);
    if dtype == Dtype::F32 {
        inner
    } else {
        Box::new(MixedPrecision::new(inner, dtype))
    }
}

/// The method names exercised across the paper's pre-training tables.
pub const PRETRAIN_METHODS: &[&str] =
    &["full-rank", "galore", "badam", "osd", "ldadam", "fira", "subtrack++"];

#[cfg(test)]
pub mod testutil {
    //! Shared optimizer test fixtures: a convex least-squares problem
    //! `min_W ||X·W − Y||²` whose gradient matrices exercise the full
    //! projection machinery (m≠n, known optimum).

    use super::*;
    use crate::tensor::gemm;
    use crate::util::rng::Rng;

    pub struct LstsqProblem {
        pub x: Matrix,      // batch×m
        pub y: Matrix,      // batch×n
        pub w_star: Matrix, // m×n
    }

    impl LstsqProblem {
        pub fn new(batch: usize, m: usize, n: usize, seed: u64) -> LstsqProblem {
            let mut rng = Rng::new(seed);
            let x = Matrix::randn(batch, m, 1.0, &mut rng);
            let w_star = Matrix::randn(m, n, 1.0, &mut rng);
            let y = gemm::matmul(&x, &w_star);
            LstsqProblem { x, y, w_star }
        }

        /// Loss 0.5‖XW−Y‖²/batch and gradient Xᵀ(XW−Y)/batch.
        pub fn loss_grad(&self, w: &Matrix) -> (f32, Matrix) {
            let pred = gemm::matmul(&self.x, w);
            let resid = pred.sub(&self.y);
            let b = self.x.rows() as f32;
            let loss = 0.5 * resid.fro_norm().powi(2) / b;
            let grad = gemm::matmul_tn(&self.x, &resid).scale(1.0 / b);
            (loss, grad)
        }
    }

    /// Run `opt` for `steps` on the least-squares problem; return
    /// (initial_loss, final_loss).
    pub fn run_lstsq(
        opt: &mut dyn Optimizer,
        prob: &LstsqProblem,
        steps: usize,
        lr: f32,
    ) -> (f32, f32) {
        let (m, n) = prob.w_star.shape();
        let mut params = vec![Param::matrix("w", Matrix::zeros(m, n))];
        let (init_loss, _) = prob.loss_grad(&params[0].value);
        let mut last = init_loss;
        for _ in 0..steps {
            let (loss, grad) = prob.loss_grad(&params[0].value);
            last = loss;
            opt.step(lr, &mut params, &[grad]);
        }
        (init_loss, last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_knows_all_methods() {
        for name in PRETRAIN_METHODS {
            let opt = by_name(name, HyperParams::default());
            assert!(!opt.name().is_empty());
        }
        for name in ["apollo", "golore", "subtrack-pure", "subtrack-pa", "subtrack-rs"] {
            let _ = by_name(name, HyperParams::default());
        }
    }

    #[test]
    #[should_panic(expected = "unknown optimizer")]
    fn factory_rejects_unknown() {
        let _ = by_name("sgd-9000", HyperParams::default());
    }

    #[test]
    fn fused_transpose_concatenates_and_invalidates_per_source() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(6);
        // Three "weights" sharing the input dim (cols = 4), ragged rows.
        let mut wq = Param::matrix("wq", Matrix::randn(3, 4, 1.0, &mut rng));
        let wk = Param::matrix("wk", Matrix::randn(2, 4, 1.0, &mut rng));
        let wv = Param::matrix("wv", Matrix::randn(5, 4, 1.0, &mut rng));
        let mut tc = TransposeCache::new();
        let fused = tc.get_fused_transpose(0, &[&wq, &wk, &wv]).clone();
        assert_eq!(fused.shape(), (4, 10));
        // Manual [Wqᵀ | Wkᵀ | Wvᵀ].
        for (off, w) in [(0usize, &wq), (3, &wk), (5, &wv)] {
            let t = w.value.t();
            for i in 0..4 {
                for j in 0..w.value.rows() {
                    assert_eq!(fused.get(i, off + j), t.get(i, j), "block at {off}");
                }
            }
        }
        // Warm reads serve the cache.
        let _ = tc.get_fused_transpose(0, &[&wq, &wk, &wv]);
        assert_eq!(tc.recomputes(), 1);
        // One source write invalidates the fused entry.
        wq.axpy_update(-0.1, &Matrix::full(3, 4, 1.0));
        let fused2 = tc.get_fused_transpose(0, &[&wq, &wk, &wv]).clone();
        assert_eq!(tc.recomputes(), 2);
        assert_ne!(fused.data(), fused2.data());
        assert_eq!(fused2.get(0, 0), wq.value.get(0, 0));
        // Untouched blocks are rebuilt identically.
        assert_eq!(fused.get(0, 3), fused2.get(0, 3));
    }

    #[test]
    fn fused_stack_concatenates_rows_and_tracks_versions() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        let wg = Param::matrix("wg", Matrix::randn(3, 4, 1.0, &mut rng));
        let mut wu = Param::matrix("wu", Matrix::randn(2, 4, 1.0, &mut rng));
        let mut tc = TransposeCache::new();
        let stack = tc.get_fused_stack(1, &[&wg, &wu]).clone();
        assert_eq!(stack.shape(), (5, 4));
        assert_eq!(&stack.data()[..12], wg.value.data());
        assert_eq!(&stack.data()[12..], wu.value.data());
        let _ = tc.get_fused_stack(1, &[&wg, &wu]);
        assert_eq!(tc.recomputes(), 1);
        wu.decay(0.5);
        let stack2 = tc.get_fused_stack(1, &[&wg, &wu]).clone();
        assert_eq!(tc.recomputes(), 2);
        assert_eq!(&stack2.data()[12..], wu.value.data());
        // Fused slots coexist with per-param entries and clear() drops both.
        let _ = tc.get(0, &wg);
        tc.clear();
        let _ = tc.get_fused_stack(1, &[&wg, &wu]);
        assert_eq!(tc.recomputes(), 4, "clear must drop fused entries too");
    }

    #[test]
    fn snapshot_restore_replays_bitexact() {
        // Every optimizer must rewind to a snapshot and replay the exact
        // same trajectory — the contract anomaly rollback depends on.
        let names = [
            "full-rank",
            "galore",
            "fira",
            "ldadam",
            "osd",
            "badam",
            "apollo",
            "golore",
            "subtrack++",
            "subtrack-pure",
        ];
        for name in names {
            let hp =
                HyperParams { rank: 3, interval: 4, scale: 1.0, ..HyperParams::default() };
            let prob = testutil::LstsqProblem::new(16, 6, 9, 123);
            let mut opt = by_name(name, hp);
            let mut params = vec![
                Param::matrix("w", Matrix::zeros(6, 9)),
                Param::vector("b", Matrix::zeros(1, 9)),
            ];
            let gb = Matrix::full(1, 9, 0.01);
            let step = |opt: &mut Box<dyn Optimizer>, params: &mut Vec<Param>| {
                let (_, gw) = prob.loss_grad(&params[0].value);
                opt.step(0.05, params, &[gw, gb.clone()]);
            };
            // Warm up past init + at least one refresh interval.
            for _ in 0..9 {
                step(&mut opt, &mut params);
            }
            let snap = opt.snapshot();
            let saved: Vec<Matrix> = params.iter().map(|p| p.value.clone()).collect();
            let mut trace_a = Vec::new();
            for _ in 0..6 {
                step(&mut opt, &mut params);
                trace_a.push(params[0].value.clone());
            }
            // Rewind optimizer + params, replay, and compare bit-for-bit.
            opt.restore(&snap);
            for (p, v) in params.iter_mut().zip(&saved) {
                p.value.copy_from(v);
                p.mark_dirty();
            }
            for (s, a) in trace_a.iter().enumerate() {
                step(&mut opt, &mut params);
                assert_eq!(
                    params[0].value.data(),
                    a.data(),
                    "{name}: replay diverged at step {s}"
                );
            }
        }
    }

    #[test]
    fn transpose_cache_invalidates_on_write() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let mut p = Param::matrix("w", Matrix::randn(4, 6, 1.0, &mut rng));
        let mut tc = TransposeCache::new();
        let t1 = tc.get(0, &p).clone();
        assert_eq!(t1, p.value.t());
        // Repeated reads with no write: served from cache.
        let _ = tc.get(0, &p);
        let _ = tc.get(0, &p);
        assert_eq!(tc.recomputes(), 1);
        // Optimizer-style write invalidates.
        let delta = Matrix::full(4, 6, 1.0);
        p.axpy_update(-0.5, &delta);
        let t2 = tc.get(0, &p).clone();
        assert_eq!(tc.recomputes(), 2);
        assert_eq!(t2, p.value.t());
        assert_ne!(t1, t2);
        // decay() and mark_dirty() also bump.
        let v = p.version();
        p.decay(0.9);
        p.mark_dirty();
        assert_eq!(p.version(), v + 2);
        let t3 = tc.get(0, &p).clone();
        assert_eq!(t3, p.value.t());
    }
}
