//! GaLore (Zhao et al., 2024) — gradient low-rank projection with a
//! truncated-SVD projector recomputed every k steps.
//!
//! The Table 2 baseline: optimizer state mr + 2nr, subspace update cost
//! O(n·m²) (full SVD of the m×n gradient). Moments are *not* rotated when the
//! projector changes — the known misalignment SubTrack++'s projection-aware
//! update fixes.

use super::adam::{AdamCfg, Moments};
use super::projector::{self, Projector};
use super::{HyperParams, Optimizer, OptimizerSnapshot, Param, ParamKind, SnapshotReader};
use crate::tensor::{Matrix, Workspace};

struct MatState {
    proj: Projector,
    moments: Moments,
}

/// GaLore optimizer.
pub struct GaLore {
    hp: HyperParams,
    adam: AdamCfg,
    mats: Vec<Option<MatState>>,
    vecs: Vec<Option<Moments>>,
    step_no: usize,
    n_subspace_updates: usize,
    n_refresh_rejections: usize,
    poison_refresh: bool,
    /// Accumulated wall-time spent in SVD projector refreshes (seconds).
    pub svd_seconds: f64,
    /// Per-step projection scratch (zero steady-state allocation).
    ws: Workspace,
}

impl GaLore {
    pub fn new(hp: HyperParams) -> GaLore {
        GaLore {
            hp,
            adam: AdamCfg::from(hp),
            mats: Vec::new(),
            vecs: Vec::new(),
            step_no: 0,
            n_subspace_updates: 0,
            n_refresh_rejections: 0,
            poison_refresh: false,
            svd_seconds: 0.0,
            ws: Workspace::new(),
        }
    }

    fn ensure_slots(&mut self, n: usize) {
        if self.mats.len() != n {
            self.mats = (0..n).map(|_| None).collect();
            self.vecs = (0..n).map(|_| None).collect();
        }
    }
}

impl Optimizer for GaLore {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_slots(params.len());
        let refresh = self.hp.interval > 0 && self.step_no % self.hp.interval == 0;
        for i in 0..params.len() {
            let g = &grads[i];
            match params[i].kind {
                ParamKind::Matrix2D if g.rows() > 1 && g.cols() > 1 => {
                    let (m, n) = g.shape();
                    let needs_init = self.mats[i].is_none();
                    if needs_init || refresh {
                        // Full truncated SVD of the gradient — O(n·m²).
                        let t0 = std::time::Instant::now();
                        if needs_init {
                            let proj = Projector::init_svd(g, self.hp.rank);
                            let (lm, ln) = proj.lowrank_shape(m, n);
                            self.mats[i] =
                                Some(MatState { proj, moments: Moments::new(lm, ln) });
                        } else {
                            // Refresh in place: the new basis lands in the
                            // existing buffer, SVD scratch is workspace-leased,
                            // moments stay untouched (GaLore's behaviour). A
                            // workspace-leased copy of the old basis backs the
                            // health guard: a degenerate (or fault-injected)
                            // candidate is rejected and the previous projector
                            // kept until the next interval.
                            let GaLore {
                                ws,
                                mats,
                                n_subspace_updates,
                                n_refresh_rejections,
                                poison_refresh,
                                ..
                            } = &mut *self;
                            let st = mats[i].as_mut().unwrap();
                            let (sr, sc) = st.proj.s.shape();
                            let mut old_s = ws.take_dirty(sr, sc);
                            old_s.copy_from(&st.proj.s);
                            st.proj.refresh_svd_into(g, ws);
                            if std::mem::take(poison_refresh) {
                                projector::poison_basis(&mut st.proj.s);
                            }
                            if projector::basis_acceptable(
                                &st.proj.s,
                                projector::REFRESH_DEFECT_TOL,
                            ) {
                                *n_subspace_updates += 1;
                            } else {
                                st.proj.s.copy_from(&old_s);
                                *n_refresh_rejections += 1;
                            }
                            ws.give(old_s);
                        }
                        self.svd_seconds += t0.elapsed().as_secs_f64();
                    }
                    let adam = self.adam;
                    let scale = self.hp.scale;
                    // Disjoint borrows: scratch pool vs per-matrix state.
                    let GaLore { ws, mats, .. } = &mut *self;
                    let st = mats[i].as_mut().expect("initialized above");
                    let (lm, ln) = st.proj.lowrank_shape(m, n);
                    let mut g_low = ws.take_dirty(lm, ln);
                    st.proj.project_into(g, &mut g_low, ws);
                    let mut dir = ws.take_dirty(lm, ln);
                    st.moments.update_into(&adam, &g_low, &mut dir);
                    let mut delta = ws.take_dirty(m, n);
                    st.proj.project_back_into(&dir, &mut delta, ws);
                    params[i].axpy_update(-lr * scale, &delta);
                    ws.give(delta);
                    ws.give(dir);
                    ws.give(g_low);
                }
                _ => {
                    if self.vecs[i].is_none() {
                        self.vecs[i] = Some(Moments::new(g.rows(), g.cols()));
                    }
                    let adam = self.adam;
                    let st = self.vecs[i].as_mut().unwrap();
                    st.fused_step(&adam, lr, 0.0, &mut params[i].value, g);
                    params[i].mark_dirty();
                }
            }
            if self.adam.weight_decay > 0.0 {
                params[i].decay(1.0 - lr * self.adam.weight_decay);
            }
        }
        self.step_no += 1;
    }

    fn state_bytes(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.bytes() + s.proj.bytes()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.bytes()).sum();
        mats + vecs
    }

    fn state_params(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.params() + s.proj.params()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.params()).sum();
        mats + vecs
    }

    fn subspace_updates(&self) -> usize {
        self.n_subspace_updates
    }

    fn workspace_misses(&self) -> usize {
        self.ws.misses()
    }

    fn projector_defect(&self) -> Option<f32> {
        Some(self.mats.iter().flatten().map(|s| s.proj.defect()).fold(0.0f32, f32::max))
    }

    fn poison_next_refresh(&mut self) {
        self.poison_refresh = true;
    }

    fn refresh_rejections(&self) -> usize {
        self.n_refresh_rejections
    }

    // Pack order: step_no, n_subspace_updates, n_refresh_rejections, matrix
    // slots (presence + projector + moments), vector moment slots.
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = OptimizerSnapshot::new();
        snap.push_int(self.step_no as u64);
        snap.push_int(self.n_subspace_updates as u64);
        snap.push_int(self.n_refresh_rejections as u64);
        snap.push_int(self.mats.len() as u64);
        for slot in &self.mats {
            match slot {
                Some(st) => {
                    snap.push_int(1);
                    st.proj.pack(&mut snap);
                    st.moments.pack(&mut snap);
                }
                None => snap.push_int(0),
            }
        }
        super::pack_moment_slots(&mut snap, &self.vecs);
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let mut r = snap.reader();
        self.step_no = r.int() as usize;
        self.n_subspace_updates = r.int() as usize;
        self.n_refresh_rejections = r.int() as usize;
        let n_mats = r.int() as usize;
        self.mats.resize_with(n_mats, || None);
        for slot in &mut self.mats {
            if r.int() == 1 {
                match slot {
                    Some(st) => {
                        st.proj.unpack_into(&mut r);
                        st.moments.unpack_into(&mut r);
                    }
                    None => {
                        *slot = Some(MatState {
                            proj: Projector::unpack(&mut r),
                            moments: Moments::unpack(&mut r),
                        });
                    }
                }
            } else {
                *slot = None;
            }
        }
        super::unpack_moment_slots(&mut r, &mut self.vecs);
    }

    fn restore_ranges(&mut self, parts: &[(&OptimizerSnapshot, usize, usize)]) -> bool {
        self.mats.clear();
        self.vecs.clear();
        self.step_no = 0;
        self.n_subspace_updates = 0;
        self.n_refresh_rejections = 0;
        for &(snap, lo, hi) in parts {
            let mut r = snap.reader();
            self.step_no = self.step_no.max(r.int() as usize);
            self.n_subspace_updates = self.n_subspace_updates.max(r.int() as usize);
            self.n_refresh_rejections = self.n_refresh_rejections.max(r.int() as usize);
            let n_mats = r.int() as usize;
            assert!(hi <= n_mats, "galore restore_ranges: slot range {lo}..{hi} out of {n_mats}");
            for i in 0..n_mats {
                if r.int() == 1 {
                    let st = MatState {
                        proj: Projector::unpack(&mut r),
                        moments: Moments::unpack(&mut r),
                    };
                    if i >= lo && i < hi {
                        self.mats.push(Some(st));
                    }
                } else if i >= lo && i < hi {
                    self.mats.push(None);
                }
            }
            super::keep_moment_slot_range(&mut r, &mut self.vecs, lo, hi);
        }
        true
    }

    fn name(&self) -> String {
        "GaLore".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};

    #[test]
    fn converges_on_lstsq() {
        let prob = LstsqProblem::new(64, 10, 14, 50);
        let mut opt = GaLore::new(HyperParams {
            rank: 4,
            interval: 20,
            scale: 1.0,
            ..HyperParams::default()
        });
        let (init, fin) = run_lstsq(&mut opt, &prob, 500, 0.05);
        assert!(fin < init * 0.05, "init={init} final={fin}");
        assert!(opt.subspace_updates() > 0);
        assert!(opt.svd_seconds > 0.0);
    }

    #[test]
    fn state_params_match_table2() {
        let (m, n, r) = (10, 24, 4);
        let prob = LstsqProblem::new(8, m, n, 51);
        let mut opt =
            GaLore::new(HyperParams { rank: r, interval: 10, ..HyperParams::default() });
        let _ = run_lstsq(&mut opt, &prob, 2, 0.01);
        assert_eq!(opt.state_params(), m * r + 2 * n * r);
    }

    #[test]
    fn full_rank_projection_converges_like_adam() {
        // With r = min(m,n) the projector is a square orthonormal rotation:
        // GaLore becomes Adam in rotated coordinates. Adam is not rotation
        // invariant (element-wise second moments), so losses need not match
        // exactly — but both must converge to ≪1% of the initial loss.
        let prob = LstsqProblem::new(32, 6, 8, 52);
        let mut galore = GaLore::new(HyperParams {
            rank: 6,
            interval: 1_000_000,
            scale: 1.0,
            ..HyperParams::default()
        });
        let mut adam = super::super::Adam::new(AdamCfg::default());
        let (init, lg) = run_lstsq(&mut galore, &prob, 100, 0.05);
        let (_, la) = run_lstsq(&mut adam, &prob, 100, 0.05);
        assert!(lg < init * 0.01, "galore {lg} of init {init}");
        assert!(la < init * 0.01, "adam {la} of init {init}");
    }
}
