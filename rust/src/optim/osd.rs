//! Online Subspace Descent (Liang et al., 2024) — the projector evolves by an
//! online-PCA (Oja) gradient step on ‖(I − SSᵀ)G‖² at every iteration,
//! avoiding SVD entirely.
//!
//! Oja's rule: S ← orth(S + η_pca·(I − SSᵀ)·G·GᵀS). We fold the
//! normalization into a periodic QR pass (every `reorth_every` steps; the
//! WY-blocked `reorthonormalize_in_place`, whose trailing updates are GEMMs
//! at rank ≥ the panel width) plus a column-norm rescale each step, which
//! matches the reference description's cost profile while staying
//! numerically stable in fp32. Like the other
//! per-iteration refresher (LDAdam), the whole step runs out of the
//! optimizer-owned workspace: the Oja temporaries, the Gᵀ view, the QR
//! scratch, and the projection buffers are all leased.

use super::adam::{AdamCfg, Moments};
use super::projector::{self, Projector, Side};
use super::{HyperParams, Optimizer, OptimizerSnapshot, Param, ParamKind, SnapshotReader};
use crate::tensor::{gemm, qr, Matrix, Workspace};

struct MatState {
    proj: Projector,
    moments: Moments,
    steps: usize,
}

/// Online Subspace Descent optimizer.
pub struct OnlineSubspaceDescent {
    hp: HyperParams,
    adam: AdamCfg,
    mats: Vec<Option<MatState>>,
    vecs: Vec<Option<Moments>>,
    n_subspace_updates: usize,
    n_refresh_rejections: usize,
    poison_refresh: bool,
    /// Oja step size for the projector update.
    pub pca_lr: f32,
    /// Full QR re-orthonormalization cadence.
    pub reorth_every: usize,
    /// Per-step Oja + projection scratch (zero steady-state allocation).
    ws: Workspace,
}

impl OnlineSubspaceDescent {
    pub fn new(hp: HyperParams) -> OnlineSubspaceDescent {
        OnlineSubspaceDescent {
            hp,
            adam: AdamCfg::from(hp),
            mats: Vec::new(),
            vecs: Vec::new(),
            n_subspace_updates: 0,
            n_refresh_rejections: 0,
            poison_refresh: false,
            pca_lr: 0.1,
            reorth_every: 10,
            ws: Workspace::new(),
        }
    }

    fn ensure_slots(&mut self, n: usize) {
        if self.mats.len() != n {
            self.mats = (0..n).map(|_| None).collect();
            self.vecs = (0..n).map(|_| None).collect();
        }
    }
}

/// One Oja update of the basis given the oriented gradient (rows = subspace
/// dimension): S ← S + η·(I − SSᵀ)·G·(GᵀS), normalized. Allocating wrapper
/// around [`oja_step_ws`] for tests and one-off callers.
#[cfg(test)]
fn oja_step(s: &Matrix, g_oriented: &Matrix, pca_lr: f32) -> Matrix {
    let mut s_new = s.clone();
    oja_step_ws(&mut s_new, g_oriented, pca_lr, &mut Workspace::new());
    s_new
}

/// The Oja update in place, every temporary leased from `ws`.
fn oja_step_ws(s: &mut Matrix, g_oriented: &Matrix, pca_lr: f32, ws: &mut Workspace) {
    let (dim, r) = s.shape();
    let ncols = g_oriented.cols();
    let mut gts = ws.take_dirty(ncols, r);
    gemm::matmul_tn_into(&mut gts, g_oriented, s, ws); // n×r
    let mut ggts = ws.take_dirty(dim, r);
    gemm::matmul_into(&mut ggts, g_oriented, &gts); // m×r
    // Project out the existing span: ortho = (I − SSᵀ)·GGᵀS, in place.
    let mut st_ggts = ws.take_dirty(r, r);
    gemm::matmul_tn_into(&mut st_ggts, s, &ggts, ws); // r×r
    let mut within = ws.take_dirty(dim, r);
    gemm::matmul_into(&mut within, s, &st_ggts); // m×r
    ggts.zip_assign(&within, |a, b| a - b);
    // Normalize the step so η is scale-free w.r.t. the gradient magnitude.
    let norm = ggts.fro_norm();
    if norm > 1e-30 {
        s.axpy(pca_lr / norm, &ggts);
    }
    ws.give(within);
    ws.give(st_ggts);
    ws.give(ggts);
    ws.give(gts);
}

impl Optimizer for OnlineSubspaceDescent {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_slots(params.len());
        for i in 0..params.len() {
            let g = &grads[i];
            match params[i].kind {
                ParamKind::Matrix2D if g.rows() > 1 && g.cols() > 1 => {
                    let (m, n) = g.shape();
                    if self.mats[i].is_none() {
                        let proj = Projector::init_svd(g, self.hp.rank);
                        let (lm, ln) = proj.lowrank_shape(m, n);
                        self.mats[i] =
                            Some(MatState { proj, moments: Moments::new(lm, ln), steps: 0 });
                    }
                    let pca_lr = self.pca_lr;
                    let reorth = self.reorth_every;
                    let adam = self.adam;
                    let scale = self.hp.scale;
                    // Disjoint borrows: scratch pool vs per-matrix state.
                    let OnlineSubspaceDescent {
                        ws,
                        mats,
                        n_subspace_updates,
                        n_refresh_rejections,
                        poison_refresh,
                        ..
                    } = &mut *self;
                    let st = mats[i].as_mut().expect("initialized above");
                    // Online PCA projector update every step, in place. A
                    // workspace-leased copy of the old basis backs the health
                    // guard; between reorthonormalizations the basis drifts
                    // from orthonormal by design, so the guard here checks
                    // finiteness only (a NaN gradient would otherwise poison
                    // the basis permanently).
                    let (sr, sc) = st.proj.s.shape();
                    let mut old_s = ws.take_dirty(sr, sc);
                    old_s.copy_from(&st.proj.s);
                    match st.proj.side {
                        Side::Left => oja_step_ws(&mut st.proj.s, g, pca_lr, ws),
                        Side::Right => {
                            let mut gt = ws.take_dirty(n, m);
                            g.transpose_into(&mut gt);
                            oja_step_ws(&mut st.proj.s, &gt, pca_lr, ws);
                            ws.give(gt);
                        }
                    }
                    st.steps += 1;
                    if st.steps % reorth == 0 {
                        qr::reorthonormalize_in_place(&mut st.proj.s, ws);
                    }
                    if std::mem::take(poison_refresh) {
                        projector::poison_basis(&mut st.proj.s);
                    }
                    if st.proj.s.data().iter().all(|x| x.is_finite()) {
                        *n_subspace_updates += 1;
                    } else {
                        st.proj.s.copy_from(&old_s);
                        *n_refresh_rejections += 1;
                    }
                    ws.give(old_s);

                    let (lm, ln) = st.proj.lowrank_shape(m, n);
                    let mut g_low = ws.take_dirty(lm, ln);
                    st.proj.project_into(g, &mut g_low, ws);
                    let mut dir = ws.take_dirty(lm, ln);
                    st.moments.update_into(&adam, &g_low, &mut dir);
                    let mut delta = ws.take_dirty(m, n);
                    st.proj.project_back_into(&dir, &mut delta, ws);
                    params[i].axpy_update(-lr * scale, &delta);
                    ws.give(delta);
                    ws.give(dir);
                    ws.give(g_low);
                }
                _ => {
                    if self.vecs[i].is_none() {
                        self.vecs[i] = Some(Moments::new(g.rows(), g.cols()));
                    }
                    let adam = self.adam;
                    let st = self.vecs[i].as_mut().unwrap();
                    st.fused_step(&adam, lr, 0.0, &mut params[i].value, g);
                    params[i].mark_dirty();
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.bytes() + s.proj.bytes()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.bytes()).sum();
        mats + vecs
    }

    fn state_params(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.params() + s.proj.params()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.params()).sum();
        mats + vecs
    }

    fn subspace_updates(&self) -> usize {
        self.n_subspace_updates
    }

    fn workspace_misses(&self) -> usize {
        self.ws.misses()
    }

    fn projector_defect(&self) -> Option<f32> {
        Some(self.mats.iter().flatten().map(|s| s.proj.defect()).fold(0.0f32, f32::max))
    }

    fn poison_next_refresh(&mut self) {
        self.poison_refresh = true;
    }

    fn refresh_rejections(&self) -> usize {
        self.n_refresh_rejections
    }

    // Pack order: n_subspace_updates, n_refresh_rejections, matrix slots
    // (presence + projector + moments + steps), vector moment slots.
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = OptimizerSnapshot::new();
        snap.push_int(self.n_subspace_updates as u64);
        snap.push_int(self.n_refresh_rejections as u64);
        snap.push_int(self.mats.len() as u64);
        for slot in &self.mats {
            match slot {
                Some(st) => {
                    snap.push_int(1);
                    st.proj.pack(&mut snap);
                    st.moments.pack(&mut snap);
                    snap.push_int(st.steps as u64);
                }
                None => snap.push_int(0),
            }
        }
        super::pack_moment_slots(&mut snap, &self.vecs);
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let mut r = snap.reader();
        self.n_subspace_updates = r.int() as usize;
        self.n_refresh_rejections = r.int() as usize;
        let n_mats = r.int() as usize;
        self.mats.resize_with(n_mats, || None);
        for slot in &mut self.mats {
            if r.int() == 1 {
                match slot {
                    Some(st) => {
                        st.proj.unpack_into(&mut r);
                        st.moments.unpack_into(&mut r);
                        st.steps = r.int() as usize;
                    }
                    None => {
                        *slot = Some(MatState {
                            proj: Projector::unpack(&mut r),
                            moments: Moments::unpack(&mut r),
                            steps: r.int() as usize,
                        });
                    }
                }
            } else {
                *slot = None;
            }
        }
        super::unpack_moment_slots(&mut r, &mut self.vecs);
    }

    fn restore_ranges(&mut self, parts: &[(&OptimizerSnapshot, usize, usize)]) -> bool {
        self.mats.clear();
        self.vecs.clear();
        self.n_subspace_updates = 0;
        self.n_refresh_rejections = 0;
        for &(snap, lo, hi) in parts {
            let mut r = snap.reader();
            self.n_subspace_updates = self.n_subspace_updates.max(r.int() as usize);
            self.n_refresh_rejections = self.n_refresh_rejections.max(r.int() as usize);
            let n_mats = r.int() as usize;
            assert!(hi <= n_mats, "osd restore_ranges: slot range {lo}..{hi} out of {n_mats}");
            for i in 0..n_mats {
                if r.int() == 1 {
                    let st = MatState {
                        proj: Projector::unpack(&mut r),
                        moments: Moments::unpack(&mut r),
                        steps: r.int() as usize,
                    };
                    if i >= lo && i < hi {
                        self.mats.push(Some(st));
                    }
                } else if i >= lo && i < hi {
                    self.mats.push(None);
                }
            }
            super::keep_moment_slot_range(&mut r, &mut self.vecs, lo, hi);
        }
        true
    }

    fn name(&self) -> String {
        "Online Subspace Descent".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};
    use crate::tensor::qr::orthonormality_defect;

    #[test]
    fn converges_on_lstsq() {
        let prob = LstsqProblem::new(64, 10, 14, 80);
        let mut opt = OnlineSubspaceDescent::new(HyperParams {
            rank: 4,
            scale: 1.0,
            ..HyperParams::default()
        });
        let (init, fin) = run_lstsq(&mut opt, &prob, 500, 0.05);
        assert!(fin < init * 0.1, "init={init} final={fin}");
    }

    #[test]
    fn basis_stays_near_orthonormal() {
        let prob = LstsqProblem::new(32, 12, 16, 81);
        let mut opt = OnlineSubspaceDescent::new(HyperParams {
            rank: 3,
            scale: 1.0,
            ..HyperParams::default()
        });
        let _ = run_lstsq(&mut opt, &prob, 100, 0.05);
        for st in opt.mats.iter().flatten() {
            let defect = orthonormality_defect(&st.proj.s);
            assert!(defect < 0.05, "defect {defect}");
        }
    }

    #[test]
    fn oja_step_tracks_dominant_direction() {
        // Feeding a fixed rank-1 gradient repeatedly must rotate S toward it.
        let mut rng = crate::util::rng::Rng::new(82);
        let mut u = vec![0.0f32; 12];
        rng.fill_normal(&mut u, 1.0);
        let un = (u.iter().map(|x| x * x).sum::<f32>()).sqrt();
        u.iter_mut().for_each(|x| *x /= un);
        let mut g = Matrix::zeros(12, 8);
        for i in 0..12 {
            for j in 0..8 {
                g.set(i, j, u[i] * (j as f32 + 1.0));
            }
        }
        let base = Matrix::randn(12, 2, 1.0, &mut rng);
        let (mut s, _) = qr::thin_qr(&base);
        for t in 0..300 {
            s = oja_step(&s, &g, 0.05);
            if t % 10 == 0 {
                s = qr::reorthonormalize(&s);
            }
        }
        // u should lie (mostly) in span(S).
        let su = gemm::matvec_t(&s, &u);
        let captured: f32 = su.iter().map(|x| x * x).sum();
        assert!(captured > 0.95, "captured {captured}");
    }
}
