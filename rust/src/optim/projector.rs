//! Shared low-rank projection machinery.
//!
//! For a gradient matrix G ∈ ℝ^{m×n} the paper (following GaLore) projects on
//! the *shorter* side: if m ≤ n the subspace basis is S ∈ ℝ^{m×r} over the
//! left singular directions and the low-rank gradient is G̃ = SᵀG ∈ ℝ^{r×n};
//! otherwise S ∈ ℝ^{n×r} over right singular directions and G̃ = G·S ∈ ℝ^{m×r}.
//! This keeps the moment tensors at min(m,n-side) cost: mr + 2nr total
//! optimizer state per matrix (Table 2).

use super::adam::Moments;
use super::{OptimizerSnapshot, SnapshotReader};
use crate::tensor::{gemm, qr, svd, Matrix, Workspace};
use crate::util::rng::Rng;

/// Which side of the gradient the subspace basis multiplies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// m ≤ n: S is m×r, G̃ = SᵀG (r×n).
    Left,
    /// m > n: S is n×r, G̃ = G·S (m×r).
    Right,
}

/// Pick the projection side for an m×n gradient.
pub fn side_for(m: usize, n: usize) -> Side {
    if m <= n {
        Side::Left
    } else {
        Side::Right
    }
}

impl Side {
    /// Stable integer encoding for snapshots.
    pub fn to_u64(self) -> u64 {
        match self {
            Side::Left => 0,
            Side::Right => 1,
        }
    }

    /// Inverse of [`Side::to_u64`]. Panics on unknown encodings.
    pub fn from_u64(v: u64) -> Side {
        match v {
            0 => Side::Left,
            1 => Side::Right,
            other => panic!("invalid Side encoding: {other}"),
        }
    }
}

/// Maximum orthonormality defect a refreshed basis may carry before the
/// refresh guard rejects it and keeps the previous projector (the sentinel
/// tentpole's "refresh fallback"). Healthy QR/SVD refreshes sit around
/// 1e-5; a defect past this bound means the factorization degenerated.
pub const REFRESH_DEFECT_TOL: f32 = 1e-2;

/// Whether a candidate orthonormal basis is safe to adopt: every entry
/// finite and ‖SᵀS − I‖_max within `tol`.
pub fn basis_acceptable(s: &Matrix, tol: f32) -> bool {
    if !s.data().iter().all(|x| x.is_finite()) {
        return false;
    }
    qr::orthonormality_defect(s) <= tol
}

/// Fault injection: overwrite a basis with NaNs so the refresh guard's
/// rejection path can be exercised deterministically.
pub fn poison_basis(s: &mut Matrix) {
    for x in s.data_mut() {
        *x = f32::NAN;
    }
}

/// An orthonormal (or random, for APOLLO) rank-r subspace basis for one
/// parameter matrix.
#[derive(Clone, Debug)]
pub struct Projector {
    pub s: Matrix,
    pub side: Side,
}

impl Projector {
    /// Initialize from the rank-r truncated SVD of `g` (GaLore / SubTrack++
    /// initialization, Eq. (1)).
    pub fn init_svd(g: &Matrix, rank: usize) -> Projector {
        let (m, n) = g.shape();
        let side = side_for(m, n);
        let t = svd::truncated_svd(g, rank.min(m.min(n)));
        let s = match side {
            Side::Left => t.u,  // m×r — left singular vectors
            Side::Right => t.v, // n×r — right singular vectors
        };
        Projector { s, side }
    }

    /// Initialize with a seeded Gaussian matrix scaled by 1/√r (APOLLO-style
    /// random projection; *not* orthonormal).
    pub fn init_random(m: usize, n: usize, rank: usize, rng: &mut Rng) -> Projector {
        let side = side_for(m, n);
        let dim = match side {
            Side::Left => m,
            Side::Right => n,
        };
        let r = rank.min(m.min(n));
        let s = Matrix::randn(dim, r, 1.0 / (r as f32).sqrt(), rng);
        Projector { s, side }
    }

    /// Initialize with a random *orthonormal* basis (GoLore's late-phase
    /// projector — unbiased directions, valid for projection-back).
    pub fn init_random_orthonormal(m: usize, n: usize, rank: usize, rng: &mut Rng) -> Projector {
        let side = side_for(m, n);
        let dim = match side {
            Side::Left => m,
            Side::Right => n,
        };
        let r = rank.min(m.min(n));
        let raw = Matrix::randn(dim, r, 1.0, rng);
        let (q, _) = crate::tensor::qr::thin_qr(&raw);
        Projector { s: q, side }
    }

    /// Refresh the basis from the rank-r truncated SVD of `g`, **in place**:
    /// the new singular vectors land directly in the existing basis buffer
    /// and all SVD scratch is leased from `ws`. Bit-identical to replacing
    /// the projector with [`Projector::init_svd`] of the same gradient.
    pub fn refresh_svd_into(&mut self, g: &Matrix, ws: &mut Workspace) {
        svd::truncated_basis_into(g, self.side == Side::Right, &mut self.s, ws);
    }

    /// Refresh with a fresh random orthonormal basis, in place (GoLore's
    /// late-phase refresh); QR scratch leased from `ws`, and the
    /// orthonormalization runs through the WY-blocked `thin_qr_into`.
    /// Bit-identical to [`Projector::init_random_orthonormal`] at the same
    /// RNG state (both route through the same kernel at the same block size).
    pub fn refresh_random_orthonormal_into(&mut self, rng: &mut Rng, ws: &mut Workspace) {
        let (dim, r) = self.s.shape();
        let mut raw = ws.take_dirty(dim, r);
        rng.fill_normal(raw.data_mut(), 1.0);
        let mut rr = ws.take_dirty(r, r);
        qr::thin_qr_into(&raw, &mut self.s, &mut rr, ws);
        ws.give(rr);
        ws.give(raw);
    }

    /// Refresh with a fresh Gaussian sketch scaled by 1/√r, in place
    /// (APOLLO's projector re-draw; *not* orthonormal). Bit-identical to
    /// [`Projector::init_random`] at the same RNG state.
    pub fn refresh_random_into(&mut self, rng: &mut Rng) {
        let r = self.s.cols();
        rng.fill_normal(self.s.data_mut(), 1.0 / (r as f32).sqrt());
    }

    /// Orthonormality defect ‖SᵀS − I‖_max of the current basis
    /// (diagnostic; see `Optimizer::projector_defect`).
    pub fn defect(&self) -> f32 {
        qr::orthonormality_defect(&self.s)
    }

    /// Rank of the subspace.
    pub fn rank(&self) -> usize {
        self.s.cols()
    }

    /// G̃: project the full gradient into the subspace.
    pub fn project(&self, g: &Matrix) -> Matrix {
        match self.side {
            Side::Left => gemm::matmul_tn(&self.s, g), // (m×r)ᵀ·(m×n) = r×n
            Side::Right => gemm::matmul(g, &self.s),   // (m×n)·(n×r) = m×r
        }
    }

    /// Allocation-free [`project`]: writes G̃ into `out` (shape
    /// [`lowrank_shape`]), leasing transpose scratch from `ws`.
    ///
    /// [`project`]: Projector::project
    /// [`lowrank_shape`]: Projector::lowrank_shape
    pub fn project_into(&self, g: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        match self.side {
            Side::Left => gemm::matmul_tn_into(out, &self.s, g, ws),
            Side::Right => gemm::matmul_into(out, g, &self.s),
        }
    }

    /// Ĝ: map a low-rank update back to full size.
    pub fn project_back(&self, lowrank: &Matrix) -> Matrix {
        match self.side {
            Side::Left => gemm::matmul(&self.s, lowrank), // (m×r)·(r×n) = m×n
            Side::Right => gemm::matmul_nt(lowrank, &self.s), // (m×r)·(n×r)ᵀ = m×n
        }
    }

    /// Allocation-free [`project_back`]: writes Ĝ into the full-size `out`.
    ///
    /// [`project_back`]: Projector::project_back
    pub fn project_back_into(&self, lowrank: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        match self.side {
            Side::Left => gemm::matmul_into(out, &self.s, lowrank),
            Side::Right => gemm::matmul_nt_into(out, lowrank, &self.s, ws),
        }
    }

    /// The low-rank shape for an m×n gradient under this projector.
    pub fn lowrank_shape(&self, m: usize, n: usize) -> (usize, usize) {
        match self.side {
            Side::Left => (self.rank(), n),
            Side::Right => (m, self.rank()),
        }
    }

    /// Change-of-basis matrix Q = SₜᵀSₜ₋₁ (r×r) between this basis and a
    /// previous one; the projection-aware moment rotation of Eqs. (8)–(9).
    pub fn change_of_basis(&self, prev: &Projector) -> Matrix {
        gemm::matmul_tn(&self.s, &prev.s)
    }

    /// Number of f32 entries in the basis (mr or nr — Table 2 accounting).
    pub fn params(&self) -> usize {
        self.s.len()
    }

    pub fn bytes(&self) -> usize {
        self.params() * std::mem::size_of::<f32>()
    }

    /// Pack `side` + basis into a snapshot (see `Optimizer::snapshot`).
    pub fn pack(&self, snap: &mut OptimizerSnapshot) {
        snap.push_int(self.side.to_u64());
        snap.push_mat(&self.s);
    }

    /// Rebuild a projector from the stream produced by [`Projector::pack`].
    pub fn unpack(r: &mut SnapshotReader) -> Projector {
        let side = Side::from_u64(r.int());
        Projector { s: r.mat(), side }
    }

    /// In-place [`Projector::unpack`] (no allocation when shapes match).
    pub fn unpack_into(&mut self, r: &mut SnapshotReader) {
        self.side = Side::from_u64(r.int());
        r.mat_into(&mut self.s);
    }
}

/// Rotate first moment M ← Q·M (Left) or M·Qᵀ (Right) — Eq. (8)'s
/// SₜᵀSₜ₋₁·Mₜ₋₁ generalized to both sides.
pub fn rotate_first_moment(q: &Matrix, m: &Matrix, side: Side) -> Matrix {
    match side {
        Side::Left => gemm::matmul(q, m),
        Side::Right => gemm::matmul_nt(m, q),
    }
}

/// Allocation-free [`rotate_first_moment`]: writes into `out`, leasing
/// transpose scratch from `ws`.
pub fn rotate_first_moment_into(
    q: &Matrix,
    m: &Matrix,
    side: Side,
    out: &mut Matrix,
    ws: &mut Workspace,
) {
    match side {
        Side::Left => gemm::matmul_into(out, q, m),
        Side::Right => gemm::matmul_nt_into(out, m, q, ws),
    }
}

/// Projection-aware rotation of a full [`Moments`] pair, in place — the
/// Eqs. (8)–(9) update every refresh step applies, with all temporaries
/// leased from `ws` (the allocation-free periodic-path form of
/// [`rotate_first_moment`] + [`rotate_second_moment`]; element-for-element
/// identical arithmetic).
pub fn rotate_moments_into(
    q: &Matrix,
    moments: &mut Moments,
    side: Side,
    beta2: f32,
    ws: &mut Workspace,
) {
    let (mr, mc) = moments.m.shape();
    let mut rot_m = ws.take_dirty(mr, mc);
    rotate_first_moment_into(q, &moments.m, side, &mut rot_m, ws);
    // V′ = (1−β₂^{t−1}) · | Q∘² (V − M∘²) + (Q M)∘² |  (Eq. 9)
    let (qr_, qc) = q.shape();
    let mut q2 = ws.take_dirty(qr_, qc);
    q.zip_into(q, &mut q2, |a, _| a * a);
    let mut var = ws.take_dirty(mr, mc);
    moments.v.zip_into(&moments.m, &mut var, |v, m| (v - m * m).max(0.0));
    let mut rot_var = ws.take_dirty(mr, mc);
    rotate_first_moment_into(&q2, &var, side, &mut rot_var, ws);
    let debias = 1.0 - beta2.powi(moments.t.max(1) as i32 - 1);
    rot_var.zip_into(&rot_m, &mut moments.v, |a, b| (debias * (a + b * b)).abs());
    moments.m.copy_from(&rot_m);
    ws.give(rot_var);
    ws.give(var);
    ws.give(q2);
    ws.give(rot_m);
}

/// Projection-aware second-moment rotation — Eq. (9):
/// V′ = (1−β₂^{t−1}) · | Q∘² (V − M∘²) + (Q M)∘² |
/// where ∘ denotes element-wise operations. Negative variance estimates are
/// clipped at zero (Appendix C). The caller folds in β₂ and the new gradient.
pub fn rotate_second_moment(
    q: &Matrix,
    m: &Matrix,
    v: &Matrix,
    side: Side,
    beta2: f32,
    t: usize,
) -> Matrix {
    let q2 = q.map(|x| x * x);
    let var = v.zip(m, |v, m| (v - m * m).max(0.0));
    let rot_var = rotate_first_moment(&q2, &var, side);
    let rot_m = rotate_first_moment(q, m, side);
    let rot_m2 = rot_m.map(|x| x * x);
    let debias = 1.0 - beta2.powi(t.max(1) as i32 - 1);
    rot_var.zip(&rot_m2, |a, b| (debias * (a + b)).abs())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::qr::orthonormality_defect;
    use crate::util::proptest;

    #[test]
    fn side_selection() {
        assert_eq!(side_for(4, 8), Side::Left);
        assert_eq!(side_for(8, 4), Side::Right);
        assert_eq!(side_for(4, 4), Side::Left);
    }

    #[test]
    fn svd_init_orthonormal_both_sides() {
        let mut rng = Rng::new(31);
        for (m, n) in [(10, 30), (30, 10)] {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            let p = Projector::init_svd(&g, 4);
            assert_eq!(p.rank(), 4);
            assert!(orthonormality_defect(&p.s) < 1e-4);
            let lr = p.project(&g);
            assert_eq!(lr.shape(), p.lowrank_shape(m, n));
            let back = p.project_back(&lr);
            assert_eq!(back.shape(), (m, n));
        }
    }

    #[test]
    fn projection_captures_low_rank_gradient() {
        // If G is exactly rank 3 and we project with rank 3, the round trip
        // is lossless.
        let mut rng = Rng::new(32);
        let u = Matrix::randn(12, 3, 1.0, &mut rng);
        let v = Matrix::randn(20, 3, 1.0, &mut rng);
        let g = gemm::matmul_nt(&u, &v);
        let p = Projector::init_svd(&g, 3);
        let back = p.project_back(&p.project(&g));
        proptest::close(back.data(), g.data(), 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn projection_is_contraction() {
        proptest::check(
            33,
            30,
            |rng| {
                let (m, n) = proptest::shape(rng, 20, 20);
                let r = 1 + rng.below(m.min(n));
                (Matrix::randn(m, n, 1.0, rng), r)
            },
            |(g, r)| {
                let p = Projector::init_svd(g, *r);
                let back = p.project_back(&p.project(g));
                // ‖P(G)‖ ≤ ‖G‖ for an orthonormal projector.
                if back.fro_norm() > g.fro_norm() * (1.0 + 1e-4) + 1e-5 {
                    return Err(format!(
                        "projection expanded: {} > {}",
                        back.fro_norm(),
                        g.fro_norm()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn into_variants_match_allocating_paths() {
        let mut rng = Rng::new(39);
        let mut ws = Workspace::new();
        for (m, n) in [(10, 30), (30, 10)] {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            let p = Projector::init_svd(&g, 4);
            let low = p.project(&g);
            let (lm, ln) = p.lowrank_shape(m, n);
            let mut low2 = ws.take(lm, ln);
            p.project_into(&g, &mut low2, &mut ws);
            assert_eq!(low.data(), low2.data(), "project_into diverged ({m}x{n})");
            let back = p.project_back(&low);
            let mut back2 = ws.take(m, n);
            p.project_back_into(&low2, &mut back2, &mut ws);
            assert_eq!(back.data(), back2.data(), "project_back_into diverged ({m}x{n})");
            ws.give(low2);
            ws.give(back2);
        }
    }

    #[test]
    fn change_of_basis_identity_when_same() {
        let mut rng = Rng::new(34);
        let g = Matrix::randn(8, 16, 1.0, &mut rng);
        let p = Projector::init_svd(&g, 5);
        let q = p.change_of_basis(&p);
        let defect = q.sub(&Matrix::eye(5)).max_abs();
        assert!(defect < 1e-4, "SᵀS should be I, defect {defect}");
    }

    #[test]
    fn moment_rotation_preserves_under_identity() {
        let mut rng = Rng::new(35);
        let m = Matrix::randn(5, 9, 1.0, &mut rng);
        let v = m.map(|x| x * x + 0.5);
        let q = Matrix::eye(5);
        let rm = rotate_first_moment(&q, &m, Side::Left);
        proptest::close(rm.data(), m.data(), 1e-6, 1e-6).unwrap();
        // t=1 ⇒ debias factor (1-β₂⁰)=0 ⇒ V′=0; t→∞ ⇒ factor→1.
        let rv = rotate_second_moment(&q, &m, &v, Side::Left, 0.999, 100_000);
        proptest::close(rv.data(), v.data(), 1e-2, 1e-2).unwrap();
    }

    #[test]
    fn second_moment_rotation_nonnegative() {
        proptest::check(
            36,
            25,
            |rng| {
                let r = 1 + rng.below(6);
                let n = 1 + rng.below(10);
                let q = Matrix::randn(r, r, 1.0, rng);
                let m = Matrix::randn(r, n, 1.0, rng);
                let v = Matrix::randn(r, n, 0.5, rng).map(|x| x.abs());
                (q, m, v)
            },
            |(q, m, v)| {
                let rv = rotate_second_moment(q, m, v, Side::Left, 0.999, 10);
                if rv.data().iter().any(|&x| x < 0.0) {
                    return Err("negative variance after rotation".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn random_projector_shapes() {
        let mut rng = Rng::new(37);
        let p = Projector::init_random(6, 20, 4, &mut rng);
        assert_eq!(p.side, Side::Left);
        assert_eq!(p.s.shape(), (6, 4));
        let po = Projector::init_random_orthonormal(20, 6, 4, &mut rng);
        assert_eq!(po.side, Side::Right);
        assert!(orthonormality_defect(&po.s) < 1e-4);
    }

    #[test]
    fn refresh_svd_into_matches_init_svd() {
        let mut rng = Rng::new(40);
        let mut ws = Workspace::new();
        for (m, n) in [(10, 30), (30, 10)] {
            let g0 = Matrix::randn(m, n, 1.0, &mut rng);
            let g1 = Matrix::randn(m, n, 1.0, &mut rng);
            let mut p = Projector::init_svd(&g0, 4);
            p.refresh_svd_into(&g1, &mut ws);
            let fresh = Projector::init_svd(&g1, 4);
            assert_eq!(p.s.data(), fresh.s.data(), "refresh diverged ({m}x{n})");
            // Second refresh with the same shapes: no new allocations.
            let misses = ws.misses();
            p.refresh_svd_into(&g0, &mut ws);
            assert_eq!(ws.misses(), misses, "steady-state refresh allocated");
        }
    }

    #[test]
    fn refresh_random_orthonormal_matches_init() {
        let mut ws = Workspace::new();
        let mut rng_a = Rng::new(41);
        let mut rng_b = Rng::new(41);
        let g = Matrix::randn(12, 20, 1.0, &mut Rng::new(1));
        let mut p = Projector::init_svd(&g, 3);
        p.refresh_random_orthonormal_into(&mut rng_a, &mut ws);
        let fresh = Projector::init_random_orthonormal(12, 20, 3, &mut rng_b);
        assert_eq!(p.s.data(), fresh.s.data());
        assert!(p.defect() < 1e-4);
    }

    #[test]
    fn rotate_moments_into_matches_allocating_rotation() {
        let mut rng = Rng::new(42);
        let mut ws = Workspace::new();
        for side in [Side::Left, Side::Right] {
            let r = 4;
            let (rows, cols) = match side {
                Side::Left => (r, 9),
                Side::Right => (9, r),
            };
            let q = Matrix::randn(r, r, 1.0, &mut rng);
            let mut moments = Moments::new(rows, cols);
            moments.m = Matrix::randn(rows, cols, 1.0, &mut rng);
            moments.v = Matrix::randn(rows, cols, 0.5, &mut rng).map(|x| x.abs());
            moments.t = 7;
            let want_m = rotate_first_moment(&q, &moments.m, side);
            let want_v =
                rotate_second_moment(&q, &moments.m, &moments.v, side, 0.999, moments.t);
            rotate_moments_into(&q, &mut moments, side, 0.999, &mut ws);
            assert_eq!(moments.m.data(), want_m.data(), "{side:?} first moment");
            assert_eq!(moments.v.data(), want_v.data(), "{side:?} second moment");
        }
    }

    #[test]
    fn rank_clamped_to_min_dim() {
        let mut rng = Rng::new(38);
        let g = Matrix::randn(3, 10, 1.0, &mut rng);
        let p = Projector::init_svd(&g, 8);
        assert_eq!(p.rank(), 3);
    }
}
