//! Fira (Chen et al., 2025) — GaLore's SVD projector plus norm-based
//! recovery scaling of the discarded gradient component with a
//! gradient-clipping-like limiter.
//!
//! Fira observed that adaptive optimizers scale consistently between the
//! low-rank and full-rank regimes, so the column-wise ratio
//! φᵢ = ‖G̃ᴼ₍:,ᵢ₎‖/‖G̃₍:,ᵢ₎‖ learned in the subspace can rescale the residual
//! (I − SSᵀ)G. SubTrack++ adopts exactly this recovery term (Eqs. 10–12) but
//! replaces the SVD subspace refresh with Grassmannian tracking.

use super::adam::{AdamCfg, Moments};
use super::projector::{Projector, Side};
use super::{HyperParams, Optimizer, Param, ParamKind};
use crate::tensor::Matrix;

struct MatState {
    proj: Projector,
    moments: Moments,
    prev_lambda_norm: f32,
}

/// Fira optimizer.
pub struct Fira {
    hp: HyperParams,
    adam: AdamCfg,
    mats: Vec<Option<MatState>>,
    vecs: Vec<Option<Moments>>,
    step_no: usize,
    n_subspace_updates: usize,
    /// Accumulated SVD refresh wall-time (seconds).
    pub svd_seconds: f64,
}

impl Fira {
    pub fn new(hp: HyperParams) -> Fira {
        Fira {
            hp,
            adam: AdamCfg::from(hp),
            mats: Vec::new(),
            vecs: Vec::new(),
            step_no: 0,
            n_subspace_updates: 0,
            svd_seconds: 0.0,
        }
    }

    fn ensure_slots(&mut self, n: usize) {
        if self.mats.len() != n {
            self.mats = (0..n).map(|_| None).collect();
            self.vecs = (0..n).map(|_| None).collect();
        }
    }
}

/// Column/row-wise φ scaling of the residual — shared with SubTrack++'s
/// recovery component (see `subtrack::scale_residual`; duplicated here in the
/// baseline's own terms to keep the two methods independently auditable).
fn fira_scale_residual(dir: &Matrix, g_low: &Matrix, resid: &Matrix, side: Side) -> Matrix {
    match side {
        Side::Left => {
            let num = dir.col_norms();
            let den = g_low.col_norms();
            let mut out = resid.clone();
            for i in 0..out.rows() {
                for (j, v) in out.row_mut(i).iter_mut().enumerate() {
                    let phi = if den[j] > 1e-30 { num[j] / den[j] } else { 0.0 };
                    *v *= phi;
                }
            }
            out
        }
        Side::Right => {
            let mut out = resid.clone();
            for i in 0..out.rows() {
                let num = (dir.row(i).iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt();
                let den =
                    (g_low.row(i).iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt();
                let phi = if den > 1e-30 { (num / den) as f32 } else { 0.0 };
                for v in out.row_mut(i) {
                    *v *= phi;
                }
            }
            out
        }
    }
}

impl Optimizer for Fira {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_slots(params.len());
        let refresh = self.hp.interval > 0 && self.step_no % self.hp.interval == 0;
        for i in 0..params.len() {
            let g = &grads[i];
            match params[i].kind {
                ParamKind::Matrix2D if g.rows() > 1 && g.cols() > 1 => {
                    let (m, n) = g.shape();
                    let needs_init = self.mats[i].is_none();
                    if needs_init || refresh {
                        let t0 = std::time::Instant::now();
                        let proj = Projector::init_svd(g, self.hp.rank);
                        self.svd_seconds += t0.elapsed().as_secs_f64();
                        if needs_init {
                            let (lm, ln) = proj.lowrank_shape(m, n);
                            self.mats[i] = Some(MatState {
                                proj,
                                moments: Moments::new(lm, ln),
                                prev_lambda_norm: 0.0,
                            });
                        } else {
                            self.mats[i].as_mut().unwrap().proj = proj;
                            self.n_subspace_updates += 1;
                        }
                    }
                    let zeta = self.hp.zeta;
                    let st = self.mats[i].as_mut().unwrap();
                    let g_low = st.proj.project(g);
                    let dir = st.moments.update(&self.adam, &g_low);
                    let mut delta = st.proj.project_back(&dir);
                    // Recovery scaling + limiter.
                    let resid = g.sub(&st.proj.project_back(&g_low));
                    let mut lambda = fira_scale_residual(&dir, &g_low, &resid, st.proj.side);
                    let lnorm = lambda.fro_norm();
                    if st.prev_lambda_norm > 0.0 && lnorm > zeta * st.prev_lambda_norm {
                        let target = zeta * st.prev_lambda_norm;
                        lambda.scale_mut(target / lnorm);
                        st.prev_lambda_norm = target;
                    } else {
                        st.prev_lambda_norm = lnorm;
                    }
                    delta.axpy(1.0, &lambda);
                    params[i].value.axpy(-lr * self.hp.scale, &delta);
                }
                _ => {
                    if self.vecs[i].is_none() {
                        self.vecs[i] = Some(Moments::new(g.rows(), g.cols()));
                    }
                    let st = self.vecs[i].as_mut().unwrap();
                    let dir = st.update(&self.adam, g);
                    params[i].value.axpy(-lr, &dir);
                }
            }
        }
        self.step_no += 1;
    }

    fn state_bytes(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.bytes() + s.proj.bytes()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.bytes()).sum();
        mats + vecs
    }

    fn state_params(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.params() + s.proj.params()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.params()).sum();
        mats + vecs
    }

    fn subspace_updates(&self) -> usize {
        self.n_subspace_updates
    }

    fn name(&self) -> String {
        "Fira".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};

    #[test]
    fn converges_on_lstsq() {
        let prob = LstsqProblem::new(64, 10, 14, 60);
        let mut opt = Fira::new(HyperParams {
            rank: 4,
            interval: 20,
            scale: 1.0,
            ..HyperParams::default()
        });
        let (init, fin) = run_lstsq(&mut opt, &prob, 500, 0.05);
        assert!(fin < init * 0.05, "init={init} final={fin}");
    }

    #[test]
    fn recovery_beats_galore_when_rank_too_small() {
        // With rank 1 on an intrinsically higher-rank problem, the recovery
        // term should help Fira converge faster than GaLore.
        let prob = LstsqProblem::new(64, 10, 14, 61);
        let hp = HyperParams { rank: 1, interval: 25, scale: 1.0, ..HyperParams::default() };
        let mut fira = Fira::new(hp);
        let mut galore = super::super::GaLore::new(hp);
        let (_, lf) = run_lstsq(&mut fira, &prob, 300, 0.05);
        let (_, lg) = run_lstsq(&mut galore, &prob, 300, 0.05);
        assert!(lf < lg, "fira {lf} should beat galore {lg} at rank 1");
    }

    #[test]
    fn state_params_match_table2() {
        let (m, n, r) = (10, 24, 4);
        let prob = LstsqProblem::new(8, m, n, 62);
        let mut opt = Fira::new(HyperParams { rank: r, interval: 10, ..HyperParams::default() });
        let _ = run_lstsq(&mut opt, &prob, 2, 0.01);
        assert_eq!(opt.state_params(), m * r + 2 * n * r);
    }
}
