//! Fira (Chen et al., 2025) — GaLore's SVD projector plus norm-based
//! recovery scaling of the discarded gradient component with a
//! gradient-clipping-like limiter.
//!
//! Fira observed that adaptive optimizers scale consistently between the
//! low-rank and full-rank regimes, so the column-wise ratio
//! φᵢ = ‖G̃ᴼ₍:,ᵢ₎‖/‖G̃₍:,ᵢ₎‖ learned in the subspace can rescale the residual
//! (I − SSᵀ)G. SubTrack++ adopts exactly this recovery term (Eqs. 10–12) but
//! replaces the SVD subspace refresh with Grassmannian tracking.

use super::adam::{AdamCfg, Moments};
use super::projector::{self, Projector, Side};
use super::{HyperParams, Optimizer, OptimizerSnapshot, Param, ParamKind, SnapshotReader};
use crate::tensor::{Matrix, Workspace};

struct MatState {
    proj: Projector,
    moments: Moments,
    prev_lambda_norm: f32,
}

/// Fira optimizer.
pub struct Fira {
    hp: HyperParams,
    adam: AdamCfg,
    mats: Vec<Option<MatState>>,
    vecs: Vec<Option<Moments>>,
    step_no: usize,
    n_subspace_updates: usize,
    n_refresh_rejections: usize,
    poison_refresh: bool,
    /// Accumulated SVD refresh wall-time (seconds).
    pub svd_seconds: f64,
    /// Per-step projection/recovery scratch (zero steady-state allocation).
    ws: Workspace,
}

impl Fira {
    pub fn new(hp: HyperParams) -> Fira {
        Fira {
            hp,
            adam: AdamCfg::from(hp),
            mats: Vec::new(),
            vecs: Vec::new(),
            step_no: 0,
            n_subspace_updates: 0,
            n_refresh_rejections: 0,
            poison_refresh: false,
            svd_seconds: 0.0,
            ws: Workspace::new(),
        }
    }

    fn ensure_slots(&mut self, n: usize) {
        if self.mats.len() != n {
            self.mats = (0..n).map(|_| None).collect();
            self.vecs = (0..n).map(|_| None).collect();
        }
    }
}

/// Column/row-wise φ scaling of the residual, in place — shared with
/// SubTrack++'s recovery component (see `subtrack::scale_residual_inplace`;
/// duplicated here in the baseline's own terms to keep the two methods
/// independently auditable). φ scratch is leased from `ws`.
fn fira_scale_residual(
    dir: &Matrix,
    g_low: &Matrix,
    resid: &mut Matrix,
    side: Side,
    ws: &mut Workspace,
) {
    match side {
        Side::Left => {
            let mut num = ws.take_vec_dirty(dir.cols());
            let mut den = ws.take_vec_dirty(g_low.cols());
            dir.col_norms_into(&mut num);
            g_low.col_norms_into(&mut den);
            for i in 0..resid.rows() {
                for (j, v) in resid.row_mut(i).iter_mut().enumerate() {
                    let phi = if den[j] > 1e-30 { num[j] / den[j] } else { 0.0 };
                    *v *= phi;
                }
            }
            ws.give_vec(num);
            ws.give_vec(den);
        }
        Side::Right => {
            for i in 0..resid.rows() {
                let num = (dir.row(i).iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt();
                let den =
                    (g_low.row(i).iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt();
                let phi = if den > 1e-30 { (num / den) as f32 } else { 0.0 };
                for v in resid.row_mut(i) {
                    *v *= phi;
                }
            }
        }
    }
}

impl Optimizer for Fira {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_slots(params.len());
        let refresh = self.hp.interval > 0 && self.step_no % self.hp.interval == 0;
        for i in 0..params.len() {
            let g = &grads[i];
            match params[i].kind {
                ParamKind::Matrix2D if g.rows() > 1 && g.cols() > 1 => {
                    let (m, n) = g.shape();
                    let needs_init = self.mats[i].is_none();
                    if needs_init || refresh {
                        let t0 = std::time::Instant::now();
                        if needs_init {
                            let proj = Projector::init_svd(g, self.hp.rank);
                            let (lm, ln) = proj.lowrank_shape(m, n);
                            self.mats[i] = Some(MatState {
                                proj,
                                moments: Moments::new(lm, ln),
                                prev_lambda_norm: 0.0,
                            });
                        } else {
                            // In-place refresh with workspace-leased scratch,
                            // behind the health guard: a degenerate (or
                            // fault-injected) candidate basis is rejected and
                            // the previous projector kept.
                            let Fira {
                                ws,
                                mats,
                                n_subspace_updates,
                                n_refresh_rejections,
                                poison_refresh,
                                ..
                            } = &mut *self;
                            let st = mats[i].as_mut().unwrap();
                            let (sr, sc) = st.proj.s.shape();
                            let mut old_s = ws.take_dirty(sr, sc);
                            old_s.copy_from(&st.proj.s);
                            st.proj.refresh_svd_into(g, ws);
                            if std::mem::take(poison_refresh) {
                                projector::poison_basis(&mut st.proj.s);
                            }
                            if projector::basis_acceptable(
                                &st.proj.s,
                                projector::REFRESH_DEFECT_TOL,
                            ) {
                                *n_subspace_updates += 1;
                            } else {
                                st.proj.s.copy_from(&old_s);
                                *n_refresh_rejections += 1;
                            }
                            ws.give(old_s);
                        }
                        self.svd_seconds += t0.elapsed().as_secs_f64();
                    }
                    let zeta = self.hp.zeta;
                    let adam = self.adam;
                    let scale = self.hp.scale;
                    // Disjoint borrows: scratch pool vs per-matrix state.
                    let Fira { ws, mats, .. } = &mut *self;
                    let st = mats[i].as_mut().expect("initialized above");
                    let (lm, ln) = st.proj.lowrank_shape(m, n);
                    let mut g_low = ws.take_dirty(lm, ln);
                    st.proj.project_into(g, &mut g_low, ws);
                    let mut dir = ws.take_dirty(lm, ln);
                    st.moments.update_into(&adam, &g_low, &mut dir);
                    let mut delta = ws.take_dirty(m, n);
                    st.proj.project_back_into(&dir, &mut delta, ws);
                    // Recovery scaling + limiter, all in workspace buffers.
                    let mut lambda = ws.take_dirty(m, n);
                    st.proj.project_back_into(&g_low, &mut lambda, ws); // S·G̃
                    lambda.zip_assign(g, |back, gv| gv - back); // G − S·G̃
                    fira_scale_residual(&dir, &g_low, &mut lambda, st.proj.side, ws);
                    let lnorm = lambda.fro_norm();
                    if st.prev_lambda_norm > 0.0 && lnorm > zeta * st.prev_lambda_norm {
                        let target = zeta * st.prev_lambda_norm;
                        lambda.scale_mut(target / lnorm);
                        st.prev_lambda_norm = target;
                    } else {
                        st.prev_lambda_norm = lnorm;
                    }
                    delta.axpy(1.0, &lambda);
                    params[i].axpy_update(-lr * scale, &delta);
                    ws.give(lambda);
                    ws.give(delta);
                    ws.give(dir);
                    ws.give(g_low);
                }
                _ => {
                    if self.vecs[i].is_none() {
                        self.vecs[i] = Some(Moments::new(g.rows(), g.cols()));
                    }
                    let adam = self.adam;
                    let st = self.vecs[i].as_mut().unwrap();
                    st.fused_step(&adam, lr, 0.0, &mut params[i].value, g);
                    params[i].mark_dirty();
                }
            }
        }
        self.step_no += 1;
    }

    fn state_bytes(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.bytes() + s.proj.bytes()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.bytes()).sum();
        mats + vecs
    }

    fn state_params(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.params() + s.proj.params()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.params()).sum();
        mats + vecs
    }

    fn subspace_updates(&self) -> usize {
        self.n_subspace_updates
    }

    fn workspace_misses(&self) -> usize {
        self.ws.misses()
    }

    fn projector_defect(&self) -> Option<f32> {
        Some(self.mats.iter().flatten().map(|s| s.proj.defect()).fold(0.0f32, f32::max))
    }

    fn poison_next_refresh(&mut self) {
        self.poison_refresh = true;
    }

    fn refresh_rejections(&self) -> usize {
        self.n_refresh_rejections
    }

    // Pack order: step_no, n_subspace_updates, n_refresh_rejections, matrix
    // slots (presence + projector + moments + prev_lambda_norm), vector
    // moment slots.
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = OptimizerSnapshot::new();
        snap.push_int(self.step_no as u64);
        snap.push_int(self.n_subspace_updates as u64);
        snap.push_int(self.n_refresh_rejections as u64);
        snap.push_int(self.mats.len() as u64);
        for slot in &self.mats {
            match slot {
                Some(st) => {
                    snap.push_int(1);
                    st.proj.pack(&mut snap);
                    st.moments.pack(&mut snap);
                    snap.push_float(st.prev_lambda_norm as f64);
                }
                None => snap.push_int(0),
            }
        }
        super::pack_moment_slots(&mut snap, &self.vecs);
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let mut r = snap.reader();
        self.step_no = r.int() as usize;
        self.n_subspace_updates = r.int() as usize;
        self.n_refresh_rejections = r.int() as usize;
        let n_mats = r.int() as usize;
        self.mats.resize_with(n_mats, || None);
        for slot in &mut self.mats {
            if r.int() == 1 {
                match slot {
                    Some(st) => {
                        st.proj.unpack_into(&mut r);
                        st.moments.unpack_into(&mut r);
                        st.prev_lambda_norm = r.float() as f32;
                    }
                    None => {
                        *slot = Some(MatState {
                            proj: Projector::unpack(&mut r),
                            moments: Moments::unpack(&mut r),
                            prev_lambda_norm: r.float() as f32,
                        });
                    }
                }
            } else {
                *slot = None;
            }
        }
        super::unpack_moment_slots(&mut r, &mut self.vecs);
    }

    fn restore_ranges(&mut self, parts: &[(&OptimizerSnapshot, usize, usize)]) -> bool {
        self.mats.clear();
        self.vecs.clear();
        self.step_no = 0;
        self.n_subspace_updates = 0;
        self.n_refresh_rejections = 0;
        for &(snap, lo, hi) in parts {
            let mut r = snap.reader();
            self.step_no = self.step_no.max(r.int() as usize);
            self.n_subspace_updates = self.n_subspace_updates.max(r.int() as usize);
            self.n_refresh_rejections = self.n_refresh_rejections.max(r.int() as usize);
            let n_mats = r.int() as usize;
            assert!(hi <= n_mats, "fira restore_ranges: slot range {lo}..{hi} out of {n_mats}");
            for i in 0..n_mats {
                if r.int() == 1 {
                    let st = MatState {
                        proj: Projector::unpack(&mut r),
                        moments: Moments::unpack(&mut r),
                        prev_lambda_norm: r.float() as f32,
                    };
                    if i >= lo && i < hi {
                        self.mats.push(Some(st));
                    }
                } else if i >= lo && i < hi {
                    self.mats.push(None);
                }
            }
            super::keep_moment_slot_range(&mut r, &mut self.vecs, lo, hi);
        }
        true
    }

    fn name(&self) -> String {
        "Fira".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};

    #[test]
    fn converges_on_lstsq() {
        let prob = LstsqProblem::new(64, 10, 14, 60);
        let mut opt = Fira::new(HyperParams {
            rank: 4,
            interval: 20,
            scale: 1.0,
            ..HyperParams::default()
        });
        let (init, fin) = run_lstsq(&mut opt, &prob, 500, 0.05);
        assert!(fin < init * 0.05, "init={init} final={fin}");
    }

    #[test]
    fn recovery_beats_galore_when_rank_too_small() {
        // With rank 1 on an intrinsically higher-rank problem, the recovery
        // term should help Fira converge faster than GaLore.
        let prob = LstsqProblem::new(64, 10, 14, 61);
        let hp = HyperParams { rank: 1, interval: 25, scale: 1.0, ..HyperParams::default() };
        let mut fira = Fira::new(hp);
        let mut galore = super::super::GaLore::new(hp);
        let (_, lf) = run_lstsq(&mut fira, &prob, 300, 0.05);
        let (_, lg) = run_lstsq(&mut galore, &prob, 300, 0.05);
        assert!(lf < lg, "fira {lf} should beat galore {lg} at rank 1");
    }

    #[test]
    fn state_params_match_table2() {
        let (m, n, r) = (10, 24, 4);
        let prob = LstsqProblem::new(8, m, n, 62);
        let mut opt = Fira::new(HyperParams { rank: r, interval: 10, ..HyperParams::default() });
        let _ = run_lstsq(&mut opt, &prob, 2, 0.01);
        assert_eq!(opt.state_params(), m * r + 2 * n * r);
    }
}
