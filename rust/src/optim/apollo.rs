//! APOLLO (Zhu et al., 2025) — SGD-like memory, AdamW-level performance.
//!
//! APOLLO never projects the update back from the subspace. Instead it runs a
//! tiny Adam in a *random-projection* space purely to estimate channel-wise
//! learning-rate scaling factors, then applies those factors to the raw
//! full-rank gradient:
//!
//!   G̃ = P·G (P random, re-drawn every k steps),   G̃ᴼ = Adam(G̃)
//!   φⱼ = ‖G̃ᴼ₍:,ⱼ₎‖ / ‖G̃₍:,ⱼ₎‖,                    W ← W − lr·φ∘G
//!
//! Because P need not be orthonormal or accurate, the rank can be far smaller
//! than GaLore's — the source of APOLLO's memory savings (Figure 1b shows it
//! mid-pack here because the paper runs it at the same rank).

use super::adam::{AdamCfg, Moments};
use super::projector::{Projector, Side};
use super::{HyperParams, Optimizer, OptimizerSnapshot, Param, ParamKind, SnapshotReader};
use crate::tensor::{Matrix, Workspace};
use crate::util::rng::Rng;

struct MatState {
    proj: Projector,
    moments: Moments,
    /// Sketch re-draw stream, keyed on the parameter name so draws are
    /// independent of slot order / shard membership (see
    /// [`super::param_stream_rng`]).
    rng: Rng,
}

/// APOLLO optimizer.
pub struct Apollo {
    hp: HyperParams,
    adam: AdamCfg,
    mats: Vec<Option<MatState>>,
    vecs: Vec<Option<Moments>>,
    step_no: usize,
    n_subspace_updates: usize,
    /// Per-step projection/scaling scratch (zero steady-state allocation;
    /// the periodic projector re-draw writes into the existing basis).
    ws: Workspace,
}

impl Apollo {
    pub fn new(hp: HyperParams) -> Apollo {
        Apollo {
            hp,
            adam: AdamCfg::from(hp),
            mats: Vec::new(),
            vecs: Vec::new(),
            step_no: 0,
            n_subspace_updates: 0,
            ws: Workspace::new(),
        }
    }

    fn ensure_slots(&mut self, n: usize) {
        if self.mats.len() != n {
            self.mats = (0..n).map(|_| None).collect();
            self.vecs = (0..n).map(|_| None).collect();
        }
    }
}

impl Optimizer for Apollo {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_slots(params.len());
        let refresh = self.hp.interval > 0 && self.step_no % self.hp.interval == 0;
        for i in 0..params.len() {
            let g = &grads[i];
            match params[i].kind {
                ParamKind::Matrix2D if g.rows() > 1 && g.cols() > 1 => {
                    let (m, n) = g.shape();
                    let needs_init = self.mats[i].is_none();
                    if needs_init {
                        // Cheap random projection — no SVD anywhere.
                        let mut rng =
                            super::param_stream_rng(self.hp.seed, 0xa901_10, &params[i].name);
                        let proj = Projector::init_random(m, n, self.hp.rank, &mut rng);
                        let (lm, ln) = proj.lowrank_shape(m, n);
                        self.mats[i] =
                            Some(MatState { proj, moments: Moments::new(lm, ln), rng });
                    } else if refresh {
                        // Re-draw the sketch into the existing basis buffer.
                        let st = self.mats[i].as_mut().expect("initialized above");
                        st.proj.refresh_random_into(&mut st.rng);
                        self.n_subspace_updates += 1;
                    }
                    let adam = self.adam;
                    // Disjoint borrows: scratch pool vs per-matrix state.
                    let Apollo { ws, mats, .. } = &mut *self;
                    let st = mats[i].as_mut().expect("initialized above");
                    let (lm, ln) = st.proj.lowrank_shape(m, n);
                    let mut g_low = ws.take_dirty(lm, ln);
                    st.proj.project_into(g, &mut g_low, ws);
                    let mut dir = ws.take_dirty(lm, ln);
                    st.moments.update_into(&adam, &g_low, &mut dir);
                    // Channel-wise scaling of the RAW gradient (no project-back).
                    let mut scaled = ws.take_dirty(m, n);
                    scaled.copy_from(g);
                    apply_channel_scale_inplace(&dir, &g_low, &mut scaled, st.proj.side, ws);
                    params[i].axpy_update(-lr, &scaled);
                    ws.give(scaled);
                    ws.give(dir);
                    ws.give(g_low);
                }
                _ => {
                    if self.vecs[i].is_none() {
                        self.vecs[i] = Some(Moments::new(g.rows(), g.cols()));
                    }
                    let adam = self.adam;
                    let st = self.vecs[i].as_mut().unwrap();
                    st.fused_step(&adam, lr, 0.0, &mut params[i].value, g);
                    params[i].mark_dirty();
                }
            }
        }
        self.step_no += 1;
    }

    fn state_bytes(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.bytes() + s.proj.bytes()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.bytes()).sum();
        mats + vecs
    }

    fn state_params(&self) -> usize {
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.params() + s.proj.params()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.params()).sum();
        mats + vecs
    }

    fn subspace_updates(&self) -> usize {
        self.n_subspace_updates
    }

    fn workspace_misses(&self) -> usize {
        self.ws.misses()
    }

    // Pack order: step_no, n_subspace_updates, matrix slots (presence +
    // projector + moments + the slot's name-keyed rng), vector moment slots.
    // APOLLO's sketch is not orthonormal, so there is no refresh guard (and
    // no poison hook).
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = OptimizerSnapshot::new();
        snap.push_int(self.step_no as u64);
        snap.push_int(self.n_subspace_updates as u64);
        snap.push_int(self.mats.len() as u64);
        for slot in &self.mats {
            match slot {
                Some(st) => {
                    snap.push_int(1);
                    st.proj.pack(&mut snap);
                    st.moments.pack(&mut snap);
                    snap.push_rng(&st.rng);
                }
                None => snap.push_int(0),
            }
        }
        super::pack_moment_slots(&mut snap, &self.vecs);
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let mut r = snap.reader();
        self.step_no = r.int() as usize;
        self.n_subspace_updates = r.int() as usize;
        let n_mats = r.int() as usize;
        self.mats.resize_with(n_mats, || None);
        for slot in &mut self.mats {
            if r.int() == 1 {
                match slot {
                    Some(st) => {
                        st.proj.unpack_into(&mut r);
                        st.moments.unpack_into(&mut r);
                        st.rng = r.rng();
                    }
                    None => {
                        *slot = Some(MatState {
                            proj: Projector::unpack(&mut r),
                            moments: Moments::unpack(&mut r),
                            rng: r.rng(),
                        });
                    }
                }
            } else {
                *slot = None;
            }
        }
        super::unpack_moment_slots(&mut r, &mut self.vecs);
    }

    fn restore_ranges(&mut self, parts: &[(&OptimizerSnapshot, usize, usize)]) -> bool {
        self.mats.clear();
        self.vecs.clear();
        self.step_no = 0;
        self.n_subspace_updates = 0;
        for &(snap, lo, hi) in parts {
            let mut r = snap.reader();
            self.step_no = self.step_no.max(r.int() as usize);
            self.n_subspace_updates = self.n_subspace_updates.max(r.int() as usize);
            let n_mats = r.int() as usize;
            assert!(hi <= n_mats, "apollo restore_ranges: slot range {lo}..{hi} out of {n_mats}");
            for i in 0..n_mats {
                if r.int() == 1 {
                    let st = MatState {
                        proj: Projector::unpack(&mut r),
                        moments: Moments::unpack(&mut r),
                        rng: r.rng(),
                    };
                    if i >= lo && i < hi {
                        self.mats.push(Some(st));
                    }
                } else if i >= lo && i < hi {
                    self.mats.push(None);
                }
            }
            super::keep_moment_slot_range(&mut r, &mut self.vecs, lo, hi);
        }
        true
    }

    fn name(&self) -> String {
        "APOLLO".into()
    }
}

/// φⱼ = ‖dir₍:,ⱼ₎‖/‖G̃₍:,ⱼ₎‖ applied along the channel axis of the raw
/// gradient copy in `out` (columns for Left projections, rows for Right),
/// in place; the Left-side φ scratch is leased from `ws`.
fn apply_channel_scale_inplace(
    dir: &Matrix,
    g_low: &Matrix,
    out: &mut Matrix,
    side: Side,
    ws: &mut Workspace,
) {
    match side {
        Side::Left => {
            let mut num = ws.take_vec_dirty(dir.cols());
            let mut den = ws.take_vec_dirty(g_low.cols());
            dir.col_norms_into(&mut num);
            g_low.col_norms_into(&mut den);
            for i in 0..out.rows() {
                for (j, v) in out.row_mut(i).iter_mut().enumerate() {
                    let phi = if den[j] > 1e-30 { num[j] / den[j] } else { 1.0 };
                    *v *= phi;
                }
            }
            ws.give_vec(num);
            ws.give_vec(den);
        }
        Side::Right => {
            for i in 0..out.rows() {
                let num = (dir.row(i).iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt();
                let den =
                    (g_low.row(i).iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt();
                let phi = if den > 1e-30 { (num / den) as f32 } else { 1.0 };
                for v in out.row_mut(i) {
                    *v *= phi;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};

    #[test]
    fn converges_on_lstsq() {
        let prob = LstsqProblem::new(64, 10, 14, 100);
        let mut opt = Apollo::new(HyperParams { rank: 2, interval: 50, ..HyperParams::default() });
        let (init, fin) = run_lstsq(&mut opt, &prob, 500, 0.02);
        assert!(fin < init * 0.1, "init={init} final={fin}");
    }

    #[test]
    fn works_at_rank_1() {
        // APOLLO's selling point: usable at extremely low rank.
        let prob = LstsqProblem::new(64, 10, 14, 101);
        let mut opt = Apollo::new(HyperParams { rank: 1, interval: 50, ..HyperParams::default() });
        let (init, fin) = run_lstsq(&mut opt, &prob, 500, 0.02);
        assert!(fin < init * 0.5, "init={init} final={fin}");
    }

    #[test]
    fn updates_are_full_rank_despite_low_rank_state() {
        // The applied update must touch all channels (it scales the raw
        // gradient), unlike GaLore whose update is rank-limited.
        let prob = LstsqProblem::new(32, 6, 20, 102);
        let mut opt = Apollo::new(HyperParams { rank: 1, interval: 50, ..HyperParams::default() });
        let mut params = vec![super::super::Param::matrix("w", Matrix::zeros(6, 20))];
        let (_, g) = prob.loss_grad(&params[0].value);
        opt.step(0.05, &mut params, std::slice::from_ref(&g));
        // Every column of W must have moved (g is dense).
        let w = &params[0].value;
        for j in 0..20 {
            let col_norm: f32 = (0..6).map(|i| w.get(i, j).abs()).sum();
            assert!(col_norm > 0.0, "column {j} untouched");
        }
    }
}
