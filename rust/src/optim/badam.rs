//! BAdam (Luo et al., 2024) — block coordinate descent with Adam.
//!
//! Only one block of parameters is active at a time; Adam states exist only
//! for the active block (freed on switch). This gives the smallest memory
//! and wall-time of all baselines (paper Tables 8–9) at the cost of partial
//! parameter tuning and the worst evaluation loss (Table 1).

use super::adam::{AdamCfg, Moments};
use super::{HyperParams, Optimizer, OptimizerSnapshot, Param};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Block switching policy ("Switch Mode" in the paper's hyperparameter
/// tables — the paper uses Random).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchMode {
    Random,
    Ordered,
}

/// BAdam optimizer. Each parameter tensor forms one block.
pub struct BAdam {
    adam: AdamCfg,
    /// Steps between block switches ("Block Switch Interval", paper: 100).
    pub switch_interval: usize,
    pub mode: SwitchMode,
    active: usize,
    /// Moments for the active block only.
    state: Option<Moments>,
    step_no: usize,
    rng: Rng,
    n_switches: usize,
}

impl BAdam {
    pub fn new(hp: HyperParams) -> BAdam {
        BAdam {
            adam: AdamCfg::from(hp),
            switch_interval: 100,
            mode: SwitchMode::Random,
            active: 0,
            state: None,
            step_no: 0,
            rng: Rng::new(hp.seed ^ 0xbada),
            n_switches: 0,
        }
    }

    fn maybe_switch(&mut self, n_blocks: usize) {
        if self.step_no % self.switch_interval == 0 {
            let next = match self.mode {
                SwitchMode::Random => self.rng.below(n_blocks),
                SwitchMode::Ordered => (self.active + 1) % n_blocks,
            };
            if self.step_no > 0 || self.state.is_none() {
                self.active = next;
                self.state = None; // moments freed; realloc lazily
                self.n_switches += 1;
            }
        }
    }
}

impl Optimizer for BAdam {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        if params.is_empty() {
            return;
        }
        self.maybe_switch(params.len());
        let i = self.active.min(params.len() - 1);
        let g = &grads[i];
        if self.state.as_ref().map(|s| s.m.shape()) != Some(g.shape()) {
            self.state = Some(Moments::new(g.rows(), g.cols()));
        }
        let st = self.state.as_mut().unwrap();
        let dir = st.update(&self.adam, g);
        params[i].axpy_update(-lr, &dir);
        self.step_no += 1;
    }

    fn state_bytes(&self) -> usize {
        self.state.as_ref().map(|s| s.bytes()).unwrap_or(0)
    }

    fn state_params(&self) -> usize {
        self.state.as_ref().map(|s| s.params()).unwrap_or(0)
    }

    fn subspace_updates(&self) -> usize {
        self.n_switches
    }

    // BAdam rotates a single *global* active block with a global RNG; its
    // state cannot be split by parameter index without changing the method.
    fn partitionable(&self) -> bool {
        false
    }

    // Pack order: active, step_no, n_switches, rng, active-block moments
    // (presence + payload).
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = OptimizerSnapshot::new();
        snap.push_int(self.active as u64);
        snap.push_int(self.step_no as u64);
        snap.push_int(self.n_switches as u64);
        snap.push_rng(&self.rng);
        match &self.state {
            Some(st) => {
                snap.push_int(1);
                st.pack(&mut snap);
            }
            None => snap.push_int(0),
        }
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let mut r = snap.reader();
        self.active = r.int() as usize;
        self.step_no = r.int() as usize;
        self.n_switches = r.int() as usize;
        self.rng = r.rng();
        if r.int() == 1 {
            match &mut self.state {
                Some(st) => st.unpack_into(&mut r),
                None => self.state = Some(Moments::unpack(&mut r)),
            }
        } else {
            self.state = None;
        }
    }

    fn name(&self) -> String {
        "BAdam".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::LstsqProblem;
    use crate::optim::Param;

    /// Two-block least-squares problem so block descent has something to
    /// cycle over.
    fn two_block_problem() -> (LstsqProblem, LstsqProblem) {
        (LstsqProblem::new(32, 6, 8, 90), LstsqProblem::new(32, 7, 5, 91))
    }

    #[test]
    fn optimizes_blocks_alternately() {
        let (p1, p2) = two_block_problem();
        let mut opt = BAdam::new(HyperParams::default());
        opt.switch_interval = 20;
        opt.mode = SwitchMode::Ordered;
        let mut params = vec![
            Param::matrix("w1", Matrix::zeros(6, 8)),
            Param::matrix("w2", Matrix::zeros(7, 5)),
        ];
        let (l1_init, _) = p1.loss_grad(&params[0].value);
        let (l2_init, _) = p2.loss_grad(&params[1].value);
        for _ in 0..400 {
            let (_, g1) = p1.loss_grad(&params[0].value);
            let (_, g2) = p2.loss_grad(&params[1].value);
            opt.step(0.05, &mut params, &[g1, g2]);
        }
        let (l1, _) = p1.loss_grad(&params[0].value);
        let (l2, _) = p2.loss_grad(&params[1].value);
        assert!(l1 < l1_init * 0.2, "block 1: {l1_init} -> {l1}");
        assert!(l2 < l2_init * 0.2, "block 2: {l2_init} -> {l2}");
        assert!(opt.subspace_updates() >= 19, "switches: {}", opt.subspace_updates());
    }

    #[test]
    fn memory_is_single_block_only() {
        let (p1, _) = two_block_problem();
        let mut opt = BAdam::new(HyperParams::default());
        opt.mode = SwitchMode::Ordered;
        let mut params = vec![
            Param::matrix("w1", Matrix::zeros(6, 8)),
            Param::matrix("w2", Matrix::zeros(7, 5)),
        ];
        let (_, g1) = p1.loss_grad(&params[0].value);
        let g2 = Matrix::zeros(7, 5);
        opt.step(0.05, &mut params, &[g1, g2]);
        // Only one block's moments are held: ≤ max(2·48, 2·35).
        assert!(opt.state_params() <= 2 * 48);
        assert!(opt.state_params() > 0);
    }

    #[test]
    fn random_mode_visits_multiple_blocks() {
        let mut opt = BAdam::new(HyperParams { seed: 7, ..HyperParams::default() });
        opt.switch_interval = 1;
        let mut params: Vec<Param> =
            (0..4).map(|i| Param::matrix(&format!("w{i}"), Matrix::zeros(3, 3))).collect();
        let mut visited = std::collections::BTreeSet::new();
        for _ in 0..64 {
            let grads: Vec<Matrix> = (0..4).map(|_| Matrix::full(3, 3, 0.1)).collect();
            opt.step(0.01, &mut params, &grads);
            visited.insert(opt.active);
        }
        assert!(visited.len() >= 3, "visited {visited:?}");
    }
}
