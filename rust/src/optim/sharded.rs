//! ZeRO-1 style optimizer state partitioning across data-parallel shards.
//!
//! [`ShardedOptimizer`] wraps `k` independent instances of a base method,
//! each owning a *contiguous range of parameter indices* balanced by element
//! count. A step hands every shard its parameter/gradient sub-slices on the
//! work-stealing pool; each inner instance runs
//! [`Optimizer::step_partition`] and therefore holds Adam moments, projector
//! factors, and per-method extras **only for its own range** — the in-process
//! analogue of ZeRO-1's "each rank keeps 1/k of optimizer state". Because
//! shards update disjoint parameter sub-slices in place, the "all-gather" of
//! updated parameter slices is the shared address space itself.
//!
//! Correctness relies on two properties of the per-method code:
//!
//! 1. **No cross-parameter coupling.** Every partitionable method keeps its
//!    state strictly per-tensor (moments/projector keyed by slot), so a
//!    partition behaves exactly like a small full run. Methods with global
//!    state (BAdam's single active block) report
//!    [`Optimizer::partitionable`] `= false` and fall back to one inner
//!    instance over the full range.
//! 2. **Identity-keyed randomness.** Stochastic draws are keyed on the
//!    parameter *name* ([`super::param_stream_rng`]), not the instance's
//!    draw order, so trajectories are bit-identical for any shard count.
//!
//! The equivalence tests at the bottom pin both properties for every method
//! in [`super::PRETRAIN_METHODS`] plus the stochastic extras.

use super::{by_name, HyperParams, Optimizer, OptimizerSnapshot, Param};
use crate::tensor::{gemm, pool, Matrix};
use std::sync::Mutex;

/// One shard's slice of work for a partitioned step (see [`ShardedOptimizer`]).
struct ShardTask<'a> {
    opt: &'a mut Box<dyn Optimizer>,
    params: &'a mut [Param],
    grads: &'a [Matrix],
}

/// First integer of the elastic (reshardable) sharded snapshot layout.
/// Chosen so it can never collide with a legacy layout, whose first integer
/// is the shard count, or with any plain method's leading slot count.
const ELASTIC_MAGIC: u64 = u64::MAX;
const ELASTIC_VERSION: u64 = 1;

/// An optimizer whose state is partitioned across `k` contiguous
/// parameter-index ranges (ZeRO-1 semantics, one inner instance per shard).
pub struct ShardedOptimizer {
    inner: Vec<Box<dyn Optimizer>>,
    /// Half-open param-index ranges, parallel to `inner`. Computed (and
    /// frozen) on the first step, when the parameter list is first seen.
    bounds: Vec<(usize, usize)>,
    /// Element count per parameter, captured alongside `bounds`. Persisted
    /// in the snapshot so a resume under a *different* shard count can
    /// recompute both the writing layout and its own from the same data.
    numels: Vec<u64>,
}

impl ShardedOptimizer {
    /// `shards` partitions of method `name`. Methods that are not
    /// [`partitionable`](Optimizer::partitionable) collapse to a single
    /// inner instance over the full range (replicated-state fallback).
    pub fn new(name: &str, hp: HyperParams, shards: usize) -> ShardedOptimizer {
        let probe = by_name(name, hp);
        let k = if probe.partitionable() { shards.max(1) } else { 1 };
        let mut inner = vec![probe];
        while inner.len() < k {
            inner.push(by_name(name, hp));
        }
        ShardedOptimizer { inner, bounds: Vec::new(), numels: Vec::new() }
    }

    /// Number of state shards (1 when the method fell back to replication).
    pub fn shards(&self) -> usize {
        self.inner.len()
    }

    /// Contiguous ranges balanced by cumulative element count: shard `s`
    /// ends at the first index whose cumulative numel reaches
    /// `total·(s+1)/k`. Deterministic in the parameter list alone, so every
    /// step (and every resume) recomputes identical bounds.
    fn compute_bounds(params: &[Param], k: usize) -> Vec<(usize, usize)> {
        let numels: Vec<u64> = params.iter().map(|p| p.numel() as u64).collect();
        Self::bounds_from_numels(&numels, k)
    }

    /// [`compute_bounds`](Self::compute_bounds) over a bare numel table —
    /// the form used at restore time, when the snapshot (not the live
    /// parameter list) supplies the element counts.
    fn bounds_from_numels(numels: &[u64], k: usize) -> Vec<(usize, usize)> {
        let total: u128 = numels.iter().map(|&n| n as u128).sum();
        let mut bounds = Vec::with_capacity(k);
        let mut start = 0usize;
        let mut acc: u128 = 0;
        for s in 0..k {
            let mut end = start;
            if s == k - 1 {
                end = numels.len();
            } else {
                let target = total * (s as u128 + 1) / k as u128;
                while end < numels.len() && acc < target {
                    acc += numels[end] as u128;
                    end += 1;
                }
            }
            bounds.push((start, end));
            start = end;
        }
        bounds
    }

    fn ensure_bounds(&mut self, params: &[Param]) {
        let stale = match self.bounds.last() {
            Some(&(_, end)) => end != params.len(),
            None => true,
        };
        if stale {
            self.numels = params.iter().map(|p| p.numel() as u64).collect();
            self.bounds = Self::bounds_from_numels(&self.numels, self.inner.len());
        }
    }

    /// Splice one shard's sub-snapshot back out of the wrapper's streams
    /// (the inverse of the per-shard extend in [`snapshot`](Self::snapshot)).
    fn read_sub(r: &mut super::SnapshotReader) -> OptimizerSnapshot {
        let n_mats = r.int() as usize;
        let n_ints = r.int() as usize;
        let n_floats = r.int() as usize;
        let n_rngs = r.int() as usize;
        let mut sub = OptimizerSnapshot::new();
        for _ in 0..n_mats {
            sub.mats.push(r.mat());
        }
        for _ in 0..n_ints {
            sub.ints.push(r.int());
        }
        for _ in 0..n_floats {
            sub.floats.push(r.float());
        }
        for _ in 0..n_rngs {
            sub.rngs.push(r.rng());
        }
        sub
    }
}

/// Whether `snap`'s streams are structurally consistent with the legacy
/// wrapped layout `[k, (mats, ints, floats, rngs)×k, spliced streams…]`:
/// the declared per-shard lengths must tile the streams exactly. Used to
/// tell a legacy wrapped single-shard snapshot apart from a *plain*
/// (unwrapped) optimizer snapshot from an old `workers = 1` run, which the
/// single-shard wrapper also accepts.
fn legacy_wrapped_layout_matches(snap: &OptimizerSnapshot) -> bool {
    let ints = &snap.ints;
    let Some(&k) = ints.first() else { return false };
    if k == 0 || k > 4096 {
        return false;
    }
    let mut off = 1usize;
    let (mut mats, mut sub_ints, mut floats, mut rngs) = (0u128, 0u128, 0u128, 0u128);
    for _ in 0..k {
        let Some(lens) = ints.get(off..off + 4) else { return false };
        mats += lens[0] as u128;
        sub_ints += lens[1] as u128;
        floats += lens[2] as u128;
        rngs += lens[3] as u128;
        off += 4;
    }
    mats == snap.mats.len() as u128
        && sub_ints == (ints.len() - off) as u128
        && floats == snap.floats.len() as u128
        && rngs == snap.rngs.len() as u128
}

impl Optimizer for ShardedOptimizer {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        // Bounds (and the numel table they carry into snapshots) are kept
        // fresh even on the single-shard path, so every checkpoint blob is
        // elastic regardless of shard count.
        self.ensure_bounds(params);
        if self.inner.len() == 1 {
            return self.inner[0].step(lr, params, grads);
        }
        // Carve disjoint &mut sub-slices (params) and shared sub-slices
        // (grads) per shard, pairing each with its inner instance. The
        // Mutex<Option<..>> wrapper is only move-out-of-shared-closure
        // plumbing for the pool's `Fn(usize)` interface — each slot is
        // locked exactly once, by the worker that claims its index.
        let mut tasks: Vec<Mutex<Option<ShardTask>>> = Vec::with_capacity(self.inner.len());
        {
            let mut rest = &mut params[..];
            let mut cut = 0usize;
            for (opt, &(s, e)) in self.inner.iter_mut().zip(&self.bounds) {
                let (head, tail) = rest.split_at_mut(e - cut);
                debug_assert_eq!(cut, s);
                rest = tail;
                cut = e;
                tasks.push(Mutex::new(Some(ShardTask { opt, params: head, grads: &grads[s..e] })));
            }
        }
        let n = tasks.len();
        pool::run(n, n, &|i| {
            let task = tasks[i].lock().unwrap().take();
            if let Some(t) = task {
                if t.params.is_empty() {
                    return;
                }
                // Each shard occupies one core; nested GEMM fan-out would
                // oversubscribe (results are bit-identical either way).
                gemm::run_single_threaded(|| t.opt.step_partition(lr, t.params, t.grads));
            }
        });
    }

    /// Per-shard figure (the largest shard), *not* the replicated sum — this
    /// is the number a ZeRO-1 rank actually holds, and what the paper's
    /// memory tables should report under partitioning.
    fn state_bytes(&self) -> usize {
        self.inner.iter().map(|o| o.state_bytes()).max().unwrap_or(0)
    }

    /// Per-shard figure, like [`state_bytes`](ShardedOptimizer::state_bytes).
    fn state_params(&self) -> usize {
        self.inner.iter().map(|o| o.state_params()).max().unwrap_or(0)
    }

    fn subspace_updates(&self) -> usize {
        self.inner.iter().map(|o| o.subspace_updates()).sum()
    }

    fn workspace_misses(&self) -> usize {
        self.inner.iter().map(|o| o.workspace_misses()).sum()
    }

    fn projector_defect(&self) -> Option<f32> {
        self.inner.iter().filter_map(|o| o.projector_defect()).reduce(f32::max)
    }

    fn poison_next_refresh(&mut self) {
        for o in &mut self.inner {
            o.poison_next_refresh();
        }
    }

    fn refresh_rejections(&self) -> usize {
        self.inner.iter().map(|o| o.refresh_rejections()).sum()
    }

    // Elastic pack order: magic sentinel, layout version, shard count,
    // parameter count and per-parameter numels, then per shard its four
    // stream lengths (mats, ints, floats, rngs) followed by the shard's
    // streams spliced into this snapshot's streams. The numel table is what
    // makes the blob *reshardable*: restore recomputes both the writing
    // layout's bounds and its own from it, then moves per-parameter state
    // between shard instances via [`Optimizer::restore_ranges`].
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = OptimizerSnapshot::new();
        snap.push_int(ELASTIC_MAGIC);
        snap.push_int(ELASTIC_VERSION);
        snap.push_int(self.inner.len() as u64);
        snap.push_int(self.numels.len() as u64);
        for &n in &self.numels {
            snap.push_int(n);
        }
        for o in &self.inner {
            let sub = o.snapshot();
            snap.push_int(sub.mats.len() as u64);
            snap.push_int(sub.ints.len() as u64);
            snap.push_int(sub.floats.len() as u64);
            snap.push_int(sub.rngs.len() as u64);
            snap.mats.extend(sub.mats);
            snap.ints.extend(sub.ints);
            snap.floats.extend(sub.floats);
            snap.rngs.extend(sub.rngs);
        }
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let mut r = snap.reader();
        let first = r.int();
        if first != ELASTIC_MAGIC {
            // Legacy layouts, restorable only at the writing shard count:
            // either the pre-elastic wrapped format (shard count leads), or
            // a plain unwrapped snapshot from an old `workers = 1` run
            // handed to a single-shard wrapper.
            if self.inner.len() == 1 && !legacy_wrapped_layout_matches(snap) {
                return self.inner[0].restore(snap);
            }
            let k = first as usize;
            assert_eq!(k, self.inner.len(), "sharded snapshot: shard count mismatch");
            for o in &mut self.inner {
                let sub = Self::read_sub(&mut r);
                o.restore(&sub);
            }
            return;
        }
        let version = r.int();
        assert_eq!(version, ELASTIC_VERSION, "sharded snapshot: unknown layout version");
        let k_old = r.int() as usize;
        let n_params = r.int() as usize;
        let mut numels = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            numels.push(r.int());
        }
        let subs: Vec<OptimizerSnapshot> = (0..k_old).map(|_| Self::read_sub(&mut r)).collect();
        if k_old == self.inner.len() {
            // Same layout: hand each shard its own sub-snapshot verbatim —
            // bit-identical to the pre-elastic restore path.
            for (o, sub) in self.inner.iter_mut().zip(&subs) {
                o.restore(sub);
            }
        } else {
            assert!(
                n_params > 0,
                "sharded snapshot: cannot reshard a pre-step snapshot (no parameter table)"
            );
            let old_bounds = Self::bounds_from_numels(&numels, k_old);
            let new_bounds = Self::bounds_from_numels(&numels, self.inner.len());
            for (o, &(nlo, nhi)) in self.inner.iter_mut().zip(&new_bounds) {
                let mut parts: Vec<(&OptimizerSnapshot, usize, usize)> = Vec::new();
                for (sub, &(olo, ohi)) in subs.iter().zip(&old_bounds) {
                    let lo = nlo.max(olo);
                    let hi = nhi.min(ohi);
                    if lo < hi {
                        parts.push((sub, lo - olo, hi - olo));
                    }
                }
                assert!(
                    o.restore_ranges(&parts),
                    "optimizer '{}' does not support elastic resharding; resume with \
                     train.workers matching the checkpoint ({k_old} shards)",
                    o.name()
                );
            }
            self.bounds = new_bounds;
        }
        if !numels.is_empty() {
            self.numels = numels;
        }
    }

    fn name(&self) -> String {
        self.inner[0].name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::LstsqProblem;
    use crate::optim::PRETRAIN_METHODS;

    fn test_hp() -> HyperParams {
        HyperParams { rank: 3, interval: 4, scale: 1.0, seed: 7, ..HyperParams::default() }
    }

    /// Eight parameters of mixed shapes/kinds — enough to split 2 or 4 ways
    /// with matrices and vectors on both sides of every boundary.
    fn make_params(tag: &str) -> Vec<Param> {
        let mut out = Vec::new();
        for i in 0..4 {
            out.push(Param::matrix(&format!("{tag}.w{i}"), Matrix::zeros(12, 16)));
            out.push(Param::vector(&format!("{tag}.b{i}"), Matrix::zeros(1, 16)));
        }
        out
    }

    /// Deterministic dense pseudo-gradients that evolve with the params so
    /// projector refreshes see non-stationary signal.
    fn grads_for(prob: &LstsqProblem, params: &[Param], step: usize) -> Vec<Matrix> {
        params
            .iter()
            .map(|p| {
                if p.value.rows() > 1 {
                    let (_, g) = prob.loss_grad(&p.value);
                    g
                } else {
                    Matrix::full(1, p.value.cols(), 0.01 + step as f32 * 1e-3)
                }
            })
            .collect()
    }

    fn run_traj(name: &str, shards: usize, steps: usize) -> (Vec<Param>, Box<dyn Optimizer>) {
        let prob = LstsqProblem::new(16, 12, 16, 321);
        let mut params = make_params("m");
        let mut opt: Box<dyn Optimizer> = if shards <= 1 {
            by_name(name, test_hp())
        } else {
            Box::new(ShardedOptimizer::new(name, test_hp(), shards))
        };
        for s in 0..steps {
            let grads = grads_for(&prob, &params, s);
            opt.step(0.05, &mut params, &grads);
        }
        (params, opt)
    }

    #[test]
    fn bounds_are_contiguous_balanced_and_cover() {
        let params = make_params("m");
        for k in [1, 2, 3, 4, 7] {
            let bounds = ShardedOptimizer::compute_bounds(&params, k);
            assert_eq!(bounds.len(), k);
            assert_eq!(bounds[0].0, 0);
            assert_eq!(bounds[k - 1].1, params.len());
            for w in bounds.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must tile");
            }
            let total: usize = params.iter().map(|p| p.numel()).sum();
            for &(s, e) in &bounds {
                let share: usize = params[s..e].iter().map(|p| p.numel()).sum();
                // Balanced to within one (largest) tensor.
                assert!(share <= total / k + 12 * 16, "share={share} total={total} k={k}");
            }
        }
    }

    #[test]
    fn sharded_trajectories_bit_identical_for_all_methods() {
        // The acceptance gate: every pre-training method (plus the
        // stochastic extras) must produce the same parameters under
        // 1, 2, and 4 state shards. Bit-identical, not approximately —
        // shards change *which instance* runs the math, never the math.
        let mut methods: Vec<&str> = PRETRAIN_METHODS.to_vec();
        methods.extend(["apollo", "golore", "subtrack-pure"]);
        for name in methods {
            let (base, _) = run_traj(name, 1, 9);
            for shards in [2usize, 4] {
                let (got, _) = run_traj(name, shards, 9);
                for (b, g) in base.iter().zip(&got) {
                    assert_eq!(
                        b.value.data(),
                        g.value.data(),
                        "{name}: {} diverged at {shards} shards",
                        b.name
                    );
                }
            }
        }
    }

    #[test]
    fn state_is_partitioned_not_replicated() {
        for name in ["full-rank", "galore", "subtrack++"] {
            let (_, single) = run_traj(name, 1, 5);
            let (_, sharded) = run_traj(name, 4, 5);
            let (total_p, shard_p) = (single.state_params(), sharded.state_params());
            let (total_b, shard_b) = (single.state_bytes(), sharded.state_bytes());
            assert!(shard_p > 0, "{name}: no state accounted");
            // Largest of 4 balanced shards: ≈ 1/4, never more than ~1/2.
            assert!(
                shard_p * 2 < total_p,
                "{name}: per-shard params {shard_p} not < half of {total_p}"
            );
            assert!(
                shard_b * 2 < total_b,
                "{name}: per-shard bytes {shard_b} not < half of {total_b}"
            );
        }
    }

    #[test]
    fn unpartitionable_method_falls_back_to_single_shard() {
        let opt = ShardedOptimizer::new("badam", test_hp(), 4);
        assert_eq!(opt.shards(), 1, "BAdam must collapse to replicated fallback");
        // And the fallback still matches the plain optimizer bit-for-bit.
        let (base, _) = run_traj("badam", 1, 6);
        let (got, _) = run_traj("badam", 4, 6);
        for (b, g) in base.iter().zip(&got) {
            assert_eq!(b.value.data(), g.value.data(), "badam fallback diverged");
        }
    }

    #[test]
    fn sharded_snapshot_restore_replays_bitexact() {
        for name in ["full-rank", "subtrack++", "golore", "apollo"] {
            let prob = LstsqProblem::new(16, 12, 16, 321);
            let mut params = make_params("m");
            let mut opt = ShardedOptimizer::new(name, test_hp(), 3);
            for s in 0..5 {
                let grads = grads_for(&prob, &params, s);
                opt.step(0.05, &mut params, &grads);
            }
            let snap = opt.snapshot();
            let saved: Vec<Matrix> = params.iter().map(|p| p.value.clone()).collect();
            let mut trace = Vec::new();
            for s in 5..9 {
                let grads = grads_for(&prob, &params, s);
                opt.step(0.05, &mut params, &grads);
                trace.push(params.iter().map(|p| p.value.clone()).collect::<Vec<_>>());
            }
            opt.restore(&snap);
            for (p, v) in params.iter_mut().zip(&saved) {
                p.value.copy_from(v);
                p.mark_dirty();
            }
            for (i, want) in trace.iter().enumerate() {
                let grads = grads_for(&prob, &params, 5 + i);
                opt.step(0.05, &mut params, &grads);
                for (p, w) in params.iter().zip(want) {
                    assert_eq!(p.value.data(), w.data(), "{name}: replay diverged at {i}");
                }
            }
        }
    }

    #[test]
    fn elastic_reshard_replays_bitexact_for_all_methods() {
        // Snapshot at 2 shards, resume at 1/3/4 shards: per-parameter
        // state (moments, projectors, per-slot RNG streams) moves
        // wholesale across the new layout, so the resumed trajectory must
        // match the uninterrupted 2-shard run bit for bit.
        let mut methods: Vec<&str> = PRETRAIN_METHODS.to_vec();
        methods.extend(["apollo", "golore", "subtrack-pure"]);
        for name in methods {
            if name == "badam" {
                continue; // not partitionable: always one shard, never resharded
            }
            let prob = LstsqProblem::new(16, 12, 16, 321);
            let mut params = make_params("m");
            let mut opt = ShardedOptimizer::new(name, test_hp(), 2);
            for s in 0..5 {
                let grads = grads_for(&prob, &params, s);
                opt.step(0.05, &mut params, &grads);
            }
            let snap = opt.snapshot();
            let saved: Vec<Matrix> = params.iter().map(|p| p.value.clone()).collect();
            let mut trace = Vec::new();
            for s in 5..9 {
                let grads = grads_for(&prob, &params, s);
                opt.step(0.05, &mut params, &grads);
                trace.push(params.iter().map(|p| p.value.clone()).collect::<Vec<_>>());
            }
            for k_new in [1usize, 3, 4] {
                let mut opt2 = ShardedOptimizer::new(name, test_hp(), k_new);
                opt2.restore(&snap);
                let mut params2 = make_params("m");
                for (p, v) in params2.iter_mut().zip(&saved) {
                    p.value.copy_from(v);
                    p.mark_dirty();
                }
                for (i, want) in trace.iter().enumerate() {
                    let grads = grads_for(&prob, &params2, 5 + i);
                    opt2.step(0.05, &mut params2, &grads);
                    for (p, w) in params2.iter().zip(want) {
                        assert_eq!(
                            p.value.data(),
                            w.data(),
                            "{name}: reshard 2->{k_new} diverged at replay step {i} ({})",
                            p.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn single_shard_wrapper_accepts_plain_legacy_snapshot() {
        // Old workers=1 checkpoints hold the bare method's snapshot (no
        // sharded header); the always-wrapped optimizer must keep
        // restoring them and replay identically.
        let prob = LstsqProblem::new(16, 12, 16, 321);
        let mut params = make_params("m");
        let mut plain = by_name("subtrack++", test_hp());
        for s in 0..5 {
            let grads = grads_for(&prob, &params, s);
            plain.step(0.05, &mut params, &grads);
        }
        let snap = plain.snapshot();
        let saved: Vec<Matrix> = params.iter().map(|p| p.value.clone()).collect();
        let mut trace = Vec::new();
        for s in 5..8 {
            let grads = grads_for(&prob, &params, s);
            plain.step(0.05, &mut params, &grads);
            trace.push(params.iter().map(|p| p.value.clone()).collect::<Vec<_>>());
        }
        let mut wrapped = ShardedOptimizer::new("subtrack++", test_hp(), 1);
        wrapped.restore(&snap);
        let mut params2 = make_params("m");
        for (p, v) in params2.iter_mut().zip(&saved) {
            p.value.copy_from(v);
            p.mark_dirty();
        }
        for (i, want) in trace.iter().enumerate() {
            let grads = grads_for(&prob, &params2, 5 + i);
            wrapped.step(0.05, &mut params2, &grads);
            for (p, w) in params2.iter().zip(want) {
                assert_eq!(p.value.data(), w.data(), "legacy plain restore diverged at {i}");
            }
        }
    }

    #[test]
    fn sharded_snapshot_survives_encode_decode() {
        let prob = LstsqProblem::new(16, 12, 16, 321);
        let mut params = make_params("m");
        let mut opt = ShardedOptimizer::new("subtrack++", test_hp(), 2);
        for s in 0..5 {
            let grads = grads_for(&prob, &params, s);
            opt.step(0.05, &mut params, &grads);
        }
        let snap = opt.snapshot();
        let decoded = OptimizerSnapshot::decode(&snap.encode()).expect("roundtrip");
        // Restoring from the decoded copy must continue identically to
        // restoring from the original.
        let mut a = ShardedOptimizer::new("subtrack++", test_hp(), 2);
        let mut b = ShardedOptimizer::new("subtrack++", test_hp(), 2);
        a.restore(&snap);
        b.restore(&decoded);
        let mut pa = params.iter().map(|p| p.clone()).collect::<Vec<_>>();
        let mut pb = params.iter().map(|p| p.clone()).collect::<Vec<_>>();
        for s in 0..4 {
            let ga = grads_for(&prob, &pa, s);
            let gb = grads_for(&prob, &pb, s);
            a.step(0.05, &mut pa, &ga);
            b.step(0.05, &mut pb, &gb);
        }
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.value.data(), y.value.data(), "decoded snapshot diverged");
        }
    }
}
