//! **SubTrack++** — the paper's contribution (Algorithm 1).
//!
//! Three composable components on top of low-rank Adam:
//!
//! 1. **Grassmannian subspace tracking** — instead of recomputing a truncated
//!    SVD of the gradient every k steps (GaLore/Fira), move the existing
//!    orthonormal basis S along a Grassmann geodesic in the direction of the
//!    rank-1 approximation of the tangent ∇F = −2·R·Aᵀ, where A is the least
//!    squares solution of min‖SA − G‖ (= SᵀG for orthonormal S) and
//!    R = G − SA its residual (Eqs. 2–5). Cost O(mnr) vs SVD's O(nm²).
//! 2. **Projection-aware optimizer** — when the subspace moves, rotate Adam's
//!    moments into the new basis with Q = SₜᵀSₜ₋₁ (Eqs. 8–9, Appendix C).
//! 3. **Recovery scaling** — re-inject the component of the gradient
//!    discarded by the projection, scaled per-column by
//!    φᵢ = ‖G̃ᴼ₍:,ᵢ₎‖/‖G̃₍:,ᵢ₎‖ and growth-limited by ζ (Eqs. 10–12).
//!
//! The ablation rows of Figure 3/6 correspond to [`Components`] settings.

use super::adam::{AdamCfg, Moments};
use super::projector::{self, Projector, Side};
use super::{HyperParams, Optimizer, OptimizerSnapshot, Param, ParamKind, SnapshotReader};
use crate::tensor::{gemm, qr, svd, Matrix, Workspace};
use crate::util::rng::Rng;
use std::time::Instant;

/// Which of the paper's components are enabled (ablation axes of Fig. 3/6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Components {
    /// Projection-aware moment rotation (Eqs. 8–9).
    pub projection_aware: bool,
    /// Recovery scaling of the discarded gradient component (Eqs. 10–12).
    pub recovery_scaling: bool,
}

impl Components {
    /// Full SubTrack++.
    pub fn full() -> Components {
        Components { projection_aware: true, recovery_scaling: true }
    }

    /// Pure Grassmannian subspace tracking (Fig. 3 baseline).
    pub fn pure() -> Components {
        Components { projection_aware: false, recovery_scaling: false }
    }

    pub fn pa_only() -> Components {
        Components { projection_aware: true, recovery_scaling: false }
    }

    pub fn rs_only() -> Components {
        Components { projection_aware: false, recovery_scaling: true }
    }

    pub fn label(&self) -> &'static str {
        match (self.projection_aware, self.recovery_scaling) {
            (true, true) => "SubTrack++",
            (true, false) => "SubTrack+PA",
            (false, true) => "SubTrack+RS",
            (false, false) => "SubTrack (pure)",
        }
    }
}

/// Wall-time breakdown of one Grassmannian subspace update (Appendix D,
/// Table 3). All durations in seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct UpdateBreakdown {
    /// Least-squares solve A = SᵀG (cost function, Eq. 2).
    pub lstsq: f64,
    /// Residual R = G − SA.
    pub residual: f64,
    /// Partial derivative / tangent ∇F = −2RAᵀ (Eqs. 3–4).
    pub tangent: f64,
    /// Rank-1 approximation of ∇F (power iteration).
    pub rank1: f64,
    /// Geodesic step (Eq. 5).
    pub geodesic: f64,
}

impl UpdateBreakdown {
    pub fn total(&self) -> f64 {
        self.lstsq + self.residual + self.tangent + self.rank1 + self.geodesic
    }
}

/// One Grassmannian geodesic update of the basis (Eq. 5, rank-1 form).
///
/// `g_oriented` must be oriented so rows index the *subspace* dimension:
/// the caller passes G for Left projections and Gᵀ-view logic for Right.
/// Returns the updated basis and the stage breakdown.
///
/// Rank-1 geodesic: with ∇F ≈ σ·u·vᵀ (u ⊥ span(S) because R ⊥ S), Eq. 5
/// collapses to
///   S′ = S + (S·v·(cos(σ·η) − 1) + u·sin(σ·η))·vᵀ
/// which touches O((m+r)·r) entries — the remaining columns' component
/// S·(I − vvᵀ) is implicit.
pub fn grassmannian_step(
    s: &Matrix,
    g_oriented: &Matrix,
    eta: f32,
    power_iters: usize,
    rng: &mut Rng,
) -> (Matrix, UpdateBreakdown) {
    let mut s_new = s.clone();
    let bd =
        grassmannian_step_ws(&mut s_new, g_oriented, eta, power_iters, rng, &mut Workspace::new());
    (s_new, bd)
}

/// Allocation-free [`grassmannian_step`]: updates the basis **in place**,
/// leasing every temporary (A, R, ∇F, the power-iteration vectors, and the
/// geodesic combination) from `ws` — the every-k-steps refresh allocates
/// nothing after its first occurrence.
pub fn grassmannian_step_ws(
    s: &mut Matrix,
    g_oriented: &Matrix,
    eta: f32,
    power_iters: usize,
    rng: &mut Rng,
    ws: &mut Workspace,
) -> UpdateBreakdown {
    let mut bd = UpdateBreakdown::default();
    let (dim, r) = s.shape();
    debug_assert_eq!(g_oriented.rows(), dim);
    let ncols = g_oriented.cols();

    // (1) least squares A = argmin ‖SA − G‖ = SᵀG (S orthonormal).
    let t0 = Instant::now();
    let mut a = ws.take_dirty(r, ncols);
    gemm::matmul_tn_into(&mut a, s, g_oriented, ws);
    bd.lstsq = t0.elapsed().as_secs_f64();

    // (2) residual R = G − S·A (accumulated directly into the G copy).
    let t0 = Instant::now();
    let mut resid = ws.take_dirty(dim, ncols);
    resid.copy_from(g_oriented);
    gemm::matmul_acc(&mut resid, s, &a, -1.0);
    bd.residual = t0.elapsed().as_secs_f64();

    // (3) tangent ∇F = −2·R·Aᵀ (already in the horizontal space: R ⊥ S).
    let t0 = Instant::now();
    let mut tangent = ws.take_dirty(dim, r);
    gemm::matmul_nt_into(&mut tangent, &resid, &a, ws);
    tangent.scale_mut(-2.0);
    bd.tangent = t0.elapsed().as_secs_f64();

    // (4) rank-1 approximation σ·u·vᵀ of the tangent.
    let t0 = Instant::now();
    let mut u = ws.take_vec_dirty(dim);
    let mut v = ws.take_vec_dirty(r);
    let sigma = svd::power_iteration_top1_ws(&tangent, power_iters, rng, &mut u, &mut v);
    bd.rank1 = t0.elapsed().as_secs_f64();

    // (5) geodesic step of size η (descent direction ⇒ −∇F ⇒ angle −σ·η).
    // Moving against the gradient of the cost: cos is even and sin odd, so
    // S′ = S + (S·v·(cos(σ η)−1) − u·sin(σ η))·vᵀ.
    let t0 = Instant::now();
    if sigma > 0.0 {
        // Rotation angle along the geodesic. The paper uses Θ = σ·η with a
        // constant η (Table 10: η = 10 at pre-training gradient scales where
        // σ ≈ 1e-4). We clamp at π/2 as a stability guard against abrupt
        // jumps — the same failure mode Figure 5 demonstrates for SVD — so a
        // badly scaled σ·η can at most swap one direction, never alias past it.
        let theta = (sigma * eta).min(std::f32::consts::FRAC_PI_2);
        let (sin_t, cos_t) = theta.sin_cos();
        let mut sv = ws.take_vec_dirty(dim);
        gemm::matvec_into(&mut sv, s, &v); // dim-vector S·v
        // w = sv·(cos−1) − u·sin, combined in place.
        for (svi, &ui) in sv.iter_mut().zip(&u) {
            *svi = *svi * (cos_t - 1.0) - ui * sin_t;
        }
        // S′ = S + w·vᵀ  (rank-1 outer product update)
        let sd = s.data_mut();
        for (i, &wi) in sv.iter().enumerate() {
            if wi == 0.0 {
                continue;
            }
            let row = &mut sd[i * r..(i + 1) * r];
            for (rv, &vj) in row.iter_mut().zip(&v) {
                *rv += wi * vj;
            }
        }
        ws.give_vec(sv);
    }
    bd.geodesic = t0.elapsed().as_secs_f64();
    ws.give_vec(v);
    ws.give_vec(u);
    ws.give(tangent);
    ws.give(resid);
    ws.give(a);
    bd
}

/// Per-matrix SubTrack++ state.
struct MatState {
    proj: Projector,
    moments: Moments,
    /// ‖Λₜ₋₁‖ for the ζ growth limiter (Eq. 12).
    prev_lambda_norm: f32,
    /// Count of geodesic updates applied (drives re-orthonormalization guard).
    updates: usize,
    /// Power-iteration stream, keyed on the parameter *name* so the draws a
    /// matrix sees are independent of which other parameters this instance
    /// owns — the property ZeRO-style state partitioning relies on (see
    /// [`super::param_stream_rng`]).
    rng: Rng,
}

/// Full-rank Adam state for 1-D params.
struct VecState {
    moments: Moments,
}

/// The SubTrack++ optimizer.
pub struct SubTrack {
    hp: HyperParams,
    comps: Components,
    adam: AdamCfg,
    mats: Vec<Option<MatState>>,
    vecs: Vec<Option<VecState>>,
    step_no: usize,
    n_subspace_updates: usize,
    n_refresh_rejections: usize,
    poison_refresh: bool,
    /// Accumulated stage breakdown across all subspace updates (Appendix D).
    pub breakdown: UpdateBreakdown,
    /// Re-orthonormalize the basis after this many geodesic updates (fp drift
    /// guard; analytically S stays orthonormal because u ⊥ span(S)). The
    /// pass is the WY-blocked `reorthonormalize_in_place`.
    pub reorth_every: usize,
    /// Power-iteration sweeps for the rank-1 approximation.
    pub power_iters: usize,
    /// Scratch pool for the per-step projection/recovery buffers — zero
    /// steady-state allocation (see `tensor::workspace`).
    ws: Workspace,
}

impl SubTrack {
    pub fn new(hp: HyperParams, comps: Components) -> SubTrack {
        SubTrack {
            hp,
            comps,
            adam: AdamCfg::from(hp),
            mats: Vec::new(),
            vecs: Vec::new(),
            step_no: 0,
            n_subspace_updates: 0,
            n_refresh_rejections: 0,
            poison_refresh: false,
            breakdown: UpdateBreakdown::default(),
            reorth_every: 64,
            power_iters: 8,
            ws: Workspace::new(),
        }
    }

    fn ensure_slots(&mut self, n: usize) {
        if self.mats.len() != n {
            self.mats = (0..n).map(|_| None).collect();
            self.vecs = (0..n).map(|_| None).collect();
        }
    }

    /// Advance one matrix parameter, applying W ← W + lr_scaled·delta in
    /// place (`lr_scaled` is −lr·α). All per-step buffers are leased from
    /// the optimizer's workspace, so steady-state steps allocate nothing;
    /// only the periodic geodesic subspace update (every k steps) builds
    /// temporaries.
    fn step_matrix(
        &mut self,
        idx: usize,
        g: &Matrix,
        is_update_step: bool,
        param: &mut Param,
        lr_scaled: f32,
    ) {
        let (m, n) = g.shape();
        // Initialize on first touch: SVD of G₀ (Eq. 1).
        if self.mats[idx].is_none() {
            let proj = Projector::init_svd(g, self.hp.rank);
            let (lm, ln) = proj.lowrank_shape(m, n);
            self.mats[idx] = Some(MatState {
                proj,
                moments: Moments::new(lm, ln),
                prev_lambda_norm: 0.0,
                updates: 0,
                rng: super::param_stream_rng(self.hp.seed, 0x5b71c4, &param.name),
            });
        }

        let comps = self.comps;
        let adam = self.adam;
        let eta = self.hp.eta;
        let zeta = self.hp.zeta;
        let power_iters = self.power_iters;
        let reorth_every = self.reorth_every;
        // Disjoint field borrows: scratch pool + per-matrix state + counters.
        let SubTrack {
            ws,
            mats,
            breakdown,
            n_subspace_updates,
            n_refresh_rejections,
            poison_refresh,
            ..
        } = self;
        let st = mats[idx].as_mut().expect("initialized above");

        // ---- subspace update every k steps (not at step 0: S₀ is fresh) ----
        // The whole periodic path runs out of the optimizer workspace: the
        // basis moves in place, the previous basis / Gᵀ view / change-of-basis
        // matrix are leased, and the moment rotation writes back into the
        // moment buffers — zero allocation after the first refresh. The
        // leased old basis also backs the health guard: a degenerate (or
        // fault-injected) geodesic step is rejected, keeping the previous
        // basis and moments until the next interval.
        if is_update_step && st.moments.t > 0 {
            let (dim, r) = st.proj.s.shape();
            let mut old_s = ws.take_dirty(dim, r);
            old_s.copy_from(&st.proj.s);
            let bd = match st.proj.side {
                Side::Left => {
                    grassmannian_step_ws(&mut st.proj.s, g, eta, power_iters, &mut st.rng, ws)
                }
                Side::Right => {
                    let mut gt = ws.take_dirty(n, m);
                    g.transpose_into(&mut gt);
                    let bd = grassmannian_step_ws(
                        &mut st.proj.s,
                        &gt,
                        eta,
                        power_iters,
                        &mut st.rng,
                        ws,
                    );
                    ws.give(gt);
                    bd
                }
            };
            breakdown.lstsq += bd.lstsq;
            breakdown.residual += bd.residual;
            breakdown.tangent += bd.tangent;
            breakdown.rank1 += bd.rank1;
            breakdown.geodesic += bd.geodesic;
            if std::mem::take(poison_refresh) {
                projector::poison_basis(&mut st.proj.s);
            }
            if projector::basis_acceptable(&st.proj.s, projector::REFRESH_DEFECT_TOL) {
                st.updates += 1;
                if st.updates % reorth_every == 0 {
                    qr::reorthonormalize_in_place(&mut st.proj.s, ws);
                }
                *n_subspace_updates += 1;

                if comps.projection_aware {
                    // Q = SₜᵀSₜ₋₁ (r×r); rotate moments (Eqs. 8–9).
                    let mut q = ws.take_dirty(r, r);
                    gemm::matmul_tn_into(&mut q, &st.proj.s, &old_s, ws);
                    projector::rotate_moments_into(
                        &q,
                        &mut st.moments,
                        st.proj.side,
                        adam.beta2,
                        ws,
                    );
                    ws.give(q);
                }
            } else {
                st.proj.s.copy_from(&old_s);
                *n_refresh_rejections += 1;
            }
            ws.give(old_s);
        }

        // ---- low-rank Adam (workspace-backed, allocation-free) ----
        let (lm, ln) = st.proj.lowrank_shape(m, n);
        let mut g_low = ws.take_dirty(lm, ln); // G̃ₜ
        st.proj.project_into(g, &mut g_low, ws);
        let mut dir = ws.take_dirty(lm, ln); // G̃ᴼₜ (bias-corrected)
        st.moments.update_into(&adam, &g_low, &mut dir);
        let mut delta = ws.take_dirty(m, n); // Ĝₜ
        st.proj.project_back_into(&dir, &mut delta, ws);

        // ---- recovery scaling (Eqs. 10–12) ----
        if comps.recovery_scaling {
            let mut lambda = ws.take_dirty(m, n);
            st.proj.project_back_into(&g_low, &mut lambda, ws); // S·G̃
            lambda.zip_assign(g, |back, gv| gv - back); // G − S·G̃
            scale_residual_inplace(&dir, &g_low, &mut lambda, st.proj.side, ws);
            // ζ growth limiter.
            let lnorm = lambda.fro_norm();
            if st.prev_lambda_norm > 0.0 && lnorm > zeta * st.prev_lambda_norm {
                let target = zeta * st.prev_lambda_norm;
                lambda.scale_mut(target / lnorm);
                st.prev_lambda_norm = target;
            } else {
                st.prev_lambda_norm = lnorm;
            }
            delta.axpy(1.0, &lambda);
            ws.give(lambda);
        }

        param.axpy_update(lr_scaled, &delta);
        ws.give(delta);
        ws.give(dir);
        ws.give(g_low);
    }
}

/// Λ = φ(G)·(G − S·G̃): scale the discarded residual by the ratio of the
/// optimizer-output column norm to the raw low-rank column norm (Eq. 11),
/// in place. "Columns" index the non-reduced axis: for Left projections G̃
/// is r×n and φ has n entries applied to residual columns; for Right
/// projections G̃ is m×r and φ has m entries applied to residual rows.
/// The φ numerator/denominator scratch is leased from `ws`.
fn scale_residual_inplace(
    dir: &Matrix,
    g_low: &Matrix,
    resid: &mut Matrix,
    side: Side,
    ws: &mut Workspace,
) {
    match side {
        Side::Left => {
            let mut num = ws.take_vec_dirty(dir.cols());
            let mut den = ws.take_vec_dirty(g_low.cols());
            dir.col_norms_into(&mut num);
            g_low.col_norms_into(&mut den);
            for i in 0..resid.rows() {
                let row = resid.row_mut(i);
                for (j, v) in row.iter_mut().enumerate() {
                    let phi = if den[j] > 1e-30 { num[j] / den[j] } else { 0.0 };
                    *v *= phi;
                }
            }
            ws.give_vec(num);
            ws.give_vec(den);
        }
        Side::Right => {
            for i in 0..resid.rows() {
                let num = row_norm(dir, i);
                let den = row_norm(g_low, i);
                let phi = if den > 1e-30 { num / den } else { 0.0 };
                for v in resid.row_mut(i) {
                    *v *= phi;
                }
            }
        }
    }
}

fn row_norm(m: &Matrix, i: usize) -> f32 {
    (m.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32
}

impl Optimizer for SubTrack {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_slots(params.len());
        let is_update_step = self.hp.interval > 0 && self.step_no % self.hp.interval == 0;
        let adam = self.adam;
        let scale = self.hp.scale;
        for i in 0..params.len() {
            let g = &grads[i];
            match params[i].kind {
                ParamKind::Matrix2D if g.rows() > 1 && g.cols() > 1 => {
                    // GaLore-style scale α on the whole low-rank update.
                    self.step_matrix(i, g, is_update_step, &mut params[i], -lr * scale);
                }
                _ => {
                    // Full-rank Adam path for 1-D params (fused, no temps).
                    if self.vecs[i].is_none() {
                        self.vecs[i] =
                            Some(VecState { moments: Moments::new(g.rows(), g.cols()) });
                    }
                    let st = self.vecs[i].as_mut().unwrap();
                    st.moments.fused_step(&adam, lr, 0.0, &mut params[i].value, g);
                    params[i].mark_dirty();
                }
            }
            if adam.weight_decay > 0.0 {
                params[i].decay(1.0 - lr * adam.weight_decay);
            }
        }
        self.step_no += 1;
    }

    fn state_bytes(&self) -> usize {
        let mats: usize = self
            .mats
            .iter()
            .flatten()
            .map(|s| s.moments.bytes() + s.proj.bytes())
            .sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.moments.bytes()).sum();
        mats + vecs
    }

    fn state_params(&self) -> usize {
        let mats: usize = self
            .mats
            .iter()
            .flatten()
            .map(|s| s.moments.params() + s.proj.params())
            .sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.moments.params()).sum();
        mats + vecs
    }

    fn subspace_updates(&self) -> usize {
        self.n_subspace_updates
    }

    fn workspace_misses(&self) -> usize {
        self.ws.misses()
    }

    fn projector_defect(&self) -> Option<f32> {
        Some(self.mats.iter().flatten().map(|s| s.proj.defect()).fold(0.0f32, f32::max))
    }

    fn poison_next_refresh(&mut self) {
        self.poison_refresh = true;
    }

    fn refresh_rejections(&self) -> usize {
        self.n_refresh_rejections
    }

    // Pack order: step_no, n_subspace_updates, n_refresh_rejections, matrix
    // slots (presence + projector + moments + prev_lambda_norm + updates +
    // the slot's name-keyed power-iteration rng — bit-exact replay requires
    // it), vector slots (presence + moments). The timing breakdown is
    // diagnostics-only and deliberately not rewound.
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = OptimizerSnapshot::new();
        snap.push_int(self.step_no as u64);
        snap.push_int(self.n_subspace_updates as u64);
        snap.push_int(self.n_refresh_rejections as u64);
        snap.push_int(self.mats.len() as u64);
        for slot in &self.mats {
            match slot {
                Some(st) => {
                    snap.push_int(1);
                    st.proj.pack(&mut snap);
                    st.moments.pack(&mut snap);
                    snap.push_float(st.prev_lambda_norm as f64);
                    snap.push_int(st.updates as u64);
                    snap.push_rng(&st.rng);
                }
                None => snap.push_int(0),
            }
        }
        snap.push_int(self.vecs.len() as u64);
        for slot in &self.vecs {
            match slot {
                Some(st) => {
                    snap.push_int(1);
                    st.moments.pack(&mut snap);
                }
                None => snap.push_int(0),
            }
        }
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let mut r = snap.reader();
        self.step_no = r.int() as usize;
        self.n_subspace_updates = r.int() as usize;
        self.n_refresh_rejections = r.int() as usize;
        let n_mats = r.int() as usize;
        self.mats.resize_with(n_mats, || None);
        for slot in &mut self.mats {
            if r.int() == 1 {
                match slot {
                    Some(st) => {
                        st.proj.unpack_into(&mut r);
                        st.moments.unpack_into(&mut r);
                        st.prev_lambda_norm = r.float() as f32;
                        st.updates = r.int() as usize;
                        st.rng = r.rng();
                    }
                    None => {
                        *slot = Some(MatState {
                            proj: Projector::unpack(&mut r),
                            moments: Moments::unpack(&mut r),
                            prev_lambda_norm: r.float() as f32,
                            updates: r.int() as usize,
                            rng: r.rng(),
                        });
                    }
                }
            } else {
                *slot = None;
            }
        }
        let n_vecs = r.int() as usize;
        self.vecs.resize_with(n_vecs, || None);
        for slot in &mut self.vecs {
            if r.int() == 1 {
                match slot {
                    Some(st) => st.moments.unpack_into(&mut r),
                    None => *slot = Some(VecState { moments: Moments::unpack(&mut r) }),
                }
            } else {
                *slot = None;
            }
        }
    }

    fn restore_ranges(&mut self, parts: &[(&OptimizerSnapshot, usize, usize)]) -> bool {
        self.mats.clear();
        self.vecs.clear();
        self.step_no = 0;
        self.n_subspace_updates = 0;
        self.n_refresh_rejections = 0;
        for &(snap, lo, hi) in parts {
            let mut r = snap.reader();
            self.step_no = self.step_no.max(r.int() as usize);
            self.n_subspace_updates = self.n_subspace_updates.max(r.int() as usize);
            self.n_refresh_rejections = self.n_refresh_rejections.max(r.int() as usize);
            let n_mats = r.int() as usize;
            assert!(hi <= n_mats, "subtrack restore_ranges: slot range {lo}..{hi} out of {n_mats}");
            for i in 0..n_mats {
                if r.int() == 1 {
                    let st = MatState {
                        proj: Projector::unpack(&mut r),
                        moments: Moments::unpack(&mut r),
                        prev_lambda_norm: r.float() as f32,
                        updates: r.int() as usize,
                        rng: r.rng(),
                    };
                    if i >= lo && i < hi {
                        self.mats.push(Some(st));
                    }
                } else if i >= lo && i < hi {
                    self.mats.push(None);
                }
            }
            let n_vecs = r.int() as usize;
            assert!(hi <= n_vecs, "subtrack restore_ranges: vec range {lo}..{hi} out of {n_vecs}");
            for i in 0..n_vecs {
                if r.int() == 1 {
                    let st = VecState { moments: Moments::unpack(&mut r) };
                    if i >= lo && i < hi {
                        self.vecs.push(Some(st));
                    }
                } else if i >= lo && i < hi {
                    self.vecs.push(None);
                }
            }
        }
        true
    }

    fn name(&self) -> String {
        self.comps.label().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};
    use crate::tensor::qr::orthonormality_defect;
    use crate::util::proptest;

    fn hp(rank: usize, interval: usize) -> HyperParams {
        HyperParams { rank, interval, scale: 1.0, eta: 0.5, ..HyperParams::default() }
    }

    #[test]
    fn converges_on_lstsq_all_variants() {
        for comps in
            [Components::full(), Components::pure(), Components::pa_only(), Components::rs_only()]
        {
            let prob = LstsqProblem::new(64, 10, 14, 40);
            let mut opt = SubTrack::new(hp(4, 10), comps);
            let (init, fin) = run_lstsq(&mut opt, &prob, 700, 0.05);
            assert!(
                fin < init * 0.1,
                "{}: init={init} final={fin}",
                comps.label()
            );
            assert!(opt.subspace_updates() > 0, "subspace must have been updated");
        }
    }

    #[test]
    fn full_beats_pure_on_lstsq() {
        // The ablation ordering of Fig. 3: full SubTrack++ ≤ pure tracking.
        let prob = LstsqProblem::new(64, 12, 16, 41);
        let mut pure = SubTrack::new(hp(3, 10), Components::pure());
        let mut full = SubTrack::new(hp(3, 10), Components::full());
        let (_, loss_pure) = run_lstsq(&mut pure, &prob, 300, 0.05);
        let (_, loss_full) = run_lstsq(&mut full, &prob, 300, 0.05);
        assert!(
            loss_full < loss_pure,
            "full {loss_full} should beat pure {loss_pure}"
        );
    }

    #[test]
    fn geodesic_preserves_orthonormality() {
        proptest::check(
            42,
            20,
            |rng| {
                let m = 6 + rng.below(20);
                let n = 6 + rng.below(20);
                let r = 1 + rng.below(5);
                let g = Matrix::randn(m, n, 1.0, rng);
                let base = Matrix::randn(m, r, 1.0, rng);
                let (s, _) = crate::tensor::qr::thin_qr(&base);
                (s, g)
            },
            |(s, g)| {
                let mut rng = Rng::new(7);
                let (s_new, _) = grassmannian_step(s, g, 0.3, 8, &mut rng);
                let defect = orthonormality_defect(&s_new);
                if defect > 1e-3 {
                    return Err(format!("orthonormality defect {defect}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn geodesic_reduces_estimation_error() {
        // Moving along the geodesic must reduce F(S) = ‖SSᵀG − G‖² for a
        // small step when the tangent is nonzero.
        let mut rng = Rng::new(43);
        let g = Matrix::randn(20, 30, 1.0, &mut rng);
        let base = Matrix::randn(20, 4, 1.0, &mut rng);
        let (s, _) = crate::tensor::qr::thin_qr(&base);
        let cost = |s: &Matrix| {
            let a = gemm::matmul_tn(s, &g);
            let back = gemm::matmul(s, &a);
            back.sub(&g).fro_norm()
        };
        let before = cost(&s);
        // η chosen so Θ = σ·η stays well inside the first quadrant for this
        // gradient scale (σ ≈ 2‖R‖‖A‖ ≈ 1e3 here).
        let (s_new, _) = grassmannian_step(&s, &g, 2e-5, 20, &mut rng);
        let after = cost(&s_new);
        assert!(
            after < before,
            "geodesic step should reduce estimation error: {after} !< {before}"
        );
    }

    #[test]
    fn repeated_geodesic_converges_to_dominant_subspace() {
        // Tracking a *fixed* rank-2 signal: iterated geodesic updates should
        // align S with the true column space.
        let mut rng = Rng::new(44);
        let u_true = {
            let raw = Matrix::randn(16, 2, 1.0, &mut rng);
            crate::tensor::qr::thin_qr(&raw).0
        };
        let coeff = Matrix::randn(2, 24, 1.0, &mut rng);
        let g = gemm::matmul(&u_true, &coeff);
        let base = Matrix::randn(16, 2, 1.0, &mut rng);
        let (mut s, _) = crate::tensor::qr::thin_qr(&base);
        for _ in 0..500 {
            let (s2, _) = grassmannian_step(&s, &g, 1e-3, 10, &mut rng);
            s = s2;
        }
        // Alignment: ‖U_trueᵀ S‖_F² → r when subspaces coincide.
        let overlap = gemm::matmul_tn(&u_true, &s).fro_norm().powi(2);
        assert!(overlap > 1.9, "subspace overlap {overlap} (want ≈ 2)");
    }

    #[test]
    fn zeta_limiter_bounds_lambda_growth() {
        // With a tiny ζ the recovery term's norm can grow at most ζ× per step.
        let prob = LstsqProblem::new(32, 8, 12, 45);
        let mut opt = SubTrack::new(
            HyperParams { rank: 2, interval: 5, zeta: 1.0001, scale: 1.0, ..Default::default() },
            Components::rs_only(),
        );
        // Just exercise it; the assertion is in the internal state we can
        // observe via convergence (no blow-up).
        let (init, fin) = run_lstsq(&mut opt, &prob, 200, 0.05);
        assert!(fin.is_finite() && fin < init, "no blow-up with tight ζ");
    }

    #[test]
    fn state_params_match_table2() {
        // Table 2: SubTrack++ optimizer state = mr + 2nr  (for m ≤ n:
        // projector mr, moments 2·(r·n)).
        let (m, n, r) = (10, 24, 4);
        let prob = LstsqProblem::new(8, m, n, 46);
        let mut opt = SubTrack::new(hp(r, 10), Components::full());
        let _ = run_lstsq(&mut opt, &prob, 2, 0.01);
        assert_eq!(opt.state_params(), m * r + 2 * n * r);
    }

    #[test]
    fn right_side_projection_works() {
        // m > n exercises the Right-side code path.
        let prob = LstsqProblem::new(64, 20, 6, 47);
        let mut opt = SubTrack::new(hp(3, 10), Components::full());
        let (init, fin) = run_lstsq(&mut opt, &prob, 400, 0.05);
        assert!(fin < init * 0.1, "right-side convergence: init={init} fin={fin}");
    }

    #[test]
    fn vector_params_take_adam_path() {
        let mut opt = SubTrack::new(hp(4, 10), Components::full());
        let mut params = vec![Param::vector("b", Matrix::zeros(1, 8))];
        let g = Matrix::full(1, 8, 1.0);
        for _ in 0..50 {
            let gc = g.clone();
            opt.step(0.1, &mut params, std::slice::from_ref(&gc));
        }
        // Moving against constant gradient: values decrease.
        assert!(params[0].value.get(0, 0) < -1.0);
        // No projector allocated for the vector param.
        assert_eq!(opt.subspace_updates(), 0);
    }

    #[test]
    fn breakdown_accumulates() {
        let prob = LstsqProblem::new(32, 10, 12, 48);
        let mut opt = SubTrack::new(hp(4, 5), Components::full());
        let _ = run_lstsq(&mut opt, &prob, 30, 0.05);
        assert!(opt.subspace_updates() >= 5);
        assert!(opt.breakdown.total() > 0.0);
    }
}
