//! LDAdam (Robert et al., 2025) — adaptive optimization from low-dimensional
//! gradient statistics.
//!
//! Three ingredients, per the paper:
//! * a PowerSGD-style projector refreshed **every iteration** by one block
//!   power-iteration sweep warm-started from the previous basis — O(mnr)
//!   per step (Table 2's row "LDAdam*: updates the subspace at every
//!   iteration");
//! * projection-aware moment rotation (the same Eqs. 8–9 SubTrack++ adopts);
//! * generalized error feedback: the compression error of the gradient is
//!   accumulated and re-injected into the next step's gradient. The feedback
//!   buffer is a full m×n matrix — visible in the paper's Table 8, where
//!   LDAdam's measured peak memory exceeds GaLore's despite equal optimizer
//!   state counts.

use super::adam::{AdamCfg, Moments};
use super::projector::{self, Projector, Side};
use super::{HyperParams, Optimizer, Param, ParamKind};
use crate::tensor::{gemm, qr, Matrix};

struct MatState {
    proj: Projector,
    moments: Moments,
    /// Error-feedback accumulator (full size).
    err: Matrix,
}

/// LDAdam optimizer.
pub struct LdAdam {
    hp: HyperParams,
    adam: AdamCfg,
    mats: Vec<Option<MatState>>,
    vecs: Vec<Option<Moments>>,
    n_subspace_updates: usize,
}

impl LdAdam {
    pub fn new(hp: HyperParams) -> LdAdam {
        LdAdam {
            hp,
            adam: AdamCfg::from(hp),
            mats: Vec::new(),
            vecs: Vec::new(),
            n_subspace_updates: 0,
        }
    }

    fn ensure_slots(&mut self, n: usize) {
        if self.mats.len() != n {
            self.mats = (0..n).map(|_| None).collect();
            self.vecs = (0..n).map(|_| None).collect();
        }
    }
}

/// One block power-iteration sweep, warm-started from the previous basis:
/// S′ = orth(Ĝ·(ĜᵀS)) where Ĝ is the (error-corrected) gradient oriented so
/// rows index the subspace dimension. O(mnr).
fn power_refresh(s: &Matrix, g_oriented: &Matrix) -> Matrix {
    let proj = gemm::matmul_tn(g_oriented, s); // n×r  (Gᵀ S)
    let y = gemm::matmul(g_oriented, &proj); // m×r  (G Gᵀ S)
    let (q, _) = qr::thin_qr(&y);
    q
}

impl Optimizer for LdAdam {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_slots(params.len());
        for i in 0..params.len() {
            let g = &grads[i];
            match params[i].kind {
                ParamKind::Matrix2D if g.rows() > 1 && g.cols() > 1 => {
                    let (m, n) = g.shape();
                    if self.mats[i].is_none() {
                        let proj = Projector::init_svd(g, self.hp.rank);
                        let (lm, ln) = proj.lowrank_shape(m, n);
                        self.mats[i] = Some(MatState {
                            proj,
                            moments: Moments::new(lm, ln),
                            err: Matrix::zeros(m, n),
                        });
                    }
                    let st = self.mats[i].as_mut().unwrap();

                    // Error feedback: optimize the corrected gradient.
                    let g_corr = g.add(&st.err);

                    // Projector refresh every iteration (warm-started power sweep).
                    let old_s = st.proj.s.clone();
                    let new_s = match st.proj.side {
                        Side::Left => power_refresh(&st.proj.s, &g_corr),
                        Side::Right => power_refresh(&st.proj.s, &g_corr.t()),
                    };
                    if st.moments.t > 0 {
                        // Projection-aware rotation (Eqs. 8–9).
                        let q = gemm::matmul_tn(&new_s, &old_s);
                        let side = st.proj.side;
                        let rot_m = projector::rotate_first_moment(&q, &st.moments.m, side);
                        let rot_v = projector::rotate_second_moment(
                            &q,
                            &st.moments.m,
                            &st.moments.v,
                            side,
                            self.adam.beta2,
                            st.moments.t,
                        );
                        st.moments.m = rot_m;
                        st.moments.v = rot_v;
                    }
                    st.proj.s = new_s;
                    self.n_subspace_updates += 1;

                    let g_low = st.proj.project(&g_corr);
                    // New error = component the projection discards.
                    st.err = g_corr.sub(&st.proj.project_back(&g_low));

                    let dir = st.moments.update(&self.adam, &g_low);
                    let delta = st.proj.project_back(&dir);
                    params[i].axpy_update(-lr * self.hp.scale, &delta);
                }
                _ => {
                    if self.vecs[i].is_none() {
                        self.vecs[i] = Some(Moments::new(g.rows(), g.cols()));
                    }
                    let st = self.vecs[i].as_mut().unwrap();
                    let dir = st.update(&self.adam, g);
                    params[i].axpy_update(-lr, &dir);
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        // Includes the full-size error-feedback buffer — this is what makes
        // LDAdam's measured memory the largest of the low-rank methods
        // (paper Table 8 / Figure 1b).
        let mats: usize = self
            .mats
            .iter()
            .flatten()
            .map(|s| s.moments.bytes() + s.proj.bytes() + s.err.len() * 4)
            .sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.bytes()).sum();
        mats + vecs
    }

    fn state_params(&self) -> usize {
        // Table 2 counts only moments + projector: mr + 2nr.
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.params() + s.proj.params()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.params()).sum();
        mats + vecs
    }

    fn subspace_updates(&self) -> usize {
        self.n_subspace_updates
    }

    fn name(&self) -> String {
        "LDAdam".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};

    #[test]
    fn converges_on_lstsq() {
        let prob = LstsqProblem::new(64, 10, 14, 70);
        let mut opt = LdAdam::new(HyperParams { rank: 4, scale: 1.0, ..HyperParams::default() });
        let (init, fin) = run_lstsq(&mut opt, &prob, 400, 0.05);
        assert!(fin < init * 0.05, "init={init} final={fin}");
        // Subspace refresh happens on every iteration for every 2-D param.
        assert_eq!(opt.subspace_updates(), 400);
    }

    #[test]
    fn error_feedback_recovers_rank1_information() {
        // Rank-1 projector on a rank-3 problem: error feedback lets LDAdam
        // still reach a much lower loss than GaLore at equal rank.
        let prob = LstsqProblem::new(64, 8, 10, 71);
        let hp = HyperParams { rank: 1, interval: 25, scale: 1.0, ..HyperParams::default() };
        let mut ld = LdAdam::new(hp);
        let mut galore = super::super::GaLore::new(hp);
        let (_, l_ld) = run_lstsq(&mut ld, &prob, 300, 0.05);
        let (_, l_ga) = run_lstsq(&mut galore, &prob, 300, 0.05);
        assert!(l_ld < l_ga, "ldadam {l_ld} should beat galore {l_ga} at rank 1");
    }

    #[test]
    fn memory_exceeds_state_params_due_to_error_buffer() {
        let (m, n, r) = (10, 24, 4);
        let prob = LstsqProblem::new(8, m, n, 72);
        let mut opt = LdAdam::new(HyperParams { rank: r, ..HyperParams::default() });
        let _ = run_lstsq(&mut opt, &prob, 2, 0.01);
        assert_eq!(opt.state_params(), m * r + 2 * n * r);
        assert_eq!(opt.state_bytes(), (m * r + 2 * n * r + m * n) * 4);
    }
}
