//! LDAdam (Robert et al., 2025) — adaptive optimization from low-dimensional
//! gradient statistics.
//!
//! Three ingredients, per the paper:
//! * a PowerSGD-style projector refreshed **every iteration** by one block
//!   power-iteration sweep warm-started from the previous basis — O(mnr)
//!   per step (Table 2's row "LDAdam*: updates the subspace at every
//!   iteration");
//! * projection-aware moment rotation (the same Eqs. 8–9 SubTrack++ adopts);
//! * generalized error feedback: the compression error of the gradient is
//!   accumulated and re-injected into the next step's gradient. The feedback
//!   buffer is a full m×n matrix — visible in the paper's Table 8, where
//!   LDAdam's measured peak memory exceeds GaLore's despite equal optimizer
//!   state counts.
//!
//! Because the refresh runs *every* step, LDAdam is the optimizer that
//! gains most from the workspace-backed refresh kernels: the corrected
//! gradient, the power-sweep temporaries, the QR scratch, and the rotation
//! buffers are all leased, so steps allocate nothing after the first.

use super::adam::{AdamCfg, Moments};
use super::projector::{self, Projector, Side};
use super::{HyperParams, Optimizer, OptimizerSnapshot, Param, ParamKind, SnapshotReader};
use crate::tensor::{gemm, qr, Matrix, Workspace};

struct MatState {
    proj: Projector,
    moments: Moments,
    /// Error-feedback accumulator (full size).
    err: Matrix,
}

/// LDAdam optimizer.
pub struct LdAdam {
    hp: HyperParams,
    adam: AdamCfg,
    mats: Vec<Option<MatState>>,
    vecs: Vec<Option<Moments>>,
    n_subspace_updates: usize,
    n_refresh_rejections: usize,
    poison_refresh: bool,
    /// Per-step refresh + projection scratch (zero steady-state allocation).
    ws: Workspace,
}

impl LdAdam {
    pub fn new(hp: HyperParams) -> LdAdam {
        LdAdam {
            hp,
            adam: AdamCfg::from(hp),
            mats: Vec::new(),
            vecs: Vec::new(),
            n_subspace_updates: 0,
            n_refresh_rejections: 0,
            poison_refresh: false,
            ws: Workspace::new(),
        }
    }

    fn ensure_slots(&mut self, n: usize) {
        if self.mats.len() != n {
            self.mats = (0..n).map(|_| None).collect();
            self.vecs = (0..n).map(|_| None).collect();
        }
    }
}

/// One block power-iteration sweep, warm-started from the previous basis:
/// S ← orth(Ĝ·(ĜᵀS)) where Ĝ is the (error-corrected) gradient oriented so
/// rows index the subspace dimension. O(mnr), computed in place with
/// workspace-leased temporaries; the orthonormalization is the WY-blocked
/// `thin_qr_into` (rank ≥ the panel width), so both the power sweep and the
/// QR trailing/Q-formation updates run through the threaded GEMM kernels.
fn power_refresh_into(s: &mut Matrix, g_oriented: &Matrix, ws: &mut Workspace) {
    let (dim, r) = s.shape();
    let ncols = g_oriented.cols();
    let mut proj = ws.take_dirty(ncols, r);
    gemm::matmul_tn_into(&mut proj, g_oriented, s, ws); // n×r  (Gᵀ S)
    let mut y = ws.take_dirty(dim, r);
    gemm::matmul_into(&mut y, g_oriented, &proj); // m×r  (G Gᵀ S)
    let mut rr = ws.take_dirty(r, r);
    qr::thin_qr_into(&y, s, &mut rr, ws);
    ws.give(rr);
    ws.give(y);
    ws.give(proj);
}

impl Optimizer for LdAdam {
    fn step(&mut self, lr: f32, params: &mut [Param], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        self.ensure_slots(params.len());
        for i in 0..params.len() {
            let g = &grads[i];
            match params[i].kind {
                ParamKind::Matrix2D if g.rows() > 1 && g.cols() > 1 => {
                    let (m, n) = g.shape();
                    if self.mats[i].is_none() {
                        let proj = Projector::init_svd(g, self.hp.rank);
                        let (lm, ln) = proj.lowrank_shape(m, n);
                        self.mats[i] = Some(MatState {
                            proj,
                            moments: Moments::new(lm, ln),
                            err: Matrix::zeros(m, n),
                        });
                    }
                    let adam = self.adam;
                    let lr_scaled = -lr * self.hp.scale;
                    // Disjoint borrows: scratch pool vs per-matrix state.
                    let LdAdam {
                        ws,
                        mats,
                        n_subspace_updates,
                        n_refresh_rejections,
                        poison_refresh,
                        ..
                    } = &mut *self;
                    let st = mats[i].as_mut().expect("initialized above");

                    // Error feedback: optimize the corrected gradient.
                    let mut g_corr = ws.take_dirty(m, n);
                    g.zip_into(&st.err, &mut g_corr, |gv, ev| gv + ev);

                    // Projector refresh every iteration (warm-started power
                    // sweep), moving the basis in place. The old basis backs
                    // the health guard: a degenerate (or fault-injected)
                    // candidate is rejected, keeping the previous basis and
                    // leaving the moments unrotated.
                    let (dim, r) = st.proj.s.shape();
                    let mut old_s = ws.take_dirty(dim, r);
                    old_s.copy_from(&st.proj.s);
                    match st.proj.side {
                        Side::Left => power_refresh_into(&mut st.proj.s, &g_corr, ws),
                        Side::Right => {
                            let mut gt = ws.take_dirty(n, m);
                            g_corr.transpose_into(&mut gt);
                            power_refresh_into(&mut st.proj.s, &gt, ws);
                            ws.give(gt);
                        }
                    }
                    if std::mem::take(poison_refresh) {
                        projector::poison_basis(&mut st.proj.s);
                    }
                    if projector::basis_acceptable(&st.proj.s, projector::REFRESH_DEFECT_TOL) {
                        if st.moments.t > 0 {
                            // Projection-aware rotation (Eqs. 8–9).
                            let mut q = ws.take_dirty(r, r);
                            gemm::matmul_tn_into(&mut q, &st.proj.s, &old_s, ws);
                            projector::rotate_moments_into(
                                &q,
                                &mut st.moments,
                                st.proj.side,
                                adam.beta2,
                                ws,
                            );
                            ws.give(q);
                        }
                        *n_subspace_updates += 1;
                    } else {
                        st.proj.s.copy_from(&old_s);
                        *n_refresh_rejections += 1;
                    }
                    ws.give(old_s);

                    let (lm, ln) = st.proj.lowrank_shape(m, n);
                    let mut g_low = ws.take_dirty(lm, ln);
                    st.proj.project_into(&g_corr, &mut g_low, ws);
                    // New error = component the projection discards.
                    st.proj.project_back_into(&g_low, &mut st.err, ws);
                    st.err.zip_assign(&g_corr, |back, gc| gc - back);

                    let mut dir = ws.take_dirty(lm, ln);
                    st.moments.update_into(&adam, &g_low, &mut dir);
                    let mut delta = ws.take_dirty(m, n);
                    st.proj.project_back_into(&dir, &mut delta, ws);
                    params[i].axpy_update(lr_scaled, &delta);
                    ws.give(delta);
                    ws.give(dir);
                    ws.give(g_low);
                    ws.give(g_corr);
                }
                _ => {
                    if self.vecs[i].is_none() {
                        self.vecs[i] = Some(Moments::new(g.rows(), g.cols()));
                    }
                    let adam = self.adam;
                    let st = self.vecs[i].as_mut().unwrap();
                    st.fused_step(&adam, lr, 0.0, &mut params[i].value, g);
                    params[i].mark_dirty();
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        // Includes the full-size error-feedback buffer — this is what makes
        // LDAdam's measured memory the largest of the low-rank methods
        // (paper Table 8 / Figure 1b). Element size derived, not hardcoded:
        // all optimizer state (moments, projectors, error feedback) is f32
        // regardless of the parameters' storage dtype.
        let mats: usize = self
            .mats
            .iter()
            .flatten()
            .map(|s| s.moments.bytes() + s.proj.bytes() + s.err.len() * std::mem::size_of::<f32>())
            .sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.bytes()).sum();
        mats + vecs
    }

    fn state_params(&self) -> usize {
        // Table 2 counts only moments + projector: mr + 2nr.
        let mats: usize =
            self.mats.iter().flatten().map(|s| s.moments.params() + s.proj.params()).sum();
        let vecs: usize = self.vecs.iter().flatten().map(|s| s.params()).sum();
        mats + vecs
    }

    fn subspace_updates(&self) -> usize {
        self.n_subspace_updates
    }

    fn workspace_misses(&self) -> usize {
        self.ws.misses()
    }

    fn projector_defect(&self) -> Option<f32> {
        Some(self.mats.iter().flatten().map(|s| s.proj.defect()).fold(0.0f32, f32::max))
    }

    fn poison_next_refresh(&mut self) {
        self.poison_refresh = true;
    }

    fn refresh_rejections(&self) -> usize {
        self.n_refresh_rejections
    }

    // Pack order: n_subspace_updates, n_refresh_rejections, matrix slots
    // (presence + projector + moments + error buffer), vector moment slots.
    fn snapshot(&self) -> OptimizerSnapshot {
        let mut snap = OptimizerSnapshot::new();
        snap.push_int(self.n_subspace_updates as u64);
        snap.push_int(self.n_refresh_rejections as u64);
        snap.push_int(self.mats.len() as u64);
        for slot in &self.mats {
            match slot {
                Some(st) => {
                    snap.push_int(1);
                    st.proj.pack(&mut snap);
                    st.moments.pack(&mut snap);
                    snap.push_mat(&st.err);
                }
                None => snap.push_int(0),
            }
        }
        super::pack_moment_slots(&mut snap, &self.vecs);
        snap
    }

    fn restore(&mut self, snap: &OptimizerSnapshot) {
        let mut r = snap.reader();
        self.n_subspace_updates = r.int() as usize;
        self.n_refresh_rejections = r.int() as usize;
        let n_mats = r.int() as usize;
        self.mats.resize_with(n_mats, || None);
        for slot in &mut self.mats {
            if r.int() == 1 {
                match slot {
                    Some(st) => {
                        st.proj.unpack_into(&mut r);
                        st.moments.unpack_into(&mut r);
                        r.mat_into(&mut st.err);
                    }
                    None => {
                        *slot = Some(MatState {
                            proj: Projector::unpack(&mut r),
                            moments: Moments::unpack(&mut r),
                            err: r.mat(),
                        });
                    }
                }
            } else {
                *slot = None;
            }
        }
        super::unpack_moment_slots(&mut r, &mut self.vecs);
    }

    fn restore_ranges(&mut self, parts: &[(&OptimizerSnapshot, usize, usize)]) -> bool {
        self.mats.clear();
        self.vecs.clear();
        self.n_subspace_updates = 0;
        self.n_refresh_rejections = 0;
        for &(snap, lo, hi) in parts {
            let mut r = snap.reader();
            self.n_subspace_updates = self.n_subspace_updates.max(r.int() as usize);
            self.n_refresh_rejections = self.n_refresh_rejections.max(r.int() as usize);
            let n_mats = r.int() as usize;
            assert!(hi <= n_mats, "ldadam restore_ranges: slot range {lo}..{hi} out of {n_mats}");
            for i in 0..n_mats {
                if r.int() == 1 {
                    let st = MatState {
                        proj: Projector::unpack(&mut r),
                        moments: Moments::unpack(&mut r),
                        err: r.mat(),
                    };
                    if i >= lo && i < hi {
                        self.mats.push(Some(st));
                    }
                } else if i >= lo && i < hi {
                    self.mats.push(None);
                }
            }
            super::keep_moment_slot_range(&mut r, &mut self.vecs, lo, hi);
        }
        true
    }

    fn name(&self) -> String {
        "LDAdam".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{run_lstsq, LstsqProblem};

    #[test]
    fn converges_on_lstsq() {
        let prob = LstsqProblem::new(64, 10, 14, 70);
        let mut opt = LdAdam::new(HyperParams { rank: 4, scale: 1.0, ..HyperParams::default() });
        let (init, fin) = run_lstsq(&mut opt, &prob, 400, 0.05);
        assert!(fin < init * 0.05, "init={init} final={fin}");
        // Subspace refresh happens on every iteration for every 2-D param.
        assert_eq!(opt.subspace_updates(), 400);
    }

    #[test]
    fn error_feedback_recovers_rank1_information() {
        // Rank-1 projector on a rank-3 problem: error feedback lets LDAdam
        // still reach a much lower loss than GaLore at equal rank.
        let prob = LstsqProblem::new(64, 8, 10, 71);
        let hp = HyperParams { rank: 1, interval: 25, scale: 1.0, ..HyperParams::default() };
        let mut ld = LdAdam::new(hp);
        let mut galore = super::super::GaLore::new(hp);
        let (_, l_ld) = run_lstsq(&mut ld, &prob, 300, 0.05);
        let (_, l_ga) = run_lstsq(&mut galore, &prob, 300, 0.05);
        assert!(l_ld < l_ga, "ldadam {l_ld} should beat galore {l_ga} at rank 1");
    }

    #[test]
    fn steps_allocate_only_on_the_first_iteration() {
        // The every-step refresh path is workspace-backed: after step 1 the
        // pool serves every lease.
        let prob = LstsqProblem::new(16, 6, 9, 73);
        let mut opt = LdAdam::new(HyperParams { rank: 2, scale: 1.0, ..HyperParams::default() });
        let _ = run_lstsq(&mut opt, &prob, 1, 0.05);
        let after_first = opt.workspace_misses();
        assert!(after_first > 0, "first step must populate the pool");
        let _ = run_lstsq_continue(&mut opt, &prob, 5);
        assert_eq!(opt.workspace_misses(), after_first, "steady state allocated");
    }

    /// Drive more steps on an already-warm optimizer (keeps its state).
    fn run_lstsq_continue(opt: &mut LdAdam, prob: &LstsqProblem, steps: usize) -> f32 {
        let (m, n) = prob.w_star.shape();
        let mut params = vec![Param::matrix("w", Matrix::zeros(m, n))];
        let mut last = 0.0;
        for _ in 0..steps {
            let (loss, grad) = prob.loss_grad(&params[0].value);
            last = loss;
            opt.step(0.05, &mut params, &[grad]);
        }
        last
    }

    #[test]
    fn memory_exceeds_state_params_due_to_error_buffer() {
        let (m, n, r) = (10, 24, 4);
        let prob = LstsqProblem::new(8, m, n, 72);
        let mut opt = LdAdam::new(HyperParams { rank: r, ..HyperParams::default() });
        let _ = run_lstsq(&mut opt, &prob, 2, 0.01);
        assert_eq!(opt.state_params(), m * r + 2 * n * r);
        assert_eq!(opt.state_bytes(), (m * r + 2 * n * r + m * n) * 4);
    }
}
