//! Llama-family model definitions: size configurations and the pure-Rust
//! forward/backward "native" engine, plus the classification head used by
//! the fine-tuning experiments.

pub mod classifier;
pub mod config;
pub mod llama;

pub use classifier::Classifier;
pub use config::ModelConfig;
pub use llama::{cross_entropy, Batch, Llama, StepState};
