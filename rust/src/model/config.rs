//! Llama-family model configurations.
//!
//! The paper's six pre-training sizes (Table 10) are kept verbatim for the
//! analytic memory/complexity tables; the `tiny`/`small`/`med` presets are
//! the scaled-down substitutes actually trained on this 1-core CPU testbed
//! (DESIGN.md §Substitutions). Scaling preserves the r ≪ m ≤ n regime on
//! every projected matrix.

use crate::tensor::Dtype;

/// Architecture + training-shape configuration for one model size.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub hidden: usize,
    pub intermediate: usize,
    pub heads: usize,
    pub layers: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub rope_theta: f32,
    /// Default projection rank for low-rank optimizers (paper Table 10).
    pub rank: usize,
    /// Weight/activation storage dtype (compute is always f32). Presets are
    /// `F32`; the training-config layer overrides it from `[model] dtype` or
    /// the `PALLAS_DTYPE` env knob, so models built directly from a preset
    /// (unit tests, gradchecks) stay in exact f32.
    pub dtype: Dtype,
}

impl ModelConfig {
    /// Look up a named preset. Paper rows: `60m`, `130m`, `350m`, `1b`, `3b`,
    /// `7b`. Scaled rows: `nano`, `tiny`, `small`, `med`.
    pub fn preset(name: &str) -> ModelConfig {
        let (hidden, intermediate, heads, layers, vocab, seq_len, rank) = match name {
            // ---- paper sizes (Table 10; vocab/seq from the GaLore setup) ----
            "60m" => (512, 1376, 8, 8, 32_000, 256, 128),
            "130m" => (768, 2048, 12, 12, 32_000, 256, 256),
            "350m" => (1024, 2736, 16, 24, 32_000, 256, 256),
            "1b" => (2048, 5461, 24, 32, 32_000, 256, 512),
            "3b" => (2560, 6848, 32, 32, 32_000, 256, 512),
            "7b" => (4096, 11_008, 32, 32, 32_000, 256, 1024),
            // ---- scaled-down testbed sizes (same family, same ratios) ----
            // nano: gradient-check scale.
            "nano" => (16, 44, 2, 1, 29, 8, 4),
            // tiny ≈ 0.2M params: unit/integration tests.
            "tiny" => (64, 172, 4, 2, 512, 32, 8),
            // small ≈ 1.9M params: the Table 1 "60M" stand-in.
            "small" => (128, 344, 4, 4, 1024, 64, 16),
            // med ≈ 11M params: the Table 1 "1B" stand-in & headline runs.
            "med" => (256, 688, 8, 6, 2048, 128, 32),
            other => panic!("unknown model preset: {other}"),
        };
        ModelConfig {
            name: name.to_string(),
            hidden,
            intermediate,
            heads,
            layers,
            vocab,
            seq_len,
            rope_theta: 10_000.0,
            rank,
            dtype: Dtype::F32,
        }
    }

    /// All paper-size presets (for analytic tables).
    pub fn paper_sizes() -> Vec<ModelConfig> {
        ["60m", "130m", "350m", "1b", "3b", "7b"].iter().map(|n| Self::preset(n)).collect()
    }

    /// The scaled presets used for measured runs.
    pub fn scaled_sizes() -> Vec<ModelConfig> {
        ["tiny", "small", "med"].iter().map(|n| Self::preset(n)).collect()
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Total trainable parameter count (untied LM head).
    pub fn param_count(&self) -> usize {
        let h = self.hidden;
        let f = self.intermediate;
        let v = self.vocab;
        let per_layer = 4 * h * h     // Wq Wk Wv Wo
            + 3 * h * f               // W1 (gate), W2 (down), W3 (up)
            + 2 * h; //               // two RMSNorm gains
        self.layers * per_layer + 2 * v * h + h // embed + head + final norm
    }

    /// Adam optimizer state parameter count (2 per trainable param).
    pub fn adam_state_params(&self) -> usize {
        2 * self.param_count()
    }

    /// Low-rank optimizer state parameter count at rank r: per 2-D matrix
    /// m×n (m ≤ n after orientation) it is mr + 2nr; 1-D params take 2
    /// full-rank entries each (Table 2 accounting).
    pub fn lowrank_state_params(&self, r: usize) -> usize {
        let mut total = 0usize;
        for (m, n) in self.matrix_shapes() {
            let (small, large) = if m <= n { (m, n) } else { (n, m) };
            let r = r.min(small);
            total += small * r + 2 * large * r;
        }
        for len in self.vector_shapes() {
            total += 2 * len;
        }
        total
    }

    /// Shapes of all 2-D parameter matrices.
    pub fn matrix_shapes(&self) -> Vec<(usize, usize)> {
        let h = self.hidden;
        let f = self.intermediate;
        let v = self.vocab;
        let mut out = Vec::new();
        out.push((v, h)); // embedding
        for _ in 0..self.layers {
            out.push((h, h)); // q
            out.push((h, h)); // k
            out.push((h, h)); // v
            out.push((h, h)); // o
            out.push((f, h)); // gate
            out.push((f, h)); // up
            out.push((h, f)); // down
        }
        out.push((v, h)); // lm head
        out
    }

    /// Lengths of all 1-D parameters (RMSNorm gains).
    pub fn vector_shapes(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for _ in 0..self.layers {
            out.push(self.hidden); // attn norm
            out.push(self.hidden); // mlp norm
        }
        out.push(self.hidden); // final norm
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_presets_match_table10() {
        let c = ModelConfig::preset("1b");
        assert_eq!(c.hidden, 2048);
        assert_eq!(c.intermediate, 5461);
        assert_eq!(c.heads, 24);
        assert_eq!(c.layers, 32);
        assert_eq!(c.rank, 512);
        let c7 = ModelConfig::preset("7b");
        assert_eq!(c7.hidden, 4096);
        assert_eq!(c7.rank, 1024);
    }

    #[test]
    fn param_counts_are_in_the_right_ballpark() {
        // The paper's names are nominal; our count (untied head, vocab 32k)
        // should land within ~2x of the nominal size.
        let approx = |name: &str| ModelConfig::preset(name).param_count() as f64;
        assert!((0.4e8..2.0e8).contains(&approx("60m")), "60m -> {}", approx("60m"));
        assert!((0.8e9..2.0e9).contains(&approx("1b")), "1b -> {}", approx("1b"));
        assert!((5.0e9..9.0e9).contains(&approx("7b")), "7b -> {}", approx("7b"));
    }

    #[test]
    fn scaled_sizes_stay_small() {
        assert!(ModelConfig::preset("tiny").param_count() < 500_000);
        assert!(ModelConfig::preset("small").param_count() < 3_000_000);
        assert!(ModelConfig::preset("med").param_count() < 20_000_000);
    }

    #[test]
    fn lowrank_state_smaller_than_adam() {
        for cfg in ModelConfig::paper_sizes() {
            let adam = cfg.adam_state_params();
            let lowrank = cfg.lowrank_state_params(cfg.rank);
            assert!(
                lowrank < adam,
                "{}: lowrank {lowrank} !< adam {adam}",
                cfg.name
            );
        }
    }

    #[test]
    fn head_dim_divides_for_instantiated_sizes() {
        // Paper sizes are analytic-only (Table 10's 1B row lists hidden 2048
        // with 24 heads, which does not divide evenly — we keep the row
        // verbatim but never instantiate it). Scaled sizes must divide.
        for cfg in ModelConfig::scaled_sizes() {
            assert_eq!(cfg.hidden % cfg.heads, 0, "{}", cfg.name);
        }
        assert_eq!(ModelConfig::preset("nano").hidden % ModelConfig::preset("nano").heads, 0);
    }

    #[test]
    #[should_panic(expected = "unknown model preset")]
    fn unknown_preset_panics() {
        let _ = ModelConfig::preset("900b");
    }
}
