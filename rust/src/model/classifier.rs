//! Sequence-classification head over the Llama body — the model used in the
//! GLUE/SuperGLUE fine-tuning experiments (paper Tables 4–5).
//!
//! Pooling follows the causal-LM convention: the classifier reads the final
//! hidden state of the *last* token of each sequence and maps it to class
//! logits through a trainable linear head. The backbone and head are trained
//! jointly (full-parameter fine-tuning), exactly the regime where the
//! low-rank optimizer family applies.

use super::config::ModelConfig;
use super::llama::{cross_entropy, Llama};
use crate::optim::Param;
use crate::tensor::{gemm, Matrix};
use crate::util::rng::Rng;

/// Llama body + linear classification head.
pub struct Classifier {
    pub body: Llama,
    /// Class logits head, (num_classes × hidden).
    pub head: Param,
    pub num_classes: usize,
}

impl Classifier {
    pub fn new(cfg: ModelConfig, num_classes: usize, seed: u64) -> Classifier {
        let body = Llama::new(cfg, seed);
        let mut rng = Rng::new(seed ^ 0xc1a55);
        let head = Param::matrix(
            "cls_head",
            Matrix::randn(num_classes, body.cfg.hidden, 0.02, &mut rng),
        );
        Classifier { body, head, num_classes }
    }

    /// Build from an already-pre-trained body (the fine-tuning workflow).
    pub fn from_pretrained(body: Llama, num_classes: usize, seed: u64) -> Classifier {
        let mut rng = Rng::new(seed ^ 0xc1a55);
        let head = Param::matrix(
            "cls_head",
            Matrix::randn(num_classes, body.cfg.hidden, 0.02, &mut rng),
        );
        Classifier { body, head, num_classes }
    }

    /// All trainable parameters: body params followed by the head.
    pub fn all_params(&self) -> Vec<Param> {
        let mut ps = self.body.params.clone();
        ps.push(self.head.clone());
        ps
    }

    /// Write back a parameter vector produced by `all_params`.
    pub fn set_params(&mut self, params: Vec<Param>) {
        assert_eq!(params.len(), self.body.params.len() + 1);
        let n = params.len();
        let mut params = params;
        self.head = params.pop().unwrap();
        self.body.params = params;
        debug_assert_eq!(self.body.params.len(), n - 1);
    }

    /// Class logits, one row per sequence: pool the last position.
    pub fn logits(&self, inputs: &[u32], b: usize, t: usize) -> Matrix {
        let cache = self.body.forward_hidden(inputs, b, t);
        let pooled = pool_last(&cache.hidden, b, t);
        gemm::matmul_nt(&pooled, &self.head.value)
    }

    /// Mean cross-entropy over sequences + gradients (parallel to
    /// `all_params` ordering).
    pub fn loss_and_grad(&self, inputs: &[u32], labels: &[u32], b: usize, t: usize) -> (f32, Vec<Matrix>) {
        assert_eq!(labels.len(), b);
        let cache = self.body.forward_hidden(inputs, b, t);
        let pooled = pool_last(&cache.hidden, b, t);
        let logits = gemm::matmul_nt(&pooled, &self.head.value);
        let (loss, dlogits) = cross_entropy(&logits, labels);
        // Head gradient.
        let dhead = gemm::matmul_tn(&dlogits, &pooled);
        // Pooled gradient -> scatter back to last positions.
        let dpooled = gemm::matmul(&dlogits, &self.head.value);
        let mut dhidden = Matrix::zeros(b * t, self.body.cfg.hidden);
        for bi in 0..b {
            dhidden.row_mut(bi * t + t - 1).copy_from_slice(dpooled.row(bi));
        }
        let mut grads = self.body.zero_grads();
        self.body.backward_hidden(cache, inputs, dhidden, &mut grads);
        grads.push(dhead);
        (loss, grads)
    }

    /// Accuracy over a labelled set.
    pub fn accuracy(&self, inputs: &[u32], labels: &[u32], b: usize, t: usize) -> f32 {
        let logits = self.logits(inputs, b, t);
        let mut correct = 0usize;
        for (bi, &label) in labels.iter().enumerate() {
            let row = logits.row(bi);
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred == label as usize {
                correct += 1;
            }
        }
        correct as f32 / labels.len().max(1) as f32
    }
}

fn pool_last(hidden: &Matrix, b: usize, t: usize) -> Matrix {
    let h = hidden.cols();
    let mut out = Matrix::zeros(b, h);
    for bi in 0..b {
        out.row_mut(bi).copy_from_slice(hidden.row(bi * t + t - 1));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Adam, AdamCfg, Optimizer};

    #[test]
    fn classifier_gradcheck_head_and_embedding() {
        let cfg = ModelConfig::preset("nano");
        let mut clf = Classifier::new(cfg.clone(), 3, 21);
        let mut rng = Rng::new(22);
        let (b, t) = (2, cfg.seq_len);
        let inputs: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        let labels = vec![0u32, 2u32];
        let (_, grads) = clf.loss_and_grad(&inputs, &labels, b, t);
        let eps = 3e-3;
        // Head entry.
        let orig = clf.head.value.get(1, 2);
        clf.head.value.set(1, 2, orig + eps);
        let lp = {
            let logits = clf.logits(&inputs, b, t);
            cross_entropy(&logits, &labels).0
        };
        clf.head.value.set(1, 2, orig - eps);
        let lm = {
            let logits = clf.logits(&inputs, b, t);
            cross_entropy(&logits, &labels).0
        };
        clf.head.value.set(1, 2, orig);
        let num = (lp - lm) / (2.0 * eps);
        let ana = grads.last().unwrap().get(1, 2);
        assert!((num - ana).abs() < 1e-2, "head grad {num} vs {ana}");
        // Embedding entry of a token that occurs in the input.
        let tok = inputs[0] as usize;
        let orig = clf.body.params[0].value.get(tok, 0);
        clf.body.params[0].value.set(tok, 0, orig + eps);
        let lp = {
            let logits = clf.logits(&inputs, b, t);
            cross_entropy(&logits, &labels).0
        };
        clf.body.params[0].value.set(tok, 0, orig - eps);
        let lm = {
            let logits = clf.logits(&inputs, b, t);
            cross_entropy(&logits, &labels).0
        };
        clf.body.params[0].value.set(tok, 0, orig);
        let num = (lp - lm) / (2.0 * eps);
        let ana = grads[0].get(tok, 0);
        assert!((num - ana).abs() < 1e-2, "embed grad {num} vs {ana}");
    }

    #[test]
    fn finetuning_learns_a_separable_task() {
        // Label = whether the last token is below vocab/2 — trivially
        // separable from the final hidden state.
        let cfg = ModelConfig::preset("nano");
        let mut clf = Classifier::new(cfg.clone(), 2, 30);
        let mut rng = Rng::new(31);
        let (b, t) = (8, cfg.seq_len);
        let make = |rng: &mut Rng| {
            let inputs: Vec<u32> =
                (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
            let labels: Vec<u32> = (0..b)
                .map(|bi| (inputs[bi * t + t - 1] < cfg.vocab as u32 / 2) as u32)
                .collect();
            (inputs, labels)
        };
        let mut opt = Adam::new(AdamCfg::default());
        for _ in 0..60 {
            let (inputs, labels) = make(&mut rng);
            let (_, grads) = clf.loss_and_grad(&inputs, &labels, b, t);
            let mut params = clf.all_params();
            opt.step(5e-3, &mut params, &grads);
            clf.set_params(params);
        }
        let (inputs, labels) = make(&mut rng);
        let acc = clf.accuracy(&inputs, &labels, b, t);
        assert!(acc >= 0.75, "fine-tuned accuracy {acc}");
    }
}
