//! Llama-family transformer with a hand-written backward pass — the "native"
//! training engine.
//!
//! Architecture (matches the paper's pre-training setup): token embedding →
//! L × [RMSNorm → multi-head causal attention with RoPE → residual →
//! RMSNorm → SwiGLU MLP → residual] → RMSNorm → untied LM head →
//! cross-entropy loss.
//!
//! Everything operates on flattened (B·T)×H row-major matrices. The backward
//! pass is exact (verified against central finite differences in the tests
//! below and in `rust/tests/gradcheck.rs`).
//!
//! # The allocation-free step loop
//!
//! The `_ws` entry points ([`Llama::forward_hidden_ws`],
//! [`Llama::backward_hidden_ws`], [`Llama::loss_and_grad_into`]) thread a
//! persistent [`StepState`] — a [`Workspace`] buffer pool, a
//! [`TransposeCache`] of `Wᵀ` per weight, and a [`WorkspaceBank`] of
//! per-task attention scratch — through the whole pass. Every intermediate
//! (activations, attention probabilities, gradients of activations, RoPE
//! tables) is leased from the pool and returned before the step ends, so
//! steady-state steps allocate no matrix buffers (only the small
//! Vec-of-pointer containers holding them are rebuilt per step); the
//! transpose cache makes the `x·Wᵀ` linears pay their O(h²) transpose once
//! per weight *update* instead of once per call. The historical allocating
//! API ([`Llama::loss`], [`Llama::loss_and_grad`], …) now wraps the `_ws`
//! path with a throwaway state, which keeps direct weight pokes (e.g.
//! finite-difference tests) safe: a fresh transpose cache can never be
//! stale.
//!
//! # Head-parallel attention
//!
//! The per-(batch, head) attention work — forward and backward — is fanned
//! out on the persistent worker pool: each `(bi, hi)` pair is one pool task
//! that slices its own Q/K/V head views, runs the fused triangular
//! causal-softmax pipeline ([`gemm::attn_scores_into`] →
//! [`ops::causal_softmax_rows`] → [`gemm::attn_apply_into`], never touching
//! the masked upper triangle), and writes disjoint column bands of
//! `attn_cat` / `dqkv`. Task scratch is leased per task from the
//! [`StepState`]'s pre-sized [`WorkspaceBank`], and the kernels inside a
//! task are purely sequential (the same single-budget pattern the
//! data-parallel shards use), so losses and gradients are **bit-identical
//! across 1/2/8 workers** at fixed chunk settings
//! (`rust/tests/attn_parallel.rs`). The three QKV projections run as one
//! `(B·T)×h · h×3h` GEMM against the cached fused `[Wqᵀ|Wkᵀ|Wvᵀ]` (and
//! gate/up as `x·[Wgᵀ|Wuᵀ]`), with the matching stacked operands fusing the
//! backward `dn1`/`dn2` accumulations — fewer, larger GEMMs that clear the
//! threading gate where per-weight products did not.

use super::config::ModelConfig;
use crate::optim::{Param, TransposeCache};
use crate::tensor::pool::{self, SendPtr};
use crate::tensor::{dtype, gemm, ops, Dtype, Matrix, Workspace, WorkspaceBank};
use crate::util::rng::Rng;

/// A training batch of token ids. `inputs[b*t + i]` is position i of sequence
/// b; `targets` is the next-token shift (or classification labels when used
/// through the classifier head).
#[derive(Clone, Debug)]
pub struct Batch {
    pub inputs: Vec<u32>,
    pub targets: Vec<u32>,
    pub b: usize,
    pub t: usize,
}

impl Batch {
    pub fn tokens(&self) -> usize {
        self.b * self.t
    }
}

/// Persistent per-driver state for the zero-allocation step loop: the
/// scratch-buffer pool and the cached weight transposes. Owned by whoever
/// drives repeated steps (the trainer, a DP worker, a bench harness). Do not
/// share one across code that mutates weights without bumping
/// [`Param::version`] — see the module docs.
#[derive(Default)]
pub struct StepState {
    pub ws: Workspace,
    pub tcache: TransposeCache,
    /// Per-task scratch for the head-parallel attention fan-out: concurrent
    /// pool tasks lease whole workspaces from this bank (see the leasing
    /// rules in `tensor::workspace`). Pre-sized on the first step; recycled
    /// across steps so the zero-allocation contract extends to the fan-out
    /// (gated by `rust/tests/zero_alloc.rs`).
    pub heads: WorkspaceBank,
}

impl StepState {
    pub fn new() -> StepState {
        StepState::default()
    }
}

/// Parameter index layout. Per layer: [attn_norm, wq, wk, wv, wo, mlp_norm,
/// w_gate, w_up, w_down]; global: embed first, final_norm + lm_head last.
#[derive(Clone, Copy)]
struct LayerIdx(usize);

impl LayerIdx {
    const STRIDE: usize = 9;
    fn attn_norm(self) -> usize {
        self.0
    }
    fn wq(self) -> usize {
        self.0 + 1
    }
    fn wk(self) -> usize {
        self.0 + 2
    }
    fn wv(self) -> usize {
        self.0 + 3
    }
    fn wo(self) -> usize {
        self.0 + 4
    }
    fn mlp_norm(self) -> usize {
        self.0 + 5
    }
    fn w_gate(self) -> usize {
        self.0 + 6
    }
    fn w_up(self) -> usize {
        self.0 + 7
    }
    fn w_down(self) -> usize {
        self.0 + 8
    }
}

const RMS_EPS: f32 = 1e-5;

/// Fused-operand slot layout in the [`TransposeCache`]'s multi-param table:
/// four slots per layer, offset by `layer · FUSED_SLOTS_PER_LAYER`. The
/// slot ↔ parameter-set mapping is fixed for the cache's lifetime (the
/// cache keys fused entries on source *versions*, not identities).
const FUSED_SLOTS_PER_LAYER: usize = 4;
/// `[Wqᵀ | Wkᵀ | Wvᵀ]` — the h×3h fused QKV projection operand.
const FUSED_QKV_T: usize = 0;
/// `[Wq; Wk; Wv]` — the 3h×h stack the fused `dn1` accumulation multiplies.
const FUSED_QKV_STACK: usize = 1;
/// `[Wgᵀ | Wuᵀ]` — the h×2f fused SwiGLU gate/up projection operand.
const FUSED_GU_T: usize = 2;
/// `[Wg; Wu]` — the 2f×h stack the fused `dn2` accumulation multiplies.
const FUSED_GU_STACK: usize = 3;

/// The model: a parameter vector in a fixed layout plus the config.
pub struct Llama {
    pub cfg: ModelConfig,
    pub params: Vec<Param>,
}

/// Per-layer forward cache needed by the backward pass. Every matrix and
/// vector in here is leased from the step workspace and returned by
/// `layer_backward` (or [`Cache::recycle`]).
struct LayerCache {
    /// Input to the layer (pre attention-norm).
    x_in: Matrix,
    /// RMSNorm #1 output.
    n1: Matrix,
    /// Inverse RMS of x_in rows.
    inv_rms1: Vec<f32>,
    /// Fused post-RoPE projections, (B·T)×3h: columns [0, h) hold Q,
    /// [h, 2h) hold K, [2h, 3h) hold V.
    qkv: Matrix,
    /// Causal attention probabilities, one T×T matrix per (batch, head).
    /// Only the lower triangle is meaningful: the fused causal softmax
    /// never writes the masked half (it holds stale workspace data), and
    /// the backward kernels never read it.
    probs: Vec<Matrix>,
    /// Concatenated head outputs (input of Wo).
    attn_cat: Matrix,
    /// Residual stream after attention (input of MLP block).
    x_mid: Matrix,
    /// RMSNorm #2 output.
    n2: Matrix,
    inv_rms2: Vec<f32>,
    /// Fused SwiGLU pre-activations, (B·T)×2f: columns [0, f) hold the gate
    /// (z1 = n2·Wgᵀ), [f, 2f) the up projection (z3 = n2·Wuᵀ).
    z_gu: Matrix,
    /// silu(z1) ⊙ z3 (input of Wdown).
    h: Matrix,
}

impl LayerCache {
    fn recycle(self, ws: &mut Workspace) {
        ws.give(self.x_in);
        ws.give(self.n1);
        ws.give_vec(self.inv_rms1);
        ws.give(self.qkv);
        for p in self.probs {
            ws.give(p);
        }
        ws.give(self.attn_cat);
        ws.give(self.x_mid);
        ws.give(self.n2);
        ws.give_vec(self.inv_rms2);
        ws.give(self.z_gu);
        ws.give(self.h);
    }
}

/// Full forward cache.
pub struct Cache {
    layers: Vec<LayerCache>,
    /// Input of the final RMSNorm.
    x_final: Matrix,
    inv_rms_final: Vec<f32>,
    /// Final normed hidden states (input of the LM/classifier head).
    pub hidden: Matrix,
    b: usize,
    t: usize,
}

impl Cache {
    /// Return every buffer to the workspace (used by loss-only paths;
    /// `backward_hidden_ws` recycles incrementally as it walks the layers).
    pub fn recycle(self, ws: &mut Workspace) {
        ws.give(self.hidden);
        ws.give(self.x_final);
        ws.give_vec(self.inv_rms_final);
        for lc in self.layers {
            lc.recycle(ws);
        }
    }
}

impl Llama {
    /// Initialize with N(0, 0.02)-style scaled init (matching the GaLore
    /// reference setup: normal init, residual projections scaled by √(2L)).
    pub fn new(cfg: ModelConfig, seed: u64) -> Llama {
        let mut rng = Rng::new(seed);
        let h = cfg.hidden;
        let f = cfg.intermediate;
        let v = cfg.vocab;
        let std = 0.02f32;
        let resid_std = std / ((2 * cfg.layers) as f32).sqrt();
        let mut params = Vec::new();
        params.push(Param::matrix("embed", Matrix::randn(v, h, std, &mut rng)));
        for l in 0..cfg.layers {
            let p = |n: &str| format!("layer{l}.{n}");
            params.push(Param::vector(&p("attn_norm"), Matrix::full(1, h, 1.0)));
            params.push(Param::matrix(&p("wq"), Matrix::randn(h, h, std, &mut rng)));
            params.push(Param::matrix(&p("wk"), Matrix::randn(h, h, std, &mut rng)));
            params.push(Param::matrix(&p("wv"), Matrix::randn(h, h, std, &mut rng)));
            params.push(Param::matrix(&p("wo"), Matrix::randn(h, h, resid_std, &mut rng)));
            params.push(Param::vector(&p("mlp_norm"), Matrix::full(1, h, 1.0)));
            params.push(Param::matrix(&p("w_gate"), Matrix::randn(f, h, std, &mut rng)));
            params.push(Param::matrix(&p("w_up"), Matrix::randn(f, h, std, &mut rng)));
            params.push(Param::matrix(&p("w_down"), Matrix::randn(h, f, resid_std, &mut rng)));
        }
        params.push(Param::vector("final_norm", Matrix::full(1, h, 1.0)));
        params.push(Param::matrix("lm_head", Matrix::randn(v, h, std, &mut rng)));
        // Under a 16-bit storage dtype every weight starts on the storage
        // grid (and stays there: the optimizer write-back re-quantizes), so
        // a fresh run and a checkpoint-reloaded one see identical bytes.
        if cfg.dtype != Dtype::F32 {
            for p in &mut params {
                p.set_storage_dtype(cfg.dtype);
            }
        }
        Llama { cfg, params }
    }

    fn layer_idx(&self, l: usize) -> LayerIdx {
        LayerIdx(1 + l * LayerIdx::STRIDE)
    }

    fn final_norm_idx(&self) -> usize {
        1 + self.cfg.layers * LayerIdx::STRIDE
    }

    fn head_idx(&self) -> usize {
        self.final_norm_idx() + 1
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Zero-shaped gradient buffers parallel to `params`.
    pub fn zero_grads(&self) -> Vec<Matrix> {
        self.params
            .iter()
            .map(|p| Matrix::zeros(p.value.rows(), p.value.cols()))
            .collect()
    }

    // ------------------------------------------------------------------
    // forward
    // ------------------------------------------------------------------

    /// Forward through the transformer body, returning the final normed
    /// hidden states and the cache for backward. Allocating wrapper around
    /// [`forward_hidden_ws`] (fresh state per call).
    ///
    /// [`forward_hidden_ws`]: Llama::forward_hidden_ws
    pub fn forward_hidden(&self, inputs: &[u32], b: usize, t: usize) -> Cache {
        self.forward_hidden_ws(inputs, b, t, &mut StepState::new())
    }

    /// Workspace-backed forward pass: every cache buffer is leased from
    /// `state.ws`, weight transposes come from `state.tcache`.
    pub fn forward_hidden_ws(
        &self,
        inputs: &[u32],
        b: usize,
        t: usize,
        state: &mut StepState,
    ) -> Cache {
        assert_eq!(inputs.len(), b * t);
        let h = self.cfg.hidden;
        // Embedding gather.
        let embed = &self.params[0].value;
        let mut x = state.ws.take_dirty(b * t, h);
        for (row, &id) in inputs.iter().enumerate() {
            x.row_mut(row).copy_from_slice(embed.row(id as usize));
        }

        let mut layers = Vec::with_capacity(self.cfg.layers);
        for l in 0..self.cfg.layers {
            let (x_next, cache) = self.layer_forward(l, x, b, t, state);
            layers.push(cache);
            x = x_next;
        }

        // Final RMSNorm.
        let gain = &self.params[self.final_norm_idx()].value;
        let mut hidden = state.ws.take_dirty(b * t, h);
        let mut inv_rms_final = state.ws.take_vec_dirty(b * t);
        rmsnorm_forward_into(&x, gain, &mut hidden, &mut inv_rms_final);
        quantize_act(self.cfg.dtype, &mut hidden);
        Cache { layers, x_final: x, inv_rms_final, hidden, b, t }
    }

    fn layer_forward(
        &self,
        l: usize,
        x_in: Matrix,
        b: usize,
        t: usize,
        state: &mut StepState,
    ) -> (Matrix, LayerCache) {
        let idx = self.layer_idx(l);
        let cfg = &self.cfg;
        let n_heads = cfg.heads;
        let d = cfg.head_dim();
        let h = cfg.hidden;
        let bt = b * t;
        let slot = l * FUSED_SLOTS_PER_LAYER;
        // Storage dtype for activations: each written-out activation buffer
        // is rounded onto the storage grid while the accumulations inside
        // every kernel stay f32 (no-op under f32 — the guard in
        // `quantize_act` keeps the f32 path byte-identical).
        let dt = cfg.dtype;
        let StepState { ws, tcache, heads } = state;

        // ---- attention block ----
        let mut n1 = ws.take_dirty(bt, h);
        let mut inv_rms1 = ws.take_vec_dirty(bt);
        rmsnorm_forward_into(&x_in, &self.params[idx.attn_norm()].value, &mut n1, &mut inv_rms1);
        quantize_act(dt, &mut n1);
        // Fused QKV projection: one (B·T)×h · h×3h GEMM against the cached
        // [Wqᵀ|Wkᵀ|Wvᵀ] — large enough to clear the GEMM threading gate
        // where three separate h×h products were not.
        let mut qkv = ws.take_dirty(bt, 3 * h);
        let qkv_t = tcache.get_fused_transpose(
            slot + FUSED_QKV_T,
            &[&self.params[idx.wq()], &self.params[idx.wk()], &self.params[idx.wv()]],
        );
        gemm::matmul_into(&mut qkv, &n1, qkv_t);
        // RoPE on the Q and K column bands of the fused buffer.
        rope_apply_ws(&mut qkv, t, n_heads, d, cfg.rope_theta, false, 0, ws);
        rope_apply_ws(&mut qkv, t, n_heads, d, cfg.rope_theta, false, h, ws);
        quantize_act(dt, &mut qkv);

        // Per-(batch, head) causal attention, one pool task per pair. Each
        // task leases its scratch from the pre-sized bank, runs the fused
        // triangular pipeline sequentially, and writes a disjoint column
        // band of attn_cat plus its own probs entry — so results are
        // bit-identical for any worker count.
        let mut attn_cat = ws.take_dirty(bt, h);
        let mut probs: Vec<Matrix> = (0..b * n_heads).map(|_| ws.take_dirty(t, t)).collect();
        let scale = 1.0 / (d as f32).sqrt();
        let workers = attn_plan(b, n_heads, t, d);
        heads.ensure(workers, &head_scratch_sizes(t, d));
        {
            let qkv_ref = &qkv;
            let heads_ref = &*heads;
            let cat_base = SendPtr::new(attn_cat.data_mut().as_mut_ptr());
            let probs_base = SendPtr::new(probs.as_mut_ptr());
            pool::run(workers, b * n_heads, &|ti| {
                // Kernel-level threading opted out inside the task (the DP
                // shards' single-budget pattern): the fan-out owns the
                // cores; the triangular kernels are sequential by design.
                gemm::run_single_threaded(|| {
                    let (bi, hi) = (ti / n_heads, ti % n_heads);
                    let mut tws = heads_ref.lease();
                    let mut qs = tws.take_dirty(t, d);
                    let mut ks = tws.take_dirty(t, d);
                    let mut vs = tws.take_dirty(t, d);
                    let mut out = tws.take_dirty(t, d);
                    slice_head_into(qkv_ref, &mut qs, bi, t, hi * d, d);
                    slice_head_into(qkv_ref, &mut ks, bi, t, h + hi * d, d);
                    slice_head_into(qkv_ref, &mut vs, bi, t, 2 * h + hi * d, d);
                    // SAFETY: task ti exclusively owns probs[ti].
                    let scores = unsafe { &mut *probs_base.get().add(ti) };
                    gemm::attn_scores_into(scores, &qs, &ks, 1.0, &mut tws);
                    ops::causal_softmax_rows(scores, scale);
                    quantize_probs_prefix(dt, scores);
                    gemm::attn_apply_into(&mut out, scores, &vs); // T×D
                    // SAFETY: each (bi, hi) task owns a disjoint (row,
                    // column band) region of attn_cat.
                    unsafe { write_head_raw(cat_base, h, &out, bi, t, hi * d, d) };
                    tws.give(qs);
                    tws.give(ks);
                    tws.give(vs);
                    tws.give(out);
                    heads_ref.release(tws);
                });
            });
        }
        quantize_act(dt, &mut attn_cat);
        let mut attn_out = ws.take_dirty(bt, h);
        gemm::matmul_into(&mut attn_out, &attn_cat, tcache.get(idx.wo(), &self.params[idx.wo()]));
        // Residual, folded in place: x_mid = x_in + attn_out.
        attn_out.axpy(1.0, &x_in);
        quantize_act(dt, &mut attn_out);
        let x_mid = attn_out;

        // ---- MLP block (SwiGLU) ----
        let mut n2 = ws.take_dirty(bt, h);
        let mut inv_rms2 = ws.take_vec_dirty(bt);
        rmsnorm_forward_into(&x_mid, &self.params[idx.mlp_norm()].value, &mut n2, &mut inv_rms2);
        quantize_act(dt, &mut n2);
        let f = cfg.intermediate;
        // Fused gate/up projection: one (B·T)×h · h×2f GEMM.
        let mut z_gu = ws.take_dirty(bt, 2 * f);
        let gu_t = tcache.get_fused_transpose(
            slot + FUSED_GU_T,
            &[&self.params[idx.w_gate()], &self.params[idx.w_up()]],
        );
        gemm::matmul_into(&mut z_gu, &n2, gu_t);
        quantize_act(dt, &mut z_gu);
        let mut h_act = ws.take_dirty(bt, f);
        {
            // h = silu(z1) ⊙ z3, reading each fused row's gate|up halves.
            let zd = z_gu.data();
            let hd = h_act.data_mut();
            for r in 0..bt {
                let (gate, up) = zd[r * 2 * f..(r + 1) * 2 * f].split_at(f);
                let hrow = &mut hd[r * f..(r + 1) * f];
                for ((hv, &g), &u) in hrow.iter_mut().zip(gate).zip(up) {
                    *hv = silu(g) * u;
                }
            }
        }
        quantize_act(dt, &mut h_act);
        let mut mlp_out = ws.take_dirty(bt, h);
        let wd_t = tcache.get(idx.w_down(), &self.params[idx.w_down()]);
        gemm::matmul_into(&mut mlp_out, &h_act, wd_t);
        mlp_out.axpy(1.0, &x_mid);
        quantize_act(dt, &mut mlp_out);
        let x_out = mlp_out;

        (
            x_out,
            LayerCache {
                x_in,
                n1,
                inv_rms1,
                qkv,
                probs,
                attn_cat,
                x_mid,
                n2,
                inv_rms2,
                z_gu,
                h: h_act,
            },
        )
    }

    /// Language-model logits for the final hidden states. Allocating
    /// wrapper around [`logits_ws`] (fresh state per call, so direct weight
    /// pokes stay safe).
    ///
    /// [`logits_ws`]: Llama::logits_ws
    pub fn logits(&self, hidden: &Matrix) -> Matrix {
        self.logits_ws(hidden, &mut StepState::new())
    }

    /// Workspace-backed logits: the output buffer is leased from `state.ws`
    /// (return it with `give` when done) and the LM head's transpose comes
    /// from the cache — the historical `matmul_nt` path re-transposed the
    /// full vocab×h head matrix on every eval call.
    pub fn logits_ws(&self, hidden: &Matrix, state: &mut StepState) -> Matrix {
        let head = self.head_idx();
        let StepState { ws, tcache, .. } = state;
        let mut out = ws.take_dirty(hidden.rows(), self.cfg.vocab);
        gemm::matmul_into(&mut out, hidden, tcache.get(head, &self.params[head]));
        out
    }

    /// Full LM forward: mean cross-entropy of next-token prediction.
    /// Allocating wrapper around [`loss_ws`].
    ///
    /// [`loss_ws`]: Llama::loss_ws
    pub fn loss(&self, batch: &Batch) -> f32 {
        self.loss_ws(batch, &mut StepState::new())
    }

    /// Loss with persistent step state (allocation-free after warmup).
    pub fn loss_ws(&self, batch: &Batch, state: &mut StepState) -> f32 {
        let cache = self.forward_hidden_ws(&batch.inputs, batch.b, batch.t, state);
        let bt = batch.b * batch.t;
        let head = self.head_idx();
        let StepState { ws, tcache, .. } = state;
        let mut logits = ws.take_dirty(bt, self.cfg.vocab);
        gemm::matmul_into(&mut logits, &cache.hidden, tcache.get(head, &self.params[head]));
        let loss = cross_entropy_loss(&logits, &batch.targets);
        ws.give(logits);
        cache.recycle(ws);
        loss
    }

    /// Loss + full gradient vector (parallel to `self.params`). Allocating
    /// wrapper around [`loss_and_grad_into`].
    ///
    /// [`loss_and_grad_into`]: Llama::loss_and_grad_into
    pub fn loss_and_grad(&self, batch: &Batch) -> (f32, Vec<Matrix>) {
        let mut grads = self.zero_grads();
        let loss = self.loss_and_grad_into(batch, &mut grads, &mut StepState::new());
        (loss, grads)
    }

    /// The steady-state training step: loss + gradients written into the
    /// caller's persistent `grads` buffers (zeroed first), every temporary
    /// leased from `state`. After the first (warm-up) step this performs no
    /// heap allocation — see `rust/tests/zero_alloc.rs`.
    pub fn loss_and_grad_into(
        &self,
        batch: &Batch,
        grads: &mut [Matrix],
        state: &mut StepState,
    ) -> f32 {
        assert_eq!(grads.len(), self.params.len(), "grads must parallel params");
        for g in grads.iter_mut() {
            g.data_mut().fill(0.0);
        }
        let cache = self.forward_hidden_ws(&batch.inputs, batch.b, batch.t, state);
        let bt = batch.b * batch.t;
        let head = self.head_idx();
        let (loss, dhidden) = {
            let StepState { ws, tcache, .. } = state;
            let mut logits = ws.take_dirty(bt, self.cfg.vocab);
            gemm::matmul_into(&mut logits, &cache.hidden, tcache.get(head, &self.params[head]));
            let mut dlogits = ws.take_dirty(bt, self.cfg.vocab);
            let loss = cross_entropy_into(&logits, &batch.targets, &mut dlogits);
            ws.give(logits);
            // Head: logits = hidden·Wᵀ ⇒ dW = dlogitsᵀ·hidden.
            gemm::matmul_tn_acc(&mut grads[head], &dlogits, &cache.hidden, 1.0, ws);
            let mut dhidden = ws.take_dirty(bt, self.cfg.hidden);
            gemm::matmul_into(&mut dhidden, &dlogits, &self.params[head].value);
            ws.give(dlogits);
            (loss, dhidden)
        };
        self.backward_hidden_ws(cache, &batch.inputs, dhidden, grads, state);
        loss
    }

    // ------------------------------------------------------------------
    // backward
    // ------------------------------------------------------------------

    /// Backpropagate `dhidden` (gradient w.r.t. the final normed hidden
    /// states) through the body, accumulating into `grads`. Allocating
    /// wrapper around [`backward_hidden_ws`].
    ///
    /// [`backward_hidden_ws`]: Llama::backward_hidden_ws
    pub fn backward_hidden(
        &self,
        cache: Cache,
        inputs: &[u32],
        dhidden: Matrix,
        grads: &mut [Matrix],
    ) {
        self.backward_hidden_ws(cache, inputs, dhidden, grads, &mut StepState::new());
    }

    /// Workspace-backed backward pass. Consumes the forward cache, recycling
    /// every buffer (including `dhidden`) into `state.ws` as it goes.
    pub fn backward_hidden_ws(
        &self,
        cache: Cache,
        inputs: &[u32],
        dhidden: Matrix,
        grads: &mut [Matrix],
        state: &mut StepState,
    ) {
        let Cache { mut layers, x_final, inv_rms_final, hidden, b, t } = cache;
        // Final RMSNorm backward.
        let fin = self.final_norm_idx();
        let mut dx = state.ws.take_dirty(b * t, self.cfg.hidden);
        rmsnorm_backward_acc(
            &x_final,
            &inv_rms_final,
            &self.params[fin].value,
            &dhidden,
            &mut dx,
            &mut grads[fin],
        );
        state.ws.give(dhidden);
        state.ws.give(x_final);
        state.ws.give_vec(inv_rms_final);
        state.ws.give(hidden);

        for l in (0..self.cfg.layers).rev() {
            let lc = layers.pop().expect("one cache per layer");
            dx = self.layer_backward(l, lc, dx, b, t, grads, state);
        }

        // Embedding scatter-add.
        for (row, &id) in inputs.iter().enumerate() {
            let grow = dx.row(row);
            let erow = grads[0].row_mut(id as usize);
            for (e, &g) in erow.iter_mut().zip(grow) {
                *e += g;
            }
        }
        state.ws.give(dx);
    }

    #[allow(clippy::too_many_arguments)] // mirrors the math: one arg per tensor in the chain rule
    fn layer_backward(
        &self,
        l: usize,
        lc: LayerCache,
        dx_out: Matrix,
        b: usize,
        t: usize,
        grads: &mut [Matrix],
        state: &mut StepState,
    ) -> Matrix {
        let idx = self.layer_idx(l);
        let cfg = &self.cfg;
        let n_heads = cfg.heads;
        let d = cfg.head_dim();
        let h = cfg.hidden;
        let bt = b * t;
        let f = cfg.intermediate;
        let slot = l * FUSED_SLOTS_PER_LAYER;
        let StepState { ws, tcache, heads } = state;

        // ---- MLP block backward ----
        // x_out = x_mid + h·Wdᵀ
        let mut dh = ws.take_dirty(bt, f);
        gemm::matmul_into(&mut dh, &dx_out, &self.params[idx.w_down()].value); // (BT)×F
        gemm::matmul_tn_acc(&mut grads[idx.w_down()], &dx_out, &lc.h, 1.0, ws);
        // h = silu(z1) ⊙ z3, differentiated into the fused [dz_gate | dz_up]
        // layout so the weight-grad and dn2 GEMMs below fuse too.
        let mut dz_gu = ws.take_dirty(bt, 2 * f);
        {
            let dhd = dh.data();
            let zd = lc.z_gu.data();
            let od = dz_gu.data_mut();
            for r in 0..bt {
                let (zg, zu) = zd[r * 2 * f..(r + 1) * 2 * f].split_at(f);
                let (og, ou) = od[r * 2 * f..(r + 1) * 2 * f].split_at_mut(f);
                let dhrow = &dhd[r * f..(r + 1) * f];
                for j in 0..f {
                    og[j] = dhrow[j] * silu_grad(zg[j]) * zu[j];
                    ou[j] = dhrow[j] * silu(zg[j]);
                }
            }
        }
        ws.give(dh);
        // Fused gate/up weight grads: one (2F)×h Aᵀ·B whose row blocks are
        // the per-weight gradients (contiguous in the row-major buffer).
        let mut dw_gu = ws.take_dirty(2 * f, h);
        gemm::matmul_tn_into(&mut dw_gu, &dz_gu, &lc.n2, ws);
        acc_rows(&mut grads[idx.w_gate()], &dw_gu.data()[..f * h]);
        acc_rows(&mut grads[idx.w_up()], &dw_gu.data()[f * h..]);
        ws.give(dw_gu);
        // Fused dn2 = dz_gu · [Wg; Wu] — one GEMM instead of two
        // accumulations, against the cached stack.
        let gu_stack = tcache.get_fused_stack(
            slot + FUSED_GU_STACK,
            &[&self.params[idx.w_gate()], &self.params[idx.w_up()]],
        );
        let mut dn2 = ws.take_dirty(bt, h);
        gemm::matmul_into(&mut dn2, &dz_gu, gu_stack);
        ws.give(dz_gu);
        // RMSNorm #2
        let mut dx_mid_norm = ws.take_dirty(bt, h);
        rmsnorm_backward_acc(
            &lc.x_mid,
            &lc.inv_rms2,
            &self.params[idx.mlp_norm()].value,
            &dn2,
            &mut dx_mid_norm,
            &mut grads[idx.mlp_norm()],
        );
        ws.give(dn2);
        // Residual: dx_mid = dx_out + dx_mid_norm (folded in place).
        dx_mid_norm.axpy(1.0, &dx_out);
        let dx_mid = dx_mid_norm;
        ws.give(dx_out);

        // ---- attention block backward ----
        // attn_out = attn_cat·Woᵀ ; x_mid = x_in + attn_out
        let mut dattn_cat = ws.take_dirty(bt, h);
        gemm::matmul_into(&mut dattn_cat, &dx_mid, &self.params[idx.wo()].value);
        gemm::matmul_tn_acc(&mut grads[idx.wo()], &dx_mid, &lc.attn_cat, 1.0, ws);

        // Head-parallel backward: one pool task per (batch, head), writing
        // disjoint column bands of the fused dqkv. Every kernel inside a
        // task is prefix-aware — the masked upper triangle of the cached
        // probs (stale workspace data) is never read.
        let scale = 1.0 / (d as f32).sqrt();
        let mut dqkv = ws.take_dirty(bt, 3 * h);
        let workers = attn_plan(b, n_heads, t, d);
        heads.ensure(workers, &head_scratch_sizes(t, d));
        {
            let qkv_ref = &lc.qkv;
            let dcat_ref = &dattn_cat;
            let probs_ref = &lc.probs;
            let heads_ref = &*heads;
            let dqkv_base = SendPtr::new(dqkv.data_mut().as_mut_ptr());
            pool::run(workers, b * n_heads, &|ti| {
                // Same single-budget opt-out as the forward fan-out.
                gemm::run_single_threaded(|| {
                    let (bi, hi) = (ti / n_heads, ti % n_heads);
                    let p = &probs_ref[ti]; // T×T, lower triangle live
                    let mut tws = heads_ref.lease();
                    let mut dout = tws.take_dirty(t, d);
                    let mut qs = tws.take_dirty(t, d);
                    let mut ks = tws.take_dirty(t, d);
                    let mut vs = tws.take_dirty(t, d);
                    let mut dvs = tws.take_dirty(t, d);
                    let mut dqs = tws.take_dirty(t, d);
                    let mut dks = tws.take_dirty(t, d);
                    let mut dp = tws.take_dirty(t, t);
                    slice_head_into(dcat_ref, &mut dout, bi, t, hi * d, d); // T×D
                    slice_head_into(qkv_ref, &mut qs, bi, t, hi * d, d);
                    slice_head_into(qkv_ref, &mut ks, bi, t, h + hi * d, d);
                    slice_head_into(qkv_ref, &mut vs, bi, t, 2 * h + hi * d, d);
                    // out = P·V ⇒ dV = Pᵀ·dOut, dP = dOut·Vᵀ (prefix only).
                    gemm::attn_apply_tn_into(&mut dvs, p, &dout); // T×D
                    gemm::attn_scores_into(&mut dp, &dout, &vs, 1.0, &mut tws); // T×T
                    // Fused softmax backward, in place: dp becomes the
                    // scaled dS.
                    ops::causal_softmax_grad(p, &mut dp, scale);
                    // scores = Q·Kᵀ ⇒ dQ = dS·K, dK = dSᵀ·Q.
                    gemm::attn_apply_into(&mut dqs, &dp, &ks);
                    gemm::attn_apply_tn_into(&mut dks, &dp, &qs);
                    // SAFETY: each (bi, hi) task owns disjoint (row, column
                    // band) regions of dqkv.
                    unsafe {
                        write_head_raw(dqkv_base, 3 * h, &dqs, bi, t, hi * d, d);
                        write_head_raw(dqkv_base, 3 * h, &dks, bi, t, h + hi * d, d);
                        write_head_raw(dqkv_base, 3 * h, &dvs, bi, t, 2 * h + hi * d, d);
                    }
                    tws.give(dout);
                    tws.give(qs);
                    tws.give(ks);
                    tws.give(vs);
                    tws.give(dvs);
                    tws.give(dqs);
                    tws.give(dks);
                    tws.give(dp);
                    heads_ref.release(tws);
                });
            });
        }
        ws.give(dattn_cat);
        // RoPE backward = inverse rotation on the Q and K bands.
        rope_apply_ws(&mut dqkv, t, n_heads, d, cfg.rope_theta, true, 0, ws);
        rope_apply_ws(&mut dqkv, t, n_heads, d, cfg.rope_theta, true, h, ws);

        // Fused QKV weight grads (one (3h)×h Aᵀ·B, row blocks added into
        // the per-weight buffers) and fused dn1 = dqkv · [Wq; Wk; Wv].
        let mut dw_qkv = ws.take_dirty(3 * h, h);
        gemm::matmul_tn_into(&mut dw_qkv, &dqkv, &lc.n1, ws);
        acc_rows(&mut grads[idx.wq()], &dw_qkv.data()[..h * h]);
        acc_rows(&mut grads[idx.wk()], &dw_qkv.data()[h * h..2 * h * h]);
        acc_rows(&mut grads[idx.wv()], &dw_qkv.data()[2 * h * h..]);
        ws.give(dw_qkv);
        let qkv_stack = tcache.get_fused_stack(
            slot + FUSED_QKV_STACK,
            &[&self.params[idx.wq()], &self.params[idx.wk()], &self.params[idx.wv()]],
        );
        let mut dn1 = ws.take_dirty(bt, h);
        gemm::matmul_into(&mut dn1, &dqkv, qkv_stack);
        ws.give(dqkv);
        // RMSNorm #1
        let mut dx_in_norm = ws.take_dirty(bt, h);
        rmsnorm_backward_acc(
            &lc.x_in,
            &lc.inv_rms1,
            &self.params[idx.attn_norm()].value,
            &dn1,
            &mut dx_in_norm,
            &mut grads[idx.attn_norm()],
        );
        ws.give(dn1);
        // Residual.
        dx_in_norm.axpy(1.0, &dx_mid);
        ws.give(dx_mid);
        lc.recycle(ws);
        dx_in_norm
    }
}

// ----------------------------------------------------------------------
// layer primitives
// ----------------------------------------------------------------------

#[inline]
fn silu(z: f32) -> f32 {
    z / (1.0 + (-z).exp())
}

#[inline]
fn silu_grad(z: f32) -> f32 {
    let s = 1.0 / (1.0 + (-z).exp());
    s * (1.0 + z * (1.0 - s))
}

/// Round an activation buffer onto the storage-dtype grid (no-op under
/// f32). Applied to each kernel's *written-out* activations, so the model
/// computes with storage-precision values while every accumulation inside a
/// kernel stays f32. Backward is untouched: gradients flow straight through
/// the rounding (the standard straight-through treatment).
#[inline]
fn quantize_act(dt: Dtype, m: &mut Matrix) {
    if dt != Dtype::F32 {
        dtype::quantize_slice(dt, m.data_mut());
    }
}

/// Prefix-aware variant for causal attention probabilities: only the live
/// lower triangle is swept. The strict upper triangle holds stale workspace
/// data that no kernel ever reads or writes — touching it would break the
/// triangular contract (and waste half the sweep).
#[inline]
fn quantize_probs_prefix(dt: Dtype, p: &mut Matrix) {
    if dt == Dtype::F32 {
        return;
    }
    for i in 0..p.rows() {
        let row = p.row_mut(i);
        dtype::quantize_slice(dt, &mut row[..=i]);
    }
}

/// RMSNorm forward: y = x/rms(x) ⊙ g. Returns (y, inv_rms per row).
/// (Allocating test harness around [`rmsnorm_forward_into`].)
#[cfg(test)]
fn rmsnorm_forward(x: &Matrix, gain: &Matrix) -> (Matrix, Vec<f32>) {
    let (rows, h) = x.shape();
    let mut y = Matrix::zeros(rows, h);
    let mut inv = vec![0.0f32; rows];
    rmsnorm_forward_into(x, gain, &mut y, &mut inv);
    (y, inv)
}

/// Allocation-free RMSNorm forward into caller buffers.
fn rmsnorm_forward_into(x: &Matrix, gain: &Matrix, y: &mut Matrix, inv: &mut [f32]) {
    let (rows, h) = x.shape();
    debug_assert_eq!(gain.len(), h);
    debug_assert_eq!(y.shape(), (rows, h));
    debug_assert_eq!(inv.len(), rows);
    let g = gain.data();
    for i in 0..rows {
        let xr = x.row(i);
        let ms: f32 =
            (xr.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / h as f64) as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        inv[i] = r;
        let yr = y.row_mut(i);
        for j in 0..h {
            yr[j] = xr[j] * r * g[j];
        }
    }
}

/// RMSNorm backward. Returns (dx, dgain). `inv_rms` from the forward pass.
/// (Allocating test harness around [`rmsnorm_backward_acc`].)
#[cfg(test)]
fn rmsnorm_backward(
    x: &Matrix,
    inv_rms: &[f32],
    gain: &Matrix,
    dy: &Matrix,
) -> (Matrix, Matrix) {
    let (rows, h) = x.shape();
    let mut dx = Matrix::zeros(rows, h);
    let mut dgain = Matrix::zeros(1, h);
    rmsnorm_backward_acc(x, inv_rms, gain, dy, &mut dx, &mut dgain);
    (dx, dgain)
}

/// Allocation-free RMSNorm backward: `dx` is overwritten, `dgain_acc` is
/// accumulated into (so layer gradients can sum straight into the grad
/// buffer).
fn rmsnorm_backward_acc(
    x: &Matrix,
    inv_rms: &[f32],
    gain: &Matrix,
    dy: &Matrix,
    dx: &mut Matrix,
    dgain_acc: &mut Matrix,
) {
    let (rows, h) = x.shape();
    debug_assert_eq!(dx.shape(), (rows, h));
    debug_assert_eq!(dgain_acc.len(), h);
    let g = gain.data();
    let dg = dgain_acc.data_mut();
    for i in 0..rows {
        let xr = x.row(i);
        let dyr = dy.row(i);
        let r = inv_rms[i];
        // dot = Σ_j dy_j g_j x_j
        let mut dot = 0.0f64;
        for j in 0..h {
            dot += dyr[j] as f64 * g[j] as f64 * xr[j] as f64;
            dg[j] += dyr[j] * xr[j] * r;
        }
        let c = (dot as f32) * r * r * r / h as f32;
        let dxr = dx.row_mut(i);
        for j in 0..h {
            dxr[j] = dyr[j] * g[j] * r - xr[j] * c;
        }
    }
}

/// Apply (or invert, for backward) rotary position embeddings in place.
/// Layout: row index = b·T + pos; within a row, head h occupies columns
/// [col0 + h·d, col0 + (h+1)·d) and RoPE rotates pairs (2i, 2i+1). `col0`
/// selects a column band of a wider fused matrix (the Q or K band of the
/// fused `qkv` buffer).
#[cfg(test)]
fn rope_apply(x: &mut Matrix, t: usize, n_heads: usize, d: usize, theta: f32, inverse: bool) {
    rope_apply_ws(x, t, n_heads, d, theta, inverse, 0, &mut Workspace::new());
}

/// The (cos, sin) table is position×(d/2) and identical across heads,
/// layers and Q/K — computing it once per call (instead of `powf` +
/// `sin_cos` per element) removes ~5% of the forward pass (perf log in
/// EXPERIMENTS.md §Perf). The table buffer (cos/sin interleaved) is leased
/// from the workspace so steady-state steps never allocate it.
#[allow(clippy::too_many_arguments)] // one arg per layout dimension
fn rope_apply_ws(
    x: &mut Matrix,
    t: usize,
    n_heads: usize,
    d: usize,
    theta: f32,
    inverse: bool,
    col0: usize,
    ws: &mut Workspace,
) {
    let half = d / 2;
    // cos/sin interleaved per (pos, i): table[2·(pos·half+i)] = cos, +1 = sin.
    let mut table = ws.take_vec_dirty(2 * t * half);
    for pos in 0..t {
        for i in 0..half {
            let freq = 1.0 / theta.powf(2.0 * i as f32 / d as f32);
            let mut angle = pos as f32 * freq;
            if inverse {
                angle = -angle;
            }
            let (sin, cos) = angle.sin_cos();
            table[2 * (pos * half + i)] = cos;
            table[2 * (pos * half + i) + 1] = sin;
        }
    }
    let rows = x.rows();
    for row in 0..rows {
        let pos = row % t;
        let trow = &table[2 * pos * half..2 * (pos + 1) * half];
        let xr = x.row_mut(row);
        for h in 0..n_heads {
            let base = col0 + h * d;
            for i in 0..half {
                let cos = trow[2 * i];
                let sin = trow[2 * i + 1];
                let a = xr[base + 2 * i];
                let b = xr[base + 2 * i + 1];
                xr[base + 2 * i] = a * cos - b * sin;
                xr[base + 2 * i + 1] = a * sin + b * cos;
            }
        }
    }
    ws.give_vec(table);
}

/// Copy the T×D block at column band [col0, col0+d) of batch `b` out of a
/// (B·T)×W matrix into an existing T×D buffer.
fn slice_head_into(x: &Matrix, out: &mut Matrix, b: usize, t: usize, col0: usize, d: usize) {
    debug_assert_eq!(out.shape(), (t, d));
    for i in 0..t {
        let src = &x.row(b * t + i)[col0..col0 + d];
        out.row_mut(i).copy_from_slice(src);
    }
}

/// Write a T×D head block into the column band [col0, col0+d) of rows
/// b·T..(b+1)·T behind `base` — the raw buffer of a (B·T)×`w` row-major
/// matrix shared across the fan-out's tasks.
///
/// # Safety
///
/// `base` must point at a live (B·T)×`w` buffer that outlives the call,
/// with `(b+1)·t` within its rows and `col0 + d ≤ w`. Concurrent callers
/// must write disjoint (row range × column band) regions — the
/// per-(batch, head) fan-out guarantees this because every task owns a
/// unique (b, col0) pair.
unsafe fn write_head_raw(
    base: SendPtr<f32>,
    w: usize,
    block: &Matrix,
    b: usize,
    t: usize,
    col0: usize,
    d: usize,
) {
    for i in 0..t {
        let dst = std::slice::from_raw_parts_mut(base.get().add((b * t + i) * w + col0), d);
        dst.copy_from_slice(block.row(i));
    }
}

/// `grad += block`, where `block` is the matching contiguous row block of a
/// fused gradient buffer (row-major, so rows [r0, r1) of a fused (ΣR)×C
/// product are exactly one weight's R×C gradient).
fn acc_rows(grad: &mut Matrix, block: &[f32]) {
    debug_assert_eq!(grad.len(), block.len(), "fused grad block size");
    for (g, &v) in grad.data_mut().iter_mut().zip(block) {
        *g += v;
    }
}

/// Worker plan for the per-(batch, head) attention fan-out, shared by the
/// forward and backward passes (so the scratch bank is sized once per
/// step). Routed through `gemm::plan_kernel_threads`: the `GEMM_THREADS`
/// forcing, the `PAR_KERNEL_FLOPS` auto gate, the DP-shard opt-out
/// (`gemm::run_single_threaded`) and the on-worker inline rule all apply —
/// one knob budgets every level of parallelism.
fn attn_plan(b: usize, n_heads: usize, t: usize, d: usize) -> usize {
    let tasks = b * n_heads;
    let flops = tasks.saturating_mul(t).saturating_mul(t).saturating_mul(d);
    gemm::plan_kernel_threads(flops, tasks)
}

/// Per-task scratch sizes for the attention fan-out, as (elements, count)
/// reservations for `WorkspaceBank::ensure`: the union of the forward peak
/// (4 T×D views + the score kernel's internal Bᵀ lease) and the backward
/// peak (7 T×D + the dP kernel's Bᵀ lease + one T×T). When d == t the two
/// bucket sizes coincide and must merge into one reservation, or
/// steady-state leases could still miss.
fn head_scratch_sizes(t: usize, d: usize) -> [(usize, usize); 2] {
    if d == t {
        [(t * d, 9), (0, 0)]
    } else {
        [(t * d, 8), (t * t, 1)]
    }
}

/// Mean cross-entropy + dlogits. Targets of `u32::MAX` are ignored (padding).
pub fn cross_entropy(logits: &Matrix, targets: &[u32]) -> (f32, Matrix) {
    let (rows, v) = logits.shape();
    let mut dlogits = Matrix::zeros(rows, v);
    let loss = cross_entropy_into(logits, targets, &mut dlogits);
    (loss, dlogits)
}

/// Allocation-free [`cross_entropy`]: `dlogits` is fully overwritten
/// (padded rows to zero).
pub fn cross_entropy_into(logits: &Matrix, targets: &[u32], dlogits: &mut Matrix) -> f32 {
    let (rows, _) = logits.shape();
    assert_eq!(rows, targets.len());
    assert_eq!(dlogits.shape(), logits.shape(), "dlogits shape");
    dlogits.data_mut().fill(0.0);
    let mut loss = 0.0f64;
    let count = targets.iter().filter(|&&t| t != u32::MAX).count();
    let denom = count.max(1) as f32;
    for i in 0..rows {
        let tgt = targets[i];
        if tgt == u32::MAX {
            continue;
        }
        let lr = logits.row(i);
        let max = lr.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f64;
        for &l in lr {
            sum += ((l - max) as f64).exp();
        }
        let log_sum = (sum as f32).ln() + max;
        loss += (log_sum - lr[tgt as usize]) as f64;
        let dr = dlogits.row_mut(i);
        for (j, &l) in lr.iter().enumerate() {
            let p = ((l - log_sum) as f64).exp() as f32;
            dr[j] = (p - if j == tgt as usize { 1.0 } else { 0.0 }) / denom;
        }
    }
    (loss / count.max(1) as f64) as f32
}

/// Loss-only cross entropy (eval path: no dlogits buffer needed).
fn cross_entropy_loss(logits: &Matrix, targets: &[u32]) -> f32 {
    let (rows, _) = logits.shape();
    assert_eq!(rows, targets.len());
    let mut loss = 0.0f64;
    let count = targets.iter().filter(|&&t| t != u32::MAX).count();
    for i in 0..rows {
        let tgt = targets[i];
        if tgt == u32::MAX {
            continue;
        }
        let lr = logits.row(i);
        let max = lr.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f64;
        for &l in lr {
            sum += ((l - max) as f64).exp();
        }
        let log_sum = (sum as f32).ln() + max;
        loss += (log_sum - lr[tgt as usize]) as f64;
    }
    (loss / count.max(1) as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> Llama {
        Llama::new(ModelConfig::preset("nano"), 7)
    }

    fn tiny_batch(cfg: &ModelConfig, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let b = 2;
        let t = cfg.seq_len;
        let inputs: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        let targets: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        Batch { inputs, targets, b, t }
    }

    #[test]
    fn forward_loss_is_near_log_vocab_at_init() {
        let model = tiny_model();
        let batch = tiny_batch(&model.cfg, 1);
        let loss = model.loss(&batch);
        let expect = (model.cfg.vocab as f32).ln();
        assert!(
            (loss - expect).abs() < 0.5,
            "init loss {loss} should be ≈ ln(V) = {expect}"
        );
    }

    #[test]
    fn param_count_matches_config_formula() {
        let model = tiny_model();
        assert_eq!(model.param_count(), model.cfg.param_count());
        let small = Llama::new(ModelConfig::preset("tiny"), 3);
        assert_eq!(small.param_count(), small.cfg.param_count());
    }

    /// Central-difference gradient check over a random subset of entries of
    /// every parameter tensor. This is the single most important test of the
    /// native engine.
    #[test]
    fn gradcheck_all_params() {
        let mut model = tiny_model();
        let batch = tiny_batch(&model.cfg, 2);
        let (_, grads) = model.loss_and_grad(&batch);
        let mut rng = Rng::new(99);
        let eps = 3e-3f32;
        let n_params = model.params.len();
        for pi in 0..n_params {
            let numel = model.params[pi].value.len();
            // Check up to 6 random entries per tensor.
            for _ in 0..6.min(numel) {
                let flat = rng.below(numel);
                let orig = model.params[pi].value.data()[flat];
                model.params[pi].value.data_mut()[flat] = orig + eps;
                let lp = model.loss(&batch);
                model.params[pi].value.data_mut()[flat] = orig - eps;
                let lm = model.loss(&batch);
                model.params[pi].value.data_mut()[flat] = orig;
                let numeric = (lp - lm) / (2.0 * eps);
                let analytic = grads[pi].data()[flat];
                let tol = 1e-2f32.max(0.08 * numeric.abs().max(analytic.abs()));
                assert!(
                    (numeric - analytic).abs() < tol,
                    "param {} ({}): numeric {numeric} vs analytic {analytic}",
                    model.params[pi].name,
                    flat
                );
            }
        }
    }

    /// The workspace-backed path must agree with the allocating wrapper
    /// bit-for-bit, including across repeated calls that reuse the pool and
    /// the transpose cache.
    #[test]
    fn ws_path_matches_wrapper_and_is_stable() {
        let model = Llama::new(ModelConfig::preset("tiny"), 13);
        let batch = tiny_batch(&model.cfg, 14);
        let (l1, g1) = model.loss_and_grad(&batch);
        let mut state = StepState::new();
        let mut grads = model.zero_grads();
        let l2 = model.loss_and_grad_into(&batch, &mut grads, &mut state);
        assert_eq!(l1, l2);
        for (a, b) in g1.iter().zip(&grads) {
            assert_eq!(a.data(), b.data());
        }
        // Second call through the same state: pooled buffers + cached
        // transposes must not change anything.
        let l3 = model.loss_and_grad_into(&batch, &mut grads, &mut state);
        assert_eq!(l1, l3);
        for (a, b) in g1.iter().zip(&grads) {
            assert_eq!(a.data(), b.data());
        }
        // Loss-only path agrees too.
        assert_eq!(model.loss(&batch), model.loss_ws(&batch, &mut state));
    }

    #[test]
    fn bf16_storage_stays_on_grid_and_close_to_f32() {
        let cfg = ModelConfig::preset("tiny");
        let f32_model = Llama::new(cfg.clone(), 13);
        let batch = tiny_batch(&cfg, 14);
        let mut bcfg = cfg;
        bcfg.dtype = Dtype::Bf16;
        let bf_model = Llama::new(bcfg, 13);
        // Same seed ⇒ weights are the f32 weights rounded onto the bf16 grid.
        for (p, q) in f32_model.params.iter().zip(&bf_model.params) {
            assert_eq!(q.dtype(), Dtype::Bf16);
            for (&a, &b) in p.value.data().iter().zip(q.value.data()) {
                assert_eq!(b, Dtype::Bf16.quantize(a), "{}: off-grid weight", q.name);
            }
        }
        let l32 = f32_model.loss(&batch);
        let (lbf, grads) = bf_model.loss_and_grad(&batch);
        assert!(lbf.is_finite(), "bf16 loss not finite");
        // ~ln(V) at init for both; bf16 rounding perturbs it only slightly.
        assert!(
            (l32 - lbf).abs() < 0.1 * l32.abs().max(1.0),
            "bf16 loss {lbf} too far from f32 loss {l32}"
        );
        for (g, p) in grads.iter().zip(&bf_model.params) {
            assert!(g.data().iter().all(|v| v.is_finite()), "{}: non-finite grad", p.name);
        }
    }

    #[test]
    fn cross_entropy_matches_manual() {
        // Two rows, V=3; uniform logits ⇒ loss = ln 3, dlogits = (1/3 − onehot)/2.
        let logits = Matrix::zeros(2, 3);
        let (loss, dl) = cross_entropy(&logits, &[0, 2]);
        assert!((loss - 3f32.ln()).abs() < 1e-5);
        assert!((dl.get(0, 0) - (1.0 / 3.0 - 1.0) / 2.0).abs() < 1e-5);
        assert!((dl.get(0, 1) - (1.0 / 3.0) / 2.0).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_ignores_padding() {
        let logits = Matrix::zeros(2, 3);
        let (loss, dl) = cross_entropy(&logits, &[0, u32::MAX]);
        assert!((loss - 3f32.ln()).abs() < 1e-5);
        // Padded row contributes zero gradient.
        assert_eq!(dl.row(1), &[0.0, 0.0, 0.0]);
        // Loss-only variant agrees with the full one.
        assert_eq!(cross_entropy_loss(&logits, &[0, u32::MAX]), loss);
    }

    #[test]
    fn causal_mask_blocks_future() {
        // Token at position 0 must be unaffected by tokens at positions > 0.
        let model = tiny_model();
        let mut batch = tiny_batch(&model.cfg, 3);
        let c1 = model.forward_hidden(&batch.inputs, batch.b, batch.t);
        // Perturb the last token of sequence 0.
        batch.inputs[model.cfg.seq_len - 1] =
            (batch.inputs[model.cfg.seq_len - 1] + 1) % model.cfg.vocab as u32;
        let c2 = model.forward_hidden(&batch.inputs, batch.b, batch.t);
        // Position 0 hidden state unchanged.
        let r1 = c1.hidden.row(0);
        let r2 = c2.hidden.row(0);
        for (a, b) in r1.iter().zip(r2) {
            assert!((a - b).abs() < 1e-6, "future token leaked into position 0");
        }
    }

    #[test]
    fn rope_inverse_roundtrip() {
        let mut rng = Rng::new(5);
        let (t, heads, d) = (6, 2, 8);
        let orig = Matrix::randn(2 * t, heads * d, 1.0, &mut rng);
        let mut x = orig.clone();
        rope_apply(&mut x, t, heads, d, 10_000.0, false);
        rope_apply(&mut x, t, heads, d, 10_000.0, true);
        crate::util::proptest::close(x.data(), orig.data(), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn rmsnorm_forward_backward_consistency() {
        let mut rng = Rng::new(6);
        let x = Matrix::randn(4, 10, 1.0, &mut rng);
        let gain = Matrix::randn(1, 10, 0.5, &mut rng).map(|v| v + 1.0);
        let (y, inv) = rmsnorm_forward(&x, &gain);
        // Numeric check of dx against finite differences for a random scalar
        // objective L = Σ w ⊙ y.
        let w = Matrix::randn(4, 10, 1.0, &mut rng);
        let (dx, dg) = rmsnorm_backward(&x, &inv, &gain, &w);
        let f = |x: &Matrix, gain: &Matrix| -> f32 {
            let (y, _) = rmsnorm_forward(x, gain);
            y.hadamard(&w).sum()
        };
        let eps = 1e-3;
        for &(i, j) in &[(0usize, 0usize), (1, 3), (3, 9)] {
            let mut xp = x.clone();
            xp.set(i, j, x.get(i, j) + eps);
            let mut xm = x.clone();
            xm.set(i, j, x.get(i, j) - eps);
            let num = (f(&xp, &gain) - f(&xm, &gain)) / (2.0 * eps);
            let ana = dx.get(i, j);
            assert!((num - ana).abs() < 2e-2, "dx[{i},{j}]: {num} vs {ana}");
        }
        for j in [0usize, 5, 9] {
            let mut gp = gain.clone();
            gp.set(0, j, gain.get(0, j) + eps);
            let mut gm = gain.clone();
            gm.set(0, j, gain.get(0, j) - eps);
            let num = (f(&x, &gp) - f(&x, &gm)) / (2.0 * eps);
            let ana = dg.get(0, j);
            assert!((num - ana).abs() < 2e-2, "dg[{j}]: {num} vs {ana}");
        }
        let _ = y;
    }

    #[test]
    fn training_step_reduces_loss() {
        // A few full-rank Adam steps on one fixed batch must reduce loss.
        use crate::optim::{Adam, AdamCfg, Optimizer};
        let mut model = Llama::new(ModelConfig::preset("nano"), 11);
        let batch = tiny_batch(&model.cfg, 12);
        let mut opt = Adam::new(AdamCfg::default());
        let initial = model.loss(&batch);
        for _ in 0..30 {
            let (_, grads) = model.loss_and_grad(&batch);
            opt.step(5e-3, &mut model.params, &grads);
        }
        let fin = model.loss(&batch);
        assert!(
            fin < initial * 0.7,
            "overfit one batch: {initial} -> {fin}"
        );
    }
}
