//! Llama-family transformer with a hand-written backward pass — the "native"
//! training engine.
//!
//! Architecture (matches the paper's pre-training setup): token embedding →
//! L × [RMSNorm → multi-head causal attention with RoPE → residual →
//! RMSNorm → SwiGLU MLP → residual] → RMSNorm → untied LM head →
//! cross-entropy loss.
//!
//! Everything operates on flattened (B·T)×H row-major matrices. The backward
//! pass is exact (verified against central finite differences in the tests
//! below and in `rust/tests/gradcheck.rs`).

use super::config::ModelConfig;
use crate::optim::Param;
use crate::tensor::{gemm, ops, Matrix};
use crate::util::rng::Rng;

/// A training batch of token ids. `inputs[b*t + i]` is position i of sequence
/// b; `targets` is the next-token shift (or classification labels when used
/// through the classifier head).
#[derive(Clone, Debug)]
pub struct Batch {
    pub inputs: Vec<u32>,
    pub targets: Vec<u32>,
    pub b: usize,
    pub t: usize,
}

impl Batch {
    pub fn tokens(&self) -> usize {
        self.b * self.t
    }
}

/// Parameter index layout. Per layer: [attn_norm, wq, wk, wv, wo, mlp_norm,
/// w_gate, w_up, w_down]; global: embed first, final_norm + lm_head last.
#[derive(Clone, Copy)]
struct LayerIdx(usize);

impl LayerIdx {
    const STRIDE: usize = 9;
    fn attn_norm(self) -> usize {
        self.0
    }
    fn wq(self) -> usize {
        self.0 + 1
    }
    fn wk(self) -> usize {
        self.0 + 2
    }
    fn wv(self) -> usize {
        self.0 + 3
    }
    fn wo(self) -> usize {
        self.0 + 4
    }
    fn mlp_norm(self) -> usize {
        self.0 + 5
    }
    fn w_gate(self) -> usize {
        self.0 + 6
    }
    fn w_up(self) -> usize {
        self.0 + 7
    }
    fn w_down(self) -> usize {
        self.0 + 8
    }
}

const RMS_EPS: f32 = 1e-5;

/// The model: a parameter vector in a fixed layout plus the config.
pub struct Llama {
    pub cfg: ModelConfig,
    pub params: Vec<Param>,
}

/// Per-layer forward cache needed by the backward pass.
struct LayerCache {
    /// Input to the layer (pre attention-norm).
    x_in: Matrix,
    /// RMSNorm #1 output.
    n1: Matrix,
    /// Inverse RMS of x_in rows.
    inv_rms1: Vec<f32>,
    /// Post-RoPE Q and K; V.
    q: Matrix,
    k: Matrix,
    v: Matrix,
    /// Softmax attention probabilities, one T×T matrix per (batch, head).
    probs: Vec<Matrix>,
    /// Concatenated head outputs (input of Wo).
    attn_cat: Matrix,
    /// Residual stream after attention (input of MLP block).
    x_mid: Matrix,
    /// RMSNorm #2 output.
    n2: Matrix,
    inv_rms2: Vec<f32>,
    /// Pre-activation gate (z1 = n2·Wgᵀ) and up (z3 = n2·Wuᵀ).
    z_gate: Matrix,
    z_up: Matrix,
    /// silu(z1) ⊙ z3 (input of Wdown).
    h: Matrix,
}

/// Full forward cache.
pub struct Cache {
    layers: Vec<LayerCache>,
    /// Input of the final RMSNorm.
    x_final: Matrix,
    inv_rms_final: Vec<f32>,
    /// Final normed hidden states (input of the LM/classifier head).
    pub hidden: Matrix,
    b: usize,
    t: usize,
}

impl Llama {
    /// Initialize with N(0, 0.02)-style scaled init (matching the GaLore
    /// reference setup: normal init, residual projections scaled by √(2L)).
    pub fn new(cfg: ModelConfig, seed: u64) -> Llama {
        let mut rng = Rng::new(seed);
        let h = cfg.hidden;
        let f = cfg.intermediate;
        let v = cfg.vocab;
        let std = 0.02f32;
        let resid_std = std / ((2 * cfg.layers) as f32).sqrt();
        let mut params = Vec::new();
        params.push(Param::matrix("embed", Matrix::randn(v, h, std, &mut rng)));
        for l in 0..cfg.layers {
            let p = |n: &str| format!("layer{l}.{n}");
            params.push(Param::vector(&p("attn_norm"), Matrix::full(1, h, 1.0)));
            params.push(Param::matrix(&p("wq"), Matrix::randn(h, h, std, &mut rng)));
            params.push(Param::matrix(&p("wk"), Matrix::randn(h, h, std, &mut rng)));
            params.push(Param::matrix(&p("wv"), Matrix::randn(h, h, std, &mut rng)));
            params.push(Param::matrix(&p("wo"), Matrix::randn(h, h, resid_std, &mut rng)));
            params.push(Param::vector(&p("mlp_norm"), Matrix::full(1, h, 1.0)));
            params.push(Param::matrix(&p("w_gate"), Matrix::randn(f, h, std, &mut rng)));
            params.push(Param::matrix(&p("w_up"), Matrix::randn(f, h, std, &mut rng)));
            params.push(Param::matrix(&p("w_down"), Matrix::randn(h, f, resid_std, &mut rng)));
        }
        params.push(Param::vector("final_norm", Matrix::full(1, h, 1.0)));
        params.push(Param::matrix("lm_head", Matrix::randn(v, h, std, &mut rng)));
        Llama { cfg, params }
    }

    fn layer_idx(&self, l: usize) -> LayerIdx {
        LayerIdx(1 + l * LayerIdx::STRIDE)
    }

    fn final_norm_idx(&self) -> usize {
        1 + self.cfg.layers * LayerIdx::STRIDE
    }

    fn head_idx(&self) -> usize {
        self.final_norm_idx() + 1
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Zero-shaped gradient buffers parallel to `params`.
    pub fn zero_grads(&self) -> Vec<Matrix> {
        self.params
            .iter()
            .map(|p| Matrix::zeros(p.value.rows(), p.value.cols()))
            .collect()
    }

    // ------------------------------------------------------------------
    // forward
    // ------------------------------------------------------------------

    /// Forward through the transformer body, returning the final normed
    /// hidden states and the cache for backward.
    pub fn forward_hidden(&self, inputs: &[u32], b: usize, t: usize) -> Cache {
        assert_eq!(inputs.len(), b * t);
        let h = self.cfg.hidden;
        // Embedding gather.
        let embed = &self.params[0].value;
        let mut x = Matrix::zeros(b * t, h);
        for (row, &id) in inputs.iter().enumerate() {
            x.row_mut(row).copy_from_slice(embed.row(id as usize));
        }

        let mut layers = Vec::with_capacity(self.cfg.layers);
        for l in 0..self.cfg.layers {
            let (x_next, cache) = self.layer_forward(l, &x, b, t);
            layers.push(cache);
            x = x_next;
        }

        // Final RMSNorm.
        let gain = &self.params[self.final_norm_idx()].value;
        let (hidden, inv_rms_final) = rmsnorm_forward(&x, gain);
        Cache { layers, x_final: x, inv_rms_final, hidden, b, t }
    }

    fn layer_forward(&self, l: usize, x_in: &Matrix, b: usize, t: usize) -> (Matrix, LayerCache) {
        let idx = self.layer_idx(l);
        let cfg = &self.cfg;
        let n_heads = cfg.heads;
        let d = cfg.head_dim();

        // ---- attention block ----
        let (n1, inv_rms1) = rmsnorm_forward(x_in, &self.params[idx.attn_norm()].value);
        let mut q = gemm::matmul_nt(&n1, &self.params[idx.wq()].value);
        let mut k = gemm::matmul_nt(&n1, &self.params[idx.wk()].value);
        let v = gemm::matmul_nt(&n1, &self.params[idx.wv()].value);
        rope_apply(&mut q, t, n_heads, d, cfg.rope_theta, false);
        rope_apply(&mut k, t, n_heads, d, cfg.rope_theta, false);

        // Per (batch, head) causal attention.
        let mut attn_cat = Matrix::zeros(b * t, cfg.hidden);
        let mut probs = Vec::with_capacity(b * n_heads);
        let scale = 1.0 / (d as f32).sqrt();
        for bi in 0..b {
            for hi in 0..n_heads {
                let qs = slice_head(&q, bi, hi, t, d);
                let ks = slice_head(&k, bi, hi, t, d);
                let vs = slice_head(&v, bi, hi, t, d);
                let mut scores = gemm::matmul_nt(&qs, &ks);
                scores.scale_mut(scale);
                causal_mask(&mut scores);
                ops::softmax_rows(&mut scores);
                let out = gemm::matmul(&scores, &vs); // T×D
                write_head(&mut attn_cat, &out, bi, hi, t, d);
                probs.push(scores);
            }
        }
        let attn_out = gemm::matmul_nt(&attn_cat, &self.params[idx.wo()].value);
        let x_mid = x_in.add(&attn_out);

        // ---- MLP block (SwiGLU) ----
        let (n2, inv_rms2) = rmsnorm_forward(&x_mid, &self.params[idx.mlp_norm()].value);
        let z_gate = gemm::matmul_nt(&n2, &self.params[idx.w_gate()].value);
        let z_up = gemm::matmul_nt(&n2, &self.params[idx.w_up()].value);
        let h_act = z_gate.zip(&z_up, |g, u| silu(g) * u);
        let mlp_out = gemm::matmul_nt(&h_act, &self.params[idx.w_down()].value);
        let x_out = x_mid.add(&mlp_out);

        (
            x_out,
            LayerCache {
                x_in: x_in.clone(),
                n1,
                inv_rms1,
                q,
                k,
                v,
                probs,
                attn_cat,
                x_mid,
                n2,
                inv_rms2,
                z_gate,
                z_up,
                h: h_act,
            },
        )
    }

    /// Language-model logits for the final hidden states.
    pub fn logits(&self, hidden: &Matrix) -> Matrix {
        gemm::matmul_nt(hidden, &self.params[self.head_idx()].value)
    }

    /// Full LM forward: mean cross-entropy of next-token prediction.
    pub fn loss(&self, batch: &Batch) -> f32 {
        let cache = self.forward_hidden(&batch.inputs, batch.b, batch.t);
        let logits = self.logits(&cache.hidden);
        let (loss, _) = cross_entropy(&logits, &batch.targets);
        loss
    }

    /// Loss + full gradient vector (parallel to `self.params`).
    pub fn loss_and_grad(&self, batch: &Batch) -> (f32, Vec<Matrix>) {
        let cache = self.forward_hidden(&batch.inputs, batch.b, batch.t);
        let logits = self.logits(&cache.hidden);
        let (loss, dlogits) = cross_entropy(&logits, &batch.targets);
        let mut grads = self.zero_grads();
        // Head: logits = hidden·Wᵀ.
        let head = self.head_idx();
        grads[head] = gemm::matmul_tn(&dlogits, &cache.hidden);
        let dhidden = gemm::matmul(&dlogits, &self.params[head].value);
        self.backward_hidden(&cache, &batch.inputs, dhidden, &mut grads);
        (loss, grads)
    }

    // ------------------------------------------------------------------
    // backward
    // ------------------------------------------------------------------

    /// Backpropagate `dhidden` (gradient w.r.t. the final normed hidden
    /// states) through the body, accumulating into `grads`.
    pub fn backward_hidden(
        &self,
        cache: &Cache,
        inputs: &[u32],
        dhidden: Matrix,
        grads: &mut [Matrix],
    ) {
        let (b, t) = (cache.b, cache.t);
        // Final RMSNorm backward.
        let fin = self.final_norm_idx();
        let (mut dx, dgain) = rmsnorm_backward(
            &cache.x_final,
            &cache.inv_rms_final,
            &self.params[fin].value,
            &dhidden,
        );
        grads[fin].axpy(1.0, &dgain);

        for l in (0..self.cfg.layers).rev() {
            dx = self.layer_backward(l, &cache.layers[l], dx, b, t, grads);
        }

        // Embedding scatter-add.
        for (row, &id) in inputs.iter().enumerate() {
            let grow = dx.row(row).to_vec();
            let erow = grads[0].row_mut(id as usize);
            for (e, g) in erow.iter_mut().zip(grow) {
                *e += g;
            }
        }
    }

    fn layer_backward(
        &self,
        l: usize,
        lc: &LayerCache,
        dx_out: Matrix,
        b: usize,
        t: usize,
        grads: &mut [Matrix],
    ) -> Matrix {
        let idx = self.layer_idx(l);
        let cfg = &self.cfg;
        let n_heads = cfg.heads;
        let d = cfg.head_dim();

        // ---- MLP block backward ----
        // x_out = x_mid + h·Wdᵀ
        let dh = gemm::matmul(&dx_out, &self.params[idx.w_down()].value); // (BT)×F
        grads[idx.w_down()].axpy(1.0, &gemm::matmul_tn(&dx_out, &lc.h));
        // h = silu(z1) ⊙ z3
        let dz_gate = dh.zip(&lc.z_gate, |dh, z| dh * silu_grad(z)).hadamard(&lc.z_up);
        let dz_up = dh.zip(&lc.z_gate, |dh, z| dh * silu(z));
        // z1 = n2·Wgᵀ ; z3 = n2·Wuᵀ
        grads[idx.w_gate()].axpy(1.0, &gemm::matmul_tn(&dz_gate, &lc.n2));
        grads[idx.w_up()].axpy(1.0, &gemm::matmul_tn(&dz_up, &lc.n2));
        let mut dn2 = gemm::matmul(&dz_gate, &self.params[idx.w_gate()].value);
        dn2.axpy(1.0, &gemm::matmul(&dz_up, &self.params[idx.w_up()].value));
        // RMSNorm #2
        let (dx_mid_norm, dgain2) = rmsnorm_backward(
            &lc.x_mid,
            &lc.inv_rms2,
            &self.params[idx.mlp_norm()].value,
            &dn2,
        );
        grads[idx.mlp_norm()].axpy(1.0, &dgain2);
        // Residual: dx_mid = dx_out + dx_mid_norm
        let dx_mid = dx_out.add(&dx_mid_norm);

        // ---- attention block backward ----
        // attn_out = attn_cat·Woᵀ ; x_mid = x_in + attn_out
        let dattn_cat = gemm::matmul(&dx_mid, &self.params[idx.wo()].value);
        grads[idx.wo()].axpy(1.0, &gemm::matmul_tn(&dx_mid, &lc.attn_cat));

        let scale = 1.0 / (d as f32).sqrt();
        let mut dq = Matrix::zeros(b * t, cfg.hidden);
        let mut dk = Matrix::zeros(b * t, cfg.hidden);
        let mut dv = Matrix::zeros(b * t, cfg.hidden);
        for bi in 0..b {
            for hi in 0..n_heads {
                let p = &lc.probs[bi * n_heads + hi]; // T×T
                let dout = slice_head(&dattn_cat, bi, hi, t, d); // T×D
                let vs = slice_head(&lc.v, bi, hi, t, d);
                let qs = slice_head(&lc.q, bi, hi, t, d);
                let ks = slice_head(&lc.k, bi, hi, t, d);
                // out = P·V
                let dvs = gemm::matmul_tn(p, &dout); // T×D
                let dp = gemm::matmul_nt(&dout, &vs); // T×T
                // softmax backward: dS = P ⊙ (dP − rowsum(dP⊙P))
                let mut ds = Matrix::zeros(t, t);
                for i in 0..t {
                    let dot: f32 =
                        dp.row(i).iter().zip(p.row(i)).map(|(&a, &b)| a * b).sum();
                    for j in 0..t {
                        ds.set(i, j, p.get(i, j) * (dp.get(i, j) - dot));
                    }
                }
                ds.scale_mut(scale);
                // scores = Q·Kᵀ
                let dqs = gemm::matmul(&ds, &ks);
                let dks = gemm::matmul_tn(&ds, &qs);
                write_head(&mut dq, &dqs, bi, hi, t, d);
                write_head(&mut dk, &dks, bi, hi, t, d);
                write_head(&mut dv, &dvs, bi, hi, t, d);
            }
        }
        // RoPE backward = inverse rotation.
        rope_apply(&mut dq, t, n_heads, d, cfg.rope_theta, true);
        rope_apply(&mut dk, t, n_heads, d, cfg.rope_theta, true);

        // q = n1·Wqᵀ etc.
        grads[idx.wq()].axpy(1.0, &gemm::matmul_tn(&dq, &lc.n1));
        grads[idx.wk()].axpy(1.0, &gemm::matmul_tn(&dk, &lc.n1));
        grads[idx.wv()].axpy(1.0, &gemm::matmul_tn(&dv, &lc.n1));
        let mut dn1 = gemm::matmul(&dq, &self.params[idx.wq()].value);
        dn1.axpy(1.0, &gemm::matmul(&dk, &self.params[idx.wk()].value));
        dn1.axpy(1.0, &gemm::matmul(&dv, &self.params[idx.wv()].value));
        // RMSNorm #1
        let (dx_in_norm, dgain1) = rmsnorm_backward(
            &lc.x_in,
            &lc.inv_rms1,
            &self.params[idx.attn_norm()].value,
            &dn1,
        );
        grads[idx.attn_norm()].axpy(1.0, &dgain1);
        // Residual.
        dx_mid.add(&dx_in_norm)
    }
}

// ----------------------------------------------------------------------
// layer primitives
// ----------------------------------------------------------------------

#[inline]
fn silu(z: f32) -> f32 {
    z / (1.0 + (-z).exp())
}

#[inline]
fn silu_grad(z: f32) -> f32 {
    let s = 1.0 / (1.0 + (-z).exp());
    s * (1.0 + z * (1.0 - s))
}

/// RMSNorm forward: y = x/rms(x) ⊙ g. Returns (y, inv_rms per row).
fn rmsnorm_forward(x: &Matrix, gain: &Matrix) -> (Matrix, Vec<f32>) {
    let (rows, h) = x.shape();
    debug_assert_eq!(gain.len(), h);
    let g = gain.data();
    let mut y = Matrix::zeros(rows, h);
    let mut inv = Vec::with_capacity(rows);
    for i in 0..rows {
        let xr = x.row(i);
        let ms: f32 =
            (xr.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / h as f64) as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        inv.push(r);
        let yr = y.row_mut(i);
        for j in 0..h {
            yr[j] = xr[j] * r * g[j];
        }
    }
    (y, inv)
}

/// RMSNorm backward. Returns (dx, dgain). `inv_rms` from the forward pass.
fn rmsnorm_backward(
    x: &Matrix,
    inv_rms: &[f32],
    gain: &Matrix,
    dy: &Matrix,
) -> (Matrix, Matrix) {
    let (rows, h) = x.shape();
    let g = gain.data();
    let mut dx = Matrix::zeros(rows, h);
    let mut dgain = Matrix::zeros(1, h);
    let dg = dgain.data_mut();
    for i in 0..rows {
        let xr = x.row(i);
        let dyr = dy.row(i);
        let r = inv_rms[i];
        // dot = Σ_j dy_j g_j x_j
        let mut dot = 0.0f64;
        for j in 0..h {
            dot += dyr[j] as f64 * g[j] as f64 * xr[j] as f64;
            dg[j] += dyr[j] * xr[j] * r;
        }
        let c = (dot as f32) * r * r * r / h as f32;
        let dxr = dx.row_mut(i);
        for j in 0..h {
            dxr[j] = dyr[j] * g[j] * r - xr[j] * c;
        }
    }
    (dx, dgain)
}

/// Apply (or invert, for backward) rotary position embeddings in place.
/// Layout: row index = b·T + pos; within a row, head h occupies columns
/// [h·d, (h+1)·d) and RoPE rotates pairs (2i, 2i+1).
///
/// The (cos, sin) table is position×(d/2) and identical across heads,
/// layers and Q/K — computing it once per call (instead of `powf` +
/// `sin_cos` per element) removes ~5% of the forward pass (perf log in
/// EXPERIMENTS.md §Perf).
fn rope_apply(x: &mut Matrix, t: usize, n_heads: usize, d: usize, theta: f32, inverse: bool) {
    let half = d / 2;
    // cos/sin per (pos, i).
    let mut table = vec![(0.0f32, 0.0f32); t * half];
    for pos in 0..t {
        for i in 0..half {
            let freq = 1.0 / theta.powf(2.0 * i as f32 / d as f32);
            let mut angle = pos as f32 * freq;
            if inverse {
                angle = -angle;
            }
            let (sin, cos) = angle.sin_cos();
            table[pos * half + i] = (cos, sin);
        }
    }
    let rows = x.rows();
    for row in 0..rows {
        let pos = row % t;
        let trow = &table[pos * half..(pos + 1) * half];
        let xr = x.row_mut(row);
        for h in 0..n_heads {
            let base = h * d;
            for (i, &(cos, sin)) in trow.iter().enumerate() {
                let a = xr[base + 2 * i];
                let b = xr[base + 2 * i + 1];
                xr[base + 2 * i] = a * cos - b * sin;
                xr[base + 2 * i + 1] = a * sin + b * cos;
            }
        }
    }
}

/// Copy the T×D block for (batch, head) out of a (B·T)×H matrix.
fn slice_head(x: &Matrix, b: usize, h: usize, t: usize, d: usize) -> Matrix {
    let mut out = Matrix::zeros(t, d);
    for i in 0..t {
        let src = &x.row(b * t + i)[h * d..(h + 1) * d];
        out.row_mut(i).copy_from_slice(src);
    }
    out
}

/// Write a T×D head block back into a (B·T)×H matrix.
fn write_head(x: &mut Matrix, block: &Matrix, b: usize, h: usize, t: usize, d: usize) {
    for i in 0..t {
        let dst = &mut x.row_mut(b * t + i)[h * d..(h + 1) * d];
        dst.copy_from_slice(block.row(i));
    }
}

/// Upper-triangular −∞ mask (strictly future positions).
fn causal_mask(scores: &mut Matrix) {
    let t = scores.rows();
    for i in 0..t {
        for j in (i + 1)..t {
            scores.set(i, j, f32::NEG_INFINITY);
        }
    }
}

/// Mean cross-entropy + dlogits. Targets of `u32::MAX` are ignored (padding).
pub fn cross_entropy(logits: &Matrix, targets: &[u32]) -> (f32, Matrix) {
    let (rows, v) = logits.shape();
    assert_eq!(rows, targets.len());
    let mut dlogits = Matrix::zeros(rows, v);
    let mut loss = 0.0f64;
    let mut count = 0usize;
    for i in 0..rows {
        if targets[i] == u32::MAX {
            continue;
        }
        count += 1;
    }
    let denom = count.max(1) as f32;
    for i in 0..rows {
        let tgt = targets[i];
        if tgt == u32::MAX {
            continue;
        }
        let lr = logits.row(i);
        let max = lr.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f64;
        for &l in lr {
            sum += ((l - max) as f64).exp();
        }
        let log_sum = (sum as f32).ln() + max;
        loss += (log_sum - lr[tgt as usize]) as f64;
        let dr = dlogits.row_mut(i);
        for (j, &l) in lr.iter().enumerate() {
            let p = ((l - log_sum) as f64).exp() as f32;
            dr[j] = (p - if j == tgt as usize { 1.0 } else { 0.0 }) / denom;
        }
    }
    ((loss / count.max(1) as f64) as f32, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> Llama {
        Llama::new(ModelConfig::preset("nano"), 7)
    }

    fn tiny_batch(cfg: &ModelConfig, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let b = 2;
        let t = cfg.seq_len;
        let inputs: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        let targets: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        Batch { inputs, targets, b, t }
    }

    #[test]
    fn forward_loss_is_near_log_vocab_at_init() {
        let model = tiny_model();
        let batch = tiny_batch(&model.cfg, 1);
        let loss = model.loss(&batch);
        let expect = (model.cfg.vocab as f32).ln();
        assert!(
            (loss - expect).abs() < 0.5,
            "init loss {loss} should be ≈ ln(V) = {expect}"
        );
    }

    #[test]
    fn param_count_matches_config_formula() {
        let model = tiny_model();
        assert_eq!(model.param_count(), model.cfg.param_count());
        let small = Llama::new(ModelConfig::preset("tiny"), 3);
        assert_eq!(small.param_count(), small.cfg.param_count());
    }

    /// Central-difference gradient check over a random subset of entries of
    /// every parameter tensor. This is the single most important test of the
    /// native engine.
    #[test]
    fn gradcheck_all_params() {
        let mut model = tiny_model();
        let batch = tiny_batch(&model.cfg, 2);
        let (_, grads) = model.loss_and_grad(&batch);
        let mut rng = Rng::new(99);
        let eps = 3e-3f32;
        let n_params = model.params.len();
        for pi in 0..n_params {
            let numel = model.params[pi].value.len();
            // Check up to 6 random entries per tensor.
            for _ in 0..6.min(numel) {
                let flat = rng.below(numel);
                let orig = model.params[pi].value.data()[flat];
                model.params[pi].value.data_mut()[flat] = orig + eps;
                let lp = model.loss(&batch);
                model.params[pi].value.data_mut()[flat] = orig - eps;
                let lm = model.loss(&batch);
                model.params[pi].value.data_mut()[flat] = orig;
                let numeric = (lp - lm) / (2.0 * eps);
                let analytic = grads[pi].data()[flat];
                let tol = 1e-2f32.max(0.08 * numeric.abs().max(analytic.abs()));
                assert!(
                    (numeric - analytic).abs() < tol,
                    "param {} ({}): numeric {numeric} vs analytic {analytic}",
                    model.params[pi].name,
                    flat
                );
            }
        }
    }

    #[test]
    fn cross_entropy_matches_manual() {
        // Two rows, V=3; uniform logits ⇒ loss = ln 3, dlogits = (1/3 − onehot)/2.
        let logits = Matrix::zeros(2, 3);
        let (loss, dl) = cross_entropy(&logits, &[0, 2]);
        assert!((loss - 3f32.ln()).abs() < 1e-5);
        assert!((dl.get(0, 0) - (1.0 / 3.0 - 1.0) / 2.0).abs() < 1e-5);
        assert!((dl.get(0, 1) - (1.0 / 3.0) / 2.0).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_ignores_padding() {
        let logits = Matrix::zeros(2, 3);
        let (loss, dl) = cross_entropy(&logits, &[0, u32::MAX]);
        assert!((loss - 3f32.ln()).abs() < 1e-5);
        // Padded row contributes zero gradient.
        assert_eq!(dl.row(1), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn causal_mask_blocks_future() {
        // Token at position 0 must be unaffected by tokens at positions > 0.
        let model = tiny_model();
        let mut batch = tiny_batch(&model.cfg, 3);
        let c1 = model.forward_hidden(&batch.inputs, batch.b, batch.t);
        // Perturb the last token of sequence 0.
        batch.inputs[model.cfg.seq_len - 1] =
            (batch.inputs[model.cfg.seq_len - 1] + 1) % model.cfg.vocab as u32;
        let c2 = model.forward_hidden(&batch.inputs, batch.b, batch.t);
        // Position 0 hidden state unchanged.
        let r1 = c1.hidden.row(0);
        let r2 = c2.hidden.row(0);
        for (a, b) in r1.iter().zip(r2) {
            assert!((a - b).abs() < 1e-6, "future token leaked into position 0");
        }
    }

    #[test]
    fn rope_inverse_roundtrip() {
        let mut rng = Rng::new(5);
        let (t, heads, d) = (6, 2, 8);
        let orig = Matrix::randn(2 * t, heads * d, 1.0, &mut rng);
        let mut x = orig.clone();
        rope_apply(&mut x, t, heads, d, 10_000.0, false);
        rope_apply(&mut x, t, heads, d, 10_000.0, true);
        crate::util::proptest::close(x.data(), orig.data(), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn rmsnorm_forward_backward_consistency() {
        let mut rng = Rng::new(6);
        let x = Matrix::randn(4, 10, 1.0, &mut rng);
        let gain = Matrix::randn(1, 10, 0.5, &mut rng).map(|v| v + 1.0);
        let (y, inv) = rmsnorm_forward(&x, &gain);
        // Numeric check of dx against finite differences for a random scalar
        // objective L = Σ w ⊙ y.
        let w = Matrix::randn(4, 10, 1.0, &mut rng);
        let (dx, dg) = rmsnorm_backward(&x, &inv, &gain, &w);
        let f = |x: &Matrix, gain: &Matrix| -> f32 {
            let (y, _) = rmsnorm_forward(x, gain);
            y.hadamard(&w).sum()
        };
        let eps = 1e-3;
        for &(i, j) in &[(0usize, 0usize), (1, 3), (3, 9)] {
            let mut xp = x.clone();
            xp.set(i, j, x.get(i, j) + eps);
            let mut xm = x.clone();
            xm.set(i, j, x.get(i, j) - eps);
            let num = (f(&xp, &gain) - f(&xm, &gain)) / (2.0 * eps);
            let ana = dx.get(i, j);
            assert!((num - ana).abs() < 2e-2, "dx[{i},{j}]: {num} vs {ana}");
        }
        for j in [0usize, 5, 9] {
            let mut gp = gain.clone();
            gp.set(0, j, gain.get(0, j) + eps);
            let mut gm = gain.clone();
            gm.set(0, j, gain.get(0, j) - eps);
            let num = (f(&x, &gp) - f(&x, &gm)) / (2.0 * eps);
            let ana = dg.get(0, j);
            assert!((num - ana).abs() < 2e-2, "dg[{j}]: {num} vs {ana}");
        }
        let _ = y;
    }

    #[test]
    fn training_step_reduces_loss() {
        // A few full-rank Adam steps on one fixed batch must reduce loss.
        use crate::optim::{Adam, AdamCfg, Optimizer};
        let mut model = Llama::new(ModelConfig::preset("nano"), 11);
        let batch = tiny_batch(&model.cfg, 12);
        let mut opt = Adam::new(AdamCfg::default());
        let initial = model.loss(&batch);
        for _ in 0..30 {
            let (_, grads) = model.loss_and_grad(&batch);
            opt.step(5e-3, &mut model.params, &grads);
        }
        let fin = model.loss(&batch);
        assert!(
            fin < initial * 0.7,
            "overfit one batch: {initial} -> {fin}"
        );
    }
}
