//! Conversions between the in-tree [`Matrix`]/token types and `xla::Literal`.

use crate::tensor::Matrix;

/// Matrix → 2-D f32 literal.
pub fn matrix_to_literal(m: &Matrix) -> anyhow::Result<xla::Literal> {
    let bytes: Vec<u8> = m.data().iter().flat_map(|v| v.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &[m.rows(), m.cols()],
        &bytes,
    )
    .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Matrix stored as a 1-row vector → 1-D f32 literal of length `cols`.
pub fn vector_to_literal(m: &Matrix) -> anyhow::Result<xla::Literal> {
    let bytes: Vec<u8> = m.data().iter().flat_map(|v| v.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &[m.len()],
        &bytes,
    )
    .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Token ids → 2-D i32 literal of shape (b, t).
pub fn tokens_to_literal(tokens: &[u32], b: usize, t: usize) -> anyhow::Result<xla::Literal> {
    assert_eq!(tokens.len(), b * t);
    let bytes: Vec<u8> = tokens.iter().flat_map(|&v| (v as i32).to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, &[b, t], &bytes)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// f32 literal → Matrix with the given shape (element count must match).
pub fn literal_to_matrix(lit: &xla::Literal, rows: usize, cols: usize) -> anyhow::Result<Matrix> {
    let data: Vec<f32> = lit.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    anyhow::ensure!(
        data.len() == rows * cols,
        "literal has {} elements, expected {}x{}",
        data.len(),
        rows,
        cols
    );
    Ok(Matrix::from_vec(rows, cols, data))
}

/// Scalar f32 literal → f32.
pub fn literal_to_scalar(lit: &xla::Literal) -> anyhow::Result<f32> {
    let data: Vec<f32> = lit.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    anyhow::ensure!(data.len() == 1, "expected scalar, got {} elements", data.len());
    Ok(data[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matrix_literal_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(3, 5, 1.0, &mut rng);
        let lit = match matrix_to_literal(&m) {
            Ok(l) => l,
            Err(_) => return, // xla runtime unavailable
        };
        let back = literal_to_matrix(&lit, 3, 5).unwrap();
        assert_eq!(back.data(), m.data());
    }

    #[test]
    fn token_literal_shape() {
        let toks = vec![1u32, 2, 3, 4, 5, 6];
        let lit = match tokens_to_literal(&toks, 2, 3) {
            Ok(l) => l,
            Err(_) => return,
        };
        assert_eq!(lit.element_count(), 6);
    }
}
