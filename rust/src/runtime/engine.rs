//! The PJRT gradient engine: executes the JAX-lowered `train_step` artifact
//! (forward + backward of the Layer-2 model, embedding the Layer-1 Pallas
//! kernels) with the current Rust-side parameters and returns loss +
//! gradients to the Layer-3 optimizer.
//!
//! Artifact contract (see `python/compile/aot.py`):
//!   name   : `train_step_<preset>_b<B>_t<T>`
//!   inputs : every parameter in the Rust layout order (2-D params as f32
//!            (rows, cols), 1-D params as f32 (len,)), then `inputs` i32
//!            (B, T), then `targets` i32 (B, T)
//!   output : tuple(loss f32 scalar, grad per parameter in the same order)

use super::literal;
use super::PjrtRuntime;
use crate::model::Batch;
use crate::optim::{Param, ParamKind};
use crate::tensor::Matrix;

/// Executes `train_step` artifacts for one (preset, batch-shape) bucket.
pub struct PjrtEngine {
    runtime: PjrtRuntime,
    artifact: String,
    /// Parameter shapes, captured on first call for output mapping.
    shapes: Vec<(usize, usize)>,
}

impl PjrtEngine {
    /// Create an engine for the given model preset and batch shape. Fails
    /// fast if the artifact file is missing (run `make artifacts`).
    pub fn new(
        artifacts_dir: &str,
        preset: &str,
        b: usize,
        t: usize,
    ) -> anyhow::Result<PjrtEngine> {
        let runtime = PjrtRuntime::cpu(artifacts_dir)?;
        let artifact = format!("train_step_{preset}_b{b}_t{t}");
        anyhow::ensure!(
            runtime.has_artifact(&artifact),
            "artifact {artifact} not found under {artifacts_dir} — run `make artifacts`"
        );
        Ok(PjrtEngine { runtime, artifact, shapes: Vec::new() })
    }

    pub fn artifact_name(&self) -> &str {
        &self.artifact
    }

    fn build_inputs(&mut self, params: &[Param], batch: &Batch) -> anyhow::Result<Vec<xla::Literal>> {
        self.shapes = params.iter().map(|p| p.value.shape()).collect();
        let mut lits = Vec::with_capacity(params.len() + 2);
        for p in params {
            let lit = match p.kind {
                ParamKind::Matrix2D => literal::matrix_to_literal(&p.value)?,
                ParamKind::Vector => literal::vector_to_literal(&p.value)?,
            };
            lits.push(lit);
        }
        lits.push(literal::tokens_to_literal(&batch.inputs, batch.b, batch.t)?);
        lits.push(literal::tokens_to_literal(&batch.targets, batch.b, batch.t)?);
        Ok(lits)
    }

    /// Loss + gradients via the lowered train_step.
    pub fn loss_and_grad(
        &mut self,
        params: &[Param],
        batch: &Batch,
    ) -> anyhow::Result<(f32, Vec<Matrix>)> {
        let inputs = self.build_inputs(params, batch)?;
        let artifact = self.artifact.clone();
        let outputs = self.runtime.execute(&artifact, &inputs)?;
        anyhow::ensure!(
            outputs.len() == params.len() + 1,
            "train_step returned {} outputs, expected {}",
            outputs.len(),
            params.len() + 1
        );
        let loss = literal::literal_to_scalar(&outputs[0])?;
        let mut grads = Vec::with_capacity(params.len());
        for (i, (rows, cols)) in self.shapes.iter().enumerate() {
            grads.push(literal::literal_to_matrix(&outputs[i + 1], *rows, *cols)?);
        }
        Ok((loss, grads))
    }

    /// Loss only (eval path) — reuses the same artifact and discards grads.
    pub fn loss(&mut self, params: &[Param], batch: &Batch) -> anyhow::Result<f32> {
        Ok(self.loss_and_grad(params, batch)?.0)
    }
}
