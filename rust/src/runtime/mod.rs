//! PJRT runtime — Layer 3's bridge to the JAX-lowered (Layer 2) compute
//! graphs that embed the Pallas (Layer 1) kernels.
//!
//! `make artifacts` runs `python/compile/aot.py` once at build time, writing
//! HLO **text** modules under `artifacts/` (text, not serialized protos:
//! jax ≥ 0.5 emits 64-bit instruction ids that the crate's xla_extension
//! 0.5.1 rejects; the text parser reassigns ids — see DESIGN.md §AOT).
//! This module loads those files, compiles them once on the PJRT CPU client,
//! and executes them from the training loop. Python never runs here.

pub mod engine;
pub mod literal;

pub use engine::PjrtEngine;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT client plus a compile cache keyed by artifact path.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU-backed runtime rooted at the given artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(PjrtRuntime {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Absolute path of a named artifact.
    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.artifacts_dir.join(format!("{name}.hlo.txt"))
    }

    /// Whether the artifact file exists (drives graceful skipping in tests
    /// when `make artifacts` has not run).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_path(name).exists()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, name: &str) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.artifact_path(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow::anyhow!("loading {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    /// Execute a loaded artifact on literal inputs; returns the flattened
    /// tuple elements (every artifact is lowered with `return_tuple=True`).
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {name}: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_reported() {
        let mut rt = match PjrtRuntime::cpu("artifacts") {
            Ok(rt) => rt,
            Err(_) => return, // PJRT unavailable in this environment
        };
        assert!(!rt.has_artifact("no_such_module"));
        let err = rt.load("no_such_module");
        assert!(err.is_err());
    }
}
