//! # SubTrack++ — Grassmannian gradient subspace tracking for scalable LLM training
//!
//! Full reproduction of *SubTrack++: Gradient Subspace Tracking for Scalable LLM
//! Training* (Rajabi, Nonta, Rambhatla; 2025) as a three-layer Rust + JAX/Pallas
//! stack. This crate is Layer 3: the training coordinator. It owns the training
//! loop, the optimizer family (the paper's contribution plus every baseline it
//! compares against), the data pipeline, configuration, metrics and the PJRT
//! runtime that executes the JAX-lowered (Layer 2) compute graphs embedding the
//! Pallas (Layer 1) kernels. Python never runs on the training path.
//!
//! ## Layout
//!
//! * [`tensor`] — dense f32 linear-algebra substrate (gemm, QR, Jacobi SVD,
//!   power iteration, least squares) built from scratch.
//! * [`optim`] — `Adam`/`AdamW`, `GaLore`, `Fira`, `LDAdam`, `OnlineSubspaceDescent`,
//!   `BAdam`, `Apollo`, `GoLore` and [`optim::subtrack::SubTrack`] (the paper).
//! * [`model`] — Llama-family transformer with a hand-written backward pass
//!   (the "native" engine) plus the paper's model-size configurations.
//! * [`data`] — synthetic corpus generators, tokenizer, batcher, and
//!   GLUE-style classification task generators.
//! * [`train`] — trainer, LR schedules, metrics, checkpointing, and the
//!   data-parallel worker simulation.
//! * [`runtime`] — PJRT engine: loads `artifacts/*.hlo.txt` and executes them.
//! * [`bench`] — in-tree micro-benchmark harness (criterion-like).
//! * [`util`] — RNG, CLI/config parsing, JSON/CSV emitters, property testing.
//! * [`experiments`] — the per-table/figure reproduction harnesses.

// The tensor kernels and hand-written backward passes index several slices
// per loop in lockstep; iterator rewrites would obscure the math and, in the
// GEMM inner loops, the autovectorization-friendly shape.
#![allow(clippy::needless_range_loop)]

pub mod bench;
pub mod data;
pub mod experiments;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
