//! Per-tensor dynamic loss scaling for f16 gradient storage.
//!
//! f16's narrow exponent range (max finite 65504, smallest subnormal
//! ≈ 6e-8) clips large gradients to Inf and flushes small ones to zero.
//! The standard fix is to scale the loss — equivalently, the gradients —
//! by a factor S before rounding into f16, then unscale after: values move
//! into f16's representable band and tiny gradients survive. S must adapt:
//! too large and scaled gradients overflow, too small and the underflow
//! protection is wasted. [`DynamicLossScaler`] implements the usual
//! grow/backoff loop, **per tensor** (gradient magnitudes differ by orders
//! of magnitude across a transformer's parameter groups, so one global
//! scale is dominated by its worst tensor):
//!
//! - Any non-finite scaled-f16 value ⇒ that tensor's scale halves, its
//!   good-step counter resets, and the *whole* optimizer step is skipped
//!   (the trainer drops it like a sentinel `skip` — state untouched).
//! - After [`DEFAULT_GROWTH_INTERVAL`] consecutive clean steps a tensor's
//!   scale doubles (capped), probing back toward the overflow boundary.
//!
//! bf16 needs none of this — it keeps f32's exponent range — which is why
//! the trainer only instantiates a scaler under `dtype = "f16"`. Scales
//! and counters persist through checkpoints (format-3 manifest) so a
//! resumed f16 run replays the uninterrupted one bit for bit.

use crate::tensor::{dtype, Matrix};

/// Starting scale for every tensor: 2^12, large enough to lift typical
/// late-training gradients (~1e-6) well clear of f16's subnormal floor
/// while leaving ~4 octaves of headroom below overflow for loss spikes.
pub const INIT_SCALE: f32 = 4096.0;

/// Consecutive clean steps before a tensor's scale doubles. Far shorter
/// than production defaults (~2000) because testbed runs are tens to
/// hundreds of steps; powers of two keep scaling exact in f32.
pub const DEFAULT_GROWTH_INTERVAL: u64 = 256;

const MAX_SCALE: f32 = 65536.0; // 2^16
const MIN_SCALE: f32 = 1.0;

/// Per-tensor dynamic loss scaler (module docs). Sized lazily on the
/// first [`quantize_step`](DynamicLossScaler::quantize_step) call.
pub struct DynamicLossScaler {
    scales: Vec<f32>,
    /// Consecutive overflow-free steps per tensor.
    good: Vec<u64>,
    growth_interval: u64,
    skipped: usize,
}

impl DynamicLossScaler {
    pub fn new() -> DynamicLossScaler {
        DynamicLossScaler {
            scales: Vec::new(),
            good: Vec::new(),
            growth_interval: DEFAULT_GROWTH_INTERVAL,
            skipped: 0,
        }
    }

    fn ensure(&mut self, n: usize) {
        if self.scales.len() != n {
            self.scales = vec![INIT_SCALE; n];
            self.good = vec![0; n];
        }
    }

    /// Emulate f16 gradient storage under the current scales: each value
    /// rounds through `f16(v * S)` and unscales back to f32.
    ///
    /// Returns `false` — with `grads` **untouched** — when any tensor's
    /// scaled gradient leaves f16's finite range (its scale is backed off
    /// and the caller must drop the step). Returns `true` after committing
    /// the rounded gradients and advancing the growth counters. A NaN
    /// input gradient also reads as overflow; the step is dropped either
    /// way, the scale backoff is a harmless false alarm.
    pub fn quantize_step(&mut self, grads: &mut [Matrix]) -> bool {
        self.ensure(grads.len());
        // Detection pass first so a rejected step leaves the gradients
        // exactly as computed (the trainer may still want their norm).
        let mut ok = true;
        for (i, g) in grads.iter().enumerate() {
            let s = self.scales[i];
            let overflow = g
                .data()
                .iter()
                .any(|&v| !dtype::f16_to_f32(dtype::f32_to_f16(v * s)).is_finite());
            if overflow {
                self.scales[i] = (self.scales[i] * 0.5).max(MIN_SCALE);
                self.good[i] = 0;
                ok = false;
            }
        }
        if !ok {
            self.skipped += 1;
            return false;
        }
        for (i, g) in grads.iter_mut().enumerate() {
            let s = self.scales[i];
            let inv = 1.0 / s;
            for v in g.data_mut() {
                *v = dtype::f16_to_f32(dtype::f32_to_f16(*v * s)) * inv;
            }
            self.good[i] += 1;
            if self.good[i] >= self.growth_interval && self.scales[i] < MAX_SCALE {
                self.scales[i] *= 2.0;
                self.good[i] = 0;
            }
        }
        true
    }

    /// Optimizer steps dropped for overflow so far.
    pub fn skips(&self) -> usize {
        self.skipped
    }

    /// Current per-tensor scales (empty before the first step).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Checkpoint export: `(scales, good counters)`, parallel vectors.
    pub fn export(&self) -> (Vec<f32>, Vec<u64>) {
        (self.scales.clone(), self.good.clone())
    }

    /// Checkpoint import (resume). A later `quantize_step` with a
    /// different tensor count resets to defaults rather than misaligning.
    pub fn import(&mut self, scales: &[f32], good: &[u64]) {
        self.scales = scales.to_vec();
        self.good = good.to_vec();
    }
}

impl Default for DynamicLossScaler {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overflow_backs_off_skips_and_leaves_grads_untouched() {
        let mut sc = DynamicLossScaler::new();
        // 1e30 * 4096 = 4.1e33: finite in f32, far past f16's 65504.
        let mut grads = vec![Matrix::full(2, 2, 1e30)];
        assert!(!sc.quantize_step(&mut grads));
        assert_eq!(grads[0].get(0, 0), 1e30, "rejected step must not mutate grads");
        assert_eq!(sc.scales()[0], INIT_SCALE * 0.5);
        assert_eq!(sc.skips(), 1);
    }

    #[test]
    fn repeated_overflow_walks_scale_down_to_the_floor() {
        let mut sc = DynamicLossScaler::new();
        let mut grads = vec![Matrix::full(1, 1, f32::MAX)];
        for _ in 0..40 {
            assert!(!sc.quantize_step(&mut grads));
        }
        assert_eq!(sc.scales()[0], MIN_SCALE, "scale must clamp, not hit zero");
    }

    #[test]
    fn scaling_preserves_grads_raw_f16_would_flush_to_zero() {
        // 1e-9 is below f16's smallest subnormal (~6e-8): direct f16
        // storage loses it entirely. Scaled by 4096 it lands at 4.1e-6,
        // comfortably representable.
        assert_eq!(dtype::f16_to_f32(dtype::f32_to_f16(1e-9)), 0.0, "premise");
        let mut sc = DynamicLossScaler::new();
        let mut grads = vec![Matrix::full(2, 2, 1e-9)];
        assert!(sc.quantize_step(&mut grads));
        let got = grads[0].get(0, 0);
        assert!(got > 0.0, "scaled path must not flush to zero");
        assert!((got - 1e-9).abs() / 1e-9 < 1e-2, "got {got}");
    }

    #[test]
    fn clean_streak_doubles_the_scale() {
        let mut sc = DynamicLossScaler::new();
        let mut grads = vec![Matrix::full(1, 1, 1e-3)];
        for _ in 0..DEFAULT_GROWTH_INTERVAL {
            assert!(sc.quantize_step(&mut grads));
        }
        assert_eq!(sc.scales()[0], INIT_SCALE * 2.0);
        // One overflow resets the streak and halves back.
        let mut big = vec![Matrix::full(1, 1, 1e30)];
        assert!(!sc.quantize_step(&mut big));
        assert_eq!(sc.scales()[0], INIT_SCALE);
    }

    #[test]
    fn export_import_roundtrips_state() {
        let mut sc = DynamicLossScaler::new();
        let mut grads = vec![Matrix::full(1, 1, 1e-3), Matrix::full(1, 2, 2e-3)];
        for _ in 0..5 {
            assert!(sc.quantize_step(&mut grads));
        }
        let (scales, good) = sc.export();
        assert_eq!(good, vec![5, 5]);
        let mut fresh = DynamicLossScaler::new();
        fresh.import(&scales, &good);
        assert_eq!(fresh.export(), (scales, good));
    }

    #[test]
    fn per_tensor_scales_move_independently() {
        let mut sc = DynamicLossScaler::new();
        let mut grads = vec![Matrix::full(1, 1, 1e30), Matrix::full(1, 1, 1e-3)];
        assert!(!sc.quantize_step(&mut grads));
        assert_eq!(sc.scales()[0], INIT_SCALE * 0.5, "overflowing tensor backs off");
        assert_eq!(sc.scales()[1], INIT_SCALE, "healthy tensor keeps its scale");
    }
}
