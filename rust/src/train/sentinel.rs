//! Numerical-health sentinel: per-step anomaly detection + response policy.
//!
//! Each training step the sentinel inspects the loss and the *pre-clip*
//! global gradient norm (both already bit-identical across worker counts and
//! DP shards, so verdicts are too). A step is anomalous when either value is
//! non-finite, or when the loss spikes above `spike_factor` times the rolling
//! mean of the last `spike_window` healthy losses. The configured policy maps
//! an anomaly to a verdict the trainer acts on:
//!
//! - `skip`: drop the step (parameters and optimizer state untouched).
//! - `rollback`: restore parameters + full optimizer state from the last
//!   in-memory snapshot (taken every `snapshot_every` steps).
//! - `abort`: stop training with a diagnostic dump.
//! - `escalate`: climb a ladder instead of repeating one response — the
//!   first `escalate_after` consecutive anomalies are skipped; further
//!   anomalies roll back; once the *same* snapshot has been restored
//!   `loop_restores` times without a new last-good landing in between (a
//!   rollback loop), the next rollback also re-warms the learning rate from
//!   near zero over `rewarm_steps` steps; another `loop_restores` restores
//!   with still no progress aborts.
//!
//! Every input to the ladder (loss, grad norm, consecutive-anomaly count,
//! restores-since-last-good) is bit-identical across worker counts and DP
//! shard layouts, so the event log is too.
//!
//! With `policy = "off"` (the default) `check` is a single branch — no window
//! bookkeeping, no event log.

use std::collections::VecDeque;

/// Response policy for anomalous steps ([`train.fault`] `policy` key).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPolicy {
    Off,
    Skip,
    Rollback,
    Abort,
    /// Skip → rollback → rollback-with-LR-rewarm → abort ladder.
    Escalate,
}

impl FaultPolicy {
    pub fn parse(s: &str) -> Option<FaultPolicy> {
        match s {
            "off" => Some(FaultPolicy::Off),
            "skip" => Some(FaultPolicy::Skip),
            "rollback" => Some(FaultPolicy::Rollback),
            "abort" => Some(FaultPolicy::Abort),
            "escalate" => Some(FaultPolicy::Escalate),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FaultPolicy::Off => "off",
            FaultPolicy::Skip => "skip",
            FaultPolicy::Rollback => "rollback",
            FaultPolicy::Abort => "abort",
            FaultPolicy::Escalate => "escalate",
        }
    }

    /// Policies whose responses need in-memory last-good snapshots.
    pub fn needs_snapshots(self) -> bool {
        matches!(self, FaultPolicy::Rollback | FaultPolicy::Escalate)
    }
}

/// Sentinel tuning knobs (see `[train.fault]` in ROADMAP.md).
#[derive(Clone, Copy, Debug)]
pub struct SentinelConfig {
    pub policy: FaultPolicy,
    /// Steps between in-memory last-good snapshots (rollback granularity).
    pub snapshot_every: usize,
    /// Healthy losses folded into the rolling spike baseline.
    pub spike_window: usize,
    /// Loss > factor × rolling mean ⇒ spike. Non-positive disables the
    /// spike detector (finiteness checks still apply).
    pub spike_factor: f32,
    /// `escalate` only: consecutive anomalies tolerated as skips before the
    /// ladder climbs to rollback.
    pub escalate_after: usize,
    /// `escalate` only: restores of the same snapshot (no new last-good in
    /// between) before the ladder climbs a rung — rollback → rewarm, and
    /// rewarm → abort.
    pub loop_restores: usize,
    /// `escalate` only: steps over which the LR ramps back to full after a
    /// rollback-with-rewarm.
    pub rewarm_steps: usize,
}

impl Default for SentinelConfig {
    fn default() -> SentinelConfig {
        SentinelConfig {
            policy: FaultPolicy::Off,
            snapshot_every: 25,
            spike_window: 16,
            spike_factor: 10.0,
            escalate_after: 2,
            loop_restores: 3,
            rewarm_steps: 10,
        }
    }
}

/// What the trainer should do with the step just computed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Healthy,
    Skip,
    Rollback,
    /// Rollback, then ramp the learning rate back up over
    /// [`SentinelConfig::rewarm_steps`] steps (escalate ladder rung 3).
    RollbackRewarm,
    Abort,
}

/// One anomalous step, kept for the abort dump and determinism tests.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SentinelEvent {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub verdict: Verdict,
}

/// Rolling-window health monitor. One per trainer.
pub struct Sentinel {
    cfg: SentinelConfig,
    window: VecDeque<f32>,
    events: Vec<SentinelEvent>,
    n_skips: usize,
    n_rollbacks: usize,
    n_rewarms: usize,
    /// Consecutive anomalous steps (escalate ladder rung selector).
    consec: usize,
    /// Restores issued since the last *new* snapshot landed. A fresh
    /// snapshot means the run made real progress past the previous restore
    /// point, so the ladder resets; restores without one are a loop.
    restores_since_good: usize,
}

impl Sentinel {
    pub fn new(cfg: SentinelConfig) -> Sentinel {
        Sentinel {
            cfg,
            window: VecDeque::new(),
            events: Vec::new(),
            n_skips: 0,
            n_rollbacks: 0,
            n_rewarms: 0,
            consec: 0,
            restores_since_good: 0,
        }
    }

    /// Tell the sentinel a new last-good snapshot was taken. Progress past
    /// the previous restore point resets the rollback-loop detector.
    pub fn note_snapshot(&mut self) {
        self.restores_since_good = 0;
    }

    fn anomalous(&self, loss: f32, grad_norm: f32) -> bool {
        if !loss.is_finite() || !grad_norm.is_finite() {
            return true;
        }
        if self.cfg.spike_factor > 0.0
            && self.cfg.spike_window > 0
            && self.window.len() >= self.cfg.spike_window
        {
            let mean = self.window.iter().map(|&l| l as f64).sum::<f64>()
                / self.window.len() as f64;
            return (loss as f64) > self.cfg.spike_factor as f64 * mean.max(1e-6);
        }
        false
    }

    /// Classify one step. Healthy losses feed the spike baseline; anomalies
    /// are logged and counted. After a rollback verdict the window is cleared
    /// so the replayed steps rebuild a fresh baseline instead of being judged
    /// against the pre-anomaly one.
    pub fn check(&mut self, step: usize, loss: f32, grad_norm: f32) -> Verdict {
        if self.cfg.policy == FaultPolicy::Off {
            return Verdict::Healthy;
        }
        if !self.anomalous(loss, grad_norm) {
            if self.window.len() == self.cfg.spike_window.max(1) {
                self.window.pop_front();
            }
            self.window.push_back(loss);
            self.consec = 0;
            return Verdict::Healthy;
        }
        self.consec += 1;
        let verdict = match self.cfg.policy {
            FaultPolicy::Off => unreachable!("handled above"),
            FaultPolicy::Skip => Verdict::Skip,
            FaultPolicy::Rollback => Verdict::Rollback,
            FaultPolicy::Abort => Verdict::Abort,
            FaultPolicy::Escalate => {
                if self.consec <= self.cfg.escalate_after {
                    Verdict::Skip
                } else if self.restores_since_good < self.cfg.loop_restores {
                    Verdict::Rollback
                } else if self.restores_since_good < 2 * self.cfg.loop_restores {
                    Verdict::RollbackRewarm
                } else {
                    Verdict::Abort
                }
            }
        };
        match verdict {
            Verdict::Skip => self.n_skips += 1,
            Verdict::Rollback | Verdict::RollbackRewarm => {
                self.n_rollbacks += 1;
                if verdict == Verdict::RollbackRewarm {
                    self.n_rewarms += 1;
                }
                self.restores_since_good += 1;
                self.window.clear();
            }
            _ => {}
        }
        self.events.push(SentinelEvent { step, loss, grad_norm, verdict });
        verdict
    }

    pub fn config(&self) -> &SentinelConfig {
        &self.cfg
    }

    pub fn skips(&self) -> usize {
        self.n_skips
    }

    pub fn rollbacks(&self) -> usize {
        self.n_rollbacks
    }

    pub fn rewarms(&self) -> usize {
        self.n_rewarms
    }

    pub fn events(&self) -> &[SentinelEvent] {
        &self.events
    }

    /// Diagnostic dump for `policy = "abort"` and post-mortems.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "sentinel: policy={} skips={} rollbacks={} rewarms={} \
             restores_since_good={} events={}\n",
            self.cfg.policy.as_str(),
            self.n_skips,
            self.n_rollbacks,
            self.n_rewarms,
            self.restores_since_good,
            self.events.len()
        ));
        for e in &self.events {
            out.push_str(&format!(
                "  step {:>6}  loss {:>12.6}  grad_norm {:>12.6}  -> {:?}\n",
                e.step, e.loss, e.grad_norm, e.verdict
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(policy: FaultPolicy) -> SentinelConfig {
        SentinelConfig {
            policy,
            snapshot_every: 5,
            spike_window: 4,
            spike_factor: 10.0,
            escalate_after: 2,
            loop_restores: 2,
            rewarm_steps: 4,
        }
    }

    #[test]
    fn off_policy_is_always_healthy() {
        let mut s = Sentinel::new(cfg(FaultPolicy::Off));
        assert_eq!(s.check(0, f32::NAN, f32::INFINITY), Verdict::Healthy);
        assert!(s.events().is_empty());
    }

    #[test]
    fn nonfinite_loss_or_norm_triggers_policy() {
        let mut s = Sentinel::new(cfg(FaultPolicy::Skip));
        assert_eq!(s.check(0, 1.0, 1.0), Verdict::Healthy);
        assert_eq!(s.check(1, f32::NAN, 1.0), Verdict::Skip);
        assert_eq!(s.check(2, 1.0, f32::INFINITY), Verdict::Skip);
        assert_eq!(s.skips(), 2);
        assert_eq!(s.events().len(), 2);
    }

    #[test]
    fn spike_detector_needs_full_window() {
        let mut s = Sentinel::new(cfg(FaultPolicy::Rollback));
        // Window not yet full: a big loss is not judged.
        assert_eq!(s.check(0, 100.0, 1.0), Verdict::Healthy);
        for step in 1..=4 {
            assert_eq!(s.check(step, 1.0, 1.0), Verdict::Healthy);
        }
        // Window full of ~1.0 losses; 10× mean trips the detector.
        assert_eq!(s.check(5, 50.0, 1.0), Verdict::Rollback);
        assert_eq!(s.rollbacks(), 1);
        // Window cleared on rollback: the same loss is healthy again.
        assert_eq!(s.check(6, 50.0, 1.0), Verdict::Healthy);
    }

    #[test]
    fn healthy_losses_roll_the_window() {
        let mut s = Sentinel::new(cfg(FaultPolicy::Skip));
        for step in 0..8 {
            assert_eq!(s.check(step, 1.0 + step as f32 * 0.01, 1.0), Verdict::Healthy);
        }
        // Baseline tracks recent losses, not all-time: a loss 10× the very
        // first value but < 10× the recent mean is fine.
        assert_eq!(s.check(8, 9.0, 1.0), Verdict::Healthy);
        assert_eq!(s.check(9, 12.0, 1.0), Verdict::Skip);
    }

    #[test]
    fn abort_dump_names_the_offending_step() {
        let mut s = Sentinel::new(cfg(FaultPolicy::Abort));
        assert_eq!(s.check(7, f32::NAN, 1.0), Verdict::Abort);
        let dump = s.dump();
        assert!(dump.contains("policy=abort"), "{dump}");
        assert!(dump.contains("step      7"), "{dump}");
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            FaultPolicy::Off,
            FaultPolicy::Skip,
            FaultPolicy::Rollback,
            FaultPolicy::Abort,
            FaultPolicy::Escalate,
        ] {
            assert_eq!(FaultPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(FaultPolicy::parse("retry"), None);
    }

    #[test]
    fn escalate_ladder_skips_then_rolls_back() {
        let mut s = Sentinel::new(cfg(FaultPolicy::Escalate));
        // First `escalate_after` consecutive anomalies are tolerated as skips.
        assert_eq!(s.check(0, f32::NAN, 1.0), Verdict::Skip);
        assert_eq!(s.check(1, f32::NAN, 1.0), Verdict::Skip);
        // The third climbs to rollback.
        assert_eq!(s.check(2, f32::NAN, 1.0), Verdict::Rollback);
        assert_eq!(s.skips(), 2);
        assert_eq!(s.rollbacks(), 1);
        // A healthy step resets the consecutive counter...
        assert_eq!(s.check(3, 1.0, 1.0), Verdict::Healthy);
        // ...so the ladder restarts at skip.
        assert_eq!(s.check(4, f32::NAN, 1.0), Verdict::Skip);
    }

    #[test]
    fn rollback_loop_escalates_to_rewarm_then_abort() {
        let mut s = Sentinel::new(cfg(FaultPolicy::Escalate));
        // Burn through the skip budget.
        assert_eq!(s.check(0, f32::NAN, 1.0), Verdict::Skip);
        assert_eq!(s.check(1, f32::NAN, 1.0), Verdict::Skip);
        // loop_restores = 2 plain rollbacks of the same snapshot...
        assert_eq!(s.check(2, f32::NAN, 1.0), Verdict::Rollback);
        assert_eq!(s.check(3, f32::NAN, 1.0), Verdict::Rollback);
        // ...then the ladder climbs to rollback-with-rewarm...
        assert_eq!(s.check(4, f32::NAN, 1.0), Verdict::RollbackRewarm);
        assert_eq!(s.check(5, f32::NAN, 1.0), Verdict::RollbackRewarm);
        assert_eq!(s.rewarms(), 2);
        // ...and with still no progress, aborts.
        assert_eq!(s.check(6, f32::NAN, 1.0), Verdict::Abort);
        assert_eq!(s.rollbacks(), 4);
    }

    #[test]
    fn new_snapshot_resets_the_rollback_loop_detector() {
        let mut s = Sentinel::new(cfg(FaultPolicy::Escalate));
        for step in 0..2 {
            assert_eq!(s.check(step, f32::NAN, 1.0), Verdict::Skip);
        }
        assert_eq!(s.check(2, f32::NAN, 1.0), Verdict::Rollback);
        assert_eq!(s.check(3, f32::NAN, 1.0), Verdict::Rollback);
        // Run made it to a fresh snapshot: the loop detector resets and the
        // next escalation starts back at plain rollback, not rewarm.
        s.note_snapshot();
        assert_eq!(s.check(4, f32::NAN, 1.0), Verdict::Rollback);
    }
}
