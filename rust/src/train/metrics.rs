//! Training metrics: per-step loss curve with wall-clock timestamps, memory
//! accounting (analytic optimizer-state bytes + measured RSS), and CSV/JSON
//! export for the figure/table harnesses.

use crate::util::csv::CsvWriter;
use crate::util::json::Json;
use std::time::Instant;

/// One recorded step.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
    /// Seconds since training started.
    pub elapsed: f64,
}

/// Streaming metrics log.
pub struct MetricsLog {
    start: Instant,
    /// Wall-clock seconds accumulated by earlier (pre-crash) portions of a
    /// resumed run; [`elapsed`](MetricsLog::elapsed) adds the live timer on
    /// top so `wall_time_secs` reports the whole run, not just the tail.
    prior_elapsed: f64,
    pub steps: Vec<StepRecord>,
    pub evals: Vec<(usize, f32)>,
    pub peak_state_bytes: usize,
    pub peak_rss_bytes: usize,
}

impl MetricsLog {
    pub fn new() -> MetricsLog {
        MetricsLog {
            start: Instant::now(),
            prior_elapsed: 0.0,
            steps: Vec::new(),
            evals: Vec::new(),
            peak_state_bytes: 0,
            peak_rss_bytes: 0,
        }
    }

    pub fn elapsed(&self) -> f64 {
        self.prior_elapsed + self.start.elapsed().as_secs_f64()
    }

    /// Credit wall-clock seconds spent before a resume (read from the
    /// checkpoint) so elapsed/wall-time accounting spans the whole run.
    pub fn set_prior_elapsed(&mut self, secs: f64) {
        self.prior_elapsed = secs;
    }

    pub fn record_step(&mut self, step: usize, loss: f32, lr: f32, state_bytes: usize) {
        self.steps.push(StepRecord { step, loss, lr, elapsed: self.elapsed() });
        self.peak_state_bytes = self.peak_state_bytes.max(state_bytes);
        if step % 32 == 0 {
            self.peak_rss_bytes = self.peak_rss_bytes.max(read_rss_bytes());
        }
    }

    pub fn record_eval(&mut self, step: usize, loss: f32) {
        self.evals.push((step, loss));
    }

    /// Smoothed training loss over the last `window` steps.
    pub fn recent_loss(&self, window: usize) -> f32 {
        let n = self.steps.len();
        if n == 0 {
            return f32::NAN;
        }
        let lo = n.saturating_sub(window);
        let slice = &self.steps[lo..];
        (slice.iter().map(|s| s.loss as f64).sum::<f64>() / slice.len() as f64) as f32
    }
}

impl Default for MetricsLog {
    fn default() -> Self {
        Self::new()
    }
}

/// Final report of a training run — the unit every table/figure harness
/// consumes.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub method: String,
    pub model: String,
    /// Training steps actually executed. `steps.len()` is only the *logged*
    /// step count (every `log_every`-th step) — checkpointing and resume
    /// logic must use this field, not the curve length.
    pub total_steps: usize,
    pub steps: Vec<StepRecord>,
    pub evals: Vec<(usize, f32)>,
    pub final_eval_loss: f32,
    pub wall_time_secs: f64,
    pub peak_state_bytes: usize,
    pub peak_rss_bytes: usize,
    pub param_count: usize,
    pub optimizer_state_params: usize,
    pub subspace_updates: usize,
    /// Steps dropped by the sentinel under `policy = "skip"`.
    pub sentinel_skips: usize,
    /// Anomalies handled under `policy = "rollback"` (snapshot restore, or
    /// a plain drop when no snapshot exists yet).
    pub sentinel_rollbacks: usize,
    /// Subspace refreshes discarded for yielding a non-finite or
    /// non-orthonormal basis (the previous projector was kept).
    pub refresh_rejections: usize,
    /// Weight/activation storage dtype of the run ("f32", "bf16", "f16").
    pub storage_dtype: String,
    /// Optimizer steps dropped by the f16 dynamic loss scaler (gradient
    /// overflow at the current scale); always 0 for f32/bf16 runs.
    pub scaler_skips: usize,
    /// Steps on which a DP shard failed mid-step and the survivors absorbed
    /// its micro-batch (degraded mode); 0 for clean or single-worker runs.
    pub degraded_steps: usize,
}

impl TrainReport {
    /// Loss-vs-step and loss-vs-walltime series as CSV (Figure 4).
    pub fn curve_csv(&self) -> CsvWriter {
        let mut w = CsvWriter::new(&["method", "step", "loss", "lr", "elapsed_s"]);
        for s in &self.steps {
            w.row(&[
                self.method.clone(),
                s.step.to_string(),
                format!("{:.6}", s.loss),
                format!("{:.6e}", s.lr),
                format!("{:.4}", s.elapsed),
            ]);
        }
        w
    }

    /// Summary as JSON (EXPERIMENTS.md provenance). Field meanings:
    ///
    /// - `method` / `model`: optimizer row label and model preset name.
    /// - `final_eval_loss`: loss on the deterministic eval batches after the
    ///   last step (NaN if `eval_every = 0`).
    /// - `wall_time_secs`: wall-clock for the *whole* run — resumed runs
    ///   include the checkpointed pre-crash portion.
    /// - `peak_state_bytes`: maximum analytic optimizer-state bytes observed
    ///   (per-shard figure under ZeRO-style partitioning, plus any live
    ///   rollback snapshot) — the paper's Table 8 axis.
    /// - `peak_rss_bytes`: maximum measured process RSS (sampled every 32
    ///   steps; 0 on non-Linux hosts).
    /// - `param_count`: trainable model parameters.
    /// - `optimizer_state_params`: optimizer state entries in the paper's
    ///   Table 2 sense (per-shard figure under partitioning).
    /// - `subspace_updates`: accepted projector refreshes across the run
    ///   (summed over shards).
    /// - `sentinel_skips` / `sentinel_rollbacks`: anomalous *optimizer*
    ///   steps dropped / rolled back by the health sentinel.
    /// - `refresh_rejections`: candidate bases the refresh guard discarded.
    /// - `total_steps`: optimizer steps actually executed (resume-aware;
    ///   accumulation micro-batches do not count).
    /// - `n_steps`: logged curve points (`total_steps / log_every`-ish) —
    ///   use `total_steps` for step arithmetic, never this.
    /// - `storage_dtype` / `scaler_skips`: present only for 16-bit runs
    ///   (f32 summaries stay byte-identical to earlier revisions): the
    ///   storage dtype and the steps the f16 loss scaler dropped.
    /// - `degraded_steps`: present only when > 0 (same byte-identity rule):
    ///   steps where a DP shard failure was absorbed by the survivors.
    pub fn summary_json(&self) -> Json {
        let mut fields = vec![
            ("method", Json::Str(self.method.clone())),
            ("model", Json::Str(self.model.clone())),
            ("final_eval_loss", Json::Num(self.final_eval_loss as f64)),
            ("wall_time_secs", Json::Num(self.wall_time_secs)),
            ("peak_state_bytes", Json::Num(self.peak_state_bytes as f64)),
            ("peak_rss_bytes", Json::Num(self.peak_rss_bytes as f64)),
            ("param_count", Json::Num(self.param_count as f64)),
            ("optimizer_state_params", Json::Num(self.optimizer_state_params as f64)),
            ("subspace_updates", Json::Num(self.subspace_updates as f64)),
            ("sentinel_skips", Json::Num(self.sentinel_skips as f64)),
            ("sentinel_rollbacks", Json::Num(self.sentinel_rollbacks as f64)),
            ("refresh_rejections", Json::Num(self.refresh_rejections as f64)),
            ("total_steps", Json::Num(self.total_steps as f64)),
            ("n_steps", Json::Num(self.steps.len() as f64)),
        ];
        if self.storage_dtype != "f32" {
            fields.push(("storage_dtype", Json::Str(self.storage_dtype.clone())));
            fields.push(("scaler_skips", Json::Num(self.scaler_skips as f64)));
        }
        if self.degraded_steps > 0 {
            fields.push(("degraded_steps", Json::Num(self.degraded_steps as f64)));
        }
        Json::obj(fields)
    }
}

/// Current process resident-set size in bytes (Linux /proc; 0 elsewhere).
pub fn read_rss_bytes() -> usize {
    if let Ok(text) = std::fs::read_to_string("/proc/self/status") {
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("VmRSS:") {
                let kb: usize = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0);
                return kb * 1024;
            }
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_smooths() {
        let mut m = MetricsLog::new();
        for i in 0..10 {
            m.record_step(i, 10.0 - i as f32, 1e-3, 100 * i);
        }
        assert_eq!(m.steps.len(), 10);
        assert_eq!(m.peak_state_bytes, 900);
        let recent = m.recent_loss(2);
        assert!((recent - 1.5).abs() < 1e-5, "recent {recent}");
    }

    #[test]
    fn prior_elapsed_offsets_the_clock() {
        let mut m = MetricsLog::new();
        let live = m.elapsed();
        m.set_prior_elapsed(100.0);
        assert!(m.elapsed() >= 100.0 + live, "prior portion not credited");
        m.record_step(0, 1.0, 1e-3, 0);
        assert!(m.steps[0].elapsed >= 100.0, "step timestamps must include it");
    }

    #[test]
    fn recent_loss_is_the_f64_mean() {
        // Mixed magnitudes: the smoothed loss must equal the f64 mean cast
        // once at the end (summing or dividing in f32 drifts).
        let mut m = MetricsLog::new();
        let losses = [1.5e7f32, 0.25, 3.0e6, 0.125, 7.5e6];
        for (i, &l) in losses.iter().enumerate() {
            m.record_step(i, l, 1e-3, 0);
        }
        let want = (losses.iter().map(|&l| l as f64).sum::<f64>() / losses.len() as f64) as f32;
        assert_eq!(m.recent_loss(losses.len()), want);
    }

    #[test]
    fn rss_is_positive_on_linux() {
        let rss = read_rss_bytes();
        assert!(rss > 1024 * 1024, "rss {rss}");
    }

    #[test]
    fn report_csv_has_all_steps() {
        let report = TrainReport {
            method: "test".into(),
            model: "nano".into(),
            total_steps: 2,
            steps: vec![
                StepRecord { step: 0, loss: 3.0, lr: 1e-3, elapsed: 0.1 },
                StepRecord { step: 1, loss: 2.5, lr: 1e-3, elapsed: 0.2 },
            ],
            evals: vec![],
            final_eval_loss: 2.4,
            wall_time_secs: 0.3,
            peak_state_bytes: 10,
            peak_rss_bytes: 20,
            param_count: 5,
            optimizer_state_params: 10,
            subspace_updates: 1,
            sentinel_skips: 0,
            sentinel_rollbacks: 0,
            refresh_rejections: 0,
            storage_dtype: "f32".into(),
            scaler_skips: 0,
            degraded_steps: 0,
        };
        let csv = report.curve_csv().to_string();
        assert_eq!(csv.lines().count(), 3);
        let j = report.summary_json();
        assert_eq!(j.get("final_eval_loss").unwrap().as_f64().unwrap() as f32, 2.4);
        // f32 summaries carry no dtype keys (byte-identity with earlier
        // revisions); 16-bit summaries do.
        assert!(j.get("storage_dtype").is_none());
        // Same rule for degraded mode: clean runs emit no key at all.
        assert!(j.get("degraded_steps").is_none());
        let mut bf = report.clone();
        bf.storage_dtype = "bf16".into();
        bf.degraded_steps = 2;
        let jb = bf.summary_json();
        assert_eq!(jb.get("storage_dtype").and_then(|v| v.as_str()), Some("bf16"));
        assert_eq!(jb.get("scaler_skips").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(jb.get("degraded_steps").and_then(|v| v.as_f64()), Some(2.0));
    }
}
