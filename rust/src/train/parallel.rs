//! Data-parallel worker simulation.
//!
//! Each worker computes gradients on its shard of the batch (persistent
//! [`pool`] workers sharing the frozen parameters), then the leader
//! all-reduces (averages) the shard gradients — the standard DP recipe.
//! Shards and the GEMM/QR/SVD kernels draw from the **same** worker pool,
//! so the two levels of parallelism share one thread budget: while a shard
//! runs, its thread opts out of nested kernel fan-out via
//! [`gemm::run_single_threaded`] (the pool would run nested fan-out inline
//! anyway) — which also collapses the model's per-(batch, head) attention
//! fan-out to its sequential path inside a shard, the same single-budget
//! pattern. Under the work-stealing scheduler a shard is one pool task like
//! any other: stealing may move a shard between participants before it
//! starts, but each shard executes exactly once, writes only its own slot,
//! and the reduction below walks the slots in fixed shard order — so the
//! averaged gradient is scheduling-independent, and a DP run never waits on
//! jobs other callers have in flight (per-job isolation). On this 1-core sandbox the point is *correctness of the
//! distributed code path* (gradient averaging must reproduce the
//! single-worker trajectory bit-for-bit up to fp reassociation), not
//! speedup; the same code scales across cores elsewhere.
//!
//! [`gemm::run_single_threaded`]: crate::tensor::gemm::run_single_threaded

use crate::model::{Batch, Llama};
use crate::tensor::{pool, Matrix};
use std::sync::Mutex;

/// Default data-parallel worker count: the same plumbing the GEMM row-block
/// threading uses (a forced `gemm::set_gemm_threads` count if set, otherwise
/// `available_parallelism`). `TrainConfig::workers == 0` resolves through
/// this, so one knob governs both levels of parallelism.
pub fn auto_workers() -> usize {
    crate::tensor::gemm::gemm_threads()
}

/// Split a batch into `n` contiguous shards (last shard may be smaller;
/// empty shards are dropped).
pub fn shard_batch(batch: &Batch, n: usize) -> Vec<Batch> {
    let per = (batch.b + n - 1) / n.max(1);
    let t = batch.t;
    let mut out = Vec::new();
    let mut start = 0usize;
    while start < batch.b {
        let end = (start + per).min(batch.b);
        out.push(Batch {
            inputs: batch.inputs[start * t..end * t].to_vec(),
            targets: batch.targets[start * t..end * t].to_vec(),
            b: end - start,
            t,
        });
        start = end;
    }
    out
}

/// Compute loss + gradients with `workers` data-parallel workers and average.
/// The average is weighted by shard token counts so it equals the
/// full-batch gradient exactly.
pub fn data_parallel_loss_grad(
    model: &Llama,
    batch: &Batch,
    workers: usize,
) -> (f32, Vec<Matrix>) {
    let shards = shard_batch(batch, workers);
    let slots: Vec<Mutex<Option<(f32, Vec<Matrix>, usize)>>> =
        shards.iter().map(|_| Mutex::new(None)).collect();
    pool::run(workers, shards.len(), &|i| {
        // Each shard owns one pool slot; nested GEMM fan-out inside a shard
        // would only oversubscribe (results are identical either way).
        let out = crate::tensor::gemm::run_single_threaded(|| {
            let (loss, grads) = model.loss_and_grad(&shards[i]);
            (loss, grads, shards[i].tokens())
        });
        *slots[i].lock().expect("shard slot poisoned") = Some(out);
    });

    // Reduce in fixed shard order so the average is scheduling-independent.
    let results: Vec<(f32, Vec<Matrix>, usize)> = slots
        .into_iter()
        .map(|s| s.into_inner().expect("shard slot poisoned").expect("shard did not run"))
        .collect();
    let total_tokens: usize = results.iter().map(|r| r.2).sum();
    let mut loss = 0.0f64;
    let mut grads: Vec<Matrix> = model.zero_grads();
    for (shard_loss, shard_grads, tokens) in results {
        let w = tokens as f64 / total_tokens as f64;
        loss += shard_loss as f64 * w;
        for (acc, g) in grads.iter_mut().zip(&shard_grads) {
            acc.axpy(w as f32, g);
        }
    }
    (loss as f32, grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::rng::Rng;

    fn setup() -> (Llama, Batch) {
        let cfg = ModelConfig::preset("nano");
        let model = Llama::new(cfg.clone(), 3);
        let mut rng = Rng::new(4);
        let (b, t) = (4, cfg.seq_len);
        let inputs: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        let targets: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        (model, Batch { inputs, targets, b, t })
    }

    #[test]
    fn sharding_covers_batch() {
        let (_, batch) = setup();
        for n in 1..=5 {
            let shards = shard_batch(&batch, n);
            let total: usize = shards.iter().map(|s| s.b).sum();
            assert_eq!(total, batch.b, "workers={n}");
            let cat: Vec<u32> = shards.iter().flat_map(|s| s.inputs.clone()).collect();
            assert_eq!(cat, batch.inputs);
        }
    }

    #[test]
    fn dp_gradients_match_single_worker() {
        let (model, batch) = setup();
        let (loss1, grads1) = model.loss_and_grad(&batch);
        let (loss2, grads2) = data_parallel_loss_grad(&model, &batch, 2);
        assert!((loss1 - loss2).abs() < 1e-5, "{loss1} vs {loss2}");
        for (a, b) in grads1.iter().zip(&grads2) {
            crate::util::proptest::close(a.data(), b.data(), 1e-5, 1e-4).unwrap();
        }
    }

    #[test]
    fn dp_with_more_workers_than_batch() {
        let (model, batch) = setup();
        let (loss, grads) = data_parallel_loss_grad(&model, &batch, 16);
        assert!(loss.is_finite());
        assert_eq!(grads.len(), model.params.len());
    }

    #[test]
    fn dp_gradients_bit_stable_under_steal_scheduler_and_small_chunks() {
        // Shard placement is steal-dependent, but each shard writes only its
        // own slot and the reduction walks slots in fixed order — so repeated
        // DP runs must agree bitwise, also with a tiny forced kernel chunk
        // (the worst-case steal churn inside each shard's opt-out region).
        // The knob lock keeps chunk=2 actually in force for both runs
        // (results would be bit-identical regardless — knobs are
        // result-transparent — but the test means to exercise tiny chunks).
        let _knob = crate::tensor::gemm::TEST_KNOB_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let (model, batch) = setup();
        crate::tensor::gemm::set_gemm_chunk(2);
        let (loss_a, grads_a) = data_parallel_loss_grad(&model, &batch, 4);
        let (loss_b, grads_b) = data_parallel_loss_grad(&model, &batch, 4);
        crate::tensor::gemm::set_gemm_chunk(0);
        assert_eq!(loss_a, loss_b, "DP loss not scheduling-independent");
        for (a, b) in grads_a.iter().zip(&grads_b) {
            assert_eq!(a.data(), b.data(), "DP gradient not scheduling-independent");
        }
    }
}
