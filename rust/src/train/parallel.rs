//! Data-parallel worker simulation.
//!
//! Each worker computes gradients on its shard of the batch (persistent
//! [`pool`] workers sharing the frozen parameters), then the leader
//! all-reduces (averages) the shard gradients — the standard DP recipe.
//! Shards and the GEMM/QR/SVD kernels draw from the **same** worker pool,
//! so the two levels of parallelism share one thread budget: while a shard
//! runs, its thread opts out of nested kernel fan-out via
//! [`gemm::run_single_threaded`] (the pool would run nested fan-out inline
//! anyway) — which also collapses the model's per-(batch, head) attention
//! fan-out to its sequential path inside a shard, the same single-budget
//! pattern. Under the work-stealing scheduler a shard is one pool task like
//! any other: stealing may move a shard between participants before it
//! starts, but each shard executes exactly once, writes only its own slot,
//! and the reduction below walks the slots in fixed shard order — so the
//! averaged gradient is scheduling-independent, and a DP run never waits on
//! jobs other callers have in flight (per-job isolation). On this 1-core sandbox the point is *correctness of the
//! distributed code path* (gradient averaging must reproduce the
//! single-worker trajectory bit-for-bit up to fp reassociation), not
//! speedup; the same code scales across cores elsewhere.
//!
//! [`gemm::run_single_threaded`]: crate::tensor::gemm::run_single_threaded

use crate::model::{Batch, Llama, StepState};
use crate::tensor::{gemm, pool, Matrix};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default data-parallel worker count: the same plumbing the GEMM row-block
/// threading uses (a forced `gemm::set_gemm_threads` count if set, otherwise
/// `available_parallelism`). `TrainConfig::workers == 0` resolves through
/// this, so one knob governs both levels of parallelism.
pub fn auto_workers() -> usize {
    crate::tensor::gemm::gemm_threads()
}

/// Split a batch into `n` contiguous shards (last shard may be smaller;
/// empty shards are dropped; `n = 0` behaves like `n = 1`).
pub fn shard_batch(batch: &Batch, n: usize) -> Vec<Batch> {
    let n = n.max(1);
    let per = (batch.b + n - 1) / n;
    let t = batch.t;
    let mut out = Vec::new();
    let mut start = 0usize;
    while start < batch.b {
        let end = (start + per).min(batch.b);
        out.push(Batch {
            inputs: batch.inputs[start * t..end * t].to_vec(),
            targets: batch.targets[start * t..end * t].to_vec(),
            b: end - start,
            t,
        });
        start = end;
    }
    out
}

/// One data-parallel worker's persistent buffers: its slice of the batch,
/// its gradient accumulators, and its `StepState` (workspace pool + weight
/// transpose cache + head-scratch bank).
struct ShardSlot {
    batch: Batch,
    grads: Vec<Matrix>,
    state: StepState,
    loss: f32,
    tokens: usize,
    /// False while this shard's result is missing (its task panicked this
    /// step); a degraded-mode recompute or the next step's refill heals it.
    ok: bool,
}

/// Persistent state for the data-parallel gradient step, owned by whoever
/// drives repeated steps (the trainer keeps one for the whole run).
///
/// Every per-shard buffer — the shard's `Batch` token vectors, its gradient
/// matrices, and its `StepState` scratch — lives here across steps, so a
/// steady-state DP step performs no buffer allocation: shard batches refill
/// in place, gradients are overwritten by `loss_and_grad_into`, and all
/// temporaries come from the per-shard workspace pools. This extends the
/// zero-allocation contract (`rust/tests/zero_alloc.rs`) to `workers > 1`,
/// and the per-shard gradient buffers are exactly the layout a ZeRO-style
/// reduce-scatter would consume.
pub struct DpContext {
    workers: usize,
    shards: Vec<Mutex<ShardSlot>>,
    /// Steps on which at least one shard failed and the survivors picked up
    /// its micro-batch (see [`DpContext::loss_grad_into`] degraded mode).
    degraded: usize,
    /// Test hook: shard index whose next task panics (`usize::MAX` = none).
    sabotage: AtomicUsize,
}

impl DpContext {
    pub fn new(workers: usize) -> DpContext {
        let workers = workers.max(1);
        let shards = (0..workers)
            .map(|_| {
                Mutex::new(ShardSlot {
                    batch: Batch { inputs: Vec::new(), targets: Vec::new(), b: 0, t: 0 },
                    grads: Vec::new(),
                    state: StepState::new(),
                    loss: 0.0,
                    tokens: 0,
                    ok: true,
                })
            })
            .collect();
        DpContext { workers, shards, degraded: 0, sabotage: AtomicUsize::new(usize::MAX) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Steps on which degraded mode fired (a shard failure was absorbed).
    pub fn degraded_steps(&self) -> usize {
        self.degraded
    }

    /// Make shard `i`'s next task panic once — deterministic stand-in for a
    /// shard dying mid-step, used by the degraded-mode tests and the
    /// trainer's fault injector.
    #[doc(hidden)]
    pub fn fail_next_shard(&self, i: usize) {
        self.sabotage.store(i, Ordering::Release);
    }

    /// Refill the persistent shard batches in place (same contiguous split
    /// as [`shard_batch`]); returns the number of non-empty shards.
    fn fill_shards(&mut self, batch: &Batch) -> usize {
        let per = (batch.b + self.workers - 1) / self.workers;
        let t = batch.t;
        let mut n = 0usize;
        let mut start = 0usize;
        while start < batch.b {
            let end = (start + per).min(batch.b);
            let slot = self.shards[n].get_mut().unwrap_or_else(|e| e.into_inner());
            slot.batch.inputs.clear();
            slot.batch.inputs.extend_from_slice(&batch.inputs[start * t..end * t]);
            slot.batch.targets.clear();
            slot.batch.targets.extend_from_slice(&batch.targets[start * t..end * t]);
            slot.batch.b = end - start;
            slot.batch.t = t;
            slot.ok = true;
            start = end;
            n += 1;
        }
        n
    }

    /// Compute loss + gradients with this context's workers and reduce the
    /// shard gradients into `out` (weighted by shard token counts, in fixed
    /// shard order, so the result equals the full-batch gradient exactly
    /// and is scheduling-independent).
    ///
    /// **Degraded mode**: a shard whose task panics mid-step does not sink
    /// the step — its slot is marked failed, and after the main fan-out the
    /// surviving workers recompute the failed micro-batches in a second pool
    /// job. Shard results are thread-independent, so the recomputed slots are
    /// bit-identical to what the dead shard would have produced and the
    /// fixed-order reduction below is unchanged — a degraded step reduces to
    /// exactly the clean step's gradient. The shard is healed (fresh `ok`)
    /// on the next refill; a shard that fails its recompute too is a
    /// deterministic compute failure and propagates as a panic.
    pub fn loss_grad_into(&mut self, model: &Llama, batch: &Batch, out: &mut [Matrix]) -> f32 {
        let n = self.fill_shards(batch);
        for i in 0..n {
            let slot = self.shards[i].get_mut().unwrap_or_else(|e| e.into_inner());
            if slot.grads.len() != model.params.len() {
                slot.grads = model.zero_grads();
            }
        }
        let shards = &self.shards;
        let sabotage = &self.sabotage;
        pool::run(self.workers, n, &|i| {
            let mut guard = shards[i].lock().unwrap_or_else(|e| e.into_inner());
            let slot = &mut *guard;
            // Each shard owns one pool slot; nested GEMM fan-out inside a
            // shard would only oversubscribe (results are identical either
            // way).
            let res = catch_unwind(AssertUnwindSafe(|| {
                if sabotage
                    .compare_exchange(i, usize::MAX, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    panic!("injected DP shard {i} failure");
                }
                slot.loss = gemm::run_single_threaded(|| {
                    model.loss_and_grad_into(&slot.batch, &mut slot.grads, &mut slot.state)
                });
                slot.tokens = slot.batch.tokens();
            }));
            slot.ok = res.is_ok();
        });
        self.sabotage.store(usize::MAX, Ordering::Relaxed);

        let failed: Vec<usize> = (0..n)
            .filter(|&i| !self.shards[i].get_mut().unwrap_or_else(|e| e.into_inner()).ok)
            .collect();
        if !failed.is_empty() {
            self.degraded += 1;
            eprintln!(
                "warn: {} DP shard(s) failed mid-step; survivors recomputing their micro-batches",
                failed.len()
            );
            let shards = &self.shards;
            pool::run(self.workers, failed.len(), &|j| {
                let i = failed[j];
                let mut guard = shards[i].lock().unwrap_or_else(|e| e.into_inner());
                let slot = &mut *guard;
                slot.loss = gemm::run_single_threaded(|| {
                    model.loss_and_grad_into(&slot.batch, &mut slot.grads, &mut slot.state)
                });
                slot.tokens = slot.batch.tokens();
                slot.ok = true;
            });
        }

        // Reduce in fixed shard order so the average is scheduling-independent.
        let mut total_tokens = 0usize;
        for i in 0..n {
            total_tokens += self.shards[i].get_mut().unwrap_or_else(|e| e.into_inner()).tokens;
        }
        for g in out.iter_mut() {
            g.data_mut().fill(0.0);
        }
        let mut loss = 0.0f64;
        for i in 0..n {
            let slot = self.shards[i].get_mut().unwrap_or_else(|e| e.into_inner());
            let w = slot.tokens as f64 / total_tokens as f64;
            loss += slot.loss as f64 * w;
            for (acc, g) in out.iter_mut().zip(&slot.grads) {
                acc.axpy(w as f32, g);
            }
        }
        loss as f32
    }

    /// Total workspace-pool misses across the shard `StepState`s (model
    /// scratch + head-scratch banks). Only meaningful between steps; the
    /// `workers = 2` gate in `rust/tests/zero_alloc.rs` asserts this stays
    /// flat after warm-up.
    pub fn workspace_misses(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                let slot = s.lock().unwrap_or_else(|e| e.into_inner());
                slot.state.ws.misses() + slot.state.heads.misses()
            })
            .sum()
    }
}

/// Compute loss + gradients with `workers` data-parallel workers and average.
/// One-shot convenience over [`DpContext`] (allocates fresh per-shard
/// buffers; the trainer keeps a persistent context instead).
pub fn data_parallel_loss_grad(
    model: &Llama,
    batch: &Batch,
    workers: usize,
) -> (f32, Vec<Matrix>) {
    let mut ctx = DpContext::new(workers);
    let mut grads = model.zero_grads();
    let loss = ctx.loss_grad_into(model, batch, &mut grads);
    (loss, grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::rng::Rng;

    fn setup() -> (Llama, Batch) {
        let cfg = ModelConfig::preset("nano");
        let model = Llama::new(cfg.clone(), 3);
        let mut rng = Rng::new(4);
        let (b, t) = (4, cfg.seq_len);
        let inputs: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        let targets: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        (model, Batch { inputs, targets, b, t })
    }

    #[test]
    fn sharding_covers_batch() {
        let (_, batch) = setup();
        for n in 1..=5 {
            let shards = shard_batch(&batch, n);
            let total: usize = shards.iter().map(|s| s.b).sum();
            assert_eq!(total, batch.b, "workers={n}");
            let cat: Vec<u32> = shards.iter().flat_map(|s| s.inputs.clone()).collect();
            assert_eq!(cat, batch.inputs);
        }
    }

    #[test]
    fn shard_batch_zero_workers_behaves_like_one() {
        // Regression: n = 0 used to hit a divide-by-zero computing the
        // per-shard size; it must degrade to the single-worker split.
        let (_, batch) = setup();
        let shards = shard_batch(&batch, 0);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].b, batch.b);
        assert_eq!(shards[0].inputs, batch.inputs);
        assert_eq!(shards[0].targets, batch.targets);
    }

    #[test]
    fn dp_gradients_match_single_worker() {
        let (model, batch) = setup();
        let (loss1, grads1) = model.loss_and_grad(&batch);
        let (loss2, grads2) = data_parallel_loss_grad(&model, &batch, 2);
        assert!((loss1 - loss2).abs() < 1e-5, "{loss1} vs {loss2}");
        for (a, b) in grads1.iter().zip(&grads2) {
            crate::util::proptest::close(a.data(), b.data(), 1e-5, 1e-4).unwrap();
        }
    }

    #[test]
    fn dp_with_more_workers_than_batch() {
        let (model, batch) = setup();
        let (loss, grads) = data_parallel_loss_grad(&model, &batch, 16);
        assert!(loss.is_finite());
        assert_eq!(grads.len(), model.params.len());
    }

    #[test]
    fn degraded_step_matches_clean_run_bit_for_bit() {
        let (model, batch) = setup();
        let mut clean = DpContext::new(2);
        let mut faulty = DpContext::new(2);
        let mut g_clean = model.zero_grads();
        let mut g_faulty = model.zero_grads();
        let loss_clean = clean.loss_grad_into(&model, &batch, &mut g_clean);

        faulty.fail_next_shard(1);
        let loss_faulty = faulty.loss_grad_into(&model, &batch, &mut g_faulty);
        assert_eq!(faulty.degraded_steps(), 1);
        assert_eq!(clean.degraded_steps(), 0);
        // Survivors recomputed shard 1's micro-batch: the degraded step's
        // reduction is bit-identical to the clean one.
        assert_eq!(loss_clean, loss_faulty);
        for (a, b) in g_clean.iter().zip(&g_faulty) {
            assert_eq!(a.data(), b.data(), "degraded gradient diverged");
        }

        // The shard heals on the next step: no new degraded count.
        let loss_next = faulty.loss_grad_into(&model, &batch, &mut g_faulty);
        assert_eq!(faulty.degraded_steps(), 1);
        assert_eq!(loss_next, loss_clean);
    }

    #[test]
    fn dp_gradients_bit_stable_under_steal_scheduler_and_small_chunks() {
        // Shard placement is steal-dependent, but each shard writes only its
        // own slot and the reduction walks slots in fixed order — so repeated
        // DP runs must agree bitwise, also with a tiny forced kernel chunk
        // (the worst-case steal churn inside each shard's opt-out region).
        // The knob lock keeps chunk=2 actually in force for both runs
        // (results would be bit-identical regardless — knobs are
        // result-transparent — but the test means to exercise tiny chunks).
        let _knob = crate::tensor::gemm::TEST_KNOB_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let (model, batch) = setup();
        crate::tensor::gemm::set_gemm_chunk(2);
        let (loss_a, grads_a) = data_parallel_loss_grad(&model, &batch, 4);
        let (loss_b, grads_b) = data_parallel_loss_grad(&model, &batch, 4);
        crate::tensor::gemm::set_gemm_chunk(0);
        assert_eq!(loss_a, loss_b, "DP loss not scheduling-independent");
        for (a, b) in grads_a.iter().zip(&grads_b) {
            assert_eq!(a.data(), b.data(), "DP gradient not scheduling-independent");
        }
    }
}
