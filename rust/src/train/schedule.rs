//! Learning-rate schedules. The paper's pre-training runs use linear warmup
//! (1000 steps at 10k total — scaled proportionally here) followed by cosine
//! decay, the GaLore reference setup.

/// Warmup + cosine decay schedule.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub warmup_steps: usize,
    pub total_steps: usize,
    /// Floor as a fraction of base_lr (cosine decays to this).
    pub min_ratio: f32,
}

impl LrSchedule {
    pub fn new(base_lr: f32, warmup_steps: usize, total_steps: usize) -> LrSchedule {
        LrSchedule { base_lr, warmup_steps, total_steps, min_ratio: 0.1 }
    }

    /// Constant schedule (fine-tuning runs).
    pub fn constant(lr: f32) -> LrSchedule {
        LrSchedule { base_lr: lr, warmup_steps: 0, total_steps: usize::MAX, min_ratio: 1.0 }
    }

    /// Learning rate at `step` (0-indexed).
    pub fn at(&self, step: usize) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        if self.total_steps == usize::MAX {
            return self.base_lr;
        }
        let decay_steps = self.total_steps.saturating_sub(self.warmup_steps).max(1);
        let progress =
            ((step - self.warmup_steps) as f32 / decay_steps as f32).clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        self.base_lr * (self.min_ratio + (1.0 - self.min_ratio) * cos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::new(1.0, 10, 100);
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = LrSchedule::new(1.0, 10, 100);
        assert!(s.at(10) > 0.99);
        let end = s.at(99);
        assert!((end - 0.1).abs() < 0.02, "end lr {end}");
        // Monotone decreasing after warmup.
        let mut prev = s.at(10);
        for step in 11..100 {
            let cur = s.at(step);
            assert!(cur <= prev + 1e-6);
            prev = cur;
        }
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.01);
        assert_eq!(s.at(0), 0.01);
        assert_eq!(s.at(1_000_000), 0.01);
    }

    #[test]
    fn beyond_total_clamps_to_floor() {
        let s = LrSchedule::new(1.0, 0, 50);
        assert!((s.at(500) - 0.1).abs() < 1e-6);
    }
}
