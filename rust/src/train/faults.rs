//! Deterministic fault injection for exercising the fault-tolerance runtime.
//!
//! A fault is a `(kind, step)` pair parsed from the `PALLAS_FAULT` environment
//! variable (or the `train.fault.inject` config key) as `kind@step`, e.g.
//! `nan_grad@7`. Injection keys on the trainer's step counter *after* gradient
//! reduction, so a fault fires identically for any worker count or DP shard
//! layout. When no fault is configured the trainer carries a `None` and pays a
//! single branch per step.

use std::fs::OpenOptions;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

/// What to break. Each kind corrupts a different layer of the runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Overwrite the reduced gradients with NaNs before clipping.
    NanGrad,
    /// Poison the optimizer's next subspace-refresh basis with NaNs.
    RefreshPoison,
    /// Truncate the newest checkpoint blob after it is committed
    /// (simulates a kill -9 mid-write on a non-atomic writer).
    CkptTruncate,
    /// Flip one bit in the newest checkpoint blob after it is committed.
    CkptBitflip,
    /// Panic one pool worker mid-job at the given step.
    WorkerPanic,
}

impl FaultKind {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::NanGrad => "nan_grad",
            FaultKind::RefreshPoison => "refresh_poison",
            FaultKind::CkptTruncate => "ckpt_truncate",
            FaultKind::CkptBitflip => "ckpt_bitflip",
            FaultKind::WorkerPanic => "worker_panic",
        }
    }
}

/// A single scheduled fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultInjection {
    pub kind: FaultKind,
    pub step: usize,
}

impl FaultInjection {
    /// Parse a `kind@step` spec. Returns `None` on anything malformed so a
    /// typo'd env var fails loudly at the call site rather than silently
    /// running a clean experiment labelled as faulted.
    pub fn parse(spec: &str) -> Option<FaultInjection> {
        let (kind, step) = spec.trim().split_once('@')?;
        let kind = match kind {
            "nan_grad" => FaultKind::NanGrad,
            "refresh_poison" => FaultKind::RefreshPoison,
            "ckpt_truncate" => FaultKind::CkptTruncate,
            "ckpt_bitflip" => FaultKind::CkptBitflip,
            "worker_panic" => FaultKind::WorkerPanic,
            _ => return None,
        };
        Some(FaultInjection { kind, step: step.parse().ok()? })
    }

    /// Read the `PALLAS_FAULT` env knob. Panics on a malformed spec —
    /// misconfigured CI legs should fail, not pass vacuously.
    pub fn from_env() -> Option<FaultInjection> {
        let spec = std::env::var("PALLAS_FAULT").ok()?;
        if spec.is_empty() {
            return None;
        }
        match Self::parse(&spec) {
            Some(f) => Some(f),
            None => panic!("PALLAS_FAULT: bad spec {spec:?} (want kind@step, e.g. nan_grad@7)"),
        }
    }

    pub fn fires_at(&self, step: usize) -> bool {
        self.step == step
    }
}

/// Truncate `path` to half its length, as a crash mid-write would.
pub fn truncate_file(path: &Path) -> std::io::Result<()> {
    let f = OpenOptions::new().write(true).open(path)?;
    let len = f.metadata()?.len();
    f.set_len(len / 2)
}

/// Flip one bit in the middle byte of `path`.
pub fn flip_bit(path: &Path) -> std::io::Result<()> {
    let mut f = OpenOptions::new().read(true).write(true).open(path)?;
    let len = f.metadata()?.len();
    if len == 0 {
        return Ok(());
    }
    let pos = len / 2;
    let mut byte = [0u8; 1];
    f.seek(SeekFrom::Start(pos))?;
    f.read_exact(&mut byte)?;
    byte[0] ^= 0x10;
    f.seek(SeekFrom::Start(pos))?;
    f.write_all(&byte)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        for (spec, kind, step) in [
            ("nan_grad@7", FaultKind::NanGrad, 7),
            ("refresh_poison@8", FaultKind::RefreshPoison, 8),
            ("ckpt_truncate@3", FaultKind::CkptTruncate, 3),
            ("ckpt_bitflip@0", FaultKind::CkptBitflip, 0),
            ("worker_panic@12", FaultKind::WorkerPanic, 12),
        ] {
            let f = FaultInjection::parse(spec).expect(spec);
            assert_eq!(f, FaultInjection { kind, step });
            assert_eq!(format!("{}@{}", f.kind.as_str(), f.step), spec);
            assert!(f.fires_at(step));
            assert!(!f.fires_at(step + 1));
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for spec in ["", "nan_grad", "nan_grad@", "nan_grad@x", "@7", "frobnicate@7"] {
            assert!(FaultInjection::parse(spec).is_none(), "{spec:?} should not parse");
        }
    }

    #[test]
    fn file_corruption_helpers() {
        let dir = std::env::temp_dir().join(format!("subtrack_faults_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("blob.bin");
        std::fs::write(&p, vec![0u8; 64]).unwrap();
        truncate_file(&p).unwrap();
        assert_eq!(std::fs::metadata(&p).unwrap().len(), 32);
        flip_bit(&p).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert_eq!(data.iter().filter(|&&b| b != 0).count(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
