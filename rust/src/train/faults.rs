//! Deterministic fault injection for exercising the fault-tolerance runtime.
//!
//! A fault is a `(kind, step)` pair parsed from the `PALLAS_FAULT` environment
//! variable (or the `train.fault.inject` config key) as `kind@step`, e.g.
//! `nan_grad@7`. A **schedule** is a comma-separated list of such pairs
//! (`nan_grad@3,worker_hang@5,ckpt_bitflip@8`), so one run can compound
//! faults across layers. Injection keys on the trainer's step counter *after*
//! gradient reduction, so a fault fires identically for any worker count or
//! DP shard layout. When no fault is configured the trainer carries a `None`
//! and pays a single branch per step.

use std::fs::OpenOptions;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

/// What to break. Each kind corrupts a different layer of the runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Overwrite the reduced gradients with NaNs before clipping.
    NanGrad,
    /// Poison the optimizer's next subspace-refresh basis with NaNs.
    RefreshPoison,
    /// Truncate the newest checkpoint blob after it is committed
    /// (simulates a kill -9 mid-write on a non-atomic writer).
    CkptTruncate,
    /// Flip one bit in the newest checkpoint blob after it is committed.
    CkptBitflip,
    /// Panic one pool worker mid-job at the given step.
    WorkerPanic,
    /// Hang one pool task at the given step until the pool watchdog
    /// (`GEMM_DEADLINE_MS` / `[train.watchdog]`) cancels the job; the hang
    /// is bounded so a run without the watchdog armed still terminates.
    WorkerHang,
    /// Make one pool task slow-but-alive at the given step — the progress-
    /// based watchdog must let it finish (regression guard against a
    /// total-runtime watchdog killing healthy slow jobs).
    SlowWorker,
}

impl FaultKind {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::NanGrad => "nan_grad",
            FaultKind::RefreshPoison => "refresh_poison",
            FaultKind::CkptTruncate => "ckpt_truncate",
            FaultKind::CkptBitflip => "ckpt_bitflip",
            FaultKind::WorkerPanic => "worker_panic",
            FaultKind::WorkerHang => "worker_hang",
            FaultKind::SlowWorker => "slow_worker",
        }
    }
}

/// A single scheduled fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultInjection {
    pub kind: FaultKind,
    pub step: usize,
}

impl FaultInjection {
    /// Parse a `kind@step` spec. Returns `None` on anything malformed so a
    /// typo'd env var fails loudly at the call site rather than silently
    /// running a clean experiment labelled as faulted.
    pub fn parse(spec: &str) -> Option<FaultInjection> {
        let (kind, step) = spec.trim().split_once('@')?;
        let kind = match kind {
            "nan_grad" => FaultKind::NanGrad,
            "refresh_poison" => FaultKind::RefreshPoison,
            "ckpt_truncate" => FaultKind::CkptTruncate,
            "ckpt_bitflip" => FaultKind::CkptBitflip,
            "worker_panic" => FaultKind::WorkerPanic,
            "worker_hang" => FaultKind::WorkerHang,
            "slow_worker" => FaultKind::SlowWorker,
            _ => return None,
        };
        Some(FaultInjection { kind, step: step.parse().ok()? })
    }

    pub fn fires_at(&self, step: usize) -> bool {
        self.step == step
    }
}

/// A comma-separated list of scheduled faults (`nan_grad@3,worker_hang@5`).
/// The single-fault spec is the one-element schedule, so every existing
/// `PALLAS_FAULT` / `train.fault.inject` value parses unchanged.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultSchedule {
    pub faults: Vec<FaultInjection>,
}

impl FaultSchedule {
    /// Parse a schedule, reporting *which* element is malformed. The typed
    /// error lets config loading fail with a real message instead of a
    /// pattern-match panic deep in the trainer.
    pub fn parse(spec: &str) -> Result<FaultSchedule, String> {
        let mut faults = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            match FaultInjection::parse(part) {
                Some(f) => faults.push(f),
                None => {
                    return Err(format!(
                        "bad fault spec {part:?} in {spec:?} \
                         (want kind@step[,kind@step...], e.g. nan_grad@7)"
                    ))
                }
            }
        }
        if faults.is_empty() {
            return Err(format!("empty fault schedule {spec:?}"));
        }
        Ok(FaultSchedule { faults })
    }

    /// Read the `PALLAS_FAULT` env knob. Panics on a malformed spec —
    /// misconfigured CI legs should fail, not pass vacuously. (The config
    /// path goes through [`FaultSchedule::parse`] and a typed error.)
    pub fn from_env() -> Option<FaultSchedule> {
        let spec = std::env::var("PALLAS_FAULT").ok()?;
        if spec.is_empty() {
            return None;
        }
        match Self::parse(&spec) {
            Ok(s) => Some(s),
            Err(e) => panic!("PALLAS_FAULT: {e}"),
        }
    }

    /// Kinds scheduled to fire at `step`, in spec order.
    pub fn at(&self, step: usize) -> impl Iterator<Item = FaultKind> + '_ {
        self.faults.iter().filter(move |f| f.fires_at(step)).map(|f| f.kind)
    }

    /// All scheduled `(kind, step)` pairs of the given kinds, in spec order.
    pub fn of_kinds(&self, kinds: &[FaultKind]) -> Vec<FaultInjection> {
        self.faults.iter().filter(|f| kinds.contains(&f.kind)).copied().collect()
    }
}

/// Truncate `path` to half its length, as a crash mid-write would.
pub fn truncate_file(path: &Path) -> std::io::Result<()> {
    let f = OpenOptions::new().write(true).open(path)?;
    let len = f.metadata()?.len();
    f.set_len(len / 2)
}

/// Flip one bit in the middle byte of `path`.
pub fn flip_bit(path: &Path) -> std::io::Result<()> {
    let mut f = OpenOptions::new().read(true).write(true).open(path)?;
    let len = f.metadata()?.len();
    if len == 0 {
        return Ok(());
    }
    let pos = len / 2;
    let mut byte = [0u8; 1];
    f.seek(SeekFrom::Start(pos))?;
    f.read_exact(&mut byte)?;
    byte[0] ^= 0x10;
    f.seek(SeekFrom::Start(pos))?;
    f.write_all(&byte)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        for (spec, kind, step) in [
            ("nan_grad@7", FaultKind::NanGrad, 7),
            ("refresh_poison@8", FaultKind::RefreshPoison, 8),
            ("ckpt_truncate@3", FaultKind::CkptTruncate, 3),
            ("ckpt_bitflip@0", FaultKind::CkptBitflip, 0),
            ("worker_panic@12", FaultKind::WorkerPanic, 12),
            ("worker_hang@5", FaultKind::WorkerHang, 5),
            ("slow_worker@4", FaultKind::SlowWorker, 4),
        ] {
            let f = FaultInjection::parse(spec).expect(spec);
            assert_eq!(f, FaultInjection { kind, step });
            assert_eq!(format!("{}@{}", f.kind.as_str(), f.step), spec);
            assert!(f.fires_at(step));
            assert!(!f.fires_at(step + 1));
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for spec in ["", "nan_grad", "nan_grad@", "nan_grad@x", "@7", "frobnicate@7"] {
            assert!(FaultInjection::parse(spec).is_none(), "{spec:?} should not parse");
        }
    }

    #[test]
    fn schedule_parses_multiple_faults_in_order() {
        let s = FaultSchedule::parse("nan_grad@3, worker_hang@5 ,ckpt_bitflip@3").unwrap();
        assert_eq!(s.faults.len(), 3);
        assert_eq!(
            s.at(3).collect::<Vec<_>>(),
            vec![FaultKind::NanGrad, FaultKind::CkptBitflip]
        );
        assert_eq!(s.at(5).collect::<Vec<_>>(), vec![FaultKind::WorkerHang]);
        assert_eq!(s.at(4).count(), 0);
        let ckpt = s.of_kinds(&[FaultKind::CkptTruncate, FaultKind::CkptBitflip]);
        assert_eq!(ckpt, vec![FaultInjection { kind: FaultKind::CkptBitflip, step: 3 }]);
    }

    #[test]
    fn schedule_errors_name_the_bad_element() {
        let e = FaultSchedule::parse("nan_grad@3,frobnicate@7").unwrap_err();
        assert!(e.contains("frobnicate@7"), "{e}");
        assert!(FaultSchedule::parse("").is_err());
        assert!(FaultSchedule::parse("nan_grad@3,").is_err());
    }

    #[test]
    fn file_corruption_helpers() {
        let dir = std::env::temp_dir().join(format!("subtrack_faults_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("blob.bin");
        std::fs::write(&p, vec![0u8; 64]).unwrap();
        truncate_file(&p).unwrap();
        assert_eq!(std::fs::metadata(&p).unwrap().len(), 32);
        flip_bit(&p).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert_eq!(data.iter().filter(|&&b| b != 0).count(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
