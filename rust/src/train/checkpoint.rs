//! Crash-safe checkpointing: parameters as a little-endian f32 binary blob
//! plus a JSON manifest (shapes, names, per-tensor CRC32s, step).
//!
//! Write protocol — each file goes to a `.tmp` sibling, is fsynced, then
//! atomically renamed into place; the manifest is renamed *last* so it acts
//! as the commit marker (a crash mid-save leaves at worst an orphaned `.tmp`
//! and the previous checkpoint intact). `load` verifies the manifest, blob
//! size, and every tensor's CRC before touching any parameter, and reports
//! failures through [`CkptError`] so auto-resume can distinguish "nothing
//! here" from "here but corrupt" and fall back to an older checkpoint.
//!
//! # Format 2: full training state
//!
//! A resumable run is more than its parameters: format 2 appends the
//! serialized [`OptimizerSnapshot`] (Adam moments, projector bases, RNG
//! streams, step counters) plus the corpus sampler position and accumulated
//! wall-clock to the same blob, CRC'd as its own region and described by
//! `format`/`state_bytes`/`state_crc32`/`sampler_draws`/`elapsed_secs`
//! manifest keys. [`save_full`]/[`load_full`]/[`resume_newest_full`] write
//! and read it; the params-only [`save`]/[`load`] remain as format 1 (and
//! `load` reads the parameter region of either format), so a format-1
//! checkpoint resumes with `state = None` rather than failing.
//!
//! # Format 3: 16-bit parameter storage
//!
//! When any parameter has a 16-bit storage dtype, its region holds the raw
//! storage encoding — little-endian u16 words (2 bytes/element instead of
//! 4) — and its manifest entry gains a `dtype` key ("bf16"/"f16"; omitted
//! for f32, so all-f32 saves stay byte-identical to format 1/2). The f32
//! **master weights** ride inside the [`OptimizerSnapshot`] state region
//! (the mixed-precision wrapper appends them — see `optim::master`), so a
//! killed-and-resumed 16-bit run replays bit for bit. Loading requires the
//! in-memory parameter's dtype to match the manifest's: a bf16 checkpoint
//! must not silently feed an exact-f32 run or vice versa. The f16 loss
//! scaler's per-tensor scales/counters persist as `scaler_scales`/
//! `scaler_good` manifest arrays (present only when non-empty). All three
//! formats load through the same [`load`]/[`load_full`] entry points.

use crate::optim::{OptimizerSnapshot, Param, ParamKind};
use crate::tensor::Dtype;
use crate::util::json::Json;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// The non-parameter training state a format-2 checkpoint carries.
pub struct TrainState {
    /// Full optimizer state (see [`crate::optim::Optimizer::snapshot`]).
    pub opt: OptimizerSnapshot,
    /// Corpus sampler draws consumed so far (see
    /// [`crate::data::Corpus::sampler_draws`]); resume fast-forwards the
    /// sampler here so the data stream continues where it left off.
    pub sampler_draws: u64,
    /// Wall-clock seconds the run had accumulated at save time.
    pub elapsed_secs: f64,
    /// f16 dynamic loss-scaler state: per-tensor scales and consecutive
    /// clean-step counters (parallel vectors; both empty for f32/bf16 runs,
    /// and then absent from the manifest).
    pub scaler_scales: Vec<f32>,
    pub scaler_good: Vec<u64>,
}

/// Why a checkpoint could not be loaded.
#[derive(Debug)]
pub enum CkptError {
    /// No checkpoint at this path (manifest absent — never committed).
    Missing(PathBuf),
    /// A checkpoint exists but fails integrity checks (truncated blob, CRC
    /// mismatch, malformed or mismatched manifest).
    Corrupt(String),
    /// Underlying I/O failure other than "not found".
    Io(std::io::Error),
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Missing(p) => write!(f, "checkpoint missing: {}", p.display()),
            CkptError::Corrupt(why) => write!(f, "checkpoint corrupt: {why}"),
            CkptError::Io(e) => write!(f, "checkpoint io error: {e}"),
        }
    }
}

impl std::error::Error for CkptError {}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> CkptError {
        CkptError::Io(e)
    }
}

fn corrupt(why: impl Into<String>) -> CkptError {
    CkptError::Corrupt(why.into())
}

// IEEE 802.3 CRC32, table built at compile time (no external crates).
const CRC32_TABLE: [u32; 256] = build_crc32_table();

const fn build_crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC32 (IEEE) of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Write `bytes` to `path` via tmp-file + fsync + atomic rename.
fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let tmp = path.with_extension(format!(
        "{}.tmp",
        path.extension().and_then(|e| e.to_str()).unwrap_or("dat")
    ));
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)
}

/// Save parameters to `<path>.bin` + `<path>.json`, crash-safely (format 1:
/// no optimizer/sampler state — prefer [`save_full`] for resumable runs).
pub fn save(path: impl AsRef<Path>, params: &[Param], step: usize) -> std::io::Result<()> {
    save_impl(path.as_ref(), params, step, None)
}

/// Save parameters *plus* full training state (format 2), crash-safely.
pub fn save_full(
    path: impl AsRef<Path>,
    params: &[Param],
    step: usize,
    state: &TrainState,
) -> std::io::Result<()> {
    save_impl(path.as_ref(), params, step, Some(state))
}

fn save_impl(
    path: &Path,
    params: &[Param],
    step: usize,
    state: Option<&TrainState>,
) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mixed = params.iter().any(|p| p.dtype() != Dtype::F32);
    let mut blob = Vec::with_capacity(params.iter().map(|p| p.storage_bytes()).sum());
    let mut manifest_params = Vec::new();
    for p in params {
        let start = blob.len();
        // 16-bit params store their raw storage encoding (the in-memory
        // values sit on the dtype grid, so encode→decode is lossless and
        // resume is bit-exact); f32 params store f32 words as before.
        if p.dtype() == Dtype::F32 {
            for &v in p.value.data() {
                blob.extend_from_slice(&v.to_le_bytes());
            }
        } else {
            for &v in p.value.data() {
                blob.extend_from_slice(&p.dtype().encode(v).to_le_bytes());
            }
        }
        let mut entry = vec![
            ("name", Json::Str(p.name.clone())),
            ("rows", Json::Num(p.value.rows() as f64)),
            ("cols", Json::Num(p.value.cols() as f64)),
            (
                "kind",
                Json::Str(
                    match p.kind {
                        ParamKind::Matrix2D => "matrix",
                        ParamKind::Vector => "vector",
                    }
                    .into(),
                ),
            ),
            ("crc32", Json::Num(crc32(&blob[start..]) as f64)),
        ];
        // Key omitted for f32 so all-f32 manifests stay byte-identical to
        // earlier revisions.
        if p.dtype() != Dtype::F32 {
            entry.push(("dtype", Json::Str(p.dtype().as_str().into())));
        }
        manifest_params.push(Json::obj(entry));
    }
    let mut manifest_fields = vec![
        ("step", Json::Num(step as f64)),
        ("params", Json::Arr(manifest_params)),
    ];
    let format = if mixed {
        3.0
    } else if state.is_some() {
        2.0
    } else {
        1.0
    };
    manifest_fields.push(("format", Json::Num(format)));
    if let Some(st) = state {
        // Append the state region after the parameter region, CRC'd as a
        // unit (it has internal structure of its own; per-tensor CRCs add
        // nothing for fall-back granularity — a corrupt state region fails
        // the whole checkpoint either way).
        let state_bytes = st.opt.encode();
        manifest_fields.push(("state_bytes", Json::Num(state_bytes.len() as f64)));
        manifest_fields.push(("state_crc32", Json::Num(crc32(&state_bytes) as f64)));
        manifest_fields.push(("sampler_draws", Json::Num(st.sampler_draws as f64)));
        manifest_fields.push(("elapsed_secs", Json::Num(st.elapsed_secs)));
        if !st.scaler_scales.is_empty() {
            manifest_fields.push(("scaler_scales", Json::nums(&st.scaler_scales)));
            manifest_fields.push((
                "scaler_good",
                Json::Arr(st.scaler_good.iter().map(|&g| Json::Num(g as f64)).collect()),
            ));
        }
        blob.extend_from_slice(&state_bytes);
    }
    manifest_fields.insert(1, ("blob_bytes", Json::Num(blob.len() as f64)));
    let manifest = Json::obj(manifest_fields);
    // Blob first, manifest last: the manifest's presence commits the save.
    write_atomic(&path.with_extension("bin"), &blob)?;
    write_atomic(&path.with_extension("json"), manifest.to_string().as_bytes())?;
    // Persist the renames themselves (best effort — some filesystems refuse
    // directory fsync; the data files are already synced).
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Load a checkpoint's parameters into an existing parameter vector (names
/// and shapes must match positionally), ignoring any format-2 state region.
/// All integrity checks for the parameter portion — manifest, blob size,
/// per-tensor CRCs — run before any parameter is written, so a corrupt
/// checkpoint never leaves the model half-loaded. Returns the saved step.
pub fn load(path: impl AsRef<Path>, params: &mut [Param]) -> Result<usize, CkptError> {
    load_impl(path.as_ref(), params, false).map(|(step, _)| step)
}

/// [`load`], plus the format-2 training state when present (`None` for a
/// format-1 checkpoint). A present-but-corrupt state region fails the whole
/// load — a resumed run must never silently continue with fresh optimizer
/// state when the checkpoint promised otherwise.
pub fn load_full(
    path: impl AsRef<Path>,
    params: &mut [Param],
) -> Result<(usize, Option<TrainState>), CkptError> {
    load_impl(path.as_ref(), params, true)
}

fn load_impl(
    path: &Path,
    params: &mut [Param],
    want_state: bool,
) -> Result<(usize, Option<TrainState>), CkptError> {
    let manifest_path = path.with_extension("json");
    let manifest_text = match std::fs::read_to_string(&manifest_path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Err(CkptError::Missing(manifest_path))
        }
        Err(e) => return Err(CkptError::Io(e)),
    };
    let manifest =
        Json::parse(&manifest_text).map_err(|e| corrupt(format!("manifest parse: {e}")))?;
    let step = manifest.get("step").and_then(|s| s.as_f64()).unwrap_or(0.0) as usize;
    let listed = match manifest.get("params") {
        Some(Json::Arr(xs)) => xs,
        _ => return Err(corrupt("manifest missing params")),
    };
    if listed.len() != params.len() {
        return Err(corrupt(format!(
            "param count mismatch: {} vs {}",
            listed.len(),
            params.len()
        )));
    }
    for (entry, p) in listed.iter().zip(params.iter()) {
        // Names must match positionally: a reordered but shape-compatible
        // param vector would otherwise load silently into the wrong weights.
        let name = entry.get("name").and_then(|v| v.as_str());
        if name != Some(p.name.as_str()) {
            return Err(corrupt(format!(
                "param name mismatch: manifest has {}, model expects {}",
                name.unwrap_or("<missing>"),
                p.name
            )));
        }
        let rows = entry.get("rows").and_then(|v| v.as_f64()).unwrap_or(-1.0) as usize;
        let cols = entry.get("cols").and_then(|v| v.as_f64()).unwrap_or(-1.0) as usize;
        if (rows, cols) != p.value.shape() {
            return Err(corrupt(format!("shape mismatch for {}", p.name)));
        }
        // Storage dtype must match the in-memory parameter (key absent =
        // f32, formats 1/2): a 16-bit checkpoint silently loading into an
        // exact-f32 run — or the reverse — would corrupt the byte-identity
        // guarantees both sides rely on.
        let dt_str = entry.get("dtype").and_then(|v| v.as_str()).unwrap_or("f32");
        let dt = Dtype::parse(dt_str)
            .ok_or_else(|| corrupt(format!("unknown dtype {dt_str:?} for {}", p.name)))?;
        if dt != p.dtype() {
            return Err(corrupt(format!(
                "dtype mismatch for {}: checkpoint {dt_str}, model {}",
                p.name,
                p.dtype().as_str()
            )));
        }
    }
    // The manifest committed, so the blob must exist and be intact — any
    // defect from here on is corruption, not absence.
    let mut bin = match std::fs::File::open(path.with_extension("bin")) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Err(corrupt("blob missing beside committed manifest"))
        }
        Err(e) => return Err(CkptError::Io(e)),
    };
    let mut buf = Vec::new();
    bin.read_to_end(&mut buf)?;
    let state_bytes =
        manifest.get("state_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
    let want: usize = params.iter().map(|p| p.storage_bytes()).sum::<usize>() + state_bytes;
    if buf.len() != want {
        return Err(corrupt(format!("blob size {} != expected {}", buf.len(), want)));
    }
    let mut off = 0usize;
    for (entry, p) in listed.iter().zip(params.iter()) {
        let n = p.storage_bytes();
        let stored = entry.get("crc32").and_then(|v| v.as_f64()).map(|v| v as u32);
        let actual = crc32(&buf[off..off + n]);
        if stored != Some(actual) {
            return Err(corrupt(format!(
                "crc mismatch for {}: manifest {:?}, blob {:#010x}",
                p.name, stored, actual
            )));
        }
        off += n;
    }
    // Validate (and, when asked for, decode) the state region before any
    // parameter write, preserving the nothing-half-loaded guarantee.
    let state = if state_bytes > 0 {
        let region = &buf[buf.len() - state_bytes..];
        let stored = manifest.get("state_crc32").and_then(|v| v.as_f64()).map(|v| v as u32);
        let actual = crc32(region);
        if stored != Some(actual) {
            return Err(corrupt(format!(
                "state crc mismatch: manifest {stored:?}, blob {actual:#010x}"
            )));
        }
        if want_state {
            let opt = OptimizerSnapshot::decode(region)
                .map_err(|e| corrupt(format!("state decode: {e}")))?;
            let num_arr = |key: &str| -> Vec<f64> {
                match manifest.get(key) {
                    Some(Json::Arr(xs)) => xs.iter().filter_map(|x| x.as_f64()).collect(),
                    _ => Vec::new(),
                }
            };
            Some(TrainState {
                opt,
                sampler_draws: manifest
                    .get("sampler_draws")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0) as u64,
                elapsed_secs: manifest
                    .get("elapsed_secs")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0),
                scaler_scales: num_arr("scaler_scales").iter().map(|&x| x as f32).collect(),
                scaler_good: num_arr("scaler_good").iter().map(|&x| x as u64).collect(),
            })
        } else {
            None
        }
    } else {
        None
    };
    let mut off = 0usize;
    for p in params.iter_mut() {
        let dt = p.dtype();
        if dt == Dtype::F32 {
            for v in p.value.data_mut() {
                *v = f32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
                off += 4;
            }
        } else {
            // Decoded u16 words land exactly on the dtype grid — the same
            // values quantized write-back left in memory at save time.
            for v in p.value.data_mut() {
                *v = dt.decode(u16::from_le_bytes(buf[off..off + 2].try_into().unwrap()));
                off += 2;
            }
        }
        // Invalidate any cached transposes of the overwritten weights.
        p.mark_dirty();
    }
    Ok((step, state))
}

/// Base path (no extension) of the checkpoint for `step` inside `dir`.
pub fn rotation_path(dir: &Path, step: usize) -> PathBuf {
    dir.join(format!("ckpt-{step:08}"))
}

/// All committed checkpoints in `dir`, newest first, as `(step, base path)`.
pub fn list_checkpoints(dir: &Path) -> Vec<(usize, PathBuf)> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(step) = name
            .strip_prefix("ckpt-")
            .and_then(|s| s.strip_suffix(".json"))
            .and_then(|s| s.parse::<usize>().ok())
        else {
            continue;
        };
        out.push((step, dir.join(format!("ckpt-{step:08}"))));
    }
    out.sort_by(|a, b| b.0.cmp(&a.0));
    out
}

/// Save under a step-numbered name and prune everything beyond the `keep`
/// newest (keep == 0 disables pruning). Returns the base path written.
pub fn save_rotating(
    dir: &Path,
    params: &[Param],
    step: usize,
    keep: usize,
) -> std::io::Result<PathBuf> {
    let base = rotation_path(dir, step);
    save(&base, params, step)?;
    prune(dir, keep);
    Ok(base)
}

/// [`save_rotating`] with full training state (format 2).
pub fn save_rotating_full(
    dir: &Path,
    params: &[Param],
    step: usize,
    keep: usize,
    state: &TrainState,
) -> std::io::Result<PathBuf> {
    let base = rotation_path(dir, step);
    save_full(&base, params, step, state)?;
    prune(dir, keep);
    Ok(base)
}

fn prune(dir: &Path, keep: usize) {
    if keep > 0 {
        for (_, old) in list_checkpoints(dir).into_iter().skip(keep) {
            // Manifest first so a half-pruned checkpoint reads as Missing,
            // not Corrupt.
            let _ = std::fs::remove_file(old.with_extension("json"));
            let _ = std::fs::remove_file(old.with_extension("bin"));
        }
    }
}

/// True when `dir` exists but cannot be enumerated (permissions, I/O error).
/// That case must not be confused with an *empty* dir: silently treating it
/// as empty would restart training from scratch while valid checkpoints sit
/// inaccessible. A missing dir is a normal first run and stays silent.
fn warn_if_unreadable(dir: &Path) -> bool {
    match std::fs::read_dir(dir) {
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => false,
        Err(e) => {
            eprintln!(
                "warn: checkpoint dir {} exists but is unreadable ({e}); \
                 existing checkpoints cannot be resumed — this run starts \
                 from scratch and may overwrite them once the dir is writable",
                dir.display()
            );
            true
        }
    }
}

/// Load the newest checkpoint in `dir` that passes every integrity check,
/// falling back to older ones past any that are corrupt or missing.
/// Returns `(step, base path)` of the checkpoint loaded, or `None` if no
/// valid checkpoint exists (with a loud warning when `dir` exists but is
/// unreadable — that is not the same as "no checkpoints yet").
pub fn resume_newest(dir: &Path, params: &mut [Param]) -> Option<(usize, PathBuf)> {
    if warn_if_unreadable(dir) {
        return None;
    }
    for (step, base) in list_checkpoints(dir) {
        match load(&base, params) {
            Ok(loaded) => return Some((loaded.max(step), base)),
            Err(CkptError::Missing(_) | CkptError::Corrupt(_)) => continue,
            Err(CkptError::Io(_)) => continue,
        }
    }
    None
}

/// [`resume_newest`], returning the format-2 training state as well (`None`
/// state for a format-1 checkpoint). A checkpoint whose state region is
/// corrupt is skipped entirely — params and state restore from the same
/// (older) checkpoint or not at all, never from different steps.
pub fn resume_newest_full(
    dir: &Path,
    params: &mut [Param],
) -> Option<(usize, PathBuf, Option<TrainState>)> {
    if warn_if_unreadable(dir) {
        return None;
    }
    for (step, base) in list_checkpoints(dir) {
        match load_full(&base, params) {
            Ok((loaded, state)) => return Some((loaded.max(step), base, state)),
            Err(CkptError::Missing(_) | CkptError::Corrupt(_)) => continue,
            Err(CkptError::Io(_)) => continue,
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Llama, ModelConfig};

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("subtrack_ckpt_{tag}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let dir = temp_dir("roundtrip");
        let path = dir.join("ckpt");
        save(&path, &model.params, 123).unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        // Different seed ⇒ different params before load.
        assert_ne!(fresh.params[0].value.data(), model.params[0].value.data());
        let step = load(&path, &mut fresh.params).unwrap();
        assert_eq!(step, 123);
        for (a, b) in fresh.params.iter().zip(&model.params) {
            assert_eq!(a.value.data(), b.value.data(), "{}", a.name);
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn name_mismatch_rejected() {
        use crate::tensor::Matrix;
        let mut rng = crate::util::rng::Rng::new(9);
        let params = vec![
            Param::matrix("layer0.wq", Matrix::randn(4, 4, 1.0, &mut rng)),
            Param::matrix("layer0.wk", Matrix::randn(4, 4, 1.0, &mut rng)),
        ];
        let dir = temp_dir("names");
        let path = dir.join("ckpt");
        save(&path, &params, 7).unwrap();
        // Same shapes, swapped names: loading would silently put wq's weights
        // into wk — must be rejected on the manifest names.
        let mut swapped = vec![
            Param::matrix("layer0.wk", Matrix::zeros(4, 4)),
            Param::matrix("layer0.wq", Matrix::zeros(4, 4)),
        ];
        let err = load(&path, &mut swapped).unwrap_err();
        assert!(matches!(err, CkptError::Corrupt(_)), "{err:?}");
        assert!(err.to_string().contains("name mismatch"), "{err}");
        // The matching order still loads.
        let mut ok = vec![
            Param::matrix("layer0.wq", Matrix::zeros(4, 4)),
            Param::matrix("layer0.wk", Matrix::zeros(4, 4)),
        ];
        let step = load(&path, &mut ok).unwrap();
        assert_eq!(step, 7);
        assert_eq!(ok[0].value.data(), params[0].value.data());
        assert_eq!(ok[1].value.data(), params[1].value.data());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let model = Llama::new(ModelConfig::preset("nano"), 6);
        let dir = temp_dir("shape");
        let path = dir.join("ckpt");
        save(&path, &model.params, 1).unwrap();
        let mut other = Llama::new(ModelConfig::preset("tiny"), 6);
        let err = load(&path, &mut other.params);
        assert!(err.is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_is_distinguished_from_corrupt() {
        let mut model = Llama::new(ModelConfig::preset("nano"), 6);
        let dir = temp_dir("missing");
        let err = load(dir.join("nope"), &mut model.params).unwrap_err();
        assert!(matches!(err, CkptError::Missing(_)), "{err:?}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn crc_catches_bit_flip() {
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let dir = temp_dir("bitflip");
        let path = dir.join("ckpt");
        save(&path, &model.params, 9).unwrap();
        crate::train::faults::flip_bit(&path.with_extension("bin")).unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        let before: Vec<f32> = fresh.params[0].value.data().to_vec();
        let err = load(&path, &mut fresh.params).unwrap_err();
        assert!(matches!(err, CkptError::Corrupt(_)), "{err:?}");
        assert!(err.to_string().contains("crc mismatch"), "{err}");
        // Rejected before any write: params untouched.
        assert_eq!(fresh.params[0].value.data(), &before[..]);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn truncated_blob_rejected() {
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let dir = temp_dir("trunc");
        let path = dir.join("ckpt");
        save(&path, &model.params, 9).unwrap();
        crate::train::faults::truncate_file(&path.with_extension("bin")).unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        let err = load(&path, &mut fresh.params).unwrap_err();
        assert!(matches!(err, CkptError::Corrupt(_)), "{err:?}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rotation_prunes_and_resume_falls_back_past_corruption() {
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let dir = temp_dir("rotate");
        for step in [10, 20, 30, 40] {
            save_rotating(&dir, &model.params, step, 3).unwrap();
        }
        let listed = list_checkpoints(&dir);
        let steps: Vec<usize> = listed.iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, vec![40, 30, 20], "oldest pruned, newest first");
        // Corrupt the newest two; resume must land on step 20.
        crate::train::faults::flip_bit(&rotation_path(&dir, 40).with_extension("bin")).unwrap();
        std::fs::remove_file(rotation_path(&dir, 30).with_extension("bin")).unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        let (step, _) = resume_newest(&dir, &mut fresh.params).unwrap();
        assert_eq!(step, 20);
        assert_eq!(fresh.params[0].value.data(), model.params[0].value.data());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn interrupted_rename_leaves_previous_checkpoint_valid() {
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let dir = temp_dir("interrupted");
        save_rotating(&dir, &model.params, 10, 0).unwrap();
        // Simulate a crash between blob write and manifest rename for step
        // 20: blob + manifest tmp exist, committed manifest does not.
        let base20 = rotation_path(&dir, 20);
        std::fs::write(base20.with_extension("bin"), [0u8; 16]).unwrap();
        std::fs::write(base20.with_extension("json.tmp"), b"{").unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        let (step, _) = resume_newest(&dir, &mut fresh.params).unwrap();
        assert_eq!(step, 10, "uncommitted step-20 save must be invisible");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn crc32_known_vector() {
        // The classic IEEE test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    fn full_state_for(
        model: &Llama,
        steps: usize,
    ) -> (TrainState, Box<dyn crate::optim::Optimizer>) {
        use crate::optim::{by_name, HyperParams};
        let hp = HyperParams { rank: 2, interval: 3, ..HyperParams::default() };
        let mut opt = by_name("subtrack++", hp);
        let mut params = model.params.clone();
        let grads: Vec<_> = params
            .iter()
            .map(|p| crate::tensor::Matrix::full(p.value.rows(), p.value.cols(), 0.01))
            .collect();
        for _ in 0..steps {
            opt.step(1e-3, &mut params, &grads);
        }
        let state = TrainState {
            opt: opt.snapshot(),
            sampler_draws: 42,
            elapsed_secs: 1.5,
            scaler_scales: Vec::new(),
            scaler_good: Vec::new(),
        };
        (state, opt)
    }

    #[test]
    fn full_state_roundtrip() {
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let (state, opt) = full_state_for(&model, 4);
        let dir = temp_dir("full_roundtrip");
        let path = dir.join("ckpt");
        save_full(&path, &model.params, 11, &state).unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        let (step, restored) = load_full(&path, &mut fresh.params).unwrap();
        assert_eq!(step, 11);
        let restored = restored.expect("format 2 must carry state");
        assert_eq!(restored.sampler_draws, 42);
        assert_eq!(restored.elapsed_secs, 1.5);
        for (a, b) in fresh.params.iter().zip(&model.params) {
            assert_eq!(a.value.data(), b.value.data(), "{}", a.name);
        }
        // The restored snapshot must be byte-identical to the saved one.
        assert_eq!(restored.opt.encode(), opt.snapshot().encode());
        // Params-only load reads the same file fine (ignores the state).
        let mut other = Llama::new(ModelConfig::preset("nano"), 998);
        assert_eq!(load(&path, &mut other.params).unwrap(), 11);
        assert_eq!(other.params[0].value.data(), model.params[0].value.data());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn corrupt_state_region_fails_whole_load_and_resume_falls_back() {
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let (state, _) = full_state_for(&model, 4);
        let dir = temp_dir("state_corrupt");
        save_rotating_full(&dir, &model.params, 10, 0, &state).unwrap();
        save_rotating_full(&dir, &model.params, 20, 0, &state).unwrap();
        // Flip a byte inside the step-20 state region (past the param bytes).
        let bin = rotation_path(&dir, 20).with_extension("bin");
        let mut bytes = std::fs::read(&bin).unwrap();
        let param_bytes: usize = model.params.iter().map(|p| p.numel() * 4).sum();
        assert!(bytes.len() > param_bytes, "format 2 must append state");
        let idx = param_bytes + (bytes.len() - param_bytes) / 2;
        bytes[idx] ^= 0xFF;
        std::fs::write(&bin, &bytes).unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        let err = load_full(rotation_path(&dir, 20), &mut fresh.params).unwrap_err();
        assert!(matches!(err, CkptError::Corrupt(_)), "{err:?}");
        // Auto-resume must fall back to step 10 as a unit (params + state).
        let (step, _, st) = resume_newest_full(&dir, &mut fresh.params).unwrap();
        assert_eq!(step, 10);
        assert!(st.is_some());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn format3_bf16_roundtrip_is_bit_exact_and_half_the_bytes() {
        let mut cfg = ModelConfig::preset("nano");
        cfg.dtype = Dtype::Bf16;
        let model = Llama::new(cfg.clone(), 5);
        let dir = temp_dir("format3");
        let path = dir.join("ckpt");
        save(&path, &model.params, 33).unwrap();
        // Parameter region: 2 bytes per element, not 4.
        let param_bytes: usize = model.params.iter().map(|p| p.numel() * 2).sum();
        let blob = std::fs::read(path.with_extension("bin")).unwrap();
        assert_eq!(blob.len(), param_bytes);
        let manifest = std::fs::read_to_string(path.with_extension("json")).unwrap();
        assert!(manifest.contains("\"dtype\":\"bf16\""), "{manifest}");
        let mut fresh = Llama::new(cfg, 999);
        let step = load(&path, &mut fresh.params).unwrap();
        assert_eq!(step, 33);
        for (a, b) in fresh.params.iter().zip(&model.params) {
            assert_eq!(a.value.data(), b.value.data(), "{}", a.name);
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn dtype_mismatch_rejected_both_ways() {
        let mut bf_cfg = ModelConfig::preset("nano");
        bf_cfg.dtype = Dtype::Bf16;
        let bf_model = Llama::new(bf_cfg.clone(), 5);
        let f32_model = Llama::new(ModelConfig::preset("nano"), 5);
        let dir = temp_dir("dtype_mismatch");
        let bf_path = dir.join("bf16");
        let f32_path = dir.join("f32");
        save(&bf_path, &bf_model.params, 1).unwrap();
        save(&f32_path, &f32_model.params, 1).unwrap();
        // bf16 checkpoint into an f32 model.
        let mut f32_fresh = Llama::new(ModelConfig::preset("nano"), 999);
        let err = load(&bf_path, &mut f32_fresh.params).unwrap_err();
        assert!(err.to_string().contains("dtype mismatch"), "{err}");
        // f32 checkpoint into a bf16 model.
        let mut bf_fresh = Llama::new(bf_cfg, 999);
        let err = load(&f32_path, &mut bf_fresh.params).unwrap_err();
        assert!(err.to_string().contains("dtype mismatch"), "{err}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn scaler_state_roundtrips_through_the_manifest() {
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let (mut state, _) = full_state_for(&model, 2);
        state.scaler_scales = vec![4096.0, 1024.0];
        state.scaler_good = vec![7, 0];
        let dir = temp_dir("scaler_state");
        let path = dir.join("ckpt");
        save_full(&path, &model.params, 3, &state).unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        let (_, restored) = load_full(&path, &mut fresh.params).unwrap();
        let restored = restored.unwrap();
        assert_eq!(restored.scaler_scales, vec![4096.0, 1024.0]);
        assert_eq!(restored.scaler_good, vec![7, 0]);
        // Empty scaler state stays out of the manifest entirely.
        let (plain, _) = full_state_for(&model, 2);
        save_full(&path, &model.params, 4, &plain).unwrap();
        let manifest = std::fs::read_to_string(path.with_extension("json")).unwrap();
        assert!(!manifest.contains("scaler_scales"), "{manifest}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn format1_resume_reports_no_state() {
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let dir = temp_dir("v1_no_state");
        save_rotating(&dir, &model.params, 10, 0).unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        let (step, _, st) = resume_newest_full(&dir, &mut fresh.params).unwrap();
        assert_eq!(step, 10);
        assert!(st.is_none(), "format 1 carries no state");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    #[cfg(unix)]
    fn unreadable_dir_resumes_gracefully_and_is_not_treated_as_empty() {
        use std::os::unix::fs::PermissionsExt;
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let dir = temp_dir("unreadable");
        save_rotating(&dir, &model.params, 7, 0).unwrap();
        std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o000)).unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        let res = resume_newest(&dir, &mut fresh.params);
        // Root (common in CI containers) ignores directory modes; only
        // assert the graceful-None path when the dir really is unreadable.
        // Either way the call must not panic and must not corrupt params.
        if std::fs::read_dir(&dir).is_err() {
            assert!(res.is_none(), "unreadable dir must resume as None, loudly");
            assert_ne!(fresh.params[0].value.data(), model.params[0].value.data());
        }
        // Perms restored, the same checkpoint resumes normally.
        std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o755)).unwrap();
        let (step, _) = resume_newest(&dir, &mut fresh.params).unwrap();
        assert_eq!(step, 7);
        for (a, b) in fresh.params.iter().zip(&model.params) {
            assert_eq!(a.value.data(), b.value.data(), "{}", a.name);
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_dir_resumes_silently_as_a_first_run() {
        let dir = temp_dir("never_created");
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        assert!(resume_newest(&dir, &mut fresh.params).is_none());
        assert!(resume_newest_full(&dir, &mut fresh.params).is_none());
    }
}
