//! Checkpointing: parameters as a little-endian f32 binary blob plus a JSON
//! manifest (shapes, names, step, config echo) for integrity checking.

use crate::optim::{Param, ParamKind};
use crate::util::json::Json;
use std::io::{Read, Write};
use std::path::Path;

/// Save parameters to `<path>.bin` + `<path>.json`.
pub fn save(path: impl AsRef<Path>, params: &[Param], step: usize) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut bin = std::fs::File::create(path.with_extension("bin"))?;
    let mut manifest_params = Vec::new();
    for p in params {
        for &v in p.value.data() {
            bin.write_all(&v.to_le_bytes())?;
        }
        manifest_params.push(Json::obj(vec![
            ("name", Json::Str(p.name.clone())),
            ("rows", Json::Num(p.value.rows() as f64)),
            ("cols", Json::Num(p.value.cols() as f64)),
            (
                "kind",
                Json::Str(
                    match p.kind {
                        ParamKind::Matrix2D => "matrix",
                        ParamKind::Vector => "vector",
                    }
                    .into(),
                ),
            ),
        ]));
    }
    let manifest = Json::obj(vec![
        ("step", Json::Num(step as f64)),
        ("params", Json::Arr(manifest_params)),
    ]);
    std::fs::write(path.with_extension("json"), manifest.to_string())
}

/// Load a checkpoint into an existing parameter vector (shapes must match).
/// Returns the saved step.
pub fn load(path: impl AsRef<Path>, params: &mut [Param]) -> std::io::Result<usize> {
    let path = path.as_ref();
    let manifest_text = std::fs::read_to_string(path.with_extension("json"))?;
    let manifest = Json::parse(&manifest_text)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    let step = manifest.get("step").and_then(|s| s.as_f64()).unwrap_or(0.0) as usize;
    let listed = match manifest.get("params") {
        Some(Json::Arr(xs)) => xs,
        _ => {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "manifest missing params",
            ))
        }
    };
    if listed.len() != params.len() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("param count mismatch: {} vs {}", listed.len(), params.len()),
        ));
    }
    for (entry, p) in listed.iter().zip(params.iter()) {
        // Names must match positionally: a reordered but shape-compatible
        // param vector would otherwise load silently into the wrong weights.
        let name = entry.get("name").and_then(|v| v.as_str());
        if name != Some(p.name.as_str()) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "param name mismatch: manifest has {}, model expects {}",
                    name.unwrap_or("<missing>"),
                    p.name
                ),
            ));
        }
        let rows = entry.get("rows").and_then(|v| v.as_f64()).unwrap_or(-1.0) as usize;
        let cols = entry.get("cols").and_then(|v| v.as_f64()).unwrap_or(-1.0) as usize;
        if (rows, cols) != p.value.shape() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("shape mismatch for {}", p.name),
            ));
        }
    }
    let mut bin = std::fs::File::open(path.with_extension("bin"))?;
    let mut buf = Vec::new();
    bin.read_to_end(&mut buf)?;
    let want: usize = params.iter().map(|p| p.numel() * 4).sum();
    if buf.len() != want {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("blob size {} != expected {}", buf.len(), want),
        ));
    }
    let mut off = 0usize;
    for p in params.iter_mut() {
        for v in p.value.data_mut() {
            *v = f32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
            off += 4;
        }
        // Invalidate any cached transposes of the overwritten weights.
        p.mark_dirty();
    }
    Ok(step)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Llama, ModelConfig};

    #[test]
    fn roundtrip() {
        let model = Llama::new(ModelConfig::preset("nano"), 5);
        let dir = std::env::temp_dir().join("subtrack_ckpt_test");
        let path = dir.join("ckpt");
        save(&path, &model.params, 123).unwrap();
        let mut fresh = Llama::new(ModelConfig::preset("nano"), 999);
        // Different seed ⇒ different params before load.
        assert_ne!(fresh.params[0].value.data(), model.params[0].value.data());
        let step = load(&path, &mut fresh.params).unwrap();
        assert_eq!(step, 123);
        for (a, b) in fresh.params.iter().zip(&model.params) {
            assert_eq!(a.value.data(), b.value.data(), "{}", a.name);
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn name_mismatch_rejected() {
        use crate::tensor::Matrix;
        let mut rng = crate::util::rng::Rng::new(9);
        let params = vec![
            Param::matrix("layer0.wq", Matrix::randn(4, 4, 1.0, &mut rng)),
            Param::matrix("layer0.wk", Matrix::randn(4, 4, 1.0, &mut rng)),
        ];
        let dir = std::env::temp_dir().join("subtrack_ckpt_test_names");
        let path = dir.join("ckpt");
        save(&path, &params, 7).unwrap();
        // Same shapes, swapped names: loading would silently put wq's weights
        // into wk — must be rejected on the manifest names.
        let mut swapped = vec![
            Param::matrix("layer0.wk", Matrix::zeros(4, 4)),
            Param::matrix("layer0.wq", Matrix::zeros(4, 4)),
        ];
        let err = load(&path, &mut swapped).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("name mismatch"), "{err}");
        // The matching order still loads.
        let mut ok = vec![
            Param::matrix("layer0.wq", Matrix::zeros(4, 4)),
            Param::matrix("layer0.wk", Matrix::zeros(4, 4)),
        ];
        let step = load(&path, &mut ok).unwrap();
        assert_eq!(step, 7);
        assert_eq!(ok[0].value.data(), params[0].value.data());
        assert_eq!(ok[1].value.data(), params[1].value.data());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let model = Llama::new(ModelConfig::preset("nano"), 6);
        let dir = std::env::temp_dir().join("subtrack_ckpt_test2");
        let path = dir.join("ckpt");
        save(&path, &model.params, 1).unwrap();
        let mut other = Llama::new(ModelConfig::preset("tiny"), 6);
        let err = load(&path, &mut other.params);
        assert!(err.is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}
