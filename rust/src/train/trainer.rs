//! The trainer: owns the model, the optimizer, the data stream and the
//! metrics; drives pre-training and fine-tuning runs for every experiment
//! harness.
//!
//! Engine selection: the **native** engine computes loss/gradients with the
//! pure-Rust backward pass in [`crate::model::llama`]; the **pjrt** engine
//! executes the JAX-lowered `train_step` artifact (which embeds the Pallas
//! kernels) through [`crate::runtime`]. Both produce gradients for the same
//! Rust-side optimizer family — the paper's contribution always runs in
//! Layer 3.

use crate::data::{Corpus, CorpusKind};
use crate::model::{Batch, Llama, ModelConfig, StepState};
use crate::optim::{self, HyperParams, Optimizer, OptimizerSnapshot};
use crate::tensor::{dtype, ops, pool, Dtype, Matrix};
use crate::train::checkpoint;
use crate::train::faults::{FaultInjection, FaultKind, FaultSchedule};
use crate::train::metrics::{MetricsLog, TrainReport};
use crate::train::parallel;
use crate::train::scaler::DynamicLossScaler;
use crate::train::schedule::LrSchedule;
use crate::train::sentinel::{FaultPolicy, Sentinel, SentinelConfig, Verdict};
use crate::util::config::Config;
use std::path::PathBuf;

/// Which gradient engine backs the trainer.
pub enum EngineSel {
    Native,
    Pjrt(crate::runtime::PjrtEngine),
}

/// Everything a training run needs. Built programmatically or from a
/// `configs/*.toml` file (+ CLI overrides).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelConfig,
    pub method: String,
    pub hp: HyperParams,
    pub steps: usize,
    pub batch_size: usize,
    /// Gradient-accumulation micro-batches per optimizer step (1 = off).
    /// Each micro-batch samples `batch_size` fresh sequences, so the
    /// effective batch is `batch_size * accum_steps`; `steps`, the sentinel,
    /// fault injection and the LR schedule all count *optimizer* steps.
    pub accum_steps: usize,
    pub lr: f32,
    pub warmup_steps: usize,
    pub grad_clip: f32,
    pub seed: u64,
    /// Simulated data-parallel worker count (1 = off).
    pub workers: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub corpus_kind: CorpusKind,
    pub corpus_len: usize,
    /// Log every N steps (loss curve resolution).
    pub log_every: usize,
    /// Numerical-health sentinel policy + knobs (`[train.fault]`).
    pub sentinel: SentinelConfig,
    /// Scheduled fault injection (`PALLAS_FAULT` env / `train.fault.inject`);
    /// comma-separated `kind@step` specs compound faults in one run.
    pub fault: Option<FaultSchedule>,
    /// Pool-watchdog deadline in ms (`[train.watchdog] deadline_ms`): armed
    /// for the duration of `run` when > 0 and `GEMM_DEADLINE_MS` is unset
    /// (the env knob wins). 0 = watchdog off (the preset default).
    pub watchdog_deadline_ms: usize,
    /// Crash-safe checkpoint directory ("" = checkpointing disabled).
    pub checkpoint_dir: String,
    /// Save a rotating checkpoint every N steps (0 = disabled).
    pub checkpoint_every: usize,
    /// Rotation depth: keep the newest K checkpoints (0 = keep all).
    pub checkpoint_keep: usize,
}

impl TrainConfig {
    /// Reasonable defaults for a given model preset + method, mirroring the
    /// paper's Table 10 hyperparameters scaled to this testbed.
    pub fn preset(model: &str, method: &str, steps: usize) -> TrainConfig {
        let mut model = ModelConfig::preset(model);
        // Storage dtype: presets are f32; the PALLAS_DTYPE env knob flips
        // every trainer-built run (the CI mixed-precision leg), and
        // `[model] dtype` in a config file does the same per run.
        model.dtype = dtype::env_dtype().unwrap_or(Dtype::F32);
        let hp = HyperParams {
            rank: model.rank,
            // Match the paper's wall-time protocol by default: interval
            // sized so a full run has ~10 subspace updates (Table 9).
            interval: (steps / 10).max(1),
            scale: 0.25,
            eta: 10.0,
            zeta: 1.01,
            ..HyperParams::default()
        };
        TrainConfig {
            model,
            method: method.to_string(),
            hp,
            steps,
            batch_size: 8,
            accum_steps: 1,
            lr: 1e-3,
            warmup_steps: steps / 10,
            grad_clip: 1.0,
            seed: 42,
            workers: 1,
            eval_every: (steps / 10).max(1),
            eval_batches: 4,
            corpus_kind: CorpusKind::Markov,
            corpus_len: 200_000,
            log_every: 1,
            sentinel: SentinelConfig::default(),
            fault: None,
            watchdog_deadline_ms: 0,
            checkpoint_dir: String::new(),
            checkpoint_every: 0,
            checkpoint_keep: 3,
        }
    }

    /// Load from a parsed TOML config (see `configs/`).
    pub fn from_config(cfg: &Config) -> TrainConfig {
        let model_name = cfg.str("model.preset", "small");
        let steps = cfg.int("train.steps", 400) as usize;
        let method = cfg.str("optim.method", "subtrack++");
        let mut tc = TrainConfig::preset(&model_name, &method, steps);
        tc.model.hidden = cfg.int("model.hidden", tc.model.hidden as i64) as usize;
        tc.model.layers = cfg.int("model.layers", tc.model.layers as i64) as usize;
        tc.model.vocab = cfg.int("model.vocab", tc.model.vocab as i64) as usize;
        tc.model.seq_len = cfg.int("model.seq_len", tc.model.seq_len as i64) as usize;
        let dtype_str = cfg.str("model.dtype", "");
        if !dtype_str.is_empty() {
            tc.model.dtype = Dtype::parse(&dtype_str)
                .unwrap_or_else(|| panic!("model.dtype: unknown dtype {dtype_str:?}"));
        }
        // The env knob wins over the config file (CI mixed-precision legs),
        // mirroring PALLAS_FAULT below.
        if let Some(dt) = dtype::env_dtype() {
            tc.model.dtype = dt;
        }
        tc.batch_size = cfg.int("train.batch_size", tc.batch_size as i64) as usize;
        tc.accum_steps = (cfg.int("train.accum_steps", tc.accum_steps as i64) as usize).max(1);
        tc.lr = cfg.float("train.lr", tc.lr as f64) as f32;
        tc.warmup_steps = cfg.int("train.warmup_steps", tc.warmup_steps as i64) as usize;
        tc.grad_clip = cfg.float("train.grad_clip", tc.grad_clip as f64) as f32;
        tc.seed = cfg.int("train.seed", tc.seed as i64) as u64;
        tc.workers = cfg.int("train.workers", 1) as usize;
        // Evaluation/logging cadence (preset values as defaults). eval_every
        // may be 0 (= mid-run eval disabled); eval_batches and log_every are
        // divisors in the loop, so clamp them to ≥ 1.
        tc.eval_every = cfg.int("train.eval_every", tc.eval_every as i64) as usize;
        tc.eval_batches = (cfg.int("train.eval_batches", tc.eval_batches as i64) as usize).max(1);
        tc.log_every = (cfg.int("train.log_every", tc.log_every as i64) as usize).max(1);
        tc.hp.rank = cfg.int("optim.rank", tc.hp.rank as i64) as usize;
        tc.hp.interval = cfg.int("optim.interval", tc.hp.interval as i64) as usize;
        tc.hp.scale = cfg.float("optim.scale", tc.hp.scale as f64) as f32;
        tc.hp.eta = cfg.float("optim.eta", tc.hp.eta as f64) as f32;
        tc.hp.zeta = cfg.float("optim.zeta", tc.hp.zeta as f64) as f32;
        tc.corpus_len = cfg.int("data.corpus_len", tc.corpus_len as i64) as usize;
        tc.corpus_kind = match cfg.str("data.corpus", "markov").as_str() {
            "hierarchical" => CorpusKind::Hierarchical,
            _ => CorpusKind::Markov,
        };
        // [train.fault]: sentinel policy + knobs, plus scheduled injection.
        let policy = cfg.str("train.fault.policy", tc.sentinel.policy.as_str());
        tc.sentinel.policy = FaultPolicy::parse(&policy)
            .unwrap_or_else(|| panic!("train.fault.policy: unknown policy {policy:?}"));
        tc.sentinel.snapshot_every =
            (cfg.int("train.fault.snapshot_every", tc.sentinel.snapshot_every as i64) as usize)
                .max(1);
        tc.sentinel.spike_window =
            cfg.int("train.fault.spike_window", tc.sentinel.spike_window as i64) as usize;
        tc.sentinel.spike_factor =
            cfg.float("train.fault.spike_factor", tc.sentinel.spike_factor as f64) as f32;
        tc.sentinel.escalate_after =
            cfg.int("train.fault.escalate_after", tc.sentinel.escalate_after as i64) as usize;
        tc.sentinel.loop_restores =
            (cfg.int("train.fault.loop_restores", tc.sentinel.loop_restores as i64) as usize)
                .max(1);
        tc.sentinel.rewarm_steps =
            (cfg.int("train.fault.rewarm_steps", tc.sentinel.rewarm_steps as i64) as usize)
                .max(1);
        let inject = cfg.str("train.fault.inject", "");
        if !inject.is_empty() {
            // Validated at config-load time: the typed parse error names the
            // offending element instead of a pattern-match panic mid-run.
            tc.fault = Some(FaultSchedule::parse(&inject).unwrap_or_else(|e| {
                panic!("train.fault.inject: {e}")
            }));
        }
        // The env knob wins over the config file (CI fault legs).
        if let Some(f) = FaultSchedule::from_env() {
            tc.fault = Some(f);
        }
        // [train.watchdog]: pool-level hang detection (default off).
        tc.watchdog_deadline_ms =
            cfg.int("train.watchdog.deadline_ms", tc.watchdog_deadline_ms as i64) as usize;
        // [train.checkpoint]: crash-safe rotating checkpoints + auto-resume.
        tc.checkpoint_dir = cfg.str("train.checkpoint.dir", &tc.checkpoint_dir);
        tc.checkpoint_every =
            cfg.int("train.checkpoint.every", tc.checkpoint_every as i64) as usize;
        tc.checkpoint_keep =
            cfg.int("train.checkpoint.keep", tc.checkpoint_keep as i64) as usize;
        tc
    }
}

/// Arms the pool watchdog for the duration of one `run` and restores the
/// previous deadline on drop (so tests and repeated in-process runs don't
/// leak a global deadline). The `GEMM_DEADLINE_MS` env knob wins: when set,
/// the config key is ignored entirely.
struct WatchdogArm {
    prev: Option<usize>,
}

impl WatchdogArm {
    fn new(deadline_ms: usize) -> WatchdogArm {
        if deadline_ms > 0 && std::env::var("GEMM_DEADLINE_MS").is_err() {
            let prev = pool::pool_deadline_ms();
            pool::set_pool_deadline_ms(deadline_ms);
            WatchdogArm { prev: Some(prev) }
        } else {
            WatchdogArm { prev: None }
        }
    }
}

impl Drop for WatchdogArm {
    fn drop(&mut self) {
        if let Some(prev) = self.prev {
            pool::set_pool_deadline_ms(prev);
        }
    }
}

/// The trainer.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub model: Llama,
    pub opt: Box<dyn Optimizer>,
    pub corpus: Corpus,
    pub engine: EngineSel,
    pub metrics: MetricsLog,
    /// Persistent step-loop state (workspace + transpose cache): the native
    /// engine's forward/backward allocates no buffers after the first step.
    pub state: StepState,
    /// Numerical-health monitor (no-op when `cfg.sentinel.policy` is off).
    pub sentinel: Sentinel,
    /// `cfg.workers` with 0 resolved to the auto worker count, fixed at
    /// construction: the same count shards both the batch (data parallelism)
    /// and the optimizer state (ZeRO-style partitioning).
    workers: usize,
    /// Persistent data-parallel buffers (`None` when `workers == 1`): shard
    /// batches, shard gradients and shard `StepState`s all live here, so the
    /// DP path keeps the zero-allocation steady state.
    dp: Option<parallel::DpContext>,
    /// f16 gradient-storage loss scaler (`Some` iff `model.dtype = "f16"`;
    /// bf16 keeps f32's exponent range and needs none).
    scaler: Option<DynamicLossScaler>,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Trainer {
        let model = Llama::new(cfg.model.clone(), cfg.seed);
        let mut hp = cfg.hp;
        hp.seed = cfg.seed;
        // workers == 0 means "auto": reuse the GEMM worker-count plumbing.
        let workers = if cfg.workers == 0 { parallel::auto_workers() } else { cfg.workers.max(1) };
        // Each DP worker owns one contiguous partition of the optimizer
        // state (ZeRO-1): state memory per shard shrinks ~1/workers while
        // the update trajectory stays bit-identical for partitionable
        // methods (`rust/src/optim/sharded.rs`).
        // Under a 16-bit storage dtype the mixed-precision wrapper owns f32
        // master weights around the (possibly sharded) base optimizer; f32
        // returns the sharded optimizer unchanged.
        let opt = optim::mixed_by_name(&cfg.method, hp, workers, cfg.model.dtype);
        let corpus =
            Corpus::generate(cfg.corpus_kind, cfg.model.vocab, cfg.corpus_len, cfg.seed ^ 0xd474);
        let sentinel = Sentinel::new(cfg.sentinel);
        let dp = (workers > 1).then(|| parallel::DpContext::new(workers));
        let scaler = (cfg.model.dtype == Dtype::F16).then(DynamicLossScaler::new);
        Trainer {
            cfg,
            model,
            opt,
            corpus,
            engine: EngineSel::Native,
            metrics: MetricsLog::new(),
            state: StepState::new(),
            sentinel,
            workers,
            dp,
            scaler,
        }
    }

    /// The resolved data-parallel worker / optimizer-shard count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Switch to the PJRT engine (artifacts must exist — see `make artifacts`).
    pub fn with_pjrt(mut self, engine: crate::runtime::PjrtEngine) -> Trainer {
        self.engine = EngineSel::Pjrt(engine);
        self
    }

    /// Loss + gradients for one batch. Both native paths write into the
    /// caller's persistent buffers (allocation-free steady state): the
    /// single-worker path directly, the DP path by reducing its persistent
    /// per-shard gradients into them. The PJRT path replaces them.
    fn compute_loss_grad(
        &mut self,
        batch: &Batch,
        grads: &mut Vec<crate::tensor::Matrix>,
    ) -> anyhow::Result<f32> {
        match &mut self.engine {
            EngineSel::Native => {
                if let Some(dp) = &mut self.dp {
                    Ok(dp.loss_grad_into(&self.model, batch, grads))
                } else {
                    Ok(self.model.loss_and_grad_into(batch, grads, &mut self.state))
                }
            }
            EngineSel::Pjrt(engine) => {
                let (loss, g) = engine.loss_and_grad(&self.model.params, batch)?;
                *grads = g;
                Ok(loss)
            }
        }
    }

    /// Mean eval loss over deterministic held-out windows.
    pub fn eval_loss(&mut self) -> anyhow::Result<f32> {
        let b = self.cfg.batch_size.min(8);
        let t = self.cfg.model.seq_len;
        let mut total = 0.0f64;
        for i in 0..self.cfg.eval_batches {
            let batch = shifted_eval_batch(&self.corpus, b, t, i);
            let loss = match &mut self.engine {
                EngineSel::Native => self.model.loss_ws(&batch, &mut self.state),
                EngineSel::Pjrt(engine) => engine.loss(&self.model.params, &batch)?,
            };
            total += loss as f64;
        }
        Ok((total / self.cfg.eval_batches as f64) as f32)
    }

    /// Run the full training loop; returns the report consumed by the
    /// table/figure harnesses.
    ///
    /// Fault-tolerance wiring (all inert at the preset defaults):
    /// - If `checkpoint_dir` is set, training first auto-resumes from the
    ///   newest checkpoint there that passes integrity checks — parameters
    ///   *and* (for format-2 checkpoints) the full optimizer state, corpus
    ///   sampler position and accumulated wall-clock, so a killed-and-
    ///   resumed run replays the uninterrupted trajectory bit-for-bit —
    ///   then saves a rotating crash-safe checkpoint every
    ///   `checkpoint_every` steps.
    /// - Each step the sentinel inspects the loss and pre-clip gradient
    ///   norm *before* the optimizer applies the update, so an anomalous
    ///   step can be dropped (`skip`), rewound to the last in-memory
    ///   snapshot (`rollback`), or turned into an error (`abort`) without
    ///   ever corrupting optimizer state.
    /// - A configured [`FaultSchedule`] fires deterministically by step
    ///   number after gradient reduction, so faulted runs are reproducible
    ///   for any worker count (and faults may compound within one run).
    ///
    /// Rollback rewinds parameters and the full optimizer state but *not*
    /// the corpus sampler: replayed steps see fresh batches, which is the
    /// behavior a real run recovering from a bad region wants.
    pub fn run(&mut self) -> anyhow::Result<TrainReport> {
        let _watchdog = WatchdogArm::new(self.cfg.watchdog_deadline_ms);
        let schedule = LrSchedule::new(self.cfg.lr, self.cfg.warmup_steps, self.cfg.steps);
        let (b, t) = (self.cfg.batch_size, self.cfg.model.seq_len);
        let accum = self.cfg.accum_steps.max(1);
        // Gradient buffers persist across steps (zero-allocation hot path);
        // under accumulation a second persistent buffer holds each
        // micro-batch's gradients before they fold into the running sum.
        let mut grads = self.model.zero_grads();
        let mut micro_grads = if accum > 1 { self.model.zero_grads() } else { Vec::new() };
        let policy = self.cfg.sentinel.policy;
        let ckpt_dir = (!self.cfg.checkpoint_dir.is_empty())
            .then(|| PathBuf::from(&self.cfg.checkpoint_dir));
        let mut start_step = 0usize;
        if let Some(dir) = &ckpt_dir {
            if let Some((step, base, state)) =
                checkpoint::resume_newest_full(dir, &mut self.model.params)
            {
                start_step = step;
                let full = state.is_some();
                if let Some(st) = state {
                    self.opt.restore(&st.opt);
                    // Land the sampler on the checkpointed stream position
                    // so post-resume batches match the uninterrupted run's
                    // (guarded: a reused trainer may already be past it).
                    if st.sampler_draws >= self.corpus.sampler_draws() {
                        self.corpus.fast_forward(st.sampler_draws);
                    }
                    self.metrics.set_prior_elapsed(st.elapsed_secs);
                    if let Some(sc) = &mut self.scaler {
                        if !st.scaler_scales.is_empty() {
                            sc.import(&st.scaler_scales, &st.scaler_good);
                        }
                    }
                }
                eprintln!(
                    "trainer: resumed step {} from {} ({})",
                    step,
                    base.display(),
                    if full { "full state" } else { "params only" }
                );
            }
        }
        // Last-good (params, optimizer state) pair for rollback, refreshed
        // every `snapshot_every` healthy steps.
        let mut snapshot: Option<(Vec<Matrix>, OptimizerSnapshot)> = None;
        // LR re-warm countdown set by an escalated rollback (RollbackRewarm).
        let mut rewarm_left = 0usize;
        let mut ckpt_faults_pending: Vec<FaultInjection> = self
            .cfg
            .fault
            .as_ref()
            .map_or(Vec::new(), |s| {
                s.of_kinds(&[FaultKind::CkptTruncate, FaultKind::CkptBitflip])
            });
        for step in start_step..self.cfg.steps {
            if let Some(sched) = &self.cfg.fault {
                for kind in sched.at(step) {
                    match kind {
                        FaultKind::WorkerPanic => {
                            // One pool task panics mid-job; the pool must
                            // re-raise here and keep serving — training
                            // continues below. Under DP the same fault also
                            // kills one shard mid-step, which degraded mode
                            // must absorb without touching the trajectory.
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                pool::run(2, 4, &|i| {
                                    if i == 3 {
                                        panic!("injected worker panic (step {step})");
                                    }
                                });
                            }));
                            if let Some(dp) = &self.dp {
                                dp.fail_next_shard(0);
                            }
                        }
                        FaultKind::WorkerHang => {
                            // A sacrificial job hangs one *worker-side* task
                            // until the watchdog cancels it. The wall-clock
                            // cap keeps unarmed runs terminating; the task
                            // never runs on the publisher (the watchdog
                            // lives in the publisher's wait loop).
                            let res = pool::try_run(2, 2, &|i| {
                                if i == 1 && pool::on_worker() {
                                    let cap = std::time::Instant::now();
                                    while !pool::job_cancelled()
                                        && cap.elapsed()
                                            < std::time::Duration::from_secs(2)
                                    {
                                        std::thread::sleep(
                                            std::time::Duration::from_millis(1),
                                        );
                                    }
                                }
                            });
                            eprintln!(
                                "trainer: injected worker hang at step {step} -> {res:?}"
                            );
                        }
                        FaultKind::SlowWorker => {
                            // Slow-but-alive: the task finishes on its own,
                            // and a healthy progress-based watchdog must let
                            // it (a total-runtime watchdog would not).
                            let res = pool::try_run(2, 4, &|i| {
                                if i == 3 {
                                    std::thread::sleep(
                                        std::time::Duration::from_millis(30),
                                    );
                                }
                            });
                            assert!(
                                res.is_ok(),
                                "watchdog killed a slow-but-alive job at step {step}: {res:?}"
                            );
                        }
                        _ => {}
                    }
                }
            }
            // Gradient accumulation: `accum` micro-batches per optimizer
            // step, averaged with equal weights (each micro-batch carries
            // the same token count). `accum == 1` is byte-identical to the
            // unaccumulated loop. Everything below this block — faults,
            // sentinel, clipping, LR, checkpoints — sees one *optimizer*
            // step regardless of accum.
            let mut loss_sum = 0.0f64;
            for micro in 0..accum {
                let batch = self.corpus.sample_batch(b, t);
                let target = if micro == 0 { &mut grads } else { &mut micro_grads };
                loss_sum += self.compute_loss_grad(&batch, target)? as f64;
                if micro > 0 {
                    for (acc, g) in grads.iter_mut().zip(&micro_grads) {
                        acc.axpy(1.0, g);
                    }
                }
            }
            if accum > 1 {
                let inv = 1.0 / accum as f32;
                for g in grads.iter_mut() {
                    g.scale_mut(inv);
                }
            }
            let loss = (loss_sum / accum as f64) as f32;
            // Mixed-precision gradient storage: bf16 gradients round onto
            // the storage grid in place; f16 gradients go through the
            // dynamic loss scaler, which can declare the step
            // unrepresentable (overflow) — it is then dropped below exactly
            // like a sentinel `skip`, state untouched. f32 is a no-op.
            let mut grads_ok = true;
            match self.cfg.model.dtype {
                Dtype::F32 => {}
                Dtype::Bf16 => {
                    for g in grads.iter_mut() {
                        dtype::quantize_slice(Dtype::Bf16, g.data_mut());
                    }
                }
                Dtype::F16 => {
                    let sc = self.scaler.as_mut().expect("f16 runs own a scaler");
                    grads_ok = sc.quantize_step(&mut grads);
                }
            }
            if let Some(sched) = &self.cfg.fault {
                for kind in sched.at(step) {
                    match kind {
                        FaultKind::NanGrad => {
                            for g in grads.iter_mut() {
                                g.data_mut().fill(f32::NAN);
                            }
                        }
                        FaultKind::RefreshPoison => self.opt.poison_next_refresh(),
                        _ => {}
                    }
                }
            }
            // Clipping surfaces the pre-clip norm; with clipping off the
            // sentinel still needs it (skipped entirely when the sentinel
            // is off — the norm reduction is not free).
            let grad_norm = if !grads_ok {
                0.0 // step already condemned; don't clip or reduce
            } else if self.cfg.grad_clip > 0.0 {
                ops::clip_global_norm_slice(&mut grads, self.cfg.grad_clip)
            } else if policy != FaultPolicy::Off {
                ops::global_norm_slice(&grads)
            } else {
                0.0
            };
            // A loss-scaler overflow drops the step like a sentinel skip
            // but is accounted separately (`scaler_skips` in the report)
            // and must not disturb the sentinel's spike statistics.
            let verdict = if grads_ok {
                self.sentinel.check(step, loss, grad_norm)
            } else {
                Verdict::Skip
            };
            match verdict {
                Verdict::Healthy => {
                    let mut lr = schedule.at(step);
                    // LR re-warm after an escalated rollback: ramp linearly
                    // from 1/rewarm_steps of the scheduled LR back to full.
                    if rewarm_left > 0 {
                        let total = self.cfg.sentinel.rewarm_steps.max(1);
                        lr *= (total - rewarm_left + 1) as f32 / total as f32;
                        rewarm_left -= 1;
                    }
                    self.opt.step(lr, &mut self.model.params, &grads);
                    if step % self.cfg.log_every == 0 {
                        self.metrics.record_step(step, loss, lr, self.opt.state_bytes());
                    }
                    if policy.needs_snapshots()
                        && step % self.cfg.sentinel.snapshot_every == 0
                    {
                        match &mut snapshot {
                            Some((params, snap)) => {
                                for (dst, p) in params.iter_mut().zip(&self.model.params) {
                                    dst.copy_from(&p.value);
                                }
                                *snap = self.opt.snapshot();
                            }
                            None => {
                                let params: Vec<Matrix> = self
                                    .model
                                    .params
                                    .iter()
                                    .map(|p| p.value.clone())
                                    .collect();
                                snapshot = Some((params, self.opt.snapshot()));
                            }
                        }
                        // A fresh last-good landed: reset the rollback-loop
                        // detector (escalate ladder).
                        self.sentinel.note_snapshot();
                    }
                }
                Verdict::Skip => {} // drop the step; state untouched
                v @ (Verdict::Rollback | Verdict::RollbackRewarm) => {
                    if let Some((params, snap)) = &snapshot {
                        for (p, saved) in self.model.params.iter_mut().zip(params) {
                            p.value.copy_from(saved);
                            p.mark_dirty();
                        }
                        self.opt.restore(snap);
                    }
                    // No snapshot yet: the drop alone is the recovery.
                    if v == Verdict::RollbackRewarm {
                        rewarm_left = self.cfg.sentinel.rewarm_steps.max(1);
                    }
                }
                Verdict::Abort => {
                    eprint!("{}", self.sentinel.dump());
                    anyhow::bail!(
                        "sentinel abort at step {step}: loss={loss} grad_norm={grad_norm}"
                    );
                }
            }
            if let Some(dir) = &ckpt_dir {
                if self.cfg.checkpoint_every > 0 && (step + 1) % self.cfg.checkpoint_every == 0 {
                    let (scaler_scales, scaler_good) = match &self.scaler {
                        Some(sc) => sc.export(),
                        None => (Vec::new(), Vec::new()),
                    };
                    let train_state = checkpoint::TrainState {
                        opt: self.opt.snapshot(),
                        sampler_draws: self.corpus.sampler_draws(),
                        elapsed_secs: self.metrics.elapsed(),
                        scaler_scales,
                        scaler_good,
                    };
                    let base = checkpoint::save_rotating_full(
                        dir,
                        &self.model.params,
                        step + 1,
                        self.cfg.checkpoint_keep,
                        &train_state,
                    )?;
                    // Each pending checkpoint fault fires once, on the first
                    // save at or after its scheduled step.
                    let mut j = 0;
                    while j < ckpt_faults_pending.len() {
                        if step + 1 >= ckpt_faults_pending[j].step {
                            let f = ckpt_faults_pending.remove(j);
                            match f.kind {
                                FaultKind::CkptTruncate => {
                                    crate::train::faults::truncate_file(
                                        &base.with_extension("bin"),
                                    )?;
                                }
                                FaultKind::CkptBitflip => {
                                    crate::train::faults::flip_bit(&base.with_extension("bin"))?;
                                }
                                _ => unreachable!("pending holds only ckpt faults"),
                            }
                        } else {
                            j += 1;
                        }
                    }
                }
            }
            if self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0 {
                let ev = self.eval_loss()?;
                self.metrics.record_eval(step + 1, ev);
            }
        }
        let final_eval = self.eval_loss()?;
        Ok(TrainReport {
            method: self.opt.name(),
            model: self.cfg.model.name.clone(),
            total_steps: self.cfg.steps,
            steps: self.metrics.steps.clone(),
            evals: self.metrics.evals.clone(),
            final_eval_loss: final_eval,
            wall_time_secs: self.metrics.elapsed(),
            peak_state_bytes: self.metrics.peak_state_bytes,
            peak_rss_bytes: self.metrics.peak_rss_bytes.max(super::metrics::read_rss_bytes()),
            param_count: self.model.param_count(),
            optimizer_state_params: self.opt.state_params(),
            subspace_updates: self.opt.subspace_updates(),
            sentinel_skips: self.sentinel.skips(),
            sentinel_rollbacks: self.sentinel.rollbacks(),
            refresh_rejections: self.opt.refresh_rejections(),
            storage_dtype: self.cfg.model.dtype.as_str().to_string(),
            scaler_skips: self.scaler.as_ref().map_or(0, |s| s.skips()),
            degraded_steps: self.dp.as_ref().map_or(0, |d| d.degraded_steps()),
        })
    }
}

/// Deterministic eval batches offset by index (so eval_batches > 1 sees
/// different windows).
fn shifted_eval_batch(corpus: &Corpus, b: usize, t: usize, index: usize) -> Batch {
    let base = corpus.eval_batch(b * (index + 1), t);
    // Keep only the last b sequences of the widened batch. `eval_batch`
    // clamps its width on corpora too small for the request, so never keep
    // more than it actually returned (the old unguarded subtraction
    // underflowed and panicked on tiny corpora).
    let keep_b = b.min(base.b);
    let keep = keep_b * t;
    let start = base.inputs.len() - keep;
    Batch {
        inputs: base.inputs[start..].to_vec(),
        targets: base.targets[start..].to_vec(),
        b: keep_b,
        t,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(method: &str) -> TrainConfig {
        let mut cfg = TrainConfig::preset("nano", method, 30);
        cfg.batch_size = 4;
        cfg.corpus_len = 5_000;
        cfg.lr = 5e-3;
        cfg.eval_every = 0;
        cfg.eval_batches = 2;
        cfg.hp.rank = 4;
        cfg.hp.interval = 10;
        cfg
    }

    #[test]
    fn native_training_reduces_loss() {
        let mut tr = Trainer::new(quick_cfg("subtrack++"));
        let before = tr.eval_loss().unwrap();
        let report = tr.run().unwrap();
        assert!(
            report.final_eval_loss < before,
            "eval loss should drop: {before} -> {}",
            report.final_eval_loss
        );
        assert_eq!(report.steps.len(), 30);
        assert!(report.wall_time_secs > 0.0);
        assert!(report.peak_state_bytes > 0);
    }

    #[test]
    fn all_methods_run_a_few_steps() {
        for method in crate::optim::PRETRAIN_METHODS {
            let mut cfg = quick_cfg(method);
            cfg.steps = 5;
            let mut tr = Trainer::new(cfg);
            let report = tr.run().unwrap();
            assert!(report.final_eval_loss.is_finite(), "{method} produced NaN");
        }
    }

    #[test]
    fn config_file_roundtrip() {
        let text = r#"
[model]
preset = "nano"
seq_len = 8

[optim]
method = "galore"
rank = 2
interval = 5

[train]
steps = 4
batch_size = 2
lr = 0.001
seed = 7
"#;
        let cfg = Config::parse(text).unwrap();
        let tc = TrainConfig::from_config(&cfg);
        assert_eq!(tc.model.name, "nano");
        assert_eq!(tc.method, "galore");
        assert_eq!(tc.hp.rank, 2);
        assert_eq!(tc.steps, 4);
        assert_eq!(tc.seed, 7);
        let mut tr = Trainer::new(tc);
        let report = tr.run().unwrap();
        assert_eq!(report.method, "GaLore");
    }

    #[test]
    fn config_file_roundtrips_eval_and_log_cadence() {
        let text = r#"
[model]
preset = "nano"

[train]
steps = 12
eval_every = 6
eval_batches = 2
log_every = 3
"#;
        let cfg = Config::parse(text).unwrap();
        let tc = TrainConfig::from_config(&cfg);
        assert_eq!(tc.eval_every, 6);
        assert_eq!(tc.eval_batches, 2);
        assert_eq!(tc.log_every, 3);
        // Absent keys keep the preset defaults.
        let plain = Config::parse("[model]\npreset = \"nano\"\n[train]\nsteps = 40\n").unwrap();
        let td = TrainConfig::from_config(&plain);
        let want = TrainConfig::preset("nano", "subtrack++", 40);
        assert_eq!(td.eval_every, want.eval_every);
        assert_eq!(td.eval_batches, want.eval_batches);
        assert_eq!(td.log_every, want.log_every);
    }

    #[test]
    fn config_file_roundtrips_fault_and_checkpoint_keys() {
        let text = r#"
[model]
preset = "nano"

[train]
steps = 8

[train.fault]
policy = "rollback"
snapshot_every = 4
spike_window = 8
spike_factor = 5.0
inject = "nan_grad@3"

[train.checkpoint]
dir = "/tmp/subtrack_cfg_ckpt"
every = 4
keep = 2
"#;
        let cfg = Config::parse(text).unwrap();
        let tc = TrainConfig::from_config(&cfg);
        assert_eq!(tc.sentinel.policy, FaultPolicy::Rollback);
        assert_eq!(tc.sentinel.snapshot_every, 4);
        assert_eq!(tc.sentinel.spike_window, 8);
        assert_eq!(tc.sentinel.spike_factor, 5.0);
        // The env knob outranks the config key; only assert the config
        // value when no CI fault leg is active.
        if std::env::var("PALLAS_FAULT").is_err() {
            assert_eq!(
                tc.fault,
                Some(FaultSchedule {
                    faults: vec![FaultInjection { kind: FaultKind::NanGrad, step: 3 }]
                })
            );
        }
        assert_eq!(tc.checkpoint_dir, "/tmp/subtrack_cfg_ckpt");
        assert_eq!(tc.checkpoint_every, 4);
        assert_eq!(tc.checkpoint_keep, 2);
        // Absent sections keep the inert defaults: preset runs are
        // byte-for-byte the pre-sentinel trainer.
        let plain = Config::parse("[model]\npreset = \"nano\"\n").unwrap();
        let td = TrainConfig::from_config(&plain);
        assert_eq!(td.sentinel.policy, FaultPolicy::Off);
        assert!(td.checkpoint_dir.is_empty());
        assert_eq!(td.checkpoint_every, 0);
    }

    #[test]
    fn config_file_roundtrips_escalation_and_watchdog_keys() {
        let text = r#"
[model]
preset = "nano"

[train]
steps = 8

[train.fault]
policy = "escalate"
escalate_after = 1
loop_restores = 2
rewarm_steps = 6
inject = "nan_grad@3,worker_hang@5"

[train.watchdog]
deadline_ms = 250
"#;
        let cfg = Config::parse(text).unwrap();
        let tc = TrainConfig::from_config(&cfg);
        assert_eq!(tc.sentinel.policy, FaultPolicy::Escalate);
        assert_eq!(tc.sentinel.escalate_after, 1);
        assert_eq!(tc.sentinel.loop_restores, 2);
        assert_eq!(tc.sentinel.rewarm_steps, 6);
        assert_eq!(tc.watchdog_deadline_ms, 250);
        if std::env::var("PALLAS_FAULT").is_err() {
            let s = tc.fault.expect("schedule parsed");
            assert_eq!(s.faults.len(), 2);
            assert_eq!(s.faults[1], FaultInjection { kind: FaultKind::WorkerHang, step: 5 });
        }
        // Absent keys keep the inert defaults (watchdog off).
        let plain = Config::parse("[model]\npreset = \"nano\"\n").unwrap();
        let td = TrainConfig::from_config(&plain);
        assert_eq!(td.watchdog_deadline_ms, 0);
        assert_eq!(td.sentinel.escalate_after, SentinelConfig::default().escalate_after);
    }

    #[test]
    fn escalating_sentinel_skips_then_rolls_back_under_repeated_faults() {
        let mut cfg = quick_cfg("full-rank");
        cfg.steps = 16;
        cfg.sentinel.policy = FaultPolicy::Escalate;
        cfg.sentinel.escalate_after = 2;
        cfg.sentinel.snapshot_every = 2;
        cfg.fault = Some(FaultSchedule::parse("nan_grad@5,nan_grad@6,nan_grad@7").unwrap());
        let report = Trainer::new(cfg).run().unwrap();
        // Three consecutive anomalies: two tolerated as skips, the third
        // escalates to a rollback; training then completes healthily.
        assert_eq!(report.sentinel_skips, 2);
        assert_eq!(report.sentinel_rollbacks, 1);
        assert_eq!(report.total_steps, 16);
        assert!(report.final_eval_loss.is_finite());
    }

    #[test]
    fn dp_degraded_step_leaves_the_trajectory_unchanged() {
        let mut cfg = quick_cfg("full-rank");
        cfg.steps = 8;
        cfg.workers = 2;
        cfg.model.dtype = Dtype::F32;
        let clean = Trainer::new(cfg.clone()).run().unwrap();
        // worker_panic under DP also kills shard 0 mid-step; degraded mode
        // must absorb it bit-for-bit.
        cfg.fault = Some(FaultSchedule::parse("worker_panic@3").unwrap());
        let degraded = Trainer::new(cfg).run().unwrap();
        assert_eq!(degraded.degraded_steps, 1);
        assert_eq!(clean.degraded_steps, 0);
        let l_clean: Vec<f32> = clean.steps.iter().map(|s| s.loss).collect();
        let l_deg: Vec<f32> = degraded.steps.iter().map(|s| s.loss).collect();
        assert_eq!(l_clean, l_deg, "degraded step changed the loss stream");
        assert_eq!(clean.final_eval_loss, degraded.final_eval_loss);
        // Clean summaries omit the key; degraded ones carry the count.
        assert!(clean.summary_json().get("degraded_steps").is_none());
        assert_eq!(
            degraded.summary_json().get("degraded_steps").and_then(|v| v.as_f64()),
            Some(1.0)
        );
    }

    #[test]
    fn report_total_steps_is_true_step_count_under_sparse_logging() {
        let mut cfg = quick_cfg("full-rank");
        cfg.steps = 10;
        cfg.log_every = 3;
        let report = Trainer::new(cfg).run().unwrap();
        // Logged curve: steps 0, 3, 6, 9 — but the checkpointed step count
        // must be the number of steps actually run.
        assert_eq!(report.steps.len(), 4);
        assert_eq!(report.total_steps, 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let r1 = Trainer::new(quick_cfg("subtrack++")).run().unwrap();
        let r2 = Trainer::new(quick_cfg("subtrack++")).run().unwrap();
        assert_eq!(r1.final_eval_loss, r2.final_eval_loss);
        let losses1: Vec<f32> = r1.steps.iter().map(|s| s.loss).collect();
        let losses2: Vec<f32> = r2.steps.iter().map(|s| s.loss).collect();
        assert_eq!(losses1, losses2);
    }

    #[test]
    fn config_file_roundtrips_accum_steps() {
        let text = "[model]\npreset = \"nano\"\n\n[train]\nsteps = 8\naccum_steps = 2\n";
        let tc = TrainConfig::from_config(&Config::parse(text).unwrap());
        assert_eq!(tc.accum_steps, 2);
        // Absent key keeps the inert default; 0 clamps to 1 (it divides the
        // per-step loss and drives a loop bound).
        let plain = Config::parse("[model]\npreset = \"nano\"\n").unwrap();
        assert_eq!(TrainConfig::from_config(&plain).accum_steps, 1);
        let zero = Config::parse("[train]\naccum_steps = 0\n").unwrap();
        assert_eq!(TrainConfig::from_config(&zero).accum_steps, 1);
    }

    #[test]
    fn grad_accumulation_matches_large_batch() {
        // Two b=4 micro-batches consume the same sampler draws as one b=8
        // batch, so both runs see the same sequences; equal-weight averaging
        // then reproduces the full-batch gradient up to fp reassociation.
        let mut big = quick_cfg("full-rank");
        big.steps = 8;
        big.batch_size = 8;
        // Pin f32: the tight tolerances below compare fp-reassociated sums,
        // and 16-bit weight rounding (CI's PALLAS_DTYPE leg) would swamp
        // them without invalidating the equivalence being tested.
        big.model.dtype = Dtype::F32;
        let mut acc = big.clone();
        acc.batch_size = 4;
        acc.accum_steps = 2;
        let mut t_big = Trainer::new(big);
        let r_big = t_big.run().unwrap();
        let mut t_acc = Trainer::new(acc);
        let r_acc = t_acc.run().unwrap();
        // Metrics count optimizer steps, not micro-batches.
        assert_eq!(r_acc.total_steps, 8);
        assert_eq!(r_acc.steps.len(), r_big.steps.len());
        for (x, y) in r_big.steps.iter().zip(&r_acc.steps) {
            assert!(
                (x.loss - y.loss).abs() < 1e-4 * x.loss.abs().max(1.0),
                "step {} loss diverged: {} vs {}",
                x.step,
                x.loss,
                y.loss
            );
        }
        for (p, q) in t_big.model.params.iter().zip(&t_acc.model.params) {
            crate::util::proptest::close(p.value.data(), q.value.data(), 1e-5, 1e-4).unwrap();
        }
    }

    #[test]
    fn resume_replays_the_uninterrupted_run_bit_for_bit() {
        // The regression this PR fixes: resume used to reload parameters but
        // drop optimizer state and sampler position, so a resumed run
        // diverged from the uninterrupted one. Kill-and-resume must now be
        // invisible in the loss stream.
        for method in ["full-rank", "subtrack++"] {
            let dir = std::env::temp_dir()
                .join(format!("subtrack_resume_{method}_{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            let mut cfg = quick_cfg(method);
            cfg.steps = 20;
            cfg.hp.interval = 4; // subspace refreshes on both sides of the cut
            cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
            cfg.checkpoint_every = 5;
            cfg.checkpoint_keep = 0; // keep all
            // Uninterrupted run; leaves checkpoints at steps 5/10/15/20
            // (saving is read-only with respect to the trajectory).
            let clean = Trainer::new(cfg.clone()).run().unwrap();
            // Simulate a crash after step 10: delete the later checkpoints,
            // then re-run the same config — it must resume from step 10.
            for late in [15, 20] {
                let base = checkpoint::rotation_path(&dir, late);
                std::fs::remove_file(base.with_extension("json")).unwrap();
                std::fs::remove_file(base.with_extension("bin")).unwrap();
            }
            let resumed = Trainer::new(cfg).run().unwrap();
            let tail: Vec<(usize, f32)> =
                clean.steps.iter().skip(10).map(|s| (s.step, s.loss)).collect();
            let replay: Vec<(usize, f32)> =
                resumed.steps.iter().map(|s| (s.step, s.loss)).collect();
            assert_eq!(replay, tail, "{method}: resumed tail diverged");
            assert_eq!(
                resumed.final_eval_loss, clean.final_eval_loss,
                "{method}: final eval diverged"
            );
            assert!(
                resumed.wall_time_secs >= clean.wall_time_secs * 0.5,
                "{method}: resumed wall-time must include the pre-crash portion"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn config_file_roundtrips_dtype() {
        let text = "[model]\npreset = \"nano\"\ndtype = \"bf16\"\n[train]\nsteps = 4\n";
        let tc = TrainConfig::from_config(&Config::parse(text).unwrap());
        // The env knob outranks the config key; only assert config-derived
        // values when no CI mixed-precision leg is active.
        if std::env::var("PALLAS_DTYPE").is_err() {
            assert_eq!(tc.model.dtype, Dtype::Bf16);
            // Absent key keeps exact f32 (the byte-identity default).
            let plain = Config::parse("[model]\npreset = \"nano\"\n").unwrap();
            assert_eq!(TrainConfig::from_config(&plain).model.dtype, Dtype::F32);
        }
    }

    #[test]
    fn bf16_training_reduces_loss_and_stays_on_grid() {
        let mut cfg = quick_cfg("subtrack++");
        cfg.model.dtype = Dtype::Bf16;
        let mut tr = Trainer::new(cfg);
        let before = tr.eval_loss().unwrap();
        let report = tr.run().unwrap();
        assert!(
            report.final_eval_loss < before,
            "bf16 eval loss should drop: {before} -> {}",
            report.final_eval_loss
        );
        assert_eq!(report.storage_dtype, "bf16");
        // Every weight the run ends with sits on the bf16 grid — the
        // master-weight write-back quantizes exactly once per step.
        for p in &tr.model.params {
            for &v in p.value.data() {
                assert_eq!(v, Dtype::Bf16.quantize(v), "{}: off-grid {v}", p.name);
            }
        }
    }

    #[test]
    fn f16_training_runs_with_the_loss_scaler() {
        let mut cfg = quick_cfg("full-rank");
        cfg.steps = 15;
        cfg.model.dtype = Dtype::F16;
        let mut tr = Trainer::new(cfg);
        let report = tr.run().unwrap();
        assert!(report.final_eval_loss.is_finite());
        assert_eq!(report.storage_dtype, "f16");
        // Healthy nano-scale gradients fit f16 at the initial scale: the
        // scaler should not be dropping steps.
        assert_eq!(report.scaler_skips, 0);
        assert_eq!(report.steps.len(), 15, "every step taken");
    }

    #[test]
    fn eval_survives_tiny_corpus() {
        // shifted_eval_batch used to underflow (and panic) when the corpus
        // could not supply the widened deterministic eval batch.
        let mut cfg = quick_cfg("full-rank");
        cfg.corpus_len = 60;
        cfg.eval_batches = 3;
        let mut tr = Trainer::new(cfg);
        let loss = tr.eval_loss().unwrap();
        assert!(loss.is_finite());
    }

    #[test]
    fn data_parallel_matches_single_worker() {
        let mut cfg = quick_cfg("full-rank");
        cfg.steps = 8;
        cfg.batch_size = 4;
        // Pin f32 (see grad_accumulation_matches_large_batch): storage
        // rounding amplifies the DP reduction-order noise this test bounds.
        cfg.model.dtype = Dtype::F32;
        let single = Trainer::new(cfg.clone()).run().unwrap();
        let mut cfg2 = cfg;
        cfg2.workers = 2;
        let multi = Trainer::new(cfg2).run().unwrap();
        // Same seed, same batches; gradient averaging over shards must give
        // (numerically) the same trajectory.
        let rel = (single.final_eval_loss - multi.final_eval_loss).abs()
            / single.final_eval_loss.max(1e-6);
        assert!(
            rel < 1e-3,
            "DP divergence: {} vs {}",
            single.final_eval_loss,
            multi.final_eval_loss
        );
    }
}
