//! The training coordinator: configuration, LR schedules, the trainer loop
//! (with native and PJRT engines), metrics, checkpointing and the
//! data-parallel worker simulation.

pub mod checkpoint;
pub mod metrics;
pub mod parallel;
pub mod schedule;
pub mod trainer;

pub use metrics::{MetricsLog, TrainReport};
pub use schedule::LrSchedule;
pub use trainer::{Trainer, TrainConfig};
