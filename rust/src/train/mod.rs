//! The training coordinator: configuration, LR schedules, the trainer loop
//! (with native and PJRT engines), metrics, checkpointing, fault injection,
//! the numerical-health sentinel and the data-parallel worker simulation.

pub mod checkpoint;
pub mod faults;
pub mod metrics;
pub mod parallel;
pub mod scaler;
pub mod schedule;
pub mod sentinel;
pub mod trainer;

pub use faults::{FaultInjection, FaultKind, FaultSchedule};
pub use metrics::{MetricsLog, TrainReport};
pub use scaler::DynamicLossScaler;
pub use schedule::LrSchedule;
pub use sentinel::{FaultPolicy, Sentinel, SentinelConfig, Verdict};
pub use trainer::{Trainer, TrainConfig};
