//! Minimal property-based testing helper (proptest is unavailable offline).
//!
//! `check` runs a property over many randomly generated cases; on failure it
//! performs greedy shrinking of the integer parameters and reports the
//! minimal failing case with its seed so the failure is reproducible.

use super::rng::Rng;

/// Run `prop` over `cases` random inputs produced by `gen`.
///
/// On failure, panics with the seed and case index; re-running with the same
/// seed reproduces the exact failure.
pub fn check<T: std::fmt::Debug, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Generate a random matrix shape within bounds, biased toward edge cases
/// (1-sized dims, squares, the exact bounds).
pub fn shape(rng: &mut Rng, max_m: usize, max_n: usize) -> (usize, usize) {
    let pick = |rng: &mut Rng, max: usize| -> usize {
        match rng.below(6) {
            0 => 1,
            1 => max,
            2 => 2,
            _ => 1 + rng.below(max),
        }
    };
    let m = pick(rng, max_m);
    let n = match rng.below(4) {
        0 => m.min(max_n), // square-ish
        _ => pick(rng, max_n),
    };
    (m, n)
}

/// Assert two slices are element-wise close; returns Err with the worst
/// offender for use inside properties.
pub fn close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    let mut worst = (0usize, 0.0f32);
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        let diff = (x - y).abs();
        if diff > tol && diff > worst.1 {
            worst = (i, diff);
        }
    }
    if worst.1 > 0.0 {
        Err(format!(
            "mismatch at index {}: {} vs {} (|diff|={})",
            worst.0, a[worst.0], b[worst.0], worst.1
        ))
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, 100, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(2, 100, |r| r.below(10), |&x| {
            if x < 5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn shapes_in_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let (m, n) = shape(&mut r, 17, 23);
            assert!(m >= 1 && m <= 17);
            assert!(n >= 1 && n <= 23);
        }
    }

    #[test]
    fn close_detects_mismatch() {
        assert!(close(&[1.0, 2.0], &[1.0, 2.5], 1e-3, 1e-3).is_err());
        assert!(close(&[1.0, 2.0], &[1.0, 2.0 + 1e-6], 1e-3, 1e-3).is_ok());
        assert!(close(&[1.0], &[1.0, 2.0], 1e-3, 1e-3).is_err());
    }
}
