//! TOML-subset configuration parser (the `toml` crate is unavailable offline).
//!
//! Supports the subset used by `configs/*.toml`: `[section]` and
//! `[section.sub]` headers, `key = value` with string/int/float/bool/array
//! values, `#` comments. Keys are flattened to `section.sub.key` dotted paths.

use std::collections::BTreeMap;
use std::path::Path;

/// A parsed config: flattened dotted-path -> value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

/// A configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    fn parse(raw: &str) -> Result<Value, String> {
        let raw = raw.trim();
        if raw.is_empty() {
            return Err("empty value".into());
        }
        if let Some(inner) = raw.strip_prefix('"') {
            let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
            return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
        }
        if let Some(inner) = raw.strip_prefix('[') {
            let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
            let mut items = Vec::new();
            for part in split_top_level(inner) {
                let part = part.trim();
                if !part.is_empty() {
                    items.push(Value::parse(part)?);
                }
            }
            return Ok(Value::Arr(items));
        }
        match raw {
            "true" => return Ok(Value::Bool(true)),
            "false" => return Ok(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = raw.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = raw.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        Err(format!("cannot parse value: {raw}"))
    }
}

/// Split on commas that are not inside quotes or nested brackets.
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => parts.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw_line) in text.lines().enumerate() {
            let line = strip_comment(raw_line).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let inner = inner
                    .strip_suffix(']')
                    .ok_or(format!("line {}: bad section header", lineno + 1))?;
                section = inner.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or(format!("line {}: expected key = value", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let value =
                Value::parse(val).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            cfg.values.insert(full_key, value);
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Config, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("{}: {e}", path.as_ref().display()))?;
        Config::parse(&text)
    }

    /// Insert/override a value from a `key=value` string (CLI overrides).
    pub fn set_override(&mut self, key: &str, raw: &str) -> Result<(), String> {
        // Try typed parse first; fall back to bare string.
        let v = Value::parse(raw).unwrap_or_else(|_| Value::Str(raw.to_string()));
        self.values.insert(key.to_string(), v);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        match self.values.get(key) {
            Some(Value::Str(s)) => s.clone(),
            Some(v) => format!("{v:?}"),
            None => default.to_string(),
        }
    }

    pub fn int(&self, key: &str, default: i64) -> i64 {
        match self.values.get(key) {
            Some(Value::Int(i)) => *i,
            Some(Value::Float(f)) => *f as i64,
            _ => default,
        }
    }

    pub fn float(&self, key: &str, default: f64) -> f64 {
        match self.values.get(key) {
            Some(Value::Float(f)) => *f,
            Some(Value::Int(i)) => *i as f64,
            _ => default,
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.values.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    /// All keys under a dotted prefix.
    pub fn keys_under(&self, prefix: &str) -> Vec<String> {
        let pfx = format!("{prefix}.");
        self.values.keys().filter(|k| k.starts_with(&pfx)).cloned().collect()
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
name = "subtrack"     # trailing comment
seed = 42

[model]
hidden = 256
layers = 4
rope_theta = 10000.0

[optim.subtrack]
rank = 16
eta = 10.0
components = ["pa", "rs"]
enabled = true
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name", ""), "subtrack");
        assert_eq!(c.int("seed", 0), 42);
        assert_eq!(c.int("model.hidden", 0), 256);
        assert_eq!(c.float("model.rope_theta", 0.0), 10000.0);
        assert_eq!(c.float("optim.subtrack.eta", 0.0), 10.0);
        assert!(c.bool("optim.subtrack.enabled", false));
        match c.get("optim.subtrack.components").unwrap() {
            Value::Arr(xs) => {
                assert_eq!(xs.len(), 2);
                assert_eq!(xs[0], Value::Str("pa".into()));
            }
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn defaults_on_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int("nope", 7), 7);
        assert_eq!(c.str("nope", "d"), "d");
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set_override("model.hidden", "512").unwrap();
        assert_eq!(c.int("model.hidden", 0), 512);
    }

    #[test]
    fn comment_inside_string_preserved() {
        let c = Config::parse(r##"x = "a#b""##).unwrap();
        assert_eq!(c.str("x", ""), "a#b");
    }

    #[test]
    fn keys_under_prefix() {
        let c = Config::parse(SAMPLE).unwrap();
        let ks = c.keys_under("model");
        assert!(ks.contains(&"model.hidden".to_string()));
        assert!(!ks.contains(&"seed".to_string()));
    }

    #[test]
    fn bad_lines_error() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("key_no_value").is_err());
    }
}
