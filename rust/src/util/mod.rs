//! Small self-contained substrates: deterministic RNG, CLI argument parsing,
//! TOML-subset config parsing, JSON/CSV emission and a property-testing helper.
//!
//! The offline build environment provides no `rand`, `clap`, `serde`, `toml`,
//! `criterion` or `proptest`; these modules replace them with minimal,
//! well-tested implementations so the rest of the crate has zero external
//! runtime dependencies beyond the `xla` PJRT bridge.

pub mod cli;
pub mod config;
pub mod csv;
pub mod json;
pub mod proptest;
pub mod rng;

pub use rng::Rng;

/// Format a byte count as a human-readable string (KiB/MiB/GiB).
pub fn human_bytes(bytes: usize) -> String {
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.2} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.2} KiB", b / KIB)
    } else {
        format!("{bytes} B")
    }
}

/// Format a duration in seconds with adaptive precision.
pub fn human_secs(secs: f64) -> String {
    if secs >= 60.0 {
        format!("{:.1} min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} µs", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(human_bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(120.0), "2.0 min");
        assert_eq!(human_secs(1.5), "1.50 s");
        assert_eq!(human_secs(0.002), "2.00 ms");
        assert_eq!(human_secs(0.0000025), "2.50 µs");
    }
}
