//! CSV writer for experiment results.
//!
//! Every bench harness writes its series to `results/<id>.csv` through this
//! writer so figures/tables can be regenerated from the raw data.

use std::fs;
use std::io::Write as _;
use std::path::Path;

/// An append-style CSV writer with a fixed header.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    /// Create a writer with the given column names.
    pub fn new(columns: &[&str]) -> Self {
        CsvWriter { header: columns.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row of raw string cells. Panics if the arity mismatches.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "csv arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Append a row of display-able cells.
    pub fn rowv<T: std::fmt::Display>(&mut self, cells: &[T]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    /// Number of data rows so far.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Serialize to CSV text (quotes cells containing separators).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let emit = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if c.contains(',') || c.contains('"') || c.contains('\n') {
                    out.push('"');
                    out.push_str(&c.replace('"', "\"\""));
                    out.push('"');
                } else {
                    out.push_str(c);
                }
            }
            out.push('\n');
        };
        emit(&mut out, &self.header);
        for r in &self.rows {
            emit(&mut out, r);
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }
}

/// Parse a simple CSV (no embedded newlines) back into header + rows.
pub fn parse_simple(text: &str) -> (Vec<String>, Vec<Vec<String>>) {
    let mut lines = text.lines();
    let header = lines
        .next()
        .map(|l| split_line(l))
        .unwrap_or_default();
    let rows = lines.filter(|l| !l.is_empty()).map(split_line).collect();
    (header, rows)
}

fn split_line(line: &str) -> Vec<String> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                cells.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    cells.push(cur);
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_roundtrip() {
        let mut w = CsvWriter::new(&["step", "loss"]);
        w.rowv(&[1.0, 3.5]);
        w.rowv(&[2.0, 3.25]);
        let s = w.to_string();
        let (h, rows) = parse_simple(&s);
        assert_eq!(h, vec!["step", "loss"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1][1], "3.25");
    }

    #[test]
    fn quoting() {
        let mut w = CsvWriter::new(&["name"]);
        w.row(&["a,b \"c\"".to_string()]);
        let s = w.to_string();
        let (_, rows) = parse_simple(&s);
        assert_eq!(rows[0][0], "a,b \"c\"");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.rowv(&[1.0]);
    }
}
