//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via SplitMix64 — the same construction used by the
//! reference implementations of Blackman & Vigna. Deterministic across
//! platforms, which we rely on for reproducible experiments (every table in
//! EXPERIMENTS.md records its seed).

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (bias < 2^-53 for n << 2^53)
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal deviate via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal deviate with given mean and standard deviation (f32).
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(0, std) values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent generator (for per-worker streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Serialize the full generator state as 6 words: the xoshiro256**
    /// state, a presence flag for the cached Box-Muller deviate, and its
    /// bit pattern. Backs checkpoint serialization of optimizer RNG streams.
    pub fn state_words(&self) -> [u64; 6] {
        [
            self.s[0],
            self.s[1],
            self.s[2],
            self.s[3],
            self.spare_normal.is_some() as u64,
            self.spare_normal.unwrap_or(0.0).to_bits(),
        ]
    }

    /// Rebuild a generator from [`Rng::state_words`] output, bit-exactly.
    pub fn from_state_words(w: [u64; 6]) -> Rng {
        Rng {
            s: [w[0], w[1], w[2], w[3]],
            spare_normal: (w[4] != 0).then(|| f64::from_bits(w[5])),
        }
    }

    /// Sample an index from unnormalized weights (categorical distribution).
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn state_words_roundtrip_bitexact() {
        let mut a = Rng::new(77);
        let _ = a.normal(); // populate the spare deviate
        let mut b = Rng::from_state_words(a.state_words());
        for _ in 0..32 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut base = Rng::new(100);
        let mut a = base.split();
        let mut b = base.split();
        // Streams should not be identical.
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
