//! Command-line argument parsing (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors, defaults and a generated `--help` text. Used by the
//! `subtrack` launcher binary, the examples and every bench harness.

use std::collections::BTreeMap;

/// A declared option (for help text + validation).
#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative CLI parser.
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            values: BTreeMap::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare a `--key value` option with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(|s| s.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Parse from `std::env::args()` (skipping argv[0]). Prints help and exits
    /// on `--help`.
    pub fn parse_env(self) -> Parsed {
        let args: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&args) {
            Ok(p) => p,
            Err(HelpOrError::Help(h)) => {
                println!("{h}");
                std::process::exit(0);
            }
            Err(HelpOrError::Error(e)) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    }

    /// Parse from an explicit argument list.
    pub fn parse(mut self, args: &[String]) -> Result<Parsed, HelpOrError> {
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(HelpOrError::Help(self.help_text()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let decl = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .cloned()
                    .ok_or_else(|| HelpOrError::Error(format!("unknown option --{key}")))?;
                let val = if decl.is_flag {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    args.get(i)
                        .cloned()
                        .ok_or_else(|| HelpOrError::Error(format!("--{key} needs a value")))?
                };
                self.values.insert(key, val);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        // Apply defaults.
        for o in &self.opts {
            if let Some(d) = &o.default {
                self.values.entry(o.name.clone()).or_insert_with(|| d.clone());
            }
        }
        Ok(Parsed { values: self.values, positionals: self.positionals })
    }

    fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{kind}\n      {}{def}\n", o.name, o.help));
        }
        s
    }
}

/// Help-requested vs. parse-error outcomes.
pub enum HelpOrError {
    Help(String),
    Error(String),
}

/// The parsed arguments.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str) -> String {
        self.values
            .get(key)
            .unwrap_or_else(|| panic!("missing required option --{key}"))
            .clone()
    }

    pub fn usize(&self, key: &str) -> usize {
        self.str(key).parse().unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }

    pub fn u64(&self, key: &str) -> u64 {
        self.str(key).parse().unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }

    pub fn f32(&self, key: &str) -> f32 {
        self.str(key).parse().unwrap_or_else(|_| panic!("--{key} must be a number"))
    }

    pub fn f64(&self, key: &str) -> f64 {
        self.str(key).parse().unwrap_or_else(|_| panic!("--{key} must be a number"))
    }

    pub fn bool(&self, key: &str) -> bool {
        self.values.get(key).map(|v| v == "true" || v == "1").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_kinds() {
        let p = Cli::new("t", "test")
            .opt("steps", Some("100"), "number of steps")
            .opt("lr", None, "learning rate")
            .flag("verbose", "extra logging")
            .parse(&args(&["--steps", "250", "--lr=0.01", "--verbose", "pos1"]))
            .ok()
            .unwrap();
        assert_eq!(p.usize("steps"), 250);
        assert_eq!(p.f32("lr"), 0.01);
        assert!(p.bool("verbose"));
        assert_eq!(p.positionals, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let p = Cli::new("t", "test")
            .opt("steps", Some("100"), "steps")
            .parse(&args(&[]))
            .ok()
            .unwrap();
        assert_eq!(p.usize("steps"), 100);
    }

    #[test]
    fn unknown_option_rejected() {
        let r = Cli::new("t", "test").parse(&args(&["--nope"]));
        assert!(matches!(r, Err(HelpOrError::Error(_))));
    }

    #[test]
    fn help_requested() {
        let r = Cli::new("t", "about me").opt("x", None, "an x").parse(&args(&["--help"]));
        match r {
            Err(HelpOrError::Help(h)) => {
                assert!(h.contains("about me"));
                assert!(h.contains("--x"));
            }
            _ => panic!("expected help"),
        }
    }

    #[test]
    fn missing_value_rejected() {
        let r = Cli::new("t", "test").opt("lr", None, "lr").parse(&args(&["--lr"]));
        assert!(matches!(r, Err(HelpOrError::Error(_))));
    }
}
