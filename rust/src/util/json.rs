//! Minimal JSON value model with an emitter and a recursive-descent parser.
//!
//! Used for metrics logs, checkpoint manifests and experiment result files.
//! Replaces `serde_json`, which is unavailable in the offline build
//! environment. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (sufficient for our ASCII artifacts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn nums<T: Into<f64> + Copy>(xs: &[T]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x.into())).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize to a compact JSON string.
    #[allow(clippy::inherent_to_string)] // adding Display would shadow-trap future `{}` formatting
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

/// Read-modify-write helper for cross-run JSON ledgers (e.g.
/// `BENCH_gemm.json`): parse the file at `path` (treating a missing or
/// corrupt file as `{}`), set `key` → `value` at the top level, write back.
pub fn merge_into_file(path: &str, key: &str, value: Json) -> std::io::Result<()> {
    let mut root = read_root_object(path);
    if let Json::Obj(map) = &mut root {
        map.insert(key.to_string(), value);
    }
    std::fs::write(path, root.to_string())
}

/// Like [`merge_into_file`], but `value` (an object) is merged entry-by-entry
/// into the existing object under `key` instead of replacing it — so e.g.
/// per-preset profile records accumulate across runs.
pub fn merge_section_into_file(path: &str, key: &str, value: Json) -> std::io::Result<()> {
    let mut root = read_root_object(path);
    if let Json::Obj(map) = &mut root {
        let mut section = match map.remove(key) {
            Some(Json::Obj(m)) => m,
            _ => BTreeMap::new(),
        };
        match value {
            Json::Obj(new) => section.extend(new),
            other => {
                map.insert(key.to_string(), other);
                return std::fs::write(path, root.to_string());
            }
        }
        map.insert(key.to_string(), Json::Obj(section));
    }
    std::fs::write(path, root.to_string())
}

/// The file's top-level object, or `{}` when missing/corrupt/non-object.
fn read_root_object(path: &str) -> Json {
    let root = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .unwrap_or_else(|| Json::Obj(BTreeMap::new()));
    if matches!(root, Json::Obj(_)) {
        root
    } else {
        Json::Obj(BTreeMap::new())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).ok_or("bad codepoint")?);
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf8")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| e.to_string())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let j = Json::obj(vec![
            ("name", Json::Str("subtrack".into())),
            ("rank", Json::Num(512.0)),
            ("losses", Json::nums(&[1.5f64, 2.0, 3.25])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x\ny"}, null], "c": -2.5e3}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_f64(), Some(-2500.0));
        match j.get("a").unwrap() {
            Json::Arr(xs) => {
                assert_eq!(xs[0].as_f64(), Some(1.0));
                assert_eq!(xs[1].get("b").unwrap().as_str(), Some("x\ny"));
                assert_eq!(xs[2], Json::Null);
            }
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("quote \" slash \\ tab \t nl \n".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn integers_stay_integral() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn merge_helpers_accumulate_a_ledger() {
        let dir = std::env::temp_dir().join(format!("subtrack_json_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ledger.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        // Missing file behaves as {}.
        merge_into_file(path, "gemm", Json::obj(vec![("gflops", Json::Num(3.0))])).unwrap();
        // Replacing one key preserves the other.
        merge_section_into_file(path, "profile", Json::obj(vec![("small", Json::Num(1.0))]))
            .unwrap();
        merge_section_into_file(path, "profile", Json::obj(vec![("med", Json::Num(2.0))]))
            .unwrap();
        let root = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(
            root.get("gemm").and_then(|g| g.get("gflops")).and_then(Json::as_f64),
            Some(3.0)
        );
        // Section entries accumulated instead of replacing each other.
        assert_eq!(
            root.get("profile").and_then(|p| p.get("small")).and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(
            root.get("profile").and_then(|p| p.get("med")).and_then(Json::as_f64),
            Some(2.0)
        );
        let _ = std::fs::remove_file(path);
    }
}
