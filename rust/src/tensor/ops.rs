//! Miscellaneous tensor operations used by the model and optimizers:
//! numerically-stable softmax (full-row and fused causal-prefix modes),
//! row-wise reductions, pool-parallel gradient clipping.

use super::gemm;
use super::matrix::Matrix;
use super::pool::{self, SendPtr};
use std::borrow::{Borrow, BorrowMut};

/// The shared softmax core: numerically-stable softmax over one row
/// *segment*, applying `scale` to the raw values first (fused, so the
/// caller needs no separate `scale_mut` pass).
#[inline]
fn softmax_segment(row: &mut [f32], scale: f32) {
    let mut max = f32::NEG_INFINITY;
    for v in row.iter_mut() {
        *v *= scale;
        if *v > max {
            max = *v;
        }
    }
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Row-wise numerically-stable softmax, in place — the fused kernel's
/// full-row mode (every column of every row is live).
pub fn softmax_rows(m: &mut Matrix) {
    for i in 0..m.rows() {
        softmax_segment(m.row_mut(i), 1.0);
    }
}

/// Fused causal masked softmax over a T×T score matrix, in place: row `i` is
/// soft-maxed over its live prefix `j ≤ i` only, with `scale` applied to the
/// raw scores first. Replaces the three-pass `scale_mut` → mask-to-−∞ →
/// full-row softmax pipeline with one pass that touches half the matrix.
///
/// Contract: the strict upper triangle (`j > i`) is **never read or
/// written** — it may hold stale garbage from a dirty workspace lease, and
/// it still will afterwards. Downstream consumers must be prefix-aware
/// (see `gemm::attn_apply_into` / [`causal_softmax_grad`]).
pub fn causal_softmax_rows(m: &mut Matrix, scale: f32) {
    let t = m.rows();
    debug_assert_eq!(m.cols(), t, "causal softmax needs a square score matrix");
    for i in 0..t {
        softmax_segment(&mut m.row_mut(i)[..=i], scale);
    }
}

/// Fused backward of [`causal_softmax_rows`], in place in `dp`:
/// `dS = scale · P ⊙ (dP − rowdot(dP, P))` over each row's live prefix,
/// where the row dot also runs over the prefix only. Like the forward
/// kernel, the strict upper triangle of `p` and `dp` is never read or
/// written.
pub fn causal_softmax_grad(p: &Matrix, dp: &mut Matrix, scale: f32) {
    let t = p.rows();
    debug_assert_eq!(p.cols(), t, "causal softmax grad needs square P");
    debug_assert_eq!(dp.shape(), (t, t), "dP shape");
    for i in 0..t {
        let pr = &p.row(i)[..=i];
        let dr = &mut dp.row_mut(i)[..=i];
        let mut dot = 0.0f32;
        for (d, &pv) in dr.iter().zip(pr.iter()) {
            dot += *d * pv;
        }
        for (d, &pv) in dr.iter_mut().zip(pr.iter()) {
            *d = pv * (*d - dot) * scale;
        }
    }
}

/// Elements per partial in the parallel squared-norm reduction. A fixed
/// constant — deliberately *not* `gemm::chunk_units` — so the partial grid
/// (and therefore the f64 combine order and the clipped result) is
/// identical for any worker count and any `GEMM_CHUNK` setting.
const NORM_CHUNK: usize = 1 << 15;

thread_local! {
    /// Reusable partials buffer for [`sum_squares`]: the clip path runs once
    /// per training step per gradient matrix, so a per-call `Vec` would be a
    /// steady-state heap allocation — against the grain of the
    /// allocation-free step contract. Grows to the largest chunk count seen
    /// and is reused thereafter.
    static NORM_PARTIALS: std::cell::RefCell<Vec<f64>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Σx² of one buffer in f64: the buffer is cut into fixed [`NORM_CHUNK`]
/// chunks, each reduced sequentially, and the partials are combined in
/// chunk order. The chunk grid is the same whether the chunks run on the
/// pool or inline, so the result is deterministic across 1/2/8 workers —
/// and bit-identical to the sequential fallback.
fn sum_squares(data: &[f32]) -> f64 {
    let n = data.len();
    if n == 0 {
        return 0.0;
    }
    let seq = |lo: usize, hi: usize| {
        data[lo..hi].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
    };
    let n_chunks = n.div_ceil(NORM_CHUNK);
    if n_chunks == 1 {
        return seq(0, n);
    }
    let threads = gemm::plan_kernel_threads(2 * n, n_chunks);
    NORM_PARTIALS.with(|cell| {
        let mut partials = cell.borrow_mut();
        partials.clear();
        partials.resize(n_chunks, 0.0); // no realloc once warm
        if threads <= 1 {
            for (c, p) in partials.iter_mut().enumerate() {
                *p = seq(c * NORM_CHUNK, ((c + 1) * NORM_CHUNK).min(n));
            }
        } else {
            let base = SendPtr::new(partials.as_mut_ptr());
            pool::run(threads, n_chunks, &|c| {
                let lo = c * NORM_CHUNK;
                // Each task owns partial slot c — disjoint writes.
                unsafe { *base.get().add(c) = seq(lo, (lo + NORM_CHUNK).min(n)) };
            });
        }
        partials.iter().sum()
    })
}

/// Joint L2 norm of a set of gradient matrices via the pool-parallel
/// fixed-order reduction — the read-only half of [`clip_global_norm`], for
/// callers (the numerical-health sentinel) that need the norm without
/// clipping and without recomputing it.
pub fn global_norm_slice(grads: &[Matrix]) -> f32 {
    (grads.iter().map(|g| sum_squares(g.data())).sum::<f64>()).sqrt() as f32
}

/// The single clipping core behind both public entry points: joint L2 norm
/// via the pool-parallel fixed-order reduction, proportional scale-down
/// when over `max_norm`. A non-finite norm short-circuits the scaling —
/// multiplying by a NaN/inf-derived factor would turn *every* parameter's
/// gradient non-finite in one step; instead the norm is returned as-is for
/// the sentinel to act on.
fn clip_core<M: BorrowMut<Matrix>>(grads: &mut [M], max_norm: f32) -> f32 {
    let total: f64 = grads
        .iter()
        .map(|g| {
            let m: &Matrix = g.borrow();
            sum_squares(m.data())
        })
        .sum();
    let norm = total.sqrt() as f32;
    if norm.is_finite() && norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            let m: &mut Matrix = g.borrow_mut();
            m.scale_mut(scale);
        }
    }
    norm
}

/// Global gradient-norm clipping over a set of matrices: if the joint L2 norm
/// exceeds `max_norm`, scale all of them down proportionally. Returns the
/// pre-clip norm (the paper uses clipping 1.0 in every pre-training run).
pub fn clip_global_norm(grads: &mut [&mut Matrix], max_norm: f32) -> f32 {
    clip_core(grads, max_norm)
}

/// [`clip_global_norm`] over an owned gradient slice — the trainer's
/// hot-path form, avoiding the per-step `Vec<&mut Matrix>` of references.
pub fn clip_global_norm_slice(grads: &mut [Matrix], max_norm: f32) -> f32 {
    clip_core(grads, max_norm)
}

/// Mean of a slice.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64) as f32
}

/// Population variance of a slice.
pub fn variance(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs) as f64;
    (xs.iter().map(|&x| (x as f64 - m) * (x as f64 - m)).sum::<f64>() / xs.len() as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[1000.0, 1000.0, 1000.0]]);
        softmax_rows(&mut m);
        for i in 0..2 {
            let s: f32 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // Large-value row must not produce NaN (stability).
        assert!((m.get(1, 0) - 1.0 / 3.0).abs() < 1e-5);
        // Monotone in logits.
        assert!(m.get(0, 2) > m.get(0, 1) && m.get(0, 1) > m.get(0, 0));
    }

    /// Three-pass reference: scale, mask strictly-future entries to −∞,
    /// full-row softmax — the pipeline the fused kernel replaces.
    fn three_pass_reference(m: &Matrix, scale: f32) -> Matrix {
        let t = m.rows();
        let mut r = m.scale(scale);
        for i in 0..t {
            for j in (i + 1)..t {
                r.set(i, j, f32::NEG_INFINITY);
            }
        }
        softmax_rows(&mut r);
        r
    }

    #[test]
    fn causal_softmax_matches_three_pass_on_the_prefix() {
        let mut rng = crate::util::rng::Rng::new(11);
        for t in [1usize, 2, 5, 16] {
            let raw = Matrix::randn(t, t, 2.0, &mut rng);
            let want = three_pass_reference(&raw, 0.25);
            let mut got = raw.clone();
            causal_softmax_rows(&mut got, 0.25);
            for i in 0..t {
                for j in 0..=i {
                    assert!(
                        (want.get(i, j) - got.get(i, j)).abs() < 1e-6,
                        "prefix mismatch at ({i},{j}): {} vs {}",
                        want.get(i, j),
                        got.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn causal_softmax_never_touches_the_upper_triangle() {
        // Poison the strict upper triangle with NaN: the fused kernel must
        // neither read it (outputs stay finite) nor write it (NaN survives).
        let mut rng = crate::util::rng::Rng::new(12);
        let t = 9;
        let mut m = Matrix::randn(t, t, 1.0, &mut rng);
        for i in 0..t {
            for j in (i + 1)..t {
                m.set(i, j, f32::NAN);
            }
        }
        causal_softmax_rows(&mut m, 0.5);
        for i in 0..t {
            let mut sum = 0.0f32;
            for j in 0..=i {
                assert!(m.get(i, j).is_finite(), "NaN leaked into prefix ({i},{j})");
                sum += m.get(i, j);
            }
            assert!((sum - 1.0).abs() < 1e-5, "row {i} prefix sums to {sum}");
            for j in (i + 1)..t {
                assert!(m.get(i, j).is_nan(), "upper triangle ({i},{j}) was written");
            }
        }
        // The backward kernel carries the same contract.
        let p = m.clone();
        let mut dp = Matrix::randn(t, t, 1.0, &mut rng);
        for i in 0..t {
            for j in (i + 1)..t {
                dp.set(i, j, f32::NAN);
            }
        }
        causal_softmax_grad(&p, &mut dp, 0.5);
        for i in 0..t {
            for j in 0..=i {
                assert!(dp.get(i, j).is_finite(), "grad NaN leaked at ({i},{j})");
            }
            for j in (i + 1)..t {
                assert!(dp.get(i, j).is_nan(), "grad upper triangle written");
            }
        }
    }

    #[test]
    fn causal_softmax_grad_matches_dense_reference() {
        // Dense reference: dS = P ⊙ (dP − rowsum(dP⊙P)) · scale with the
        // masked entries of P exactly zero (as the three-pass pipeline
        // produced), so the full-row dot equals the prefix dot.
        let mut rng = crate::util::rng::Rng::new(13);
        let t = 7;
        let scale = 0.125f32;
        let raw = Matrix::randn(t, t, 1.0, &mut rng);
        let p = three_pass_reference(&raw, scale);
        let dp0 = Matrix::randn(t, t, 1.0, &mut rng);
        // Dense reference over full rows.
        let mut want = Matrix::zeros(t, t);
        for i in 0..t {
            let dot: f32 = dp0.row(i).iter().zip(p.row(i)).map(|(&a, &b)| a * b).sum();
            for j in 0..t {
                want.set(i, j, p.get(i, j) * (dp0.get(i, j) - dot) * scale);
            }
        }
        let mut got = dp0.clone();
        causal_softmax_grad(&p, &mut got, scale);
        for i in 0..t {
            for j in 0..=i {
                assert!(
                    (want.get(i, j) - got.get(i, j)).abs() < 1e-5,
                    "dS mismatch at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn softmax_full_row_mode_unchanged() {
        // The full-row mode (softmax_rows) must behave exactly as the
        // historical kernel: this is the "remaining non-attention callers"
        // path of the fused core.
        let mut m = Matrix::from_rows(&[&[0.5, -1.0, 2.0]]);
        let mut want = m.clone();
        // Historical implementation, inlined.
        {
            let row = want.row_mut(0);
            let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
        softmax_rows(&mut m);
        assert_eq!(m.data(), want.data());
    }

    #[test]
    fn parallel_clip_norm_bit_identical_across_worker_counts() {
        // Large enough for several NORM_CHUNK partials; the fixed chunk grid
        // makes the reduction identical for any worker count.
        let _knob = crate::tensor::gemm::TEST_KNOB_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut rng = crate::util::rng::Rng::new(21);
        let big = Matrix::randn(110, 1000, 1.0, &mut rng); // > 3 chunks
        let small = Matrix::randn(3, 5, 1.0, &mut rng);
        crate::tensor::gemm::set_gemm_threads(1);
        let mut g1 = vec![big.clone(), small.clone()];
        let n1 = clip_global_norm_slice(&mut g1, 1.0);
        for workers in [2usize, 8] {
            crate::tensor::gemm::set_gemm_threads(workers);
            let mut gw = vec![big.clone(), small.clone()];
            let nw = clip_global_norm_slice(&mut gw, 1.0);
            assert_eq!(n1, nw, "clip norm diverged at {workers} workers");
            assert_eq!(g1[0].data(), gw[0].data(), "clipped grad diverged");
            assert_eq!(g1[1].data(), gw[1].data(), "clipped grad diverged");
        }
        crate::tensor::gemm::set_gemm_threads(0);
        // Sanity: the chunked norm agrees with a plain f64 sweep to fp
        // tolerance.
        let dense: f64 = big
            .data()
            .iter()
            .chain(small.data())
            .map(|&x| (x as f64) * (x as f64))
            .sum();
        let want = dense.sqrt() as f32;
        assert!(
            (n1 - want).abs() / want < 1e-6,
            "chunked norm {n1} vs dense {want}"
        );
    }

    #[test]
    fn clip_scales_when_over() {
        let mut a = Matrix::from_rows(&[&[3.0, 0.0]]);
        let mut b = Matrix::from_rows(&[&[0.0, 4.0]]);
        let pre = clip_global_norm(&mut [&mut a, &mut b], 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        let post = ((a.fro_norm().powi(2) + b.fro_norm().powi(2))).sqrt();
        assert!((post - 1.0).abs() < 1e-5);
    }

    #[test]
    fn clip_slice_matches_ref_form() {
        let mut a1 = Matrix::from_rows(&[&[3.0, 0.0]]);
        let mut b1 = Matrix::from_rows(&[&[0.0, 4.0]]);
        let pre_ref = clip_global_norm(&mut [&mut a1, &mut b1], 1.0);
        let mut owned = vec![
            Matrix::from_rows(&[&[3.0, 0.0]]),
            Matrix::from_rows(&[&[0.0, 4.0]]),
        ];
        let pre_slice = clip_global_norm_slice(&mut owned, 1.0);
        assert_eq!(pre_ref, pre_slice);
        assert_eq!(owned[0].data(), a1.data());
        assert_eq!(owned[1].data(), b1.data());
    }

    #[test]
    fn clip_noop_when_under() {
        let mut a = Matrix::from_rows(&[&[0.3, 0.0]]);
        let pre = clip_global_norm(&mut [&mut a], 1.0);
        assert!((pre - 0.3).abs() < 1e-6);
        assert_eq!(a.get(0, 0), 0.3);
    }

    #[test]
    fn clip_short_circuits_on_nonfinite_norm() {
        // One NaN makes the global norm NaN; scaling by max_norm/NaN would
        // poison every gradient. The clip must leave them untouched and
        // report the non-finite norm for the sentinel.
        let mut a = Matrix::from_rows(&[&[3.0, f32::NAN]]);
        let mut b = Matrix::from_rows(&[&[7.0, 4.0]]);
        let pre = clip_global_norm(&mut [&mut a, &mut b], 1.0);
        assert!(pre.is_nan(), "pre-clip norm should be NaN, got {pre}");
        assert_eq!(a.get(0, 0), 3.0);
        assert_eq!(b.data(), &[7.0, 4.0]);
        // Same for an overflowing (infinite) norm.
        let mut c = Matrix::from_rows(&[&[f32::MAX, f32::MAX]]);
        let pre = clip_global_norm(&mut [&mut c], 1.0);
        assert!(pre.is_infinite(), "got {pre}");
        assert_eq!(c.get(0, 0), f32::MAX);
    }

    #[test]
    fn global_norm_matches_clip_norm() {
        let mut rng = crate::util::rng::Rng::new(33);
        let a = Matrix::randn(40, 50, 1.0, &mut rng);
        let b = Matrix::randn(3, 5, 1.0, &mut rng);
        let grads = vec![a, b];
        let read_only = global_norm_slice(&grads);
        let mut clipped = grads.clone();
        let pre = clip_global_norm_slice(&mut clipped, f32::MAX);
        assert_eq!(read_only, pre);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
    }
}
