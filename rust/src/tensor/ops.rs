//! Miscellaneous tensor operations used by the model and optimizers:
//! numerically-stable softmax, row-wise reductions, clipping.

use super::matrix::Matrix;

/// Row-wise numerically-stable softmax, in place.
pub fn softmax_rows(m: &mut Matrix) {
    let cols = m.cols();
    for i in 0..m.rows() {
        let row = m.row_mut(i);
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
        let _ = cols;
    }
}

/// Global gradient-norm clipping over a set of matrices: if the joint L2 norm
/// exceeds `max_norm`, scale all of them down proportionally. Returns the
/// pre-clip norm (the paper uses clipping 1.0 in every pre-training run).
pub fn clip_global_norm(grads: &mut [&mut Matrix], max_norm: f32) -> f32 {
    let total: f64 = grads
        .iter()
        .map(|g| g.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
        .sum();
    let norm = total.sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            g.scale_mut(scale);
        }
    }
    norm
}

/// [`clip_global_norm`] over an owned gradient slice — the trainer's
/// hot-path form, avoiding the per-step `Vec<&mut Matrix>` of references.
pub fn clip_global_norm_slice(grads: &mut [Matrix], max_norm: f32) -> f32 {
    let total: f64 = grads
        .iter()
        .map(|g| g.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
        .sum();
    let norm = total.sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            g.scale_mut(scale);
        }
    }
    norm
}

/// Mean of a slice.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64) as f32
}

/// Population variance of a slice.
pub fn variance(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs) as f64;
    (xs.iter().map(|&x| (x as f64 - m) * (x as f64 - m)).sum::<f64>() / xs.len() as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[1000.0, 1000.0, 1000.0]]);
        softmax_rows(&mut m);
        for i in 0..2 {
            let s: f32 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // Large-value row must not produce NaN (stability).
        assert!((m.get(1, 0) - 1.0 / 3.0).abs() < 1e-5);
        // Monotone in logits.
        assert!(m.get(0, 2) > m.get(0, 1) && m.get(0, 1) > m.get(0, 0));
    }

    #[test]
    fn clip_scales_when_over() {
        let mut a = Matrix::from_rows(&[&[3.0, 0.0]]);
        let mut b = Matrix::from_rows(&[&[0.0, 4.0]]);
        let pre = clip_global_norm(&mut [&mut a, &mut b], 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        let post = ((a.fro_norm().powi(2) + b.fro_norm().powi(2))).sqrt();
        assert!((post - 1.0).abs() < 1e-5);
    }

    #[test]
    fn clip_slice_matches_ref_form() {
        let mut a1 = Matrix::from_rows(&[&[3.0, 0.0]]);
        let mut b1 = Matrix::from_rows(&[&[0.0, 4.0]]);
        let pre_ref = clip_global_norm(&mut [&mut a1, &mut b1], 1.0);
        let mut owned = vec![
            Matrix::from_rows(&[&[3.0, 0.0]]),
            Matrix::from_rows(&[&[0.0, 4.0]]),
        ];
        let pre_slice = clip_global_norm_slice(&mut owned, 1.0);
        assert_eq!(pre_ref, pre_slice);
        assert_eq!(owned[0].data(), a1.data());
        assert_eq!(owned[1].data(), b1.data());
    }

    #[test]
    fn clip_noop_when_under() {
        let mut a = Matrix::from_rows(&[&[0.3, 0.0]]);
        let pre = clip_global_norm(&mut [&mut a], 1.0);
        assert!((pre - 0.3).abs() < 1e-6);
        assert_eq!(a.get(0, 0), 0.3);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
    }
}
