//! Dense f32 linear-algebra substrate, built from scratch.
//!
//! Everything the optimizer family and the native training engine need:
//! a row-major [`Matrix`], cache-blocked GEMM in all transpose variants,
//! Householder QR, one-sided Jacobi thin SVD, power iteration for top
//! singular triplets, and least squares. No external dependencies.
//!
//! The paper's subspace math operates per-gradient-matrix (m×n with rank
//! r ≪ m ≤ n), so all routines are tuned for tall-skinny / short-fat shapes
//! in the few-hundreds range.
//!
//! # Step-loop architecture: workspaces, `_into` kernels, threading
//!
//! The training hot path (forward → backward → optimizer) is built to
//! perform **zero matrix-buffer allocation in steady state** (a handful of
//! small pointer-sized `Vec` containers — layer-cache lists, attention-prob
//! vectors — still allocate per step) and to use every core:
//!
//! * **Workspace ownership.** A [`Workspace`] is a pool of reusable buffers
//!   keyed by element count. Each long-lived driver owns exactly one: the
//!   trainer's `StepState` (shared by `Llama::forward_hidden_ws` /
//!   `backward_hidden_ws`), and each low-rank optimizer (SubTrack++,
//!   GaLore, Fira) owns a private one for its projection / recovery
//!   buffers. Every buffer `take`n during a step is `give`n back before the
//!   step ends, so from step 2 onward the pool serves all requests without
//!   touching the allocator (asserted by `rust/tests/zero_alloc.rs`). The
//!   GEMM `_into`/`_acc` variants ([`gemm::matmul_into`],
//!   [`gemm::matmul_tn_acc`], …) write into caller-provided buffers and
//!   lease their Aᵀ/Bᵀ scratch from the same pool. Concurrent pool tasks
//!   lease whole per-task workspaces from a pre-sized [`WorkspaceBank`]
//!   (the head-parallel attention fan-out's scratch) — see the leasing
//!   rules in [`workspace`].
//!
//! * **Transpose-cache invalidation.** The model's linears compute `x·Wᵀ`;
//!   the `optim::TransposeCache` keeps one materialized `Wᵀ` per parameter
//!   so the O(h²) transpose is paid once per *weight update*, not once per
//!   layer per step. Correctness contract: every `Param` carries a version
//!   counter, every optimizer write goes through `Param::axpy_update` /
//!   `Param::decay` / `Param::mark_dirty` (which bump it), and the cache
//!   recomputes an entry iff its recorded version differs. Code that
//!   mutates `param.value` directly without bumping must never share a
//!   `TransposeCache` across the mutation (the allocating `Llama::loss` /
//!   `loss_and_grad` wrappers build a fresh cache per call for exactly this
//!   reason — finite-difference tests poke weights directly).
//!
//! * **Threading: one persistent pool, one budget, work stealing.** All
//!   kernel fan-out runs on the [`pool`] — `available_parallelism() − 1`
//!   long-lived workers spawned on first use (replacing PR-1's per-call
//!   `thread::scope` forks). The pool schedules through per-participant
//!   range deques with half-stealing (no shared claim counter, no global
//!   job queue; see the [`pool`] module docs for what may reorder and what
//!   cannot). [`gemm::matmul_acc`] splits C's rows into chunks sized by an
//!   L2-aware bytes-per-task target (`gemm::chunk_units`; `GEMM_CHUNK` /
//!   [`gemm::set_gemm_chunk`] force a size), [`qr::thin_qr`] factors WY
//!   panels and pushes its trailing update and Q formation through those
//!   same GEMM kernels (chunked reflector-column fan inside panels and for
//!   narrow inputs), the [`svd`] Jacobi sweep runs round-robin rounds of
//!   disjoint column pairs grouped into adaptively sized tasks, and the
//!   power-iteration matvecs split by output chunk. In every case one unit
//!   task's output depends only on its index and is produced by the
//!   identical sequential kernel, so results are **bit-identical for any
//!   worker count at fixed chunk/block settings** (gated by
//!   `rust/tests/subspace_props.rs`; the QR block size — `GEMM_QR_BLOCK` /
//!   [`qr::set_qr_block`] — changes the fp accumulation order and is *not*
//!   bit-transparent, and differing `GEMM_CHUNK` values promise only fp
//!   tolerance). The same plan gates everything: `gemm::set_gemm_threads` /
//!   the `GEMM_THREADS` env var force a count, auto mode threads only above
//!   [`gemm::PAR_FLOPS`] (GEMM) / [`gemm::PAR_KERNEL_FLOPS`]
//!   (pool-dispatched QR/SVD/matvec), and the data-parallel trainer shards
//!   run on the same pool with nested kernel fan-out opted out
//!   (`gemm::run_single_threaded`; nested [`pool::run`] executes inline
//!   regardless) — so DP workers and kernels can never oversubscribe the
//!   machine.
//!
//! * **Packed-panel GEMM with register-tiled micro-kernels.** Large
//!   products copy their operands into contiguous micro-panels ([`pack`])
//!   and run the 8×8 register-tiled kernels in [`microkernel`] — scalar by
//!   default, AVX2/NEON when the crate is built with the `simd` feature and
//!   the CPU supports it (runtime-detected). Every kernel reproduces the
//!   legacy kernels' per-element accumulation order, so the packed route is
//!   bit-identical to the scalar one for any shape, worker count and build
//!   flavor (`GEMM_PACK` / [`gemm::set_gemm_pack`] force either route;
//!   `rust/tests/gemm_packed.rs` gates the identity). Panel scratch leases
//!   from a process-wide bank, keeping the zero-alloc contract
//!   ([`pack::pack_misses`]).
//!
//! * **Storage dtypes.** [`dtype::Dtype`] names the reduced-precision
//!   storage formats (bf16/f16) and owns the software conversion kernels;
//!   [`dtype::MatrixB`] is the packed u16 companion of [`Matrix`]. Compute
//!   stays f32 — the widening GEMM entry points ([`gemm::matmul_wide_into`],
//!   [`gemm::matvec_wide_into`], [`gemm::transpose_wide_into`]) read packed
//!   operands and accumulate in f32 with decode fused into panel packing /
//!   the matvec row dots, so no full-matrix f32 image of the packed operand
//!   is ever materialized.
//!
//! * **Allocation-free refresh paths.** The every-k-steps subspace
//!   machinery has `_into` workspace-backed forms mirroring the GEMM ones:
//!   [`qr::thin_qr_into`] / [`qr::reorthonormalize_in_place`],
//!   [`svd::truncated_basis_into`] (the projector-refresh primitive),
//!   [`svd::power_iteration_top1_ws`] and [`svd::randomized_range_into`].
//!   All seven low-rank optimizers lease their refresh temporaries from
//!   their own workspace, so misses occur only on the first step and the
//!   first refresh (gated by `rust/tests/zero_alloc.rs`).

pub mod dtype;
pub mod gemm;
pub mod matrix;
pub mod microkernel;
pub mod ops;
pub mod pack;
pub mod pool;
pub mod qr;
pub mod svd;
pub mod workspace;

pub use dtype::{Dtype, MatrixB};
pub use matrix::Matrix;
pub use svd::{power_iteration_top1, thin_svd, Svd};
pub use workspace::{Workspace, WorkspaceBank};
