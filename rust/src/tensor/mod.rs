//! Dense f32 linear-algebra substrate, built from scratch.
//!
//! Everything the optimizer family and the native training engine need:
//! a row-major [`Matrix`], cache-blocked GEMM in all transpose variants,
//! Householder QR, one-sided Jacobi thin SVD, power iteration for top
//! singular triplets, and least squares. No external dependencies.
//!
//! The paper's subspace math operates per-gradient-matrix (m×n with rank
//! r ≪ m ≤ n), so all routines are tuned for tall-skinny / short-fat shapes
//! in the few-hundreds range.
//!
//! # Step-loop architecture: workspaces, `_into` kernels, threading
//!
//! The training hot path (forward → backward → optimizer) is built to
//! perform **zero matrix-buffer allocation in steady state** (a handful of
//! small pointer-sized `Vec` containers — layer-cache lists, attention-prob
//! vectors — still allocate per step) and to use every core:
//!
//! * **Workspace ownership.** A [`Workspace`] is a pool of reusable buffers
//!   keyed by element count. Each long-lived driver owns exactly one: the
//!   trainer's `StepState` (shared by `Llama::forward_hidden_ws` /
//!   `backward_hidden_ws`), and each low-rank optimizer (SubTrack++,
//!   GaLore, Fira) owns a private one for its projection / recovery
//!   buffers. Every buffer `take`n during a step is `give`n back before the
//!   step ends, so from step 2 onward the pool serves all requests without
//!   touching the allocator (asserted by `rust/tests/zero_alloc.rs`). The
//!   GEMM `_into`/`_acc` variants ([`gemm::matmul_into`],
//!   [`gemm::matmul_tn_acc`], …) write into caller-provided buffers and
//!   lease their Aᵀ/Bᵀ scratch from the same pool.
//!
//! * **Transpose-cache invalidation.** The model's linears compute `x·Wᵀ`;
//!   the `optim::TransposeCache` keeps one materialized `Wᵀ` per parameter
//!   so the O(h²) transpose is paid once per *weight update*, not once per
//!   layer per step. Correctness contract: every `Param` carries a version
//!   counter, every optimizer write goes through `Param::axpy_update` /
//!   `Param::decay` / `Param::mark_dirty` (which bump it), and the cache
//!   recomputes an entry iff its recorded version differs. Code that
//!   mutates `param.value` directly without bumping must never share a
//!   `TransposeCache` across the mutation (the allocating `Llama::loss` /
//!   `loss_and_grad` wrappers build a fresh cache per call for exactly this
//!   reason — finite-difference tests poke weights directly).
//!
//! * **Threading.** [`gemm::matmul_acc`] splits C's rows across scoped
//!   threads; each row is computed by the identical scalar kernel, so
//!   results are bit-identical for any worker count, and auto mode degrades
//!   to the single-core path for small products or single-core hosts.
//!   QR ([`qr`]) and the SVD power iteration ([`svd`]) remain
//!   single-threaded — they run once per subspace refresh, off the
//!   steady-state path (tracked in ROADMAP.md "Open items").

pub mod gemm;
pub mod matrix;
pub mod ops;
pub mod qr;
pub mod svd;
pub mod workspace;

pub use matrix::Matrix;
pub use svd::{power_iteration_top1, thin_svd, Svd};
pub use workspace::Workspace;
