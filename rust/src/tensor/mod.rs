//! Dense f32 linear-algebra substrate, built from scratch.
//!
//! Everything the optimizer family and the native training engine need:
//! a row-major [`Matrix`], cache-blocked GEMM in all transpose variants,
//! Householder QR, one-sided Jacobi thin SVD, power iteration for top
//! singular triplets, and least squares. No external dependencies.
//!
//! The paper's subspace math operates per-gradient-matrix (m×n with rank
//! r ≪ m ≤ n), so all routines are tuned for tall-skinny / short-fat shapes
//! in the few-hundreds range running on a single CPU core.

pub mod gemm;
pub mod matrix;
pub mod ops;
pub mod qr;
pub mod svd;

pub use matrix::Matrix;
pub use svd::{power_iteration_top1, thin_svd, Svd};
