//! Row-major dense f32 matrix.

use crate::util::rng::Rng;
use std::fmt;

/// A dense row-major matrix of `f32`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, value: f32) -> Matrix {
        Matrix { rows, cols, data: vec![value; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a nested-slice literal (rows of equal length).
    pub fn from_rows(rows: &[&[f32]]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// i.i.d. N(0, std) entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols)
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` copied into a Vec.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Transposed copy.
    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Transpose into an existing `cols`×`rows` buffer (blocked for cache
    /// friendliness; every output entry is written).
    pub fn transpose_into(&self, out: &mut Matrix) {
        assert_eq!(
            out.shape(),
            (self.cols, self.rows),
            "transpose_into output shape"
        );
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    /// Consume the matrix, releasing its backing buffer (workspace recycling).
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Overwrite `self` with the contents of a same-shaped matrix.
    pub fn copy_from(&mut self, src: &Matrix) {
        assert_eq!(self.shape(), src.shape(), "shape mismatch");
        self.data.copy_from_slice(&src.data);
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        // Accumulate in f64 for robustness on large matrices.
        (self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f32 {
        self.data.iter().map(|&x| x as f64).sum::<f64>() as f32
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &b| a.max(b.abs()))
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place element-wise update.
    pub fn apply(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// self + other (new matrix).
    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a + b)
    }

    /// self - other (new matrix).
    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a - b)
    }

    /// Hadamard product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a * b)
    }

    /// Element-wise combine.
    pub fn zip(&self, other: &Matrix, f: impl Fn(f32, f32) -> f32) -> Matrix {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// Element-wise combine into an existing same-shaped buffer
    /// (allocation-free `zip`).
    pub fn zip_into(&self, other: &Matrix, out: &mut Matrix, f: impl Fn(f32, f32) -> f32) {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        assert_eq!(self.shape(), out.shape(), "shape mismatch");
        for ((o, &a), &b) in out.data.iter_mut().zip(&self.data).zip(&other.data) {
            *o = f(a, b);
        }
    }

    /// In-place element-wise combine: `self[i] = f(self[i], other[i])`.
    pub fn zip_assign(&mut self, other: &Matrix, f: impl Fn(f32, f32) -> f32) {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a = f(*a, b);
        }
    }

    /// In-place `self += alpha * other` (axpy).
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scaled copy.
    pub fn scale(&self, alpha: f32) -> Matrix {
        self.map(|x| alpha * x)
    }

    /// In-place scale.
    pub fn scale_mut(&mut self, alpha: f32) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Copy of the leading `k` columns.
    pub fn take_cols(&self, k: usize) -> Matrix {
        assert!(k <= self.cols);
        let mut out = Matrix::zeros(self.rows, k);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..k]);
        }
        out
    }

    /// Dot product of columns j1 and j2 (f64 accumulation).
    pub fn col_dot(&self, j1: usize, j2: usize) -> f64 {
        let mut acc = 0.0f64;
        let mut idx1 = j1;
        let mut idx2 = j2;
        for _ in 0..self.rows {
            acc += self.data[idx1] as f64 * self.data[idx2] as f64;
            idx1 += self.cols;
            idx2 += self.cols;
        }
        acc
    }

    /// Euclidean norms of each column written into `out` (len = cols).
    /// Matches [`col_norms`] bit-for-bit: `col_dot` accumulates rows in the
    /// same order, in f64.
    ///
    /// [`col_norms`]: Matrix::col_norms
    pub fn col_norms_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.cols, "col_norms_into length");
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.col_dot(j, j).sqrt() as f32;
        }
    }

    /// Euclidean norms of each column.
    pub fn col_norms(&self) -> Vec<f32> {
        let mut acc = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (j, &v) in row.iter().enumerate() {
                acc[j] += (v as f64) * (v as f64);
            }
        }
        acc.into_iter().map(|x| x.sqrt() as f32).collect()
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(6);
        for i in 0..show_rows {
            let row = self.row(i);
            let cells: Vec<String> =
                row.iter().take(8).map(|v| format!("{v:>10.4}")).collect();
            let ell = if self.cols > 8 { " …" } else { "" };
            writeln!(f, "  [{}{ell}]", cells.join(", "))?;
        }
        if self.rows > show_rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.shape(), (2, 2));
        assert_eq!(m.get(1, 0), 3.0);
        assert_eq!(m.col(1), vec![2.0, 4.0]);
        assert_eq!(Matrix::eye(3).get(2, 2), 1.0);
        assert_eq!(Matrix::eye(3).get(0, 2), 0.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(13, 37, 1.0, &mut rng);
        assert_eq!(m.t().t(), m);
        assert_eq!(m.t().get(5, 7), m.get(7, 5));
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_rows(&[&[1.0, 2.0]]);
        let b = Matrix::from_rows(&[&[3.0, -1.0]]);
        assert_eq!(a.add(&b).data(), &[4.0, 1.0]);
        assert_eq!(a.sub(&b).data(), &[-2.0, 3.0]);
        assert_eq!(a.hadamard(&b).data(), &[3.0, -2.0]);
        let mut c = a.clone();
        c.axpy(2.0, &b);
        assert_eq!(c.data(), &[7.0, 0.0]);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_rows(&[&[3.0, 0.0], &[0.0, 4.0]]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-6);
        assert_eq!(m.max_abs(), 4.0);
        let cn = m.col_norms();
        assert!((cn[0] - 3.0).abs() < 1e-6 && (cn[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn take_cols_works() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let t = m.take_cols(2);
        assert_eq!(t.shape(), (2, 2));
        assert_eq!(t.data(), &[1.0, 2.0, 4.0, 5.0]);
    }

    #[test]
    fn transpose_into_matches_t() {
        let mut rng = Rng::new(8);
        let m = Matrix::randn(13, 37, 1.0, &mut rng);
        let mut out = Matrix::full(37, 13, 9.0);
        m.transpose_into(&mut out);
        assert_eq!(out, m.t());
        let back = out.into_vec();
        assert_eq!(back.len(), 13 * 37);
    }

    #[test]
    fn zip_into_and_assign() {
        let a = Matrix::from_rows(&[&[1.0, 2.0]]);
        let b = Matrix::from_rows(&[&[3.0, -1.0]]);
        let mut out = Matrix::full(1, 2, 5.0);
        a.zip_into(&b, &mut out, |x, y| x * y);
        assert_eq!(out.data(), &[3.0, -2.0]);
        out.zip_assign(&a, |o, x| o + x);
        assert_eq!(out.data(), &[4.0, 0.0]);
        let mut c = Matrix::zeros(1, 2);
        c.copy_from(&a);
        assert_eq!(c.data(), a.data());
    }

    #[test]
    fn col_norms_into_matches_col_norms() {
        let mut rng = Rng::new(9);
        let m = Matrix::randn(11, 7, 1.0, &mut rng);
        let want = m.col_norms();
        let mut got = vec![0.0f32; 7];
        m.col_norms_into(&mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn col_dot_f64_accumulation() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert!((m.col_dot(0, 1) - (2.0 + 12.0 + 30.0)).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(2, 3);
        let _ = a.add(&b);
    }
}
