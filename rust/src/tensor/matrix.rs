//! Row-major dense f32 matrix.

use crate::util::rng::Rng;
use std::fmt;

/// A dense row-major matrix of `f32`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, value: f32) -> Matrix {
        Matrix { rows, cols, data: vec![value; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a nested-slice literal (rows of equal length).
    pub fn from_rows(rows: &[&[f32]]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// i.i.d. N(0, std) entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols)
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` copied into a Vec.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Transposed copy.
    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        // Accumulate in f64 for robustness on large matrices.
        (self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f32 {
        self.data.iter().map(|&x| x as f64).sum::<f64>() as f32
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &b| a.max(b.abs()))
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place element-wise update.
    pub fn apply(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// self + other (new matrix).
    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a + b)
    }

    /// self - other (new matrix).
    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a - b)
    }

    /// Hadamard product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a * b)
    }

    /// Element-wise combine.
    pub fn zip(&self, other: &Matrix, f: impl Fn(f32, f32) -> f32) -> Matrix {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// In-place `self += alpha * other` (axpy).
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scaled copy.
    pub fn scale(&self, alpha: f32) -> Matrix {
        self.map(|x| alpha * x)
    }

    /// In-place scale.
    pub fn scale_mut(&mut self, alpha: f32) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Copy of the leading `k` columns.
    pub fn take_cols(&self, k: usize) -> Matrix {
        assert!(k <= self.cols);
        let mut out = Matrix::zeros(self.rows, k);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..k]);
        }
        out
    }

    /// Dot product of columns j1 and j2 (f64 accumulation).
    pub fn col_dot(&self, j1: usize, j2: usize) -> f64 {
        let mut acc = 0.0f64;
        let mut idx1 = j1;
        let mut idx2 = j2;
        for _ in 0..self.rows {
            acc += self.data[idx1] as f64 * self.data[idx2] as f64;
            idx1 += self.cols;
            idx2 += self.cols;
        }
        acc
    }

    /// Euclidean norms of each column.
    pub fn col_norms(&self) -> Vec<f32> {
        let mut acc = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (j, &v) in row.iter().enumerate() {
                acc[j] += (v as f64) * (v as f64);
            }
        }
        acc.into_iter().map(|x| x.sqrt() as f32).collect()
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(6);
        for i in 0..show_rows {
            let row = self.row(i);
            let cells: Vec<String> =
                row.iter().take(8).map(|v| format!("{v:>10.4}")).collect();
            let ell = if self.cols > 8 { " …" } else { "" };
            writeln!(f, "  [{}{ell}]", cells.join(", "))?;
        }
        if self.rows > show_rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.shape(), (2, 2));
        assert_eq!(m.get(1, 0), 3.0);
        assert_eq!(m.col(1), vec![2.0, 4.0]);
        assert_eq!(Matrix::eye(3).get(2, 2), 1.0);
        assert_eq!(Matrix::eye(3).get(0, 2), 0.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(13, 37, 1.0, &mut rng);
        assert_eq!(m.t().t(), m);
        assert_eq!(m.t().get(5, 7), m.get(7, 5));
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_rows(&[&[1.0, 2.0]]);
        let b = Matrix::from_rows(&[&[3.0, -1.0]]);
        assert_eq!(a.add(&b).data(), &[4.0, 1.0]);
        assert_eq!(a.sub(&b).data(), &[-2.0, 3.0]);
        assert_eq!(a.hadamard(&b).data(), &[3.0, -2.0]);
        let mut c = a.clone();
        c.axpy(2.0, &b);
        assert_eq!(c.data(), &[7.0, 0.0]);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_rows(&[&[3.0, 0.0], &[0.0, 4.0]]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-6);
        assert_eq!(m.max_abs(), 4.0);
        let cn = m.col_norms();
        assert!((cn[0] - 3.0).abs() < 1e-6 && (cn[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn take_cols_works() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let t = m.take_cols(2);
        assert_eq!(t.shape(), (2, 2));
        assert_eq!(t.data(), &[1.0, 2.0, 4.0, 5.0]);
    }

    #[test]
    fn col_dot_f64_accumulation() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert!((m.col_dot(0, 1) - (2.0 + 12.0 + 30.0)).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(2, 3);
        let _ = a.add(&b);
    }
}
