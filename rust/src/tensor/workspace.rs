//! Reusable scratch-buffer arena for the allocation-free hot path.
//!
//! A [`Workspace`] hands out reusable buffers keyed by *element count* (not
//! shape), so a buffer released as 512×128 can be re-issued as 128×512 — the
//! forward/backward pass and the optimizer projection paths cycle through a
//! fixed set of sizes every step, and after the first (warm-up) step every
//! `take` is a pool hit. The hit/miss counters make that property testable:
//! steady-state training steps must add **zero** misses (see
//! `rust/tests/zero_alloc.rs`).
//!
//! Ownership protocol: `take` transfers ownership of a buffer to the caller;
//! the caller returns it with `give` when done. Buffers that are *not*
//! returned are simply dropped (correct, but they cost a fresh allocation —
//! a miss — the next time that size is requested). Zero-length requests are
//! served without touching the pool or the counters: `Vec::new()` does not
//! allocate, so degenerate 0-dim shapes can never cause steady-state misses.
//!
//! # Per-task leasing: [`WorkspaceBank`]
//!
//! A `Workspace` is single-owner by design (`&mut` methods) — it cannot be
//! shared by the concurrent tasks a `pool::run` fan-out spawns. The
//! [`WorkspaceBank`] closes that gap: it holds a free list of whole
//! `Workspace` instances behind a mutex, and each pool task **leases one
//! workspace for the duration of the task** ([`WorkspaceBank::lease`] /
//! [`WorkspaceBank::release`]), taking and giving its scratch buffers
//! through the normal single-owner API. The leasing rules that keep the
//! zero-allocation contract intact:
//!
//! * **Pre-size before fanning out.** [`WorkspaceBank::ensure`] tops the
//!   bank up to N workspaces, each pre-stocked ([`Workspace::reserve`])
//!   with the buffer sizes the tasks will take. N must be ≥ the fan-out's
//!   participant count, so every concurrent lease is served from the free
//!   list and every `take` inside a task is a pool hit. `ensure` is
//!   idempotent: steady-state calls verify and do nothing.
//! * **Return everything.** A task must `give` every buffer back to its
//!   leased workspace and `release` the workspace before finishing;
//!   otherwise the next step re-allocates (a miss, visible in
//!   [`WorkspaceBank::misses`]).
//! * **Scratch only.** Which workspace a lease returns is
//!   scheduling-dependent, so leased buffers carry no data across tasks:
//!   tasks must fully overwrite what they read (the `take_dirty` contract).
//!   Results therefore stay bit-identical for any worker count.
//!
//! Misses are counted inside each member workspace; [`WorkspaceBank::misses`]
//! sums them and is only meaningful *at rest* (between steps, when every
//! lease has been released) — the gate in `rust/tests/zero_alloc.rs` reads
//! it there.
//!
//! Besides the attention fan-out's pre-sized bank, the packed-panel GEMM
//! leases its A/B panel buffers from a process-wide *self-warming* bank
//! (`tensor::pack::bank`): leases that outrun the free list fall back to a
//! fresh `Workspace` (a miss) which the bank absorbs on release, so no
//! `ensure` call is needed and steady-state products of recurring shapes
//! allocate nothing (`tensor::pack::pack_misses` gates this).

use super::matrix::Matrix;
use std::collections::HashMap;
use std::sync::Mutex;

/// A pool of reusable `f32` buffers keyed by length.
#[derive(Debug, Default)]
pub struct Workspace {
    pools: HashMap<usize, Vec<Vec<f32>>>,
    hits: usize,
    misses: usize,
    /// Total f32 elements ever allocated by this workspace (high-water cost).
    allocated: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Take a zeroed `rows`×`cols` matrix from the pool (allocating on miss).
    pub fn take(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_vec(rows * cols))
    }

    /// Take a `rows`×`cols` matrix with **unspecified contents** (stale data
    /// from a previous lease). Only for callers that fully overwrite every
    /// element before reading — skipping the zero-fill saves a full memory
    /// sweep per lease on the hot path. Accumulation targets must use
    /// [`take`] instead.
    ///
    /// [`take`]: Workspace::take
    pub fn take_dirty(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_vec_dirty(rows * cols))
    }

    /// Take a zeroed buffer of `len` f32s from the pool (allocating on miss).
    pub fn take_vec(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_vec_dirty(len);
        v.fill(0.0);
        v
    }

    /// [`take_vec`] without the zero-fill: contents are unspecified; the
    /// caller must write every element before reading.
    ///
    /// [`take_vec`]: Workspace::take_vec
    pub fn take_vec_dirty(&mut self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        match self.pools.get_mut(&len).and_then(|p| p.pop()) {
            Some(v) => {
                self.hits += 1;
                v
            }
            None => {
                self.misses += 1;
                self.allocated += len;
                vec![0.0; len]
            }
        }
    }

    /// Top the pool up so at least `count` buffers of `len` are ready to be
    /// taken without allocating. Fresh buffers count as misses (they are
    /// warm-up allocations, same as a cold `take`); once the pool holds
    /// `count` buffers this is a no-op, so steady-state calls are free.
    pub fn reserve(&mut self, len: usize, count: usize) {
        if len == 0 {
            return;
        }
        let have = self.pools.get(&len).map_or(0, |p| p.len());
        for _ in have..count {
            self.misses += 1;
            self.allocated += len;
            self.pools.entry(len).or_default().push(vec![0.0; len]);
        }
    }

    /// Return a matrix's buffer to the pool.
    pub fn give(&mut self, m: Matrix) {
        self.give_vec(m.into_vec());
    }

    /// Return a raw buffer to the pool.
    pub fn give_vec(&mut self, v: Vec<f32>) {
        if v.is_empty() {
            return;
        }
        self.pools.entry(v.len()).or_default().push(v);
    }

    /// Pool hits since construction (or the last [`reset_counters`]).
    ///
    /// [`reset_counters`]: Workspace::reset_counters
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Pool misses (fresh allocations) since construction / counter reset.
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Total f32 elements this workspace has ever allocated.
    pub fn allocated_elems(&self) -> usize {
        self.allocated
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    /// Drop every pooled buffer (keeps counters).
    pub fn clear(&mut self) {
        self.pools.clear();
    }
}

/// A bank of [`Workspace`]s leasable by concurrent pool tasks (see the
/// module docs for the leasing rules). Owned next to the single-owner step
/// workspace — e.g. `model::StepState` holds one for the per-(batch, head)
/// attention scratch — and recycled across steps so the zero-allocation
/// contract extends to fanned-out work.
#[derive(Debug, Default)]
pub struct WorkspaceBank {
    free: Mutex<Vec<Workspace>>,
}

impl WorkspaceBank {
    pub fn new() -> WorkspaceBank {
        WorkspaceBank::default()
    }

    /// Pre-size the bank: grow the free list to `slots` workspaces and
    /// stock each with `count` buffers of `len` elements per `(len, count)`
    /// entry. Idempotent — a warm call verifies and allocates nothing.
    /// Call *at rest* (before fanning out), with `slots` ≥ the planned
    /// participant count, so concurrent leases never allocate.
    pub fn ensure(&self, slots: usize, sizes: &[(usize, usize)]) {
        let mut free = self.free.lock().unwrap_or_else(|e| e.into_inner());
        while free.len() < slots {
            free.push(Workspace::new());
        }
        for ws in free.iter_mut() {
            for &(len, count) in sizes {
                ws.reserve(len, count);
            }
        }
    }

    /// Lease one workspace for the duration of a task. Falls back to a
    /// fresh (empty) workspace when the free list is dry — correct, but its
    /// takes will allocate; [`ensure`] with a sufficient slot count prevents
    /// that.
    ///
    /// [`ensure`]: WorkspaceBank::ensure
    pub fn lease(&self) -> Workspace {
        self.free
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default()
    }

    /// Return a leased workspace to the free list.
    pub fn release(&self, ws: Workspace) {
        self.free.lock().unwrap_or_else(|e| e.into_inner()).push(ws);
    }

    /// Total misses across the banked workspaces. Only meaningful at rest
    /// (every lease released) — the zero-alloc gate's per-head scratch
    /// proxy.
    pub fn misses(&self) -> usize {
        self.free
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|ws| ws.misses())
            .sum()
    }

    /// Workspaces currently at rest in the bank.
    pub fn len(&self) -> usize {
        self.free.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_is_a_hit() {
        let mut ws = Workspace::new();
        let m = ws.take(3, 4);
        assert_eq!(ws.misses(), 1);
        ws.give(m);
        let m2 = ws.take(3, 4);
        assert_eq!((ws.hits(), ws.misses()), (1, 1));
        assert!(m2.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn mismatched_shapes_share_buffers_by_numel() {
        let mut ws = Workspace::new();
        let mut m = ws.take(3, 4);
        m.set(2, 1, 7.0);
        ws.give(m);
        // Same element count, different shape: must be a hit, and zeroed.
        let m2 = ws.take(4, 3);
        assert_eq!(m2.shape(), (4, 3));
        assert_eq!((ws.hits(), ws.misses()), (1, 1));
        assert!(m2.data().iter().all(|&v| v == 0.0));
        ws.give(m2);
        // Different element count: a miss.
        let m3 = ws.take(5, 5);
        assert_eq!((ws.hits(), ws.misses()), (1, 2));
        ws.give(m3);
    }

    #[test]
    fn dirty_take_skips_the_zero_fill() {
        let mut ws = Workspace::new();
        let mut m = ws.take_dirty(2, 3);
        m.data_mut().fill(4.5);
        ws.give(m);
        // Dirty lease: stale contents survive (hit counted as usual).
        let m2 = ws.take_dirty(2, 3);
        assert_eq!((ws.hits(), ws.misses()), (1, 1));
        assert!(m2.data().iter().all(|&v| v == 4.5));
        ws.give(m2);
        // Zeroed lease of the same buffer wipes it.
        let m3 = ws.take(3, 2);
        assert!(m3.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn zero_len_never_counts() {
        let mut ws = Workspace::new();
        let a = ws.take(0, 7);
        let b = ws.take(3, 0);
        assert_eq!(a.shape(), (0, 7));
        assert_eq!(b.shape(), (3, 0));
        ws.give(a);
        ws.give(b);
        let _ = ws.take(0, 0);
        assert_eq!((ws.hits(), ws.misses()), (0, 0));
        assert_eq!(ws.allocated_elems(), 0);
    }

    #[test]
    fn reserve_tops_up_then_noops() {
        let mut ws = Workspace::new();
        ws.reserve(12, 3);
        assert_eq!(ws.misses(), 3);
        // Warm call: pool already holds 3 buffers of len 12.
        ws.reserve(12, 3);
        assert_eq!(ws.misses(), 3);
        // All three takes are hits.
        let a = ws.take_vec(12);
        let b = ws.take_vec(12);
        let c = ws.take_vec(12);
        assert_eq!((ws.hits(), ws.misses()), (3, 3));
        ws.give_vec(a);
        ws.give_vec(b);
        ws.give_vec(c);
        // Partial pool tops up only the difference.
        let d = ws.take_vec(12);
        ws.reserve(12, 3);
        assert_eq!(ws.misses(), 4);
        ws.give_vec(d);
        // Zero-length reservations never touch the pool.
        ws.reserve(0, 8);
        assert_eq!(ws.misses(), 4);
    }

    #[test]
    fn bank_leases_are_prestocked_and_recycle() {
        let bank = WorkspaceBank::new();
        bank.ensure(2, &[(8, 2), (16, 1)]);
        let warmup = bank.misses();
        assert_eq!(warmup, 2 * 3, "2 slots × (2 + 1) reserved buffers");
        assert_eq!(bank.len(), 2);
        // A lease/take/give/release cycle adds no misses.
        let mut ws = bank.lease();
        let m = ws.take_dirty(2, 4);
        let v = ws.take_vec_dirty(16);
        ws.give(m);
        ws.give_vec(v);
        bank.release(ws);
        assert_eq!(bank.misses(), warmup, "warm lease allocated");
        // Warm ensure is a no-op.
        bank.ensure(2, &[(8, 2), (16, 1)]);
        assert_eq!(bank.misses(), warmup);
        // Over-leasing past the free list still works (fresh workspace; its
        // takes miss, and the bank absorbs it on release).
        let a = bank.lease();
        let b = bank.lease();
        let mut c = bank.lease();
        let m = c.take_dirty(1, 8);
        c.give(m);
        bank.release(a);
        bank.release(b);
        bank.release(c);
        assert_eq!(bank.len(), 3);
        assert_eq!(bank.misses(), warmup + 1);
    }

    #[test]
    fn steady_state_has_no_misses() {
        let mut ws = Workspace::new();
        // Simulate three "steps", each cycling the same set of shapes.
        let mut misses_after_first = 0;
        for step in 0..3 {
            let a = ws.take(8, 16);
            let b = ws.take(16, 4);
            let c = ws.take(8, 4);
            ws.give(a);
            ws.give(b);
            ws.give(c);
            if step == 0 {
                misses_after_first = ws.misses();
            }
        }
        assert_eq!(ws.misses(), misses_after_first, "steady state must not allocate");
    }
}
