//! Reusable scratch-buffer arena for the allocation-free hot path.
//!
//! A [`Workspace`] hands out reusable buffers keyed by *element count* (not
//! shape), so a buffer released as 512×128 can be re-issued as 128×512 — the
//! forward/backward pass and the optimizer projection paths cycle through a
//! fixed set of sizes every step, and after the first (warm-up) step every
//! `take` is a pool hit. The hit/miss counters make that property testable:
//! steady-state training steps must add **zero** misses (see
//! `rust/tests/zero_alloc.rs`).
//!
//! Ownership protocol: `take` transfers ownership of a buffer to the caller;
//! the caller returns it with `give` when done. Buffers that are *not*
//! returned are simply dropped (correct, but they cost a fresh allocation —
//! a miss — the next time that size is requested). Zero-length requests are
//! served without touching the pool or the counters: `Vec::new()` does not
//! allocate, so degenerate 0-dim shapes can never cause steady-state misses.

use super::matrix::Matrix;
use std::collections::HashMap;

/// A pool of reusable `f32` buffers keyed by length.
#[derive(Debug, Default)]
pub struct Workspace {
    pools: HashMap<usize, Vec<Vec<f32>>>,
    hits: usize,
    misses: usize,
    /// Total f32 elements ever allocated by this workspace (high-water cost).
    allocated: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Take a zeroed `rows`×`cols` matrix from the pool (allocating on miss).
    pub fn take(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_vec(rows * cols))
    }

    /// Take a `rows`×`cols` matrix with **unspecified contents** (stale data
    /// from a previous lease). Only for callers that fully overwrite every
    /// element before reading — skipping the zero-fill saves a full memory
    /// sweep per lease on the hot path. Accumulation targets must use
    /// [`take`] instead.
    ///
    /// [`take`]: Workspace::take
    pub fn take_dirty(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_vec_dirty(rows * cols))
    }

    /// Take a zeroed buffer of `len` f32s from the pool (allocating on miss).
    pub fn take_vec(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_vec_dirty(len);
        v.fill(0.0);
        v
    }

    /// [`take_vec`] without the zero-fill: contents are unspecified; the
    /// caller must write every element before reading.
    ///
    /// [`take_vec`]: Workspace::take_vec
    pub fn take_vec_dirty(&mut self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        match self.pools.get_mut(&len).and_then(|p| p.pop()) {
            Some(v) => {
                self.hits += 1;
                v
            }
            None => {
                self.misses += 1;
                self.allocated += len;
                vec![0.0; len]
            }
        }
    }

    /// Return a matrix's buffer to the pool.
    pub fn give(&mut self, m: Matrix) {
        self.give_vec(m.into_vec());
    }

    /// Return a raw buffer to the pool.
    pub fn give_vec(&mut self, v: Vec<f32>) {
        if v.is_empty() {
            return;
        }
        self.pools.entry(v.len()).or_default().push(v);
    }

    /// Pool hits since construction (or the last [`reset_counters`]).
    ///
    /// [`reset_counters`]: Workspace::reset_counters
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Pool misses (fresh allocations) since construction / counter reset.
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Total f32 elements this workspace has ever allocated.
    pub fn allocated_elems(&self) -> usize {
        self.allocated
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    /// Drop every pooled buffer (keeps counters).
    pub fn clear(&mut self) {
        self.pools.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_is_a_hit() {
        let mut ws = Workspace::new();
        let m = ws.take(3, 4);
        assert_eq!(ws.misses(), 1);
        ws.give(m);
        let m2 = ws.take(3, 4);
        assert_eq!((ws.hits(), ws.misses()), (1, 1));
        assert!(m2.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn mismatched_shapes_share_buffers_by_numel() {
        let mut ws = Workspace::new();
        let mut m = ws.take(3, 4);
        m.set(2, 1, 7.0);
        ws.give(m);
        // Same element count, different shape: must be a hit, and zeroed.
        let m2 = ws.take(4, 3);
        assert_eq!(m2.shape(), (4, 3));
        assert_eq!((ws.hits(), ws.misses()), (1, 1));
        assert!(m2.data().iter().all(|&v| v == 0.0));
        ws.give(m2);
        // Different element count: a miss.
        let m3 = ws.take(5, 5);
        assert_eq!((ws.hits(), ws.misses()), (1, 2));
        ws.give(m3);
    }

    #[test]
    fn dirty_take_skips_the_zero_fill() {
        let mut ws = Workspace::new();
        let mut m = ws.take_dirty(2, 3);
        m.data_mut().fill(4.5);
        ws.give(m);
        // Dirty lease: stale contents survive (hit counted as usual).
        let m2 = ws.take_dirty(2, 3);
        assert_eq!((ws.hits(), ws.misses()), (1, 1));
        assert!(m2.data().iter().all(|&v| v == 4.5));
        ws.give(m2);
        // Zeroed lease of the same buffer wipes it.
        let m3 = ws.take(3, 2);
        assert!(m3.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn zero_len_never_counts() {
        let mut ws = Workspace::new();
        let a = ws.take(0, 7);
        let b = ws.take(3, 0);
        assert_eq!(a.shape(), (0, 7));
        assert_eq!(b.shape(), (3, 0));
        ws.give(a);
        ws.give(b);
        let _ = ws.take(0, 0);
        assert_eq!((ws.hits(), ws.misses()), (0, 0));
        assert_eq!(ws.allocated_elems(), 0);
    }

    #[test]
    fn steady_state_has_no_misses() {
        let mut ws = Workspace::new();
        // Simulate three "steps", each cycling the same set of shapes.
        let mut misses_after_first = 0;
        for step in 0..3 {
            let a = ws.take(8, 16);
            let b = ws.take(16, 4);
            let c = ws.take(8, 4);
            ws.give(a);
            ws.give(b);
            ws.give(c);
            if step == 0 {
                misses_after_first = ws.misses();
            }
        }
        assert_eq!(ws.misses(), misses_after_first, "steady state must not allocate");
    }
}
