//! Register-tiled GEMM micro-kernels over packed panels.
//!
//! A micro-kernel multiplies one packed `MR`×kc A micro-panel by one packed
//! kc×`NR` B micro-panel, accumulating into an `MR`×`NR` tile of C (row-major,
//! leading dimension `ldc`). Panel layout is produced by [`super::pack`]: at
//! k-step `p` the A panel holds the tile's `MR` column entries contiguously at
//! `ap[p*MR..]` and the B panel holds the `NR` row entries at `bp[p*NR..]`, so
//! the kernel streams both panels linearly.
//!
//! # Bit-identity contract
//!
//! Every kernel — scalar, AVX2, NEON — reproduces the exact per-element
//! accumulation order of the legacy row kernel in [`super::gemm`]: k-steps in
//! groups of four, each group summed left-associatively
//! (`((a0·b0 + a1·b1) + a2·b2) + a3·b3`) and folded into C with a single add,
//! then the `kc % 4` remainder one step at a time. The SIMD kernels use
//! separate multiply and add intrinsics — **never FMA**, which would change
//! the rounding — and vectorize across the `NR` columns, so every lane is an
//! independent C element computing the identical scalar sequence. Holding the
//! C tile in registers for the duration of one call is associativity-neutral
//! (the adds happen in the same order, only the store is deferred), so all
//! kernels agree with the legacy path **bitwise**, and `simd` builds agree
//! with scalar builds bitwise. `rust/tests/gemm_packed.rs` and the tests
//! below gate this.
//!
//! Partial edge tiles (`mr < MR` or `nr < NR`) always go through the scalar
//! [`mk_edge`] in both build flavors, writing only the live region directly
//! in C — the full-tile kernels are reached only for complete tiles.
//!
//! Kernel selection happens once per process ([`active`]): AVX2 requires the
//! `simd` cargo feature *and* a runtime `is_x86_feature_detected!` probe,
//! NEON requires the feature on aarch64; everything else falls back to the
//! scalar kernel, which is also the oracle the SIMD paths are tested against.

use std::sync::OnceLock;

/// Micro-tile rows (A panel height).
pub const MR: usize = 8;
/// Micro-tile columns (B panel width — one AVX2 vector, two NEON vectors).
pub const NR: usize = 8;

/// A full-tile micro-kernel: `(kc, ap, bp, c, ldc)` accumulates the packed
/// `MR`×kc · kc×`NR` product into the `MR`×`NR` tile at `c`.
pub type MicroFn = unsafe fn(usize, *const f32, *const f32, *mut f32, usize);

/// Scalar micro-kernel over the live `mr`×`nr` corner of a tile. This is the
/// only kernel edge tiles ever use (in both scalar and `simd` builds), so
/// ragged shapes cannot diverge between build flavors.
///
/// # Safety
///
/// `ap` must be valid for `kc * MR` reads, `bp` for `kc * NR` reads, and `c`
/// must point to a row-major block with leading dimension `ldc` where rows
/// `0..mr` each have `nr` writable elements. Requires `mr <= MR`, `nr <= NR`
/// and `nr <= ldc` (for `mr > 0`).
pub unsafe fn mk_edge(
    kc: usize,
    ap: *const f32,
    bp: *const f32,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let mut p = 0usize;
    while p + 4 <= kc {
        let a0 = ap.add(p * MR);
        let a1 = ap.add((p + 1) * MR);
        let a2 = ap.add((p + 2) * MR);
        let a3 = ap.add((p + 3) * MR);
        let b0 = bp.add(p * NR);
        let b1 = bp.add((p + 1) * NR);
        let b2 = bp.add((p + 2) * NR);
        let b3 = bp.add((p + 3) * NR);
        for i in 0..mr {
            let x0 = *a0.add(i);
            let x1 = *a1.add(i);
            let x2 = *a2.add(i);
            let x3 = *a3.add(i);
            let crow = c.add(i * ldc);
            for j in 0..nr {
                *crow.add(j) +=
                    x0 * *b0.add(j) + x1 * *b1.add(j) + x2 * *b2.add(j) + x3 * *b3.add(j);
            }
        }
        p += 4;
    }
    while p < kc {
        let a0 = ap.add(p * MR);
        let b0 = bp.add(p * NR);
        for i in 0..mr {
            let x = *a0.add(i);
            let crow = c.add(i * ldc);
            for j in 0..nr {
                *crow.add(j) += x * *b0.add(j);
            }
        }
        p += 1;
    }
}

/// Scalar full-tile kernel (the portable fallback and bit-identity oracle).
///
/// # Safety
///
/// Same as [`mk_edge`] with `mr = MR`, `nr = NR`: the full `MR`×`NR` tile at
/// `c` must be writable.
pub unsafe fn mk_scalar(kc: usize, ap: *const f32, bp: *const f32, c: *mut f32, ldc: usize) {
    mk_edge(kc, ap, bp, c, ldc, MR, NR);
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// AVX2 full-tile kernel: one 8-lane vector per C row, broadcast A,
    /// separate mul/add (no FMA) in the canonical 4-group order.
    ///
    /// # Safety
    ///
    /// Same contract as [`super::mk_scalar`]; additionally the CPU must
    /// support AVX2 (guarded by the runtime probe in [`super::active`]).
    #[target_feature(enable = "avx2")]
    #[allow(clippy::needless_range_loop, clippy::missing_safety_doc)]
    pub unsafe fn mk_avx2(kc: usize, ap: *const f32, bp: *const f32, c: *mut f32, ldc: usize) {
        let mut acc = [_mm256_setzero_ps(); MR];
        for i in 0..MR {
            acc[i] = _mm256_loadu_ps(c.add(i * ldc));
        }
        let mut p = 0usize;
        while p + 4 <= kc {
            let b0 = _mm256_loadu_ps(bp.add(p * NR));
            let b1 = _mm256_loadu_ps(bp.add((p + 1) * NR));
            let b2 = _mm256_loadu_ps(bp.add((p + 2) * NR));
            let b3 = _mm256_loadu_ps(bp.add((p + 3) * NR));
            for i in 0..MR {
                let a0 = _mm256_set1_ps(*ap.add(p * MR + i));
                let a1 = _mm256_set1_ps(*ap.add((p + 1) * MR + i));
                let a2 = _mm256_set1_ps(*ap.add((p + 2) * MR + i));
                let a3 = _mm256_set1_ps(*ap.add((p + 3) * MR + i));
                let mut t = _mm256_mul_ps(a0, b0);
                t = _mm256_add_ps(t, _mm256_mul_ps(a1, b1));
                t = _mm256_add_ps(t, _mm256_mul_ps(a2, b2));
                t = _mm256_add_ps(t, _mm256_mul_ps(a3, b3));
                acc[i] = _mm256_add_ps(acc[i], t);
            }
            p += 4;
        }
        while p < kc {
            let b0 = _mm256_loadu_ps(bp.add(p * NR));
            for i in 0..MR {
                let a0 = _mm256_set1_ps(*ap.add(p * MR + i));
                acc[i] = _mm256_add_ps(acc[i], _mm256_mul_ps(a0, b0));
            }
            p += 1;
        }
        for i in 0..MR {
            _mm256_storeu_ps(c.add(i * ldc), acc[i]);
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod arm {
    use super::{MR, NR};
    use std::arch::aarch64::*;

    /// NEON full-tile kernel: two 4-lane vectors per C row, broadcast A,
    /// separate mul/add (no FMA) in the canonical 4-group order.
    ///
    /// # Safety
    ///
    /// Same contract as [`super::mk_scalar`]; additionally the CPU must
    /// support NEON (guarded by the runtime probe in [`super::active`]).
    #[target_feature(enable = "neon")]
    #[allow(clippy::needless_range_loop, clippy::missing_safety_doc)]
    pub unsafe fn mk_neon(kc: usize, ap: *const f32, bp: *const f32, c: *mut f32, ldc: usize) {
        let mut lo = [vdupq_n_f32(0.0); MR];
        let mut hi = [vdupq_n_f32(0.0); MR];
        for i in 0..MR {
            lo[i] = vld1q_f32(c.add(i * ldc));
            hi[i] = vld1q_f32(c.add(i * ldc + 4));
        }
        let mut p = 0usize;
        while p + 4 <= kc {
            let b0l = vld1q_f32(bp.add(p * NR));
            let b0h = vld1q_f32(bp.add(p * NR + 4));
            let b1l = vld1q_f32(bp.add((p + 1) * NR));
            let b1h = vld1q_f32(bp.add((p + 1) * NR + 4));
            let b2l = vld1q_f32(bp.add((p + 2) * NR));
            let b2h = vld1q_f32(bp.add((p + 2) * NR + 4));
            let b3l = vld1q_f32(bp.add((p + 3) * NR));
            let b3h = vld1q_f32(bp.add((p + 3) * NR + 4));
            for i in 0..MR {
                let a0 = vdupq_n_f32(*ap.add(p * MR + i));
                let a1 = vdupq_n_f32(*ap.add((p + 1) * MR + i));
                let a2 = vdupq_n_f32(*ap.add((p + 2) * MR + i));
                let a3 = vdupq_n_f32(*ap.add((p + 3) * MR + i));
                let mut tl = vmulq_f32(a0, b0l);
                tl = vaddq_f32(tl, vmulq_f32(a1, b1l));
                tl = vaddq_f32(tl, vmulq_f32(a2, b2l));
                tl = vaddq_f32(tl, vmulq_f32(a3, b3l));
                lo[i] = vaddq_f32(lo[i], tl);
                let mut th = vmulq_f32(a0, b0h);
                th = vaddq_f32(th, vmulq_f32(a1, b1h));
                th = vaddq_f32(th, vmulq_f32(a2, b2h));
                th = vaddq_f32(th, vmulq_f32(a3, b3h));
                hi[i] = vaddq_f32(hi[i], th);
            }
            p += 4;
        }
        while p < kc {
            let b0l = vld1q_f32(bp.add(p * NR));
            let b0h = vld1q_f32(bp.add(p * NR + 4));
            for i in 0..MR {
                let a0 = vdupq_n_f32(*ap.add(p * MR + i));
                lo[i] = vaddq_f32(lo[i], vmulq_f32(a0, b0l));
                hi[i] = vaddq_f32(hi[i], vmulq_f32(a0, b0h));
            }
            p += 1;
        }
        for i in 0..MR {
            vst1q_f32(c.add(i * ldc), lo[i]);
            vst1q_f32(c.add(i * ldc + 4), hi[i]);
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn simd_kernel() -> Option<(MicroFn, &'static str)> {
    if std::is_x86_feature_detected!("avx2") {
        Some((x86::mk_avx2 as MicroFn, "avx2"))
    } else {
        None
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
fn simd_kernel() -> Option<(MicroFn, &'static str)> {
    if std::arch::is_aarch64_feature_detected!("neon") {
        Some((arm::mk_neon as MicroFn, "neon"))
    } else {
        None
    }
}

#[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn simd_kernel() -> Option<(MicroFn, &'static str)> {
    None
}

static ACTIVE: OnceLock<(MicroFn, &'static str)> = OnceLock::new();

fn resolve() -> (MicroFn, &'static str) {
    simd_kernel().unwrap_or((mk_scalar as MicroFn, "scalar"))
}

/// The full-tile kernel selected for this process: the SIMD kernel when the
/// `simd` feature is on and the CPU supports it, else [`mk_scalar`]. All
/// candidates are bitwise-equal, so the choice affects speed only.
pub fn active() -> MicroFn {
    ACTIVE.get_or_init(resolve).0
}

/// The name of the selected kernel (`"avx2"`, `"neon"` or `"scalar"`) — for
/// bench ledgers and tests.
pub fn active_name() -> &'static str {
    ACTIVE.get_or_init(resolve).1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn panels(kc: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let ap: Vec<f32> = (0..kc * MR).map(|_| rng.normal() as f32).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|_| rng.normal() as f32).collect();
        (ap, bp)
    }

    #[test]
    fn active_kernel_matches_scalar_bitwise() {
        // The dispatch target (AVX2/NEON when the `simd` feature found
        // hardware, scalar otherwise) must agree with the scalar oracle
        // bit-for-bit on full tiles — including kc % 4 remainders.
        let mut rng = Rng::new(91);
        let kern = active();
        for kc in [0usize, 1, 3, 4, 7, 16, 257] {
            let (ap, bp) = panels(kc, &mut rng);
            let init: Vec<f32> = (0..MR * NR).map(|_| rng.normal() as f32).collect();
            let mut want = init.clone();
            let mut got = init;
            unsafe {
                mk_scalar(kc, ap.as_ptr(), bp.as_ptr(), want.as_mut_ptr(), NR);
                kern(kc, ap.as_ptr(), bp.as_ptr(), got.as_mut_ptr(), NR);
            }
            assert_eq!(want, got, "active kernel diverged from scalar at kc={kc}");
        }
        if !cfg!(feature = "simd") {
            assert_eq!(active_name(), "scalar");
        }
    }

    #[test]
    fn edge_kernel_touches_only_the_live_region() {
        // mk_edge on a partial tile must leave every element outside the
        // mr×nr corner untouched (the packed driver points it straight into
        // C, where the neighbors are other tasks' live data).
        let mut rng = Rng::new(92);
        let kc = 9;
        let (ap, bp) = panels(kc, &mut rng);
        for (mr, nr) in [(1usize, 1usize), (3, 5), (7, 8), (8, 7), (5, 2)] {
            let mut c = vec![777.0f32; MR * NR];
            unsafe { mk_edge(kc, ap.as_ptr(), bp.as_ptr(), c.as_mut_ptr(), NR, mr, nr) };
            for i in 0..MR {
                for j in 0..NR {
                    if i < mr && j < nr {
                        continue;
                    }
                    let v = c[i * NR + j];
                    assert_eq!(v, 777.0, "edge kernel wrote outside ({mr}x{nr}) at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn full_tile_matches_reference_dot_products() {
        // Sanity against an f64 reference: the packed-panel kernel computes
        // the same product the panel layout encodes.
        let mut rng = Rng::new(93);
        let kc = 33;
        let (ap, bp) = panels(kc, &mut rng);
        let mut c = vec![0.0f32; MR * NR];
        unsafe { mk_scalar(kc, ap.as_ptr(), bp.as_ptr(), c.as_mut_ptr(), NR) };
        for i in 0..MR {
            for j in 0..NR {
                let want: f64 = (0..kc)
                    .map(|p| ap[p * MR + i] as f64 * bp[p * NR + j] as f64)
                    .sum();
                let got = c[i * NR + j] as f64;
                assert!((got - want).abs() < 1e-3, "tile[{i},{j}] {got} vs {want}");
            }
        }
    }
}
