//! Householder QR decomposition and least squares.
//!
//! Thin QR (m×n, m ≥ n): A = Q·R with Q m×n orthonormal columns, R n×n upper
//! triangular. Used to (re-)orthonormalize subspace bases and to solve the
//! general least-squares problem; the SubTrack++ hot path avoids it because
//! its basis S is already orthonormal (then argmin_A ‖SA−G‖ = SᵀG).

use super::gemm;
use super::matrix::Matrix;

/// Thin QR via Householder reflections. Returns (Q m×n, R n×n). Requires m ≥ n.
pub fn thin_qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = a.shape();
    assert!(m >= n, "thin_qr requires m >= n, got {m}x{n}");
    // Work on a copy of A; accumulate Householder vectors in-place (LAPACK style).
    let mut r = a.clone();
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);
    for k in 0..n {
        // Householder vector for column k, rows k..m.
        let mut v: Vec<f32> = (k..m).map(|i| r.get(i, k)).collect();
        let norm_x = (v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32;
        if norm_x > 0.0 {
            let alpha = if v[0] >= 0.0 { -norm_x } else { norm_x };
            v[0] -= alpha;
            let vnorm =
                (v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32;
            if vnorm > 1e-30 {
                for x in v.iter_mut() {
                    *x /= vnorm;
                }
                // Apply H = I - 2vvᵀ to R[k.., k..].
                for j in k..n {
                    let mut dot = 0.0f64;
                    for (idx, i) in (k..m).enumerate() {
                        dot += v[idx] as f64 * r.get(i, j) as f64;
                    }
                    let dot = 2.0 * dot as f32;
                    for (idx, i) in (k..m).enumerate() {
                        let val = r.get(i, j) - dot * v[idx];
                        r.set(i, j, val);
                    }
                }
            } else {
                v = vec![0.0; m - k];
            }
        }
        vs.push(v);
    }
    // Extract R (n×n upper triangular).
    let mut rr = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rr.set(i, j, r.get(i, j));
        }
    }
    // Form thin Q by applying reflections to the first n columns of I.
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        if v.iter().all(|&x| x == 0.0) {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0f64;
            for (idx, i) in (k..m).enumerate() {
                dot += v[idx] as f64 * q.get(i, j) as f64;
            }
            let dot = 2.0 * dot as f32;
            for (idx, i) in (k..m).enumerate() {
                let val = q.get(i, j) - dot * v[idx];
                q.set(i, j, val);
            }
        }
    }
    (q, rr)
}

/// Re-orthonormalize the columns of `a` in place via thin QR (drift guard).
/// Sign-fixes columns so the diagonal of R is non-negative, making the result
/// a continuous deformation of the input basis.
pub fn reorthonormalize(a: &Matrix) -> Matrix {
    let (q, r) = thin_qr(a);
    let mut q = q;
    let n = q.cols();
    for j in 0..n {
        if r.get(j, j) < 0.0 {
            for i in 0..q.rows() {
                let v = -q.get(i, j);
                q.set(i, j, v);
            }
        }
    }
    q
}

/// Solve the least squares problem min_X ‖A·X − B‖_F for A m×n (m ≥ n,
/// full column rank), B m×p. Returns X n×p. Householder QR + back substitution.
pub fn lstsq(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, n) = a.shape();
    let (mb, p) = b.shape();
    assert_eq!(m, mb, "lstsq row mismatch");
    let (q, r) = thin_qr(a);
    // X = R⁻¹ Qᵀ B
    let qtb = gemm::matmul_tn(&q, b); // n×p
    let mut x = Matrix::zeros(n, p);
    for col in 0..p {
        for i in (0..n).rev() {
            let mut acc = qtb.get(i, col) as f64;
            for j in (i + 1)..n {
                acc -= r.get(i, j) as f64 * x.get(j, col) as f64;
            }
            let rii = r.get(i, i);
            x.set(i, col, if rii.abs() > 1e-30 { (acc / rii as f64) as f32 } else { 0.0 });
        }
    }
    x
}

/// ‖QᵀQ − I‖_max — orthonormality defect of a basis (test/diagnostic helper).
pub fn orthonormality_defect(q: &Matrix) -> f32 {
    let g = gemm::matmul_tn(q, q);
    let n = g.rows();
    let mut worst = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g.get(i, j) - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(20, 8, 1.0, &mut rng);
        let (q, r) = thin_qr(&a);
        assert_eq!(q.shape(), (20, 8));
        assert_eq!(r.shape(), (8, 8));
        let back = gemm::matmul(&q, &r);
        proptest::close(back.data(), a.data(), 1e-4, 1e-4).unwrap();
        assert!(orthonormality_defect(&q) < 1e-5, "Q orthonormal");
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(10, 5, 1.0, &mut rng);
        let (_, r) = thin_qr(&a);
        for i in 0..5 {
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn property_qr_roundtrip() {
        proptest::check(
            7,
            40,
            |rng| {
                let n = 1 + rng.below(12);
                let m = n + rng.below(20);
                Matrix::randn(m, n, 1.0, rng)
            },
            |a| {
                let (q, r) = thin_qr(a);
                proptest::close(gemm::matmul(&q, &r).data(), a.data(), 2e-4, 2e-3)?;
                if orthonormality_defect(&q) > 1e-4 {
                    return Err("Q not orthonormal".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn lstsq_exact_system() {
        // Overdetermined but consistent: A·x = b exactly.
        let mut rng = Rng::new(6);
        let a = Matrix::randn(15, 4, 1.0, &mut rng);
        let x_true = Matrix::randn(4, 3, 1.0, &mut rng);
        let b = gemm::matmul(&a, &x_true);
        let x = lstsq(&a, &b);
        proptest::close(x.data(), x_true.data(), 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn lstsq_residual_orthogonal_to_range() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(20, 5, 1.0, &mut rng);
        let b = Matrix::randn(20, 2, 1.0, &mut rng);
        let x = lstsq(&a, &b);
        let resid = b.sub(&gemm::matmul(&a, &x));
        // Aᵀ r = 0 at the optimum.
        let at_r = gemm::matmul_tn(&a, &resid);
        assert!(at_r.max_abs() < 1e-3, "normal equations hold, got {}", at_r.max_abs());
    }

    #[test]
    fn lstsq_orthonormal_a_equals_transpose_product() {
        // When A has orthonormal columns, lstsq(A, B) == AᵀB. This identity is
        // the SubTrack++ fast path.
        let mut rng = Rng::new(9);
        let raw = Matrix::randn(30, 6, 1.0, &mut rng);
        let (q, _) = thin_qr(&raw);
        let b = Matrix::randn(30, 9, 1.0, &mut rng);
        let x = lstsq(&q, &b);
        let qt_b = gemm::matmul_tn(&q, &b);
        proptest::close(x.data(), qt_b.data(), 1e-4, 1e-3).unwrap();
    }

    #[test]
    fn reorthonormalize_fixes_drift() {
        let mut rng = Rng::new(10);
        let raw = Matrix::randn(25, 5, 1.0, &mut rng);
        let (q, _) = thin_qr(&raw);
        // Inject drift.
        let mut drifted = q.clone();
        drifted.apply(|x| x * 1.001);
        drifted.set(0, 0, drifted.get(0, 0) + 0.01);
        let fixed = reorthonormalize(&drifted);
        assert!(orthonormality_defect(&fixed) < 1e-5);
        // Should stay close to the original basis (same subspace, same signs).
        let diff = fixed.sub(&q).max_abs();
        assert!(diff < 0.05, "basis moved too much: {diff}");
    }
}
