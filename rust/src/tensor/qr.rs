//! Householder QR decomposition and least squares.
//!
//! Thin QR (m×n, m ≥ n): A = Q·R with Q m×n orthonormal columns, R n×n upper
//! triangular. Used to (re-)orthonormalize subspace bases and to solve the
//! general least-squares problem; the SubTrack++ hot path avoids it because
//! its basis S is already orthonormal (then argmin_A ‖SA−G‖ = SᵀG).
//!
//! # Threading and workspaces
//!
//! The trailing-matrix update `H·W[k.., k..]` — the O(mn²) bulk of the
//! factorization — is parallelized across *columns* on the persistent
//! [`pool`]: each column's reflection is one sequential f64 dot plus a
//! scaled subtraction, computed entirely by whichever worker claims it, so
//! results are **bit-identical for any worker count** (the same contract as
//! `gemm::matmul_acc`). [`thin_qr_into`] leases its working copy and the
//! packed Householder vectors from a caller [`Workspace`], making the
//! subspace-refresh paths allocation-free after warm-up.

use super::gemm;
use super::matrix::Matrix;
use super::pool::{self, SendPtr};
use super::workspace::Workspace;

/// Thin QR via Householder reflections. Returns (Q m×n, R n×n). Requires m ≥ n.
pub fn thin_qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = a.shape();
    let mut q = Matrix::zeros(m, n);
    let mut r = Matrix::zeros(n, n);
    thin_qr_into(a, &mut q, &mut r, &mut Workspace::new());
    (q, r)
}

/// Allocation-free [`thin_qr`]: writes Q (m×n) and R (n×n) into
/// caller-provided buffers, leasing the m×n working copy and the packed
/// Householder vectors from `ws`. Outputs are fully overwritten.
pub fn thin_qr_into(a: &Matrix, q: &mut Matrix, r: &mut Matrix, ws: &mut Workspace) {
    let (m, n) = a.shape();
    assert!(m >= n, "thin_qr requires m >= n, got {m}x{n}");
    assert_eq!(q.shape(), (m, n), "thin_qr Q output shape");
    assert_eq!(r.shape(), (n, n), "thin_qr R output shape");
    // Reduce a working copy of A in place (LAPACK style).
    let mut w = ws.take_dirty(m, n);
    w.copy_from(a);
    // Householder vectors, packed: v_k has m−k entries at offset
    // k·m − k(k−1)/2. Every entry is written below (the degenerate branches
    // store explicit zeros), so a dirty lease is safe.
    let mut vs = ws.take_vec_dirty(packed_len(m, n));
    for k in 0..n {
        let v = &mut vs[packed_off(m, k)..packed_off(m, k + 1)];
        // Gather column k, rows k..m.
        for (idx, i) in (k..m).enumerate() {
            v[idx] = w.get(i, k);
        }
        let norm_x = (v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32;
        if norm_x > 0.0 {
            let alpha = if v[0] >= 0.0 { -norm_x } else { norm_x };
            v[0] -= alpha;
            let vnorm =
                (v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32;
            if vnorm > 1e-30 {
                for x in v.iter_mut() {
                    *x /= vnorm;
                }
                // Apply H = I − 2vvᵀ to W[k.., k..] (threaded per column).
                reflect_block(&mut w, k, v, k, n);
            } else {
                v.fill(0.0);
            }
        }
        // norm_x == 0 ⇒ the gathered column was all zeros ⇒ v already zero.
    }
    // Extract R (n×n upper triangular).
    r.data_mut().fill(0.0);
    for i in 0..n {
        for j in i..n {
            r.set(i, j, w.get(i, j));
        }
    }
    // Form thin Q by applying reflections to the first n columns of I.
    q.data_mut().fill(0.0);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    for k in (0..n).rev() {
        let v = &vs[packed_off(m, k)..packed_off(m, k + 1)];
        if v.iter().all(|&x| x == 0.0) {
            continue;
        }
        reflect_block(q, k, v, 0, n);
    }
    ws.give_vec(vs);
    ws.give(w);
}

/// Total packed length of the n Householder vectors: Σ_{k<n} (m−k).
fn packed_len(m: usize, n: usize) -> usize {
    n * m - n.saturating_sub(1) * n / 2
}

/// Offset of v_k in the packed buffer.
fn packed_off(m: usize, k: usize) -> usize {
    k * m - k.saturating_sub(1) * k / 2
}

/// Apply the reflector H = I − 2vvᵀ (acting on rows k..rows) to columns
/// [jlo, jhi) of `w`, fanning column blocks out over the worker pool. Each
/// column is processed start-to-finish by one worker with the identical
/// sequential kernel, so any worker count is bit-identical.
fn reflect_block(w: &mut Matrix, k: usize, v: &[f32], jlo: usize, jhi: usize) {
    let (rows, ncols) = w.shape();
    debug_assert_eq!(v.len(), rows - k);
    let cols = jhi - jlo;
    if cols == 0 || v.is_empty() {
        return;
    }
    let flops = 4usize.saturating_mul(rows - k).saturating_mul(cols);
    let threads = gemm::plan_kernel_threads(flops, cols);
    let base = SendPtr::new(w.data_mut().as_mut_ptr());
    if threads <= 1 {
        reflect_cols(base, ncols, k, v, jlo, jhi);
        return;
    }
    let per = cols.div_ceil(threads);
    let chunks = cols.div_ceil(per);
    pool::run(threads, chunks, &|t| {
        let lo = jlo + t * per;
        let hi = (lo + per).min(jhi);
        reflect_cols(base, ncols, k, v, lo, hi);
    });
}

/// Sequential per-column reflector kernel over columns [jlo, jhi): for each
/// column, an f64 dot with v over rows k.. then the rank-1 subtraction.
/// Tasks touch disjoint columns of the shared buffer.
fn reflect_cols(base: SendPtr<f32>, ncols: usize, k: usize, v: &[f32], jlo: usize, jhi: usize) {
    for j in jlo..jhi {
        unsafe {
            let mut dot = 0.0f64;
            let mut idx = k * ncols + j;
            for &vi in v {
                dot += vi as f64 * (*base.get().add(idx)) as f64;
                idx += ncols;
            }
            let scale = 2.0 * dot as f32;
            let mut idx = k * ncols + j;
            for &vi in v {
                let p = base.get().add(idx);
                *p -= scale * vi;
                idx += ncols;
            }
        }
    }
}

/// Re-orthonormalize the columns of `a` via thin QR (drift guard).
/// Sign-fixes columns so the diagonal of R is non-negative, making the result
/// a continuous deformation of the input basis.
pub fn reorthonormalize(a: &Matrix) -> Matrix {
    let mut s = a.clone();
    reorthonormalize_in_place(&mut s, &mut Workspace::new());
    s
}

/// Allocation-free [`reorthonormalize`]: replaces `s` with the sign-fixed Q
/// of its thin QR, leasing all scratch from `ws`.
pub fn reorthonormalize_in_place(s: &mut Matrix, ws: &mut Workspace) {
    let (m, n) = s.shape();
    let mut q = ws.take_dirty(m, n);
    let mut r = ws.take_dirty(n, n);
    thin_qr_into(s, &mut q, &mut r, ws);
    for j in 0..n {
        if r.get(j, j) < 0.0 {
            for i in 0..m {
                let v = -q.get(i, j);
                q.set(i, j, v);
            }
        }
    }
    s.copy_from(&q);
    ws.give(q);
    ws.give(r);
}

/// Solve the least squares problem min_X ‖A·X − B‖_F for A m×n (m ≥ n,
/// full column rank), B m×p. Returns X n×p. Householder QR + back substitution.
pub fn lstsq(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, n) = a.shape();
    let (mb, p) = b.shape();
    assert_eq!(m, mb, "lstsq row mismatch");
    let (q, r) = thin_qr(a);
    // X = R⁻¹ Qᵀ B
    let qtb = gemm::matmul_tn(&q, b); // n×p
    let mut x = Matrix::zeros(n, p);
    for col in 0..p {
        for i in (0..n).rev() {
            let mut acc = qtb.get(i, col) as f64;
            for j in (i + 1)..n {
                acc -= r.get(i, j) as f64 * x.get(j, col) as f64;
            }
            let rii = r.get(i, i);
            x.set(i, col, if rii.abs() > 1e-30 { (acc / rii as f64) as f32 } else { 0.0 });
        }
    }
    x
}

/// ‖QᵀQ − I‖_max — orthonormality defect of a basis (test/diagnostic helper).
pub fn orthonormality_defect(q: &Matrix) -> f32 {
    let g = gemm::matmul_tn(q, q);
    let n = g.rows();
    let mut worst = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g.get(i, j) - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(20, 8, 1.0, &mut rng);
        let (q, r) = thin_qr(&a);
        assert_eq!(q.shape(), (20, 8));
        assert_eq!(r.shape(), (8, 8));
        let back = gemm::matmul(&q, &r);
        proptest::close(back.data(), a.data(), 1e-4, 1e-4).unwrap();
        assert!(orthonormality_defect(&q) < 1e-5, "Q orthonormal");
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(10, 5, 1.0, &mut rng);
        let (_, r) = thin_qr(&a);
        for i in 0..5 {
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn property_qr_roundtrip() {
        proptest::check(
            7,
            40,
            |rng| {
                let n = 1 + rng.below(12);
                let m = n + rng.below(20);
                Matrix::randn(m, n, 1.0, rng)
            },
            |a| {
                let (q, r) = thin_qr(a);
                proptest::close(gemm::matmul(&q, &r).data(), a.data(), 2e-4, 2e-3)?;
                if orthonormality_defect(&q) > 1e-4 {
                    return Err("Q not orthonormal".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn into_variant_reuses_workspace_and_matches() {
        // Repeated thin_qr_into calls with recurring shapes must settle to
        // zero new misses, and agree with the allocating wrapper bitwise.
        let mut rng = Rng::new(11);
        let mut ws = Workspace::new();
        let a = Matrix::randn(24, 6, 1.0, &mut rng);
        let (q_want, r_want) = thin_qr(&a);
        let mut q = ws.take_dirty(24, 6);
        let mut r = ws.take_dirty(6, 6);
        thin_qr_into(&a, &mut q, &mut r, &mut ws);
        assert_eq!(q.data(), q_want.data());
        assert_eq!(r.data(), r_want.data());
        let misses = ws.misses();
        for _ in 0..3 {
            thin_qr_into(&a, &mut q, &mut r, &mut ws);
        }
        assert_eq!(ws.misses(), misses, "steady-state thin_qr_into allocated");
        ws.give(q);
        ws.give(r);
    }

    #[test]
    fn rank_deficient_columns_are_handled() {
        // A duplicate column makes one Householder step degenerate; the
        // factorization must still reconstruct A.
        let mut rng = Rng::new(12);
        let mut a = Matrix::randn(12, 4, 1.0, &mut rng);
        for i in 0..12 {
            let v = a.get(i, 0);
            a.set(i, 2, v);
        }
        let (q, r) = thin_qr(&a);
        proptest::close(gemm::matmul(&q, &r).data(), a.data(), 1e-4, 1e-3).unwrap();
    }

    #[test]
    fn lstsq_exact_system() {
        // Overdetermined but consistent: A·x = b exactly.
        let mut rng = Rng::new(6);
        let a = Matrix::randn(15, 4, 1.0, &mut rng);
        let x_true = Matrix::randn(4, 3, 1.0, &mut rng);
        let b = gemm::matmul(&a, &x_true);
        let x = lstsq(&a, &b);
        proptest::close(x.data(), x_true.data(), 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn lstsq_residual_orthogonal_to_range() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(20, 5, 1.0, &mut rng);
        let b = Matrix::randn(20, 2, 1.0, &mut rng);
        let x = lstsq(&a, &b);
        let resid = b.sub(&gemm::matmul(&a, &x));
        // Aᵀ r = 0 at the optimum.
        let at_r = gemm::matmul_tn(&a, &resid);
        assert!(at_r.max_abs() < 1e-3, "normal equations hold, got {}", at_r.max_abs());
    }

    #[test]
    fn lstsq_orthonormal_a_equals_transpose_product() {
        // When A has orthonormal columns, lstsq(A, B) == AᵀB. This identity is
        // the SubTrack++ fast path.
        let mut rng = Rng::new(9);
        let raw = Matrix::randn(30, 6, 1.0, &mut rng);
        let (q, _) = thin_qr(&raw);
        let b = Matrix::randn(30, 9, 1.0, &mut rng);
        let x = lstsq(&q, &b);
        let qt_b = gemm::matmul_tn(&q, &b);
        proptest::close(x.data(), qt_b.data(), 1e-4, 1e-3).unwrap();
    }

    #[test]
    fn reorthonormalize_fixes_drift() {
        let mut rng = Rng::new(10);
        let raw = Matrix::randn(25, 5, 1.0, &mut rng);
        let (q, _) = thin_qr(&raw);
        // Inject drift.
        let mut drifted = q.clone();
        drifted.apply(|x| x * 1.001);
        drifted.set(0, 0, drifted.get(0, 0) + 0.01);
        let fixed = reorthonormalize(&drifted);
        assert!(orthonormality_defect(&fixed) < 1e-5);
        // Should stay close to the original basis (same subspace, same signs).
        let diff = fixed.sub(&q).max_abs();
        assert!(diff < 0.05, "basis moved too much: {diff}");
        // In-place variant agrees bitwise.
        let mut in_place = drifted.clone();
        reorthonormalize_in_place(&mut in_place, &mut Workspace::new());
        assert_eq!(in_place.data(), fixed.data());
    }
}
