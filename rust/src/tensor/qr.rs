//! Householder QR decomposition and least squares, WY-blocked.
//!
//! Thin QR (m×n, m ≥ n): A = Q·R with Q m×n orthonormal columns, R n×n upper
//! triangular. Used to (re-)orthonormalize subspace bases and to solve the
//! general least-squares problem; the SubTrack++ hot path avoids it because
//! its basis S is already orthonormal (then argmin_A ‖SA−G‖ = SᵀG).
//!
//! # Blocked (compact WY) scheme
//!
//! The factorization proceeds in panels of `nb` columns. Within a panel the
//! classic per-column Householder kernel runs unchanged (each reflector's
//! trailing update restricted to the panel). The panel's `nb` reflectors are
//! then accumulated into the compact WY representation
//!
//! ```text
//! H_{k0}·H_{k0+1}⋯H_{k1−1} = I − V·T·Vᵀ
//! ```
//!
//! with V m×nb lower-trapezoidal (column j holds the unit-norm v_{k0+j},
//! zeros above row k0+j) and T nb×nb upper triangular (τ = 2 on the diagonal
//! for live reflectors, 0 for degenerate ones; LAPACK `dlarft`-style
//! recurrence). The trailing matrix — the O(mn²) bulk of the work — is then
//! updated wholesale as three GEMMs, C ← C − V·Tᵀ·(VᵀC), and the backward
//! Q-formation pass applies I − V·T·Vᵀ per panel the same way. This turns
//! the memory-bound rank-1 reflector fan into the compute-bound
//! register-blocked [`gemm`] kernels (`matmul_tn_into` / `matmul_into` /
//! `matmul_acc`) — the compute-over-bandwidth trade the ROADMAP's "blocked
//! Householder (QR3)" item called for.
//!
//! # Block-size heuristic
//!
//! [`thin_qr_into`] uses [`qr_block`]: the `GEMM_QR_BLOCK` env var (read
//! once) or [`set_qr_block`] force a panel width; otherwise
//! [`DEFAULT_QR_BLOCK`] (= 8, sized for the repo's refresh ranks r ≤ 32).
//! Inputs with n < nb — and a forced block of 1 — fall back to the pure
//! per-column kernel, which is also what each panel runs internally, so the
//! narrow-matrix paths are byte-for-byte the pre-WY algorithm.
//! [`thin_qr_into_blocked`] exposes the explicit-`nb` entry point for
//! benches (`examples/gemmbench.rs` block-size sweep) and the boundary
//! property tests in `rust/tests/subspace_props.rs`.
//!
//! # Threading, determinism, workspaces
//!
//! Panel factorization fans column chunks over the persistent [`pool`]'s
//! work-stealing scheduler (chunk size from `gemm::chunk_units`, the
//! `GEMM_CHUNK` override applies; one column = one task's unit = the
//! identical sequential kernel), and the block GEMMs thread by disjoint
//! output-row chunks, so results are **bit-identical for any worker count
//! at a fixed block and chunk size** — the same contract as
//! `gemm::matmul_acc`. Different block sizes reorder the floating-point
//! accumulation and agree only to fp tolerance (tested).
//! [`thin_qr_into`] leases the working copy, the packed Householder
//! vectors, and every V/T/W panel buffer from a caller [`Workspace`]: panel
//! shapes recur across refreshes, so the subspace-refresh paths stay
//! allocation-free after their first occurrence (`rust/tests/zero_alloc.rs`).

use super::gemm;
use super::matrix::Matrix;
use super::pool::{self, SendPtr};
use super::workspace::Workspace;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Default WY panel width: wide enough that the trailing update's GEMMs beat
/// the per-column fan at the repo's refresh shapes (m a few hundred,
/// n = rank ≤ 32), narrow enough that a rank-8 refresh is a single panel.
pub const DEFAULT_QR_BLOCK: usize = 8;

/// 0 = default, otherwise a forced panel width. `usize::MAX` is the "unset"
/// sentinel: the first read seeds the value from the `GEMM_QR_BLOCK`
/// environment variable (the CI matrix runs a `GEMM_QR_BLOCK=4` leg so the
/// panel-boundary paths execute under both worker counts).
static QR_BLOCK: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Force the WY panel width (0 restores the `GEMM_QR_BLOCK` env default, or
/// [`DEFAULT_QR_BLOCK`] when the variable is unset; 1 forces the pure
/// per-column kernel). Block size changes the fp accumulation order, so —
/// unlike the worker count — it is *not* bit-transparent.
pub fn set_qr_block(nb: usize) {
    QR_BLOCK.store(if nb == 0 { usize::MAX } else { nb }, Ordering::Relaxed);
}

/// The panel width [`thin_qr_into`] will use: explicit [`set_qr_block`]
/// value, else the `GEMM_QR_BLOCK` env var (parsed once), else
/// [`DEFAULT_QR_BLOCK`].
pub fn qr_block() -> usize {
    let cur = gemm::env_knob(&QR_BLOCK, "GEMM_QR_BLOCK");
    // 0 (env unset or explicit "0") means "use the default"; the sentinel
    // can reappear if `set_qr_block(0)` raced the resolve.
    if cur == 0 || cur == usize::MAX {
        DEFAULT_QR_BLOCK
    } else {
        cur
    }
}

/// Thin QR via Householder reflections. Returns (Q m×n, R n×n). Requires m ≥ n.
pub fn thin_qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = a.shape();
    let mut q = Matrix::zeros(m, n);
    let mut r = Matrix::zeros(n, n);
    thin_qr_into(a, &mut q, &mut r, &mut Workspace::new());
    (q, r)
}

/// Allocation-free [`thin_qr`]: writes Q (m×n) and R (n×n) into
/// caller-provided buffers, leasing the m×n working copy, the packed
/// Householder vectors, and the WY panel buffers from `ws`. Outputs are
/// fully overwritten. Panel width from [`qr_block`].
pub fn thin_qr_into(a: &Matrix, q: &mut Matrix, r: &mut Matrix, ws: &mut Workspace) {
    thin_qr_into_blocked(a, q, r, ws, qr_block());
}

/// [`thin_qr_into`] at an explicit WY panel width `nb` (bench/test entry
/// point). `nb ≤ 1` — or n < `nb` — selects the pure per-column kernel.
/// At any fixed `nb` the result is bit-identical for any worker count.
pub fn thin_qr_into_blocked(
    a: &Matrix,
    q: &mut Matrix,
    r: &mut Matrix,
    ws: &mut Workspace,
    nb: usize,
) {
    let (m, n) = a.shape();
    assert!(m >= n, "thin_qr requires m >= n, got {m}x{n}");
    assert_eq!(q.shape(), (m, n), "thin_qr Q output shape");
    assert_eq!(r.shape(), (n, n), "thin_qr R output shape");
    // Reduce a working copy of A in place (LAPACK style).
    let mut w = ws.take_dirty(m, n);
    w.copy_from(a);
    // Householder vectors, packed: v_k has m−k entries at offset
    // k·m − k(k−1)/2. Every entry is written below (the degenerate branches
    // store explicit zeros), so a dirty lease is safe.
    let mut vs = ws.take_vec_dirty(packed_len(m, n));
    let blocked = nb >= 2 && n >= nb;
    if blocked {
        factor_blocked(&mut w, &mut vs, nb, ws);
    } else {
        for k in 0..n {
            householder_column(&mut w, &mut vs, k, n);
        }
    }
    // Extract R (n×n upper triangular).
    r.data_mut().fill(0.0);
    for i in 0..n {
        for j in i..n {
            r.set(i, j, w.get(i, j));
        }
    }
    // Form thin Q by applying reflections to the first n columns of I.
    q.data_mut().fill(0.0);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    if blocked {
        form_q_blocked(q, &vs, nb, ws);
    } else {
        for k in (0..n).rev() {
            let v = &vs[packed_off(m, k)..packed_off(m, k + 1)];
            if v.iter().all(|&x| x == 0.0) {
                continue;
            }
            reflect_block(q, k, v, 0, n);
        }
    }
    ws.give_vec(vs);
    ws.give(w);
}

/// Factor column k of `w`: gather the column below the diagonal, build the
/// unit-norm Householder vector v_k into the packed buffer, and apply
/// H = I − 2vvᵀ to columns [k, jhi) (the full trailing matrix in the
/// per-column scheme, the current panel in the blocked one). Degenerate
/// columns store an explicit zero vector (H = I).
fn householder_column(w: &mut Matrix, vs: &mut [f32], k: usize, jhi: usize) {
    let (m, _) = w.shape();
    let v = &mut vs[packed_off(m, k)..packed_off(m, k + 1)];
    // Gather column k, rows k..m.
    for (idx, i) in (k..m).enumerate() {
        v[idx] = w.get(i, k);
    }
    let norm_x = (v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32;
    if norm_x > 0.0 {
        let alpha = if v[0] >= 0.0 { -norm_x } else { norm_x };
        v[0] -= alpha;
        let vnorm = (v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32;
        if vnorm > 1e-30 {
            for x in v.iter_mut() {
                *x /= vnorm;
            }
            // Apply H = I − 2vvᵀ to W[k.., k..jhi) (threaded per column).
            reflect_block(w, k, v, k, jhi);
        } else {
            v.fill(0.0);
        }
    }
    // norm_x == 0 ⇒ the gathered column was all zeros ⇒ v already zero.
}

/// Blocked forward pass: factor panels of `nb` columns with the per-column
/// kernel, then update the trailing matrix through the compact WY form,
/// C ← C − V·Tᵀ·(VᵀC). (Reflectors apply in increasing k, so the combined
/// operator is (H_{k0}⋯H_{k1−1})ᵀ = I − V·Tᵀ·Vᵀ.) Every panel buffer is
/// leased from `ws`; the trailing block is staged through a contiguous copy
/// so the threaded GEMM kernels apply unchanged.
fn factor_blocked(w: &mut Matrix, vs: &mut [f32], nb: usize, ws: &mut Workspace) {
    let (m, n) = w.shape();
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + nb).min(n);
        let bs = k1 - k0;
        for k in k0..k1 {
            householder_column(w, vs, k, k1);
        }
        if k1 < n {
            let mut v = ws.take_dirty(m, bs);
            build_panel_v(vs, m, k0, bs, &mut v);
            let mut t = ws.take_dirty(bs, bs);
            build_panel_t(&v, k0, &mut t, ws);
            let tc = n - k1;
            let mut c = ws.take_dirty(m, tc);
            copy_cols(w, k1, k1 + tc, &mut c);
            let mut w1 = ws.take_dirty(bs, tc);
            gemm::matmul_tn_into(&mut w1, &v, &c, ws); // VᵀC
            let mut w2 = ws.take_dirty(bs, tc);
            gemm::matmul_tn_into(&mut w2, &t, &w1, ws); // Tᵀ(VᵀC)
            gemm::matmul_acc(&mut c, &v, &w2, -1.0); // C −= V·Tᵀ·VᵀC
            copy_cols_back(&c, w, k1, k1 + tc);
            ws.give(w2);
            ws.give(w1);
            ws.give(c);
            ws.give(t);
            ws.give(v);
        }
        k0 = k1;
    }
}

/// Blocked backward pass (Q formation): apply I − V·T·Vᵀ panel by panel in
/// reverse order, Q ← Q − V·(T·(VᵀQ)). Q is contiguous, so no staging copy
/// is needed; V and T are rebuilt from the packed vectors (O(m·nb²), small
/// next to the GEMMs).
fn form_q_blocked(q: &mut Matrix, vs: &[f32], nb: usize, ws: &mut Workspace) {
    let (m, n) = q.shape();
    let n_panels = n.div_ceil(nb);
    for p in (0..n_panels).rev() {
        let k0 = p * nb;
        let k1 = (k0 + nb).min(n);
        let bs = k1 - k0;
        let mut v = ws.take_dirty(m, bs);
        build_panel_v(vs, m, k0, bs, &mut v);
        let mut t = ws.take_dirty(bs, bs);
        build_panel_t(&v, k0, &mut t, ws);
        let mut w1 = ws.take_dirty(bs, n);
        gemm::matmul_tn_into(&mut w1, &v, q, ws); // VᵀQ
        let mut w2 = ws.take_dirty(bs, n);
        gemm::matmul_into(&mut w2, &t, &w1); // T(VᵀQ)
        gemm::matmul_acc(q, &v, &w2, -1.0); // Q −= V·T·VᵀQ
        ws.give(w2);
        ws.give(w1);
        ws.give(t);
        ws.give(v);
    }
}

/// Materialize the panel's dense V (m×bs): column j holds v_{k0+j} in rows
/// k0+j.., zeros above. Degenerate reflectors contribute a zero column.
fn build_panel_v(vs: &[f32], m: usize, k0: usize, bs: usize, v: &mut Matrix) {
    debug_assert_eq!(v.shape(), (m, bs));
    let vd = v.data_mut();
    vd.fill(0.0);
    for j in 0..bs {
        let k = k0 + j;
        let col = &vs[packed_off(m, k)..packed_off(m, k + 1)];
        for (idx, &x) in col.iter().enumerate() {
            vd[(k + idx) * bs + j] = x;
        }
    }
}

/// Accumulate the panel's upper-triangular T (bs×bs): τ_j = 2 for live
/// unit-norm reflectors (0 for degenerate ones), and
/// T[0..j, j] = −τ_j · T[0..j, 0..j] · (V[:,0..j]ᵀ v_j) — the `dlarft`
/// forward-columnwise recurrence. Sequential f64 accumulation: the fixed
/// order keeps the blocked kernel bit-identical across worker counts.
fn build_panel_t(v: &Matrix, k0: usize, t: &mut Matrix, ws: &mut Workspace) {
    let (_, bs) = v.shape();
    debug_assert_eq!(t.shape(), (bs, bs));
    t.data_mut().fill(0.0);
    let mut z = ws.take_vec_dirty(bs);
    for j in 0..bs {
        // A live reflector has v[0] = x₀ − α ≠ 0 at row k0+j; degenerate
        // ones were stored as all zeros.
        let tau: f32 = if v.get(k0 + j, j) != 0.0 { 2.0 } else { 0.0 };
        if j > 0 && tau != 0.0 {
            for (i, zi) in z.iter_mut().enumerate().take(j) {
                *zi = v.col_dot(i, j) as f32;
            }
            for i in 0..j {
                let mut acc = 0.0f64;
                for l in i..j {
                    acc += t.get(i, l) as f64 * z[l] as f64;
                }
                t.set(i, j, (-(tau as f64) * acc) as f32);
            }
        }
        t.set(j, j, tau);
    }
    ws.give_vec(z);
}

/// Copy columns [jlo, jhi) of `w` into the contiguous `out` (m×(jhi−jlo)).
fn copy_cols(w: &Matrix, jlo: usize, jhi: usize, out: &mut Matrix) {
    let (m, n) = w.shape();
    let tc = jhi - jlo;
    debug_assert_eq!(out.shape(), (m, tc));
    let wd = w.data();
    let od = out.data_mut();
    for i in 0..m {
        od[i * tc..(i + 1) * tc].copy_from_slice(&wd[i * n + jlo..i * n + jhi]);
    }
}

/// Write the contiguous `src` (m×(jhi−jlo)) back into columns [jlo, jhi).
fn copy_cols_back(src: &Matrix, w: &mut Matrix, jlo: usize, jhi: usize) {
    let (m, n) = w.shape();
    let tc = jhi - jlo;
    debug_assert_eq!(src.shape(), (m, tc));
    let sd = src.data();
    let wd = w.data_mut();
    for i in 0..m {
        wd[i * n + jlo..i * n + jhi].copy_from_slice(&sd[i * tc..(i + 1) * tc]);
    }
}

/// Total packed length of the n Householder vectors: Σ_{k<n} (m−k).
fn packed_len(m: usize, n: usize) -> usize {
    n * m - n.saturating_sub(1) * n / 2
}

/// Offset of v_k in the packed buffer.
fn packed_off(m: usize, k: usize) -> usize {
    k * m - k.saturating_sub(1) * k / 2
}

/// Apply the reflector H = I − 2vvᵀ (acting on rows k..rows) to columns
/// [jlo, jhi) of `w`, fanning column chunks out over the worker pool's
/// steal scheduler. Chunk size from [`gemm::chunk_units`] (the `GEMM_CHUNK`
/// override applies): one column streams `rows − k` strided elements twice.
/// Each column is processed start-to-finish by one task with the identical
/// sequential kernel, so any worker count is bit-identical at a fixed
/// chunk size (and the column kernel does not reassociate across chunks).
fn reflect_block(w: &mut Matrix, k: usize, v: &[f32], jlo: usize, jhi: usize) {
    let (rows, ncols) = w.shape();
    debug_assert_eq!(v.len(), rows - k);
    let cols = jhi - jlo;
    if cols == 0 || v.is_empty() {
        return;
    }
    let flops = 4usize.saturating_mul(rows - k).saturating_mul(cols);
    let threads = gemm::plan_kernel_threads(flops, cols);
    let base = SendPtr::new(w.data_mut().as_mut_ptr());
    if threads <= 1 {
        reflect_cols(base, ncols, k, v, jlo, jhi);
        return;
    }
    let per = gemm::chunk_units(cols, 8 * (rows - k), threads);
    let chunks = cols.div_ceil(per);
    pool::run(threads, chunks, &|t| {
        let lo = jlo + t * per;
        let hi = (lo + per).min(jhi);
        reflect_cols(base, ncols, k, v, lo, hi);
    });
}

/// Sequential per-column reflector kernel over columns [jlo, jhi): for each
/// column, an f64 dot with v over rows k.. then the rank-1 subtraction.
/// Tasks touch disjoint columns of the shared buffer.
fn reflect_cols(base: SendPtr<f32>, ncols: usize, k: usize, v: &[f32], jlo: usize, jhi: usize) {
    for j in jlo..jhi {
        unsafe {
            let mut dot = 0.0f64;
            let mut idx = k * ncols + j;
            for &vi in v {
                dot += vi as f64 * (*base.get().add(idx)) as f64;
                idx += ncols;
            }
            let scale = 2.0 * dot as f32;
            let mut idx = k * ncols + j;
            for &vi in v {
                let p = base.get().add(idx);
                *p -= scale * vi;
                idx += ncols;
            }
        }
    }
}

/// Re-orthonormalize the columns of `a` via thin QR (drift guard).
/// Sign-fixes columns so the diagonal of R is non-negative, making the result
/// a continuous deformation of the input basis.
pub fn reorthonormalize(a: &Matrix) -> Matrix {
    let mut s = a.clone();
    reorthonormalize_in_place(&mut s, &mut Workspace::new());
    s
}

/// Allocation-free [`reorthonormalize`]: replaces `s` with the sign-fixed Q
/// of its thin QR (WY-blocked for rank ≥ [`qr_block`]), leasing all scratch
/// from `ws`.
pub fn reorthonormalize_in_place(s: &mut Matrix, ws: &mut Workspace) {
    let (m, n) = s.shape();
    let mut q = ws.take_dirty(m, n);
    let mut r = ws.take_dirty(n, n);
    thin_qr_into(s, &mut q, &mut r, ws);
    for j in 0..n {
        if r.get(j, j) < 0.0 {
            for i in 0..m {
                let v = -q.get(i, j);
                q.set(i, j, v);
            }
        }
    }
    s.copy_from(&q);
    ws.give(q);
    ws.give(r);
}

/// Solve the least squares problem min_X ‖A·X − B‖_F for A m×n (m ≥ n,
/// full column rank), B m×p. Returns X n×p. Householder QR (WY-blocked via
/// [`thin_qr`]) + back substitution.
pub fn lstsq(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, n) = a.shape();
    let (mb, p) = b.shape();
    assert_eq!(m, mb, "lstsq row mismatch");
    let (q, r) = thin_qr(a);
    // X = R⁻¹ Qᵀ B
    let qtb = gemm::matmul_tn(&q, b); // n×p
    let mut x = Matrix::zeros(n, p);
    for col in 0..p {
        for i in (0..n).rev() {
            let mut acc = qtb.get(i, col) as f64;
            for j in (i + 1)..n {
                acc -= r.get(i, j) as f64 * x.get(j, col) as f64;
            }
            let rii = r.get(i, i);
            x.set(i, col, if rii.abs() > 1e-30 { (acc / rii as f64) as f32 } else { 0.0 });
        }
    }
    x
}

/// ‖QᵀQ − I‖_max — orthonormality defect of a basis (test/diagnostic helper).
pub fn orthonormality_defect(q: &Matrix) -> f32 {
    let g = gemm::matmul_tn(q, q);
    let n = g.rows();
    let mut worst = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g.get(i, j) - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(20, 8, 1.0, &mut rng);
        let (q, r) = thin_qr(&a);
        assert_eq!(q.shape(), (20, 8));
        assert_eq!(r.shape(), (8, 8));
        let back = gemm::matmul(&q, &r);
        proptest::close(back.data(), a.data(), 1e-4, 1e-4).unwrap();
        assert!(orthonormality_defect(&q) < 1e-5, "Q orthonormal");
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(10, 5, 1.0, &mut rng);
        let (_, r) = thin_qr(&a);
        for i in 0..5 {
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn property_qr_roundtrip() {
        proptest::check(
            7,
            40,
            |rng| {
                let n = 1 + rng.below(12);
                let m = n + rng.below(20);
                Matrix::randn(m, n, 1.0, rng)
            },
            |a| {
                let (q, r) = thin_qr(a);
                proptest::close(gemm::matmul(&q, &r).data(), a.data(), 2e-4, 2e-3)?;
                if orthonormality_defect(&q) > 1e-4 {
                    return Err("Q not orthonormal".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn into_variant_reuses_workspace_and_matches() {
        // Repeated thin_qr_into calls with recurring shapes must settle to
        // zero new misses, and agree with the allocating wrapper bitwise.
        let mut rng = Rng::new(11);
        let mut ws = Workspace::new();
        let a = Matrix::randn(24, 6, 1.0, &mut rng);
        let (q_want, r_want) = thin_qr(&a);
        let mut q = ws.take_dirty(24, 6);
        let mut r = ws.take_dirty(6, 6);
        thin_qr_into(&a, &mut q, &mut r, &mut ws);
        assert_eq!(q.data(), q_want.data());
        assert_eq!(r.data(), r_want.data());
        let misses = ws.misses();
        for _ in 0..3 {
            thin_qr_into(&a, &mut q, &mut r, &mut ws);
        }
        assert_eq!(ws.misses(), misses, "steady-state thin_qr_into allocated");
        ws.give(q);
        ws.give(r);
    }

    #[test]
    fn blocked_variant_reuses_workspace_in_steady_state() {
        // The WY panel buffers (V, T, staged trailing block, W₁/W₂) must all
        // come back to the pool: repeated blocked factorizations of the same
        // shape add no misses after the first.
        let mut rng = Rng::new(13);
        let mut ws = Workspace::new();
        let a = Matrix::randn(40, 14, 1.0, &mut rng);
        let mut q = ws.take_dirty(40, 14);
        let mut r = ws.take_dirty(14, 14);
        thin_qr_into_blocked(&a, &mut q, &mut r, &mut ws, 4);
        let misses = ws.misses();
        for _ in 0..3 {
            thin_qr_into_blocked(&a, &mut q, &mut r, &mut ws, 4);
        }
        assert_eq!(ws.misses(), misses, "steady-state blocked thin_qr allocated");
        ws.give(q);
        ws.give(r);
    }

    #[test]
    fn blocked_matches_per_column_within_fp_tolerance() {
        // Block sizes reorder the fp accumulation, so agreement is to
        // tolerance, not bitwise — but the factorization invariants hold at
        // every nb, including panel-boundary shapes (n not a multiple of nb).
        let mut rng = Rng::new(14);
        let mut ws = Workspace::new();
        for (m, n) in [(30, 9), (48, 16), (25, 7)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let mut q1 = ws.take_dirty(m, n);
            let mut r1 = ws.take_dirty(n, n);
            thin_qr_into_blocked(&a, &mut q1, &mut r1, &mut ws, 1);
            for nb in [2usize, 3, 4, 8] {
                let mut qb = ws.take_dirty(m, n);
                let mut rb = ws.take_dirty(n, n);
                thin_qr_into_blocked(&a, &mut qb, &mut rb, &mut ws, nb);
                proptest::close(qb.data(), q1.data(), 1e-4, 1e-3)
                    .unwrap_or_else(|e| panic!("Q diverged ({m}x{n}, nb={nb}): {e}"));
                proptest::close(rb.data(), r1.data(), 1e-4, 1e-3)
                    .unwrap_or_else(|e| panic!("R diverged ({m}x{n}, nb={nb}): {e}"));
                ws.give(qb);
                ws.give(rb);
            }
            ws.give(q1);
            ws.give(r1);
        }
    }

    #[test]
    fn blocked_falls_back_to_per_column_for_narrow_inputs() {
        // n < nb must take the identical per-column path, bit for bit.
        let mut rng = Rng::new(15);
        let mut ws = Workspace::new();
        let a = Matrix::randn(20, 5, 1.0, &mut rng);
        let mut q1 = ws.take_dirty(20, 5);
        let mut r1 = ws.take_dirty(5, 5);
        thin_qr_into_blocked(&a, &mut q1, &mut r1, &mut ws, 1);
        let mut q8 = ws.take_dirty(20, 5);
        let mut r8 = ws.take_dirty(5, 5);
        thin_qr_into_blocked(&a, &mut q8, &mut r8, &mut ws, 8);
        assert_eq!(q1.data(), q8.data(), "narrow fallback changed Q");
        assert_eq!(r1.data(), r8.data(), "narrow fallback changed R");
        ws.give(q1);
        ws.give(r1);
        ws.give(q8);
        ws.give(r8);
    }

    #[test]
    fn rank_deficient_columns_are_handled() {
        // A duplicate column makes one Householder step degenerate; the
        // factorization must still reconstruct A — through the per-column
        // kernel and through blocked panels containing the dead reflector.
        let mut rng = Rng::new(12);
        let mut a = Matrix::randn(12, 4, 1.0, &mut rng);
        for i in 0..12 {
            let v = a.get(i, 0);
            a.set(i, 2, v);
        }
        let (q, r) = thin_qr(&a);
        proptest::close(gemm::matmul(&q, &r).data(), a.data(), 1e-4, 1e-3).unwrap();
        let mut ws = Workspace::new();
        for nb in [2usize, 4] {
            let mut qb = ws.take_dirty(12, 4);
            let mut rb = ws.take_dirty(4, 4);
            thin_qr_into_blocked(&a, &mut qb, &mut rb, &mut ws, nb);
            let back = gemm::matmul(&qb, &rb);
            proptest::close(back.data(), a.data(), 1e-4, 1e-3)
                .unwrap_or_else(|e| panic!("nb={nb}: {e}"));
            ws.give(qb);
            ws.give(rb);
        }
    }

    #[test]
    fn lstsq_exact_system() {
        // Overdetermined but consistent: A·x = b exactly.
        let mut rng = Rng::new(6);
        let a = Matrix::randn(15, 4, 1.0, &mut rng);
        let x_true = Matrix::randn(4, 3, 1.0, &mut rng);
        let b = gemm::matmul(&a, &x_true);
        let x = lstsq(&a, &b);
        proptest::close(x.data(), x_true.data(), 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn lstsq_residual_orthogonal_to_range() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(20, 5, 1.0, &mut rng);
        let b = Matrix::randn(20, 2, 1.0, &mut rng);
        let x = lstsq(&a, &b);
        let resid = b.sub(&gemm::matmul(&a, &x));
        // Aᵀ r = 0 at the optimum.
        let at_r = gemm::matmul_tn(&a, &resid);
        assert!(at_r.max_abs() < 1e-3, "normal equations hold, got {}", at_r.max_abs());
    }

    #[test]
    fn lstsq_orthonormal_a_equals_transpose_product() {
        // When A has orthonormal columns, lstsq(A, B) == AᵀB. This identity is
        // the SubTrack++ fast path.
        let mut rng = Rng::new(9);
        let raw = Matrix::randn(30, 6, 1.0, &mut rng);
        let (q, _) = thin_qr(&raw);
        let b = Matrix::randn(30, 9, 1.0, &mut rng);
        let x = lstsq(&q, &b);
        let qt_b = gemm::matmul_tn(&q, &b);
        proptest::close(x.data(), qt_b.data(), 1e-4, 1e-3).unwrap();
    }

    #[test]
    fn reorthonormalize_fixes_drift() {
        let mut rng = Rng::new(10);
        let raw = Matrix::randn(25, 5, 1.0, &mut rng);
        let (q, _) = thin_qr(&raw);
        // Inject drift.
        let mut drifted = q.clone();
        drifted.apply(|x| x * 1.001);
        drifted.set(0, 0, drifted.get(0, 0) + 0.01);
        let fixed = reorthonormalize(&drifted);
        assert!(orthonormality_defect(&fixed) < 1e-5);
        // Should stay close to the original basis (same subspace, same signs).
        let diff = fixed.sub(&q).max_abs();
        assert!(diff < 0.05, "basis moved too much: {diff}");
        // In-place variant agrees bitwise.
        let mut in_place = drifted.clone();
        reorthonormalize_in_place(&mut in_place, &mut Workspace::new());
        assert_eq!(in_place.data(), fixed.data());
    }
}
