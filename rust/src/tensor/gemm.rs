//! Cache-blocked GEMM in all transpose variants.
//!
//! Row-major. The `ikj` loop order streams both B-rows and C-rows
//! sequentially, which autovectorizes well; blocking keeps the working set
//! inside L2. The transpose variants avoid materializing Aᵀ/Bᵀ on small
//! shapes — the subspace math (SᵀG, R·Aᵀ, SₜᵀSₜ₋₁) is dominated by these.
//!
//! Two step-loop-oriented extensions on top of the out-of-place API:
//!
//! * **`_into` / `_acc` variants** write into caller-provided buffers
//!   (typically leased from a [`Workspace`]) so steady-state training steps
//!   perform no heap allocation. The transpose variants borrow their Aᵀ/Bᵀ
//!   scratch from the workspace too.
//! * **Row-block threading with adaptive chunking**: `matmul_acc` splits
//!   C's rows into chunks dispatched on the persistent [`pool`]'s
//!   work-stealing scheduler (no external deps, no per-call forks). Chunk
//!   sizes come from `chunk_units`: an L2-aware bytes-per-task target
//!   ([`CHUNK_TARGET_BYTES`]) divided by the bytes one row streams, capped
//!   so every participant gets at least one chunk — large-k products get
//!   fine chunks the stealer can rebalance, small ones stay one-chunk-per-
//!   worker. The `GEMM_CHUNK` env var / [`set_gemm_chunk`] force a chunk
//!   size (CI runs a 4-row leg so ragged chunks and the steal path are
//!   exercised), mirroring `GEMM_THREADS`/`GEMM_QR_BLOCK`. Each row of C is
//!   computed by exactly one task with the identical single-thread kernel,
//!   so results are **bit-identical** for any worker count at a fixed chunk
//!   size (different chunk sizes are documented to agree only to fp
//!   tolerance, though the row-block kernels do not currently reassociate
//!   across chunk boundaries). Auto mode threads only above [`PAR_FLOPS`]
//!   and degrades to the single-core path when
//!   `available_parallelism() == 1`; `set_gemm_threads` (or the
//!   `GEMM_THREADS` env var, read once) forces a count (used by the DP
//!   worker plumbing in `train::parallel`, CI, and tests). The same plan
//!   gates the threaded QR/SVD/matvec kernels, so one knob budgets every
//!   level of parallelism.
//! * **Packed-panel path with register-tiled micro-kernels.** Products above
//!   [`PACK_MIN_FLOPS`] (auto mode) copy their operands into contiguous
//!   micro-panels ([`super::pack`]: A in [`MR`]-row panels with `alpha`
//!   folded in, B in [`NR`]-column panels, 16-bit `MatrixB` operands decoded
//!   during the copy) and run the register-tiled kernels in
//!   [`super::microkernel`] — scalar by default, AVX2/NEON when the `simd`
//!   cargo feature is on and the CPU supports it. Loop structure: [`KC`]-deep
//!   k-blocks advance **sequentially and outermost**; within a block, one
//!   pool dispatch covers a (row block × column group) task grid, each task
//!   packing its own [`MC`]×KC A panel and calling the micro-kernel per
//!   tile. Each C element's contributions within a k-block live in exactly
//!   one task and blocks are ordered, so the per-element accumulation order
//!   is *independent of the task grid* — and every micro-kernel reproduces
//!   the legacy kernel's canonical order (k-steps in 4-groups, each group
//!   summed left-associatively and folded into C with one add, then
//!   singles; SIMD uses separate mul/add, never FMA). The packed path is
//!   therefore **bit-identical** to the legacy kernels for every shape,
//!   worker count and build flavor — routing is behaviorally invisible and
//!   only affects speed (`rust/tests/gemm_packed.rs` gates this against the
//!   legacy oracle). `GEMM_PACK` / [`set_gemm_pack`] force the route: 0 =
//!   size-gated auto, 1 = legacy kernels only, 2 = packed whenever the
//!   shape permits. Panel scratch leases from a process-wide bank
//!   ([`super::pack::pack_misses`] gates the warm-up-only allocations), and
//!   the column-group dimension gives wide-short products (m ≪ n) real
//!   fan-out, which the row-only legacy split could never reach.

use super::dtype::MatrixB;
use super::matrix::Matrix;
use super::microkernel::{self, MR, NR};
use super::pack::{self, KBlock, SrcA, SrcB};
use super::pool::{self, SendPtr};
use super::workspace::Workspace;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Tile edge for the k-dimension blocking — also the k-depth of one packed
/// panel set (the packed driver's sequential outer blocks).
pub const KC: usize = 256;
/// Tile edge for the m-dimension blocking — also the row-block height of one
/// packed-driver task (a multiple of [`MR`], so full tiles dominate).
pub const MC: usize = 64;

/// FLOP count (2·m·k·n) above which auto mode routes a product through the
/// packed-panel path. Below it the panel copies cost more than they save;
/// the gate also requires at least one full [`MR`]×[`NR`] tile. Routing is
/// bit-transparent either way, so the threshold affects speed only.
pub const PACK_MIN_FLOPS: usize = 1 << 17;

/// FLOP count (2·m·k·n) below which auto mode stays single-threaded: forking
/// scoped threads costs tens of microseconds, which only pays off once the
/// kernel itself runs for a comparable time.
pub const PAR_FLOPS: usize = 1 << 21;

/// Auto-threading threshold for the non-GEMM kernels (QR reflector fan,
/// Jacobi rounds, matvec blocks). Dispatch on the persistent pool costs
/// ~1 µs — far below a scoped-thread fork — so these engage much earlier
/// than [`PAR_FLOPS`]; at the repo's refresh shapes (m = n = 256, r ≤ 32)
/// the Jacobi rounds and power-iteration matvecs clear this bar while
/// genuinely tiny updates (thin-QR trailing blocks at r ≤ 16) stay
/// sequential.
pub const PAR_KERNEL_FLOPS: usize = 1 << 17;

/// Bytes of streamed data one pool task should own in auto chunking mode —
/// sized to keep a chunk's A/C rows (or matvec rows, reflector columns,
/// Jacobi pair columns) resident in a per-core L2 slice while still cutting
/// large kernels into several chunks per worker so the steal scheduler has
/// slack to rebalance uneven costs.
pub const CHUNK_TARGET_BYTES: usize = 128 << 10;

/// 0 = auto (size-gated `available_parallelism`), otherwise a forced count.
/// `usize::MAX` is the "unset" sentinel: the first read seeds the value from
/// the `GEMM_THREADS` environment variable (CI exercises both kernel paths
/// by running the suite under `GEMM_THREADS=1` and `GEMM_THREADS=8`).
static GEMM_THREADS: AtomicUsize = AtomicUsize::new(usize::MAX);

/// 0 = auto (L2-target chunking), otherwise a forced chunk size in unit
/// tasks (GEMM/matvec rows, matvec_t/reflector columns, Jacobi pairs).
/// `usize::MAX` is the "unset" sentinel: the first read seeds the value
/// from the `GEMM_CHUNK` environment variable (the CI matrix runs a
/// `GEMM_CHUNK=4` leg so small, ragged chunks exercise the steal path).
static GEMM_CHUNK: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Packed-path routing: 0 = auto ([`PACK_MIN_FLOPS`]-gated), 1 = legacy
/// kernels only (the packed path's bit-identity oracle), 2 = packed
/// whenever the shape permits. `usize::MAX` is the "unset" sentinel: the
/// first read seeds the value from the `GEMM_PACK` environment variable.
/// Routing is bit-transparent, so this knob can never change results.
static GEMM_PACK: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Shared resolution for the `usize::MAX`-sentinel env knobs
/// (`GEMM_THREADS`, `GEMM_CHUNK`, `GEMM_QR_BLOCK`): an explicit setter
/// value wins; the sentinel re-resolves from `var` (parsed on first read
/// after each reset), so `set_*(0)` restores the env default rather than
/// erasing a CI-wide setting. May return the sentinel itself when a
/// concurrent `set_*(0)` races the exchange — callers treat it as "unset".
pub(crate) fn env_knob(cell: &AtomicUsize, var: &str) -> usize {
    let cur = cell.load(Ordering::Relaxed);
    if cur != usize::MAX {
        return cur;
    }
    let from_env = std::env::var(var)
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(0);
    // Only replace the sentinel so a concurrent setter wins.
    let _ = cell.compare_exchange(usize::MAX, from_env, Ordering::Relaxed, Ordering::Relaxed);
    cell.load(Ordering::Relaxed)
}

/// The forced worker count: explicit [`set_gemm_threads`] value, else the
/// `GEMM_THREADS` env var (parsed once), else 0 (auto).
fn forced_threads() -> usize {
    let n = env_knob(&GEMM_THREADS, "GEMM_THREADS");
    if n == usize::MAX {
        0
    } else {
        n
    }
}

thread_local! {
    /// Set inside data-parallel worker threads: the cores are already taken
    /// by sibling workers, so nested GEMM forking would only oversubscribe.
    static FORCE_SINGLE: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Serializes lib tests that depend on the process-global knob *values*
/// (asserting what `chunk_units` returns, or needing a forced chunk to hold
/// for a whole measured run): the harness runs this crate's tests
/// concurrently, and while the knobs are result-transparent, knob-value
/// assertions are not. (The integration binaries have their own
/// `THREAD_KNOB` for the same reason.)
#[cfg(test)]
pub(crate) static TEST_KNOB_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Force the GEMM worker count (0 restores the `GEMM_THREADS` env default,
/// or auto when the variable is unset). Threading is bit-exact, so this only
/// affects speed, never results.
pub fn set_gemm_threads(n: usize) {
    // Storing the sentinel makes the next read re-resolve the env var, so a
    // test that restores "auto" does not erase a CI-wide GEMM_THREADS=N.
    GEMM_THREADS.store(if n == 0 { usize::MAX } else { n }, Ordering::Relaxed);
}

/// The forced chunk size: explicit [`set_gemm_chunk`] value, else the
/// `GEMM_CHUNK` env var (parsed once), else 0 (auto).
fn forced_chunk() -> usize {
    let n = env_knob(&GEMM_CHUNK, "GEMM_CHUNK");
    if n == usize::MAX {
        0
    } else {
        n
    }
}

/// Force the per-task chunk size for every chunk-dispatched kernel (0
/// restores the `GEMM_CHUNK` env default, or the L2-target auto sizing when
/// the variable is unset). At a fixed chunk size results are bit-identical
/// for any worker count; *different* chunk sizes are only promised to agree
/// to fp tolerance (the documented contract, shared with `GEMM_QR_BLOCK` —
/// today's row/column/pair kernels do not reassociate across chunk
/// boundaries, but the promise leaves room for ones that do).
pub fn set_gemm_chunk(n: usize) {
    // Storing the sentinel makes the next read re-resolve the env var, so a
    // test that restores "auto" does not erase a CI-wide GEMM_CHUNK=N.
    GEMM_CHUNK.store(if n == 0 { usize::MAX } else { n }, Ordering::Relaxed);
}

/// The packed-path routing mode: explicit [`set_gemm_pack`] value, else the
/// `GEMM_PACK` env var (parsed once), else 0 (auto).
fn pack_mode() -> usize {
    let n = env_knob(&GEMM_PACK, "GEMM_PACK");
    if n == usize::MAX {
        0
    } else {
        n
    }
}

/// Force the packed-panel routing mode: 1 = legacy kernels only, 2 = packed
/// path whenever the shape permits, 0 restores the `GEMM_PACK` env default
/// (or the [`PACK_MIN_FLOPS`]-gated auto mode when the variable is unset).
/// Both routes are bit-identical by contract, so this only affects speed —
/// tests and the bench harness use it to pit the two against each other.
pub fn set_gemm_pack(n: usize) {
    // Storing the sentinel makes the next read re-resolve the env var, so a
    // test that restores "auto" does not erase a CI-wide GEMM_PACK=N.
    GEMM_PACK.store(if n == 0 { usize::MAX } else { n }, Ordering::Relaxed);
}

/// Upper bound on auto-mode chunks per worker. When one unit outweighs
/// [`CHUNK_TARGET_BYTES`] the L2 target alone would degenerate to one-unit
/// chunks — for large totals that floods the steal deques with thousands of
/// tiny tasks whose dispatch overhead swamps the work. The auto chunk is
/// floored so no worker's share splits into more than this many tasks
/// (enough slack for the stealer to rebalance, bounded dispatch cost).
/// Forced chunks are exempt: CI's `GEMM_CHUNK=4` leg deliberately
/// stress-tests tiny ragged chunks.
pub const MAX_CHUNKS_PER_WORKER: usize = 8;

/// Chunk size (in unit tasks) for a kernel that will dispatch
/// `total` units across `threads` workers, where one unit streams
/// `bytes_per_unit` bytes: the forced `GEMM_CHUNK` if set, else
/// [`CHUNK_TARGET_BYTES`]` / bytes_per_unit`, floored so one worker's share
/// never splits into more than [`MAX_CHUNKS_PER_WORKER`] tasks, and capped
/// so every worker still receives at least one chunk (and at one unit).
/// Chunking is a partitioning decision only — every unit runs the identical
/// sequential kernel whichever chunk carries it.
pub fn chunk_units(total: usize, bytes_per_unit: usize, threads: usize) -> usize {
    let forced = forced_chunk();
    if forced > 0 {
        return forced.clamp(1, total.max(1));
    }
    let per_worker = total.div_ceil(threads.max(1)).max(1);
    let target = (CHUNK_TARGET_BYTES / bytes_per_unit.max(1)).max(1);
    target.max(per_worker.div_ceil(MAX_CHUNKS_PER_WORKER)).min(per_worker)
}

/// Run `f` with GEMM threading disabled on *this* thread (results are
/// bit-identical either way). Used by data-parallel workers, which already
/// occupy one core each — nested forking would oversubscribe the machine.
pub fn run_single_threaded<R>(f: impl FnOnce() -> R) -> R {
    let prev = FORCE_SINGLE.with(|c| c.replace(true));
    let r = f();
    FORCE_SINGLE.with(|c| c.set(prev));
    r
}

/// `available_parallelism`, resolved once per process through the same
/// `usize::MAX` sentinel as the env knobs — it is a syscall, and the three
/// worker planners used to re-issue it on every kernel dispatch.
fn hw_threads() -> usize {
    static HW_THREADS: AtomicUsize = AtomicUsize::new(usize::MAX);
    let cur = HW_THREADS.load(Ordering::Relaxed);
    if cur != usize::MAX {
        return cur;
    }
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let _ = HW_THREADS.compare_exchange(usize::MAX, n, Ordering::Relaxed, Ordering::Relaxed);
    n
}

/// The shared auto-gate body behind every worker plan ([`gemm_threads`]
/// skips the gate, [`plan_rows`] and [`plan_kernel_threads`] cap the result
/// by task count): 1 inside [`run_single_threaded`] or on a pool worker
/// (nested fan-out would oversubscribe), the forced `GEMM_THREADS` count if
/// set, 1 when auto-mode work is below `threshold`, else the cached
/// hardware parallelism. Previously each planner carried its own copy of
/// this body — drift between them is what this helper removes.
fn auto_gate(flops: usize, threshold: usize) -> usize {
    if FORCE_SINGLE.with(|c| c.get()) || pool::on_worker() {
        return 1;
    }
    let forced = forced_threads();
    if forced > 0 {
        return forced;
    }
    if flops < threshold {
        return 1;
    }
    hw_threads()
}

/// 2·m·k·n, saturating — the flop estimate every GEMM plan gates on.
fn gemm_flops(m: usize, k: usize, n: usize) -> usize {
    2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n)
}

/// The worker count GEMM (and the data-parallel trainer plumbing) will use:
/// the forced count if set, else the cached `available_parallelism`.
pub fn gemm_threads() -> usize {
    let forced = forced_threads();
    if forced > 0 {
        forced
    } else {
        hw_threads()
    }
}

/// The legacy row-split plan for one m×k×n product: `(workers, rows per
/// chunk)`. Workers are capped by the planned row-*chunk* count, not raw
/// rows — the old `min(m)` cap admitted up to m workers even when chunking
/// left far fewer tasks than that, waking workers that could never receive
/// one (wide-short products were the worst case: m chunks of several rows
/// each, m workers woken).
fn plan_rows(m: usize, k: usize, n: usize) -> (usize, usize) {
    let cap = auto_gate(gemm_flops(m, k, n), PAR_FLOPS);
    if cap <= 1 || m <= 1 {
        return (1, m.max(1));
    }
    let rows_per = chunk_units(m, 4 * (k + n), cap);
    (cap.min(m.div_ceil(rows_per)).max(1), rows_per)
}

/// The worker plan for non-GEMM kernels (QR reflector columns, Jacobi
/// rotation pairs, matvec blocks): the shared [`auto_gate`] opt-outs and
/// forced count, with the caller supplying its own flop estimate. `tasks`
/// bounds the useful fan-out.
pub(crate) fn plan_kernel_threads(flops: usize, tasks: usize) -> usize {
    auto_gate(flops, PAR_KERNEL_FLOPS).min(tasks).max(1)
}

/// Should this product take the packed-panel path? Mode 1 never, mode 2
/// whenever both output dimensions are live, auto above [`PACK_MIN_FLOPS`]
/// with at least one full [`MR`]×[`NR`] tile. Both answers produce bitwise
/// identical results — this is purely a speed heuristic.
fn use_packed(m: usize, k: usize, n: usize) -> bool {
    match pack_mode() {
        1 => false,
        2 => true,
        _ => gemm_flops(m, k, n) >= PACK_MIN_FLOPS && m >= MR && n >= NR,
    }
}

/// C = A·B. Shapes: (m×k)·(k×n) → m×n.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, _) = a.shape();
    let (_, n) = b.shape();
    let mut c = Matrix::zeros(m, n);
    matmul_acc(&mut c, a, b, 1.0);
    c
}

/// C = A·B into a caller-provided buffer (shape-checked, overwritten).
pub fn matmul_into(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    let (m, k) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    assert_eq!(c.shape(), (m, n), "matmul output shape");
    c.data_mut().fill(0.0);
    matmul_acc(c, a, b, 1.0);
}

/// C += alpha · A·B, in place. Parallel across row blocks of C (and column
/// groups on the packed path).
pub fn matmul_acc(c: &mut Matrix, a: &Matrix, b: &Matrix, alpha: f32) {
    let (m, k) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul inner dims");
    assert_eq!(c.shape(), (m, n), "matmul output shape");
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    if use_packed(m, k, n) {
        let threads = auto_gate(gemm_flops(m, k, n), PAR_FLOPS);
        let (sa, sb) = (SrcA::Rows { a: ad, ld: k }, SrcB::Rows { b: bd, ld: n });
        matmul_acc_packed(cd, (m, k, n), alpha, &sa, &sb, threads);
        return;
    }
    let (threads, rows_per) = plan_rows(m, k, n);
    if threads <= 1 {
        matmul_acc_rows(cd, ad, bd, m, k, n, alpha);
        return;
    }
    // One row of the chunk streams a k-float A row and an n-float C row
    // (B is shared and stays hot across rows).
    let n_chunks = m.div_ceil(rows_per);
    // Disjoint row-block writes into C, one chunk per pool task. Every row
    // is computed by the identical scalar kernel whatever the chunking, so
    // any worker count gives bit-identical results at a fixed chunk size.
    let c_base = SendPtr::new(cd.as_mut_ptr());
    pool::run(threads, n_chunks, &|t| {
        let row0 = t * rows_per;
        let rows = rows_per.min(m - row0);
        let c_chunk =
            unsafe { std::slice::from_raw_parts_mut(c_base.get().add(row0 * n), rows * n) };
        let a_chunk = &ad[row0 * k..(row0 + rows) * k];
        matmul_acc_rows(c_chunk, a_chunk, bd, rows, k, n, alpha);
    });
}

/// The packed-panel driver: C += packed(A)·packed(B), `alpha` folded into
/// the A panels. [`KC`]-deep k-blocks advance sequentially and outermost;
/// within one block, B is packed once (fanned out over the pool) and a
/// (row block × column group) task grid runs the micro-kernels, each task
/// packing its own A rows into a bank-leased [`MC`]×[`KC`] buffer. Every C
/// element's contributions within a k-block live in exactly one task, so
/// the per-element accumulation order — and therefore every bit of the
/// result — is independent of the grid, the worker count and the chunking.
fn matmul_acc_packed(
    cd: &mut [f32],
    dims: (usize, usize, usize),
    alpha: f32,
    a: &SrcA,
    b: &SrcB,
    threads: usize,
) {
    let (m, k, n) = dims;
    if m == 0 || n == 0 {
        return;
    }
    let kern = microkernel::active();
    let col_panels = n.div_ceil(NR);
    let row_blocks = m.div_ceil(MC);
    // Wide-short products (row_blocks < threads) split columns too — the
    // fan-out the legacy row-only split could never reach.
    let col_groups = if threads > row_blocks {
        threads.div_ceil(row_blocks).min(col_panels).max(1)
    } else {
        1
    };
    let panels_per_group = col_panels.div_ceil(col_groups);
    let col_groups = col_panels.div_ceil(panels_per_group);
    let n_tasks = row_blocks * col_groups;
    let mut bws = pack::bank().lease();
    let mut bpack = bws.take_vec_dirty(col_panels * NR * KC);
    let c_base = SendPtr::new(cd.as_mut_ptr());
    for p0 in (0..k).step_by(KC) {
        let kb = KBlock { p0, kc: KC.min(k - p0) };
        pack_b_block(&mut bpack, b, kb, n, threads);
        let bpanels = &bpack[..];
        pool::run(threads.min(n_tasks), n_tasks, &|t| {
            let kc = kb.kc;
            let i0 = (t / col_groups) * MC;
            let rows = MC.min(m - i0);
            let s0 = (t % col_groups) * panels_per_group;
            let s1 = (s0 + panels_per_group).min(col_panels);
            let mut ws = pack::bank().lease();
            let mut apack = ws.take_vec_dirty(MC * KC);
            pack::pack_a(&mut apack, a, kb, i0, rows, alpha);
            for q in 0..rows.div_ceil(MR) {
                let i = i0 + q * MR;
                let mr = MR.min(m - i);
                let ap = apack[q * MR * kc..].as_ptr();
                for s in s0..s1 {
                    let j = s * NR;
                    let nr = NR.min(n - j);
                    let bp = bpanels[s * NR * kc..].as_ptr();
                    let ctile = unsafe { c_base.get().add(i * n + j) };
                    // Full tiles take the dispatched kernel; edge tiles
                    // always take the scalar edge kernel (both build
                    // flavors), writing only the live region of C.
                    if mr == MR && nr == NR {
                        unsafe { kern(kc, ap, bp, ctile, n) };
                    } else {
                        unsafe { microkernel::mk_edge(kc, ap, bp, ctile, n, mr, nr) };
                    }
                }
            }
            ws.give_vec(apack);
            pack::bank().release(ws);
        });
    }
    bws.give_vec(bpack);
    pack::bank().release(bws);
}

/// Pack the full B panel set for one k-block, fanning the panel copies out
/// over the pool when the product is threaded. Partitioning only — every
/// panel's bytes are identical whichever worker copies them.
fn pack_b_block(dst: &mut [f32], b: &SrcB, kb: KBlock, n: usize, threads: usize) {
    let col_panels = n.div_ceil(NR);
    if threads <= 1 || col_panels <= 1 {
        pack::pack_b(dst, b, kb, n, 0, col_panels);
        return;
    }
    // One panel reads and writes kc·NR floats.
    let per = chunk_units(col_panels, 8 * NR * kb.kc, threads);
    let n_chunks = col_panels.div_ceil(per);
    let panel_len = NR * kb.kc;
    let d_base = SendPtr::new(dst.as_mut_ptr());
    pool::run(threads.min(n_chunks), n_chunks, &|t| {
        let s0 = t * per;
        let panels = per.min(col_panels - s0);
        let seg = unsafe {
            std::slice::from_raw_parts_mut(d_base.get().add(s0 * panel_len), panels * panel_len)
        };
        pack::pack_b(seg, b, kb, n, s0, panels);
    });
}

/// The single-thread kernel over a contiguous row block: `cd` is `rows`×n,
/// `ad` is `rows`×k, `bd` the full k×n B.
fn matmul_acc_rows(
    cd: &mut [f32],
    ad: &[f32],
    bd: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    alpha: f32,
) {
    for i0 in (0..rows).step_by(MC) {
        let i1 = (i0 + MC).min(rows);
        for p0 in (0..k).step_by(KC) {
            let p1 = (p0 + KC).min(k);
            // 2×4 register blocking: two C rows share each streamed B row,
            // and each pass over a C row performs 4 FMAs per element. This
            // cuts C traffic 4× and B traffic 2× versus the plain axpy form
            // (measured 20 → ~30+ GFLOPS single-core AVX-512).
            let mut i = i0;
            while i + 2 <= i1 {
                let (c_lo, c_hi) = cd.split_at_mut((i + 1) * n);
                let crow0 = &mut c_lo[i * n..];
                let crow1 = &mut c_hi[..n];
                let arow0 = &ad[i * k..(i + 1) * k];
                let arow1 = &ad[(i + 1) * k..(i + 2) * k];
                let mut p = p0;
                while p + 4 <= p1 {
                    let x0 = alpha * arow0[p];
                    let x1 = alpha * arow0[p + 1];
                    let x2 = alpha * arow0[p + 2];
                    let x3 = alpha * arow0[p + 3];
                    let y0 = alpha * arow1[p];
                    let y1 = alpha * arow1[p + 1];
                    let y2 = alpha * arow1[p + 2];
                    let y3 = alpha * arow1[p + 3];
                    let b0 = &bd[p * n..(p + 1) * n];
                    let b1 = &bd[(p + 1) * n..(p + 2) * n];
                    let b2 = &bd[(p + 2) * n..(p + 3) * n];
                    let b3 = &bd[(p + 3) * n..(p + 4) * n];
                    // Zip form keeps the loops free of bounds checks so LLVM
                    // emits packed AVX-512 FMAs.
                    for (((((cv0, cv1), &v0), &v1), &v2), &v3) in crow0
                        .iter_mut()
                        .zip(crow1.iter_mut())
                        .zip(b0)
                        .zip(b1)
                        .zip(b2)
                        .zip(b3)
                    {
                        *cv0 += x0 * v0 + x1 * v1 + x2 * v2 + x3 * v3;
                        *cv1 += y0 * v0 + y1 * v1 + y2 * v2 + y3 * v3;
                    }
                    p += 4;
                }
                while p < p1 {
                    let x = alpha * arow0[p];
                    let y = alpha * arow1[p];
                    let brow = &bd[p * n..(p + 1) * n];
                    for ((cv0, cv1), &bv) in
                        crow0.iter_mut().zip(crow1.iter_mut()).zip(brow)
                    {
                        *cv0 += x * bv;
                        *cv1 += y * bv;
                    }
                    p += 1;
                }
                i += 2;
            }
            // Remainder row. No `av == 0` shortcut: a zero A entry must still
            // multiply B so NaN/Inf in B propagates into C (grad_clip relies
            // on non-finite values surfacing, not being silently dropped).
            while i < i1 {
                let arow = &ad[i * k..(i + 1) * k];
                let crow = &mut cd[i * n..(i + 1) * n];
                let mut p = p0;
                while p + 4 <= p1 {
                    let a0 = alpha * arow[p];
                    let a1 = alpha * arow[p + 1];
                    let a2 = alpha * arow[p + 2];
                    let a3 = alpha * arow[p + 3];
                    let b0 = &bd[p * n..(p + 1) * n];
                    let b1 = &bd[(p + 1) * n..(p + 2) * n];
                    let b2 = &bd[(p + 2) * n..(p + 3) * n];
                    let b3 = &bd[(p + 3) * n..(p + 4) * n];
                    for ((((cv, &v0), &v1), &v2), &v3) in
                        crow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
                    {
                        *cv += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
                    }
                    p += 4;
                }
                while p < p1 {
                    let av = alpha * arow[p];
                    let brow = &bd[p * n..(p + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                        *cv += av * bv;
                    }
                    p += 1;
                }
                i += 1;
            }
        }
    }
}

/// C = Aᵀ·B. Shapes: (k×m)ᵀ·(k×n) → m×n. A is stored k×m (not transposed).
///
/// Beyond small shapes this transposes A once (O(k·m)) and reuses the
/// register-blocked `matmul` kernel — the strided A[p,i] access pattern of
/// the direct form caps out well below it.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let (_, m) = a.shape();
    let (_, n) = b.shape();
    let mut c = Matrix::zeros(m, n);
    matmul_tn_acc(&mut c, a, b, 1.0, &mut Workspace::new());
    c
}

/// C = Aᵀ·B into a caller-provided buffer; Aᵀ scratch leased from `ws`.
pub fn matmul_tn_into(c: &mut Matrix, a: &Matrix, b: &Matrix, ws: &mut Workspace) {
    let (k, m) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul_tn inner dims: {k} vs {k2}");
    assert_eq!(c.shape(), (m, n), "matmul_tn output shape");
    c.data_mut().fill(0.0);
    matmul_tn_acc(c, a, b, 1.0, ws);
}

/// C += alpha · Aᵀ·B, in place; Aᵀ scratch leased from `ws`.
pub fn matmul_tn_acc(c: &mut Matrix, a: &Matrix, b: &Matrix, alpha: f32, ws: &mut Workspace) {
    let (k, m) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul_tn inner dims: {k} vs {k2}");
    assert_eq!(c.shape(), (m, n), "matmul_tn output shape");
    if m * n >= 32 * 32 {
        if use_packed(m, k, n) {
            // A panels pack straight out of the k×m storage — no Aᵀ scratch.
            let threads = auto_gate(gemm_flops(m, k, n), PAR_FLOPS);
            let (sa, sb) = (
                SrcA::Cols { a: a.data(), ld: m },
                SrcB::Rows { b: b.data(), ld: n },
            );
            matmul_acc_packed(c.data_mut(), (m, k, n), alpha, &sa, &sb, threads);
            return;
        }
        // Dirty lease: transpose_into writes every element.
        let mut at = ws.take_dirty(m, k);
        a.transpose_into(&mut at);
        matmul_acc(c, &at, b, alpha);
        ws.give(at);
        return;
    }
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    // C[i,:] += alpha · A[p,i] · B[p,:] — stream both A and B rows. Zero A
    // entries are NOT skipped so non-finite B values propagate.
    for p0 in (0..k).step_by(KC) {
        let p1 = (p0 + KC).min(k);
        for p in p0..p1 {
            let arow = &ad[p * m..(p + 1) * m];
            let brow = &bd[p * n..(p + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                let av = alpha * av;
                let crow = &mut cd[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// C = A·Bᵀ. Shapes: (m×k)·(n×k)ᵀ → m×n. B is stored n×k (not transposed).
///
/// For anything beyond small shapes, the row-dot formulation is memory-bound
/// (each C element is an isolated k-length dot product: ~5 GFLOPS measured),
/// while transposing B once (O(n·k)) and streaming the `ikj` kernel reaches
/// ~20 GFLOPS — a 4× win on the model's `x·Wᵀ` linears. The crossover lives
/// around 32² work; below it the transpose overhead dominates.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, _) = a.shape();
    let (n, _) = b.shape();
    let mut c = Matrix::zeros(m, n);
    matmul_nt_into(&mut c, a, b, &mut Workspace::new());
    c
}

/// C = A·Bᵀ into a caller-provided buffer; Bᵀ scratch leased from `ws`.
pub fn matmul_nt_into(c: &mut Matrix, a: &Matrix, b: &Matrix, ws: &mut Workspace) {
    let (m, k) = a.shape();
    let (n, k2) = b.shape();
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    assert_eq!(c.shape(), (m, n), "matmul_nt output shape");
    if m * n >= 32 * 32 {
        if use_packed(m, k, n) {
            // B panels pack straight out of the n×k storage — no Bᵀ scratch.
            c.data_mut().fill(0.0);
            let threads = auto_gate(gemm_flops(m, k, n), PAR_FLOPS);
            let (sa, sb) = (
                SrcA::Rows { a: a.data(), ld: k },
                SrcB::Cols { b: b.data(), ld: k },
            );
            matmul_acc_packed(c.data_mut(), (m, k, n), 1.0, &sa, &sb, threads);
            return;
        }
        // Dirty lease: transpose_into writes every element.
        let mut bt = ws.take_dirty(k, n);
        b.transpose_into(&mut bt);
        matmul_into(c, a, &bt);
        ws.give(bt);
        return;
    }
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    // Small case: direct row dots (transpose not worth it).
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut cd[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &bd[j * k..(j + 1) * k];
            *cv = arow.iter().zip(brow).map(|(&x, &y)| x * y).sum();
        }
    }
}

// ----------------------------------------------------------------------
// triangular attention kernels
// ----------------------------------------------------------------------
//
// Causal attention only ever consumes the lower triangle of its T×T score
// matrix: row i attends to positions j ≤ i. The three kernels below exploit
// that — scores are computed, soft-maxed (`ops::causal_softmax_rows`) and
// applied over each row's live prefix only, roughly halving the FLOPs and
// memory traffic of the dense mask-then-multiply pipeline. Shared contract:
// the strict upper triangle of the score/probability matrix is **never read
// or written**, so it may hold stale garbage from a dirty workspace lease.
// All three are deliberately sequential: the model fans attention out as one
// pool task per (batch, head), so the parallelism lives a level up and each
// task's output stays bit-identical for any worker count.

/// Lower-triangular scores `C[i, j] = alpha · (A row i · B row j)` for
/// `j ≤ i` only. Bᵀ is leased from `ws` so the inner loops stream
/// contiguous rows (the `matmul_nt_into` trick), but each C row computes
/// just its live prefix.
pub fn attn_scores_into(c: &mut Matrix, a: &Matrix, b: &Matrix, alpha: f32, ws: &mut Workspace) {
    let (t, d) = a.shape();
    assert_eq!(b.shape(), (t, d), "attn_scores operand shapes");
    assert_eq!(c.shape(), (t, t), "attn_scores output shape");
    if t == 0 {
        return;
    }
    // Dirty lease: transpose_into writes every element.
    let mut bt = ws.take_dirty(d, t);
    b.transpose_into(&mut bt);
    let ad = a.data();
    let btd = bt.data();
    let cd = c.data_mut();
    for i in 0..t {
        let arow = &ad[i * d..(i + 1) * d];
        let crow = &mut cd[i * t..i * t + i + 1];
        crow.fill(0.0);
        let mut p = 0;
        while p + 4 <= d {
            let a0 = alpha * arow[p];
            let a1 = alpha * arow[p + 1];
            let a2 = alpha * arow[p + 2];
            let a3 = alpha * arow[p + 3];
            let b0 = &btd[p * t..p * t + i + 1];
            let b1 = &btd[(p + 1) * t..(p + 1) * t + i + 1];
            let b2 = &btd[(p + 2) * t..(p + 2) * t + i + 1];
            let b3 = &btd[(p + 3) * t..(p + 3) * t + i + 1];
            for ((((cv, &v0), &v1), &v2), &v3) in
                crow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
            {
                *cv += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
            }
            p += 4;
        }
        while p < d {
            let av = alpha * arow[p];
            let brow = &btd[p * t..p * t + i + 1];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
            p += 1;
        }
    }
    ws.give(bt);
}

/// Prefix-weighted apply `C[i, :] = Σ_{j ≤ i} P[i, j] · V[j, :]` — the
/// `P·V` of causal attention, accumulated over each row's live prefix so
/// the masked columns of P are never read. Also serves the backward pass's
/// `dQ = dS·K` (dS is lower-triangular too).
pub fn attn_apply_into(c: &mut Matrix, p: &Matrix, v: &Matrix) {
    let (t, d) = v.shape();
    assert_eq!(p.shape(), (t, t), "attn_apply P shape");
    assert_eq!(c.shape(), (t, d), "attn_apply output shape");
    let pd = p.data();
    let vd = v.data();
    let cd = c.data_mut();
    for i in 0..t {
        let prow = &pd[i * t..i * t + i + 1];
        let crow = &mut cd[i * d..(i + 1) * d];
        crow.fill(0.0);
        let live = i + 1;
        let mut j = 0;
        while j + 4 <= live {
            let x0 = prow[j];
            let x1 = prow[j + 1];
            let x2 = prow[j + 2];
            let x3 = prow[j + 3];
            let v0 = &vd[j * d..(j + 1) * d];
            let v1 = &vd[(j + 1) * d..(j + 2) * d];
            let v2 = &vd[(j + 2) * d..(j + 3) * d];
            let v3 = &vd[(j + 3) * d..(j + 4) * d];
            for ((((cv, &w0), &w1), &w2), &w3) in
                crow.iter_mut().zip(v0).zip(v1).zip(v2).zip(v3)
            {
                *cv += x0 * w0 + x1 * w1 + x2 * w2 + x3 * w3;
            }
            j += 4;
        }
        while j < live {
            let x = prow[j];
            let vrow = &vd[j * d..(j + 1) * d];
            for (cv, &wv) in crow.iter_mut().zip(vrow) {
                *cv += x * wv;
            }
            j += 1;
        }
    }
}

/// Prefix-weighted transposed apply `C[j, :] = Σ_{i ≥ j} P[i, j] · X[i, :]`
/// — the `Pᵀ·dOut` (dV) and `dSᵀ·Q` (dK) of the attention backward pass,
/// accumulating down P's column j from the diagonal so the masked upper
/// triangle is never read.
pub fn attn_apply_tn_into(c: &mut Matrix, p: &Matrix, x: &Matrix) {
    let (t, d) = x.shape();
    assert_eq!(p.shape(), (t, t), "attn_apply_tn P shape");
    assert_eq!(c.shape(), (t, d), "attn_apply_tn output shape");
    let pd = p.data();
    let xd = x.data();
    let cd = c.data_mut();
    for j in 0..t {
        let crow = &mut cd[j * d..(j + 1) * d];
        crow.fill(0.0);
        let mut i = j;
        while i + 4 <= t {
            let x0 = pd[i * t + j];
            let x1 = pd[(i + 1) * t + j];
            let x2 = pd[(i + 2) * t + j];
            let x3 = pd[(i + 3) * t + j];
            let r0 = &xd[i * d..(i + 1) * d];
            let r1 = &xd[(i + 1) * d..(i + 2) * d];
            let r2 = &xd[(i + 2) * d..(i + 3) * d];
            let r3 = &xd[(i + 3) * d..(i + 4) * d];
            for ((((cv, &w0), &w1), &w2), &w3) in
                crow.iter_mut().zip(r0).zip(r1).zip(r2).zip(r3)
            {
                *cv += x0 * w0 + x1 * w1 + x2 * w2 + x3 * w3;
            }
            i += 4;
        }
        while i < t {
            let xv = pd[i * t + j];
            let xrow = &xd[i * d..(i + 1) * d];
            for (cv, &wv) in crow.iter_mut().zip(xrow) {
                *cv += xv * wv;
            }
            i += 1;
        }
    }
}

/// y = A·x (matrix-vector).
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; a.rows()];
    matvec_into(&mut y, a, x);
    y
}

/// y = A·x into a caller-provided slice of length `a.rows()`. Threaded over
/// output row blocks: each `y[i]` is one sequential dot product whichever
/// worker computes it, so results are bit-identical for any worker count.
pub fn matvec_into(y: &mut [f32], a: &Matrix, x: &[f32]) {
    let (m, k) = a.shape();
    assert_eq!(k, x.len(), "matvec dims");
    assert_eq!(m, y.len(), "matvec output len");
    let ad = a.data();
    let threads = plan_kernel_threads(2usize.saturating_mul(m).saturating_mul(k), m);
    if threads <= 1 {
        matvec_rows(y, ad, x, k, 0);
        return;
    }
    // One output row streams a k-float A row.
    let rows_per = chunk_units(m, 4 * k, threads);
    let n_chunks = m.div_ceil(rows_per);
    let y_base = SendPtr::new(y.as_mut_ptr());
    pool::run(threads, n_chunks, &|t| {
        let row0 = t * rows_per;
        let rows = rows_per.min(m - row0);
        let y_chunk = unsafe { std::slice::from_raw_parts_mut(y_base.get().add(row0), rows) };
        matvec_rows(y_chunk, ad, x, k, row0);
    });
}

/// Row-block matvec kernel: `y_chunk[i] = A[row0+i, :] · x`.
fn matvec_rows(y_chunk: &mut [f32], ad: &[f32], x: &[f32], k: usize, row0: usize) {
    for (i, yv) in y_chunk.iter_mut().enumerate() {
        let row = &ad[(row0 + i) * k..(row0 + i + 1) * k];
        *yv = row.iter().zip(x).map(|(&a, &b)| a * b).sum();
    }
}

/// y = Aᵀ·x (A stored m×k, result length k). Zero x entries are not skipped
/// (NaN/Inf rows of A must propagate).
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; a.cols()];
    matvec_t_into(&mut y, a, x);
    y
}

/// y = Aᵀ·x into a caller-provided slice of length `a.cols()`. Threaded over
/// output column blocks; each `y[j]` accumulates rows in index order (f32,
/// the same sequence the historical row-streaming kernel produced), so
/// results are bit-identical for any worker count.
pub fn matvec_t_into(y: &mut [f32], a: &Matrix, x: &[f32]) {
    let (m, k) = a.shape();
    assert_eq!(m, x.len(), "matvec_t dims");
    assert_eq!(k, y.len(), "matvec_t output len");
    let ad = a.data();
    let threads = plan_kernel_threads(2usize.saturating_mul(m).saturating_mul(k), k);
    if threads <= 1 {
        // Row-streaming form: one sequential pass over A (the column-block
        // kernel would stride by k per element). Produces bit-identical
        // results — each y[j] still accumulates over i in index order.
        y.fill(0.0);
        for (i, &xv) in x.iter().enumerate() {
            let row = &ad[i * k..(i + 1) * k];
            for (yv, &av) in y.iter_mut().zip(row.iter()) {
                *yv += xv * av;
            }
        }
        return;
    }
    // One output column strides down an m-element column of A.
    let cols_per = chunk_units(k, 4 * m, threads);
    let n_chunks = k.div_ceil(cols_per);
    let y_base = SendPtr::new(y.as_mut_ptr());
    pool::run(threads, n_chunks, &|t| {
        let col0 = t * cols_per;
        let cols = cols_per.min(k - col0);
        let y_chunk = unsafe { std::slice::from_raw_parts_mut(y_base.get().add(col0), cols) };
        matvec_t_cols(y_chunk, ad, x, k, col0);
    });
}

/// Column-block matvec_t kernel: `y_chunk[j] = Σ_i x[i]·A[i, col0+j]`,
/// accumulated over i in order (bit-identical to the row-streaming form).
fn matvec_t_cols(y_chunk: &mut [f32], ad: &[f32], x: &[f32], k: usize, col0: usize) {
    for (j, yv) in y_chunk.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        let mut idx = col0 + j;
        for &xv in x {
            acc += xv * ad[idx];
            idx += k;
        }
        *yv = acc;
    }
}

// ----------------------------------------------------------------------
// widening kernels: reduced-precision operands, f32 accumulation
// ----------------------------------------------------------------------
//
// Mixed-precision storage keeps compute in f32. The GEMM routes through
// the packed driver with decode fused into B-panel packing
// ([`pack::SrcB::Wide`]): each KC×NR panel is decoded straight out of the
// 16-bit words as it is copied, so no full-matrix f32 image of B ever
// exists. The matvec fuses decode into its row-dot kernel the same way.
// Decode is a pure per-word function, so the fused paths are bit-identical
// to decode-then-compute — the legacy decode-into-scratch GEMM body is kept
// behind `GEMM_PACK=1` as the oracle.

/// C = A·B with a packed reduced-precision B, f32 accumulation. Decode is
/// fused into B-panel packing; `ws` is only used by the legacy oracle path
/// (`GEMM_PACK=1`), which widens B into leased scratch first.
pub fn matmul_wide_into(c: &mut Matrix, a: &Matrix, b: &MatrixB, ws: &mut Workspace) {
    let (m, k) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul_wide inner dims: {k} vs {k2}");
    assert_eq!(c.shape(), (m, n), "matmul_wide output shape");
    if pack_mode() != 1 {
        c.data_mut().fill(0.0);
        let threads = auto_gate(gemm_flops(m, k, n), PAR_FLOPS);
        let (sa, sb) = (SrcA::Rows { a: a.data(), ld: k }, SrcB::Wide(b));
        matmul_acc_packed(c.data_mut(), (m, k, n), 1.0, &sa, &sb, threads);
        return;
    }
    // Dirty lease: decode_into writes every element.
    let mut bw = ws.take_dirty(b.rows(), b.cols());
    b.decode_into(&mut bw);
    matmul_into(c, a, &bw);
    ws.give(bw);
}

/// y = A·x with a packed reduced-precision A, f32 accumulation. Decode is
/// fused into the row-dot kernel (each weight widens in-register as the dot
/// streams), so no f32 image of A is materialized; `ws` only feeds the
/// legacy oracle path (`GEMM_PACK=1`). Threaded over output row blocks like
/// [`matvec_into`] — each `y[i]` is one sequential dot whichever worker
/// computes it, so results are bit-identical for any worker count.
pub fn matvec_wide_into(y: &mut [f32], a: &MatrixB, x: &[f32], ws: &mut Workspace) {
    let (m, k) = a.shape();
    assert_eq!(k, x.len(), "matvec_wide dims");
    assert_eq!(m, y.len(), "matvec_wide output len");
    if pack_mode() == 1 {
        // Dirty lease: decode_into writes every element.
        let mut aw = ws.take_dirty(a.rows(), a.cols());
        a.decode_into(&mut aw);
        matvec_into(y, &aw, x);
        ws.give(aw);
        return;
    }
    let decode = super::dtype::decode_fn(a.dtype());
    let ad = a.data();
    let threads = plan_kernel_threads(2usize.saturating_mul(m).saturating_mul(k), m);
    if threads <= 1 {
        matvec_wide_rows(y, ad, decode, x, k, 0);
        return;
    }
    // One output row streams a k-word A row plus the f32 x.
    let rows_per = chunk_units(m, 2 * k + 4 * k, threads);
    let n_chunks = m.div_ceil(rows_per);
    let y_base = SendPtr::new(y.as_mut_ptr());
    pool::run(threads, n_chunks, &|t| {
        let row0 = t * rows_per;
        let rows = rows_per.min(m - row0);
        let y_chunk = unsafe { std::slice::from_raw_parts_mut(y_base.get().add(row0), rows) };
        matvec_wide_rows(y_chunk, ad, decode, x, k, row0);
    });
}

/// Row-block widening matvec kernel: `y_chunk[i] = decode(A[row0+i, :]) · x`,
/// the [`matvec_rows`] dot with decode fused in — identical fold order, so
/// it is bit-identical to decode-then-`matvec_rows`.
fn matvec_wide_rows(
    y_chunk: &mut [f32],
    ad: &[u16],
    decode: fn(u16) -> f32,
    x: &[f32],
    k: usize,
    row0: usize,
) {
    for (i, yv) in y_chunk.iter_mut().enumerate() {
        let row = &ad[(row0 + i) * k..(row0 + i + 1) * k];
        *yv = row.iter().zip(x).map(|(&w, &b)| decode(w) * b).sum();
    }
}

/// out = srcᵀ, widening a packed reduced-precision src: fused decode +
/// 32-blocked transpose (the [`Matrix::transpose_into`] tiling), so no
/// scratch is needed at all.
pub fn transpose_wide_into(src: &MatrixB, out: &mut Matrix) {
    let (r, c) = src.shape();
    assert_eq!(out.shape(), (c, r), "transpose_wide output shape");
    let od = out.data_mut();
    const B: usize = 32;
    for i0 in (0..r).step_by(B) {
        let i1 = (i0 + B).min(r);
        for j0 in (0..c).step_by(B) {
            let j1 = (j0 + B).min(c);
            for i in i0..i1 {
                for j in j0..j1 {
                    od[j * r + i] = src.get(i, j);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    /// Naive reference matmul for testing.
    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let (_, n) = b.shape();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a.get(i, p) as f64 * b.get(p, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn small_known_product() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(7, 7, 1.0, &mut rng);
        let c = matmul(&a, &Matrix::eye(7));
        proptest::close(c.data(), a.data(), 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn property_matches_naive_all_variants() {
        let mut ws = Workspace::new();
        proptest::check(
            42,
            60,
            |rng| {
                let (m, k) = proptest::shape(rng, 33, 40);
                let n = 1 + rng.below(35);
                let a = Matrix::randn(m, k, 1.0, rng);
                let b = Matrix::randn(k, n, 1.0, rng);
                (a, b)
            },
            |(a, b)| {
                let (m, _) = a.shape();
                let (_, n) = b.shape();
                let want = naive(a, b);
                proptest::close(matmul(a, b).data(), want.data(), 1e-4, 1e-4)?;
                proptest::close(matmul_tn(&a.t(), b).data(), want.data(), 1e-4, 1e-4)?;
                proptest::close(matmul_nt(a, &b.t()).data(), want.data(), 1e-4, 1e-4)?;
                // _into variants, through a shared workspace with dirty
                // buffers (the _into contract is overwrite, not accumulate).
                let mut c = ws.take(m, n);
                c.data_mut().fill(7.5);
                matmul_into(&mut c, a, b);
                proptest::close(c.data(), want.data(), 1e-4, 1e-4)?;
                c.data_mut().fill(-3.25);
                matmul_tn_into(&mut c, &a.t(), b, &mut ws);
                proptest::close(c.data(), want.data(), 1e-4, 1e-4)?;
                c.data_mut().fill(0.125);
                matmul_nt_into(&mut c, a, &b.t(), &mut ws);
                proptest::close(c.data(), want.data(), 1e-4, 1e-4)?;
                // Accumulating transpose variant: C += 2·AᵀB on top of ones.
                let mut acc = ws.take(m, n);
                acc.data_mut().fill(1.0);
                matmul_tn_acc(&mut acc, &a.t(), b, 2.0, &mut ws);
                let want_acc = want.scale(2.0).map(|v| v + 1.0);
                proptest::close(acc.data(), want_acc.data(), 1e-3, 1e-3)?;
                ws.give(acc);
                ws.give(c);
                // matvec_into matches matvec.
                let x: Vec<f32> = (0..a.cols()).map(|i| (i as f32) * 0.25 - 1.0).collect();
                let y1 = matvec(a, &x);
                let mut y2 = vec![9.0f32; a.rows()];
                matvec_into(&mut y2, a, &x);
                proptest::close(&y1, &y2, 1e-6, 1e-6)?;
                Ok(())
            },
        );
    }

    #[test]
    fn threaded_matmul_bit_identical() {
        // Every row of C is computed by exactly one worker running the same
        // scalar kernel, so any thread count must be bit-identical.
        let mut rng = Rng::new(77);
        let a = Matrix::randn(101, 64, 1.0, &mut rng);
        let b = Matrix::randn(64, 53, 1.0, &mut rng);
        set_gemm_threads(1);
        let c1 = matmul(&a, &b);
        for threads in [2usize, 4] {
            set_gemm_threads(threads);
            let ct = matmul(&a, &b);
            assert_eq!(
                c1.data(),
                ct.data(),
                "threads={threads} diverged from single-thread"
            );
        }
        set_gemm_threads(0);
    }

    #[test]
    fn forced_chunk_sizes_reproduce_the_product() {
        // Ragged chunk boundaries (m=101 with chunks 1/4/7/64) must cover
        // every row exactly once; the row kernel does not reassociate
        // across chunks, so agreement here is exact.
        let _knob = TEST_KNOB_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Rng::new(78);
        let a = Matrix::randn(101, 40, 1.0, &mut rng);
        let b = Matrix::randn(40, 33, 1.0, &mut rng);
        set_gemm_threads(4);
        set_gemm_chunk(0);
        let base = matmul(&a, &b);
        for chunk in [1usize, 4, 7, 64, 1000] {
            set_gemm_chunk(chunk);
            let got = matmul(&a, &b);
            assert_eq!(base.data(), got.data(), "chunk={chunk} diverged");
            // matvec paths share the chunk knob.
            let x: Vec<f32> = (0..40).map(|i| i as f32 * 0.5 - 3.0).collect();
            let xt: Vec<f32> = (0..101).map(|i| 1.0 - i as f32 * 0.25).collect();
            let y = matvec(&a, &x);
            let yt = matvec_t(&a, &xt);
            set_gemm_chunk(0);
            assert_eq!(y, matvec(&a, &x), "matvec chunk={chunk} diverged");
            assert_eq!(yt, matvec_t(&a, &xt), "matvec_t chunk={chunk} diverged");
        }
        set_gemm_chunk(0);
        set_gemm_threads(0);

        // ---- auto sizing (same test fn: both halves mutate the global
        // chunk knob, and concurrent tests must never observe each other's
        // forced values in these assertions) ----
        // `set_gemm_chunk(0)` restores the GEMM_CHUNK *env* default by
        // design, so the auto-mode assertions only hold when CI is not
        // forcing a chunk.
        let env_forced = std::env::var("GEMM_CHUNK")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(0);
        if env_forced == 0 {
            // Fat rows: the L2 target splits each worker's share into
            // several chunks (steal slack).
            let fat = chunk_units(1024, 4 * 8192, 4);
            assert!(fat >= 1 && fat < 1024usize.div_ceil(4), "fat-row chunk {fat}");
            // Skinny rows: capped at one chunk per worker, never more.
            let skinny = chunk_units(64, 4 * 8, 4);
            assert_eq!(skinny, 16, "skinny rows should give one chunk per worker");
            // Units fatter than the whole L2 target: the old auto sizing
            // degenerated to 1-unit chunks (4096 tasks here); the
            // MAX_CHUNKS_PER_WORKER floor bounds the flood.
            let floored = chunk_units(4096, 1 << 20, 8);
            assert!(
                floored >= 512usize.div_ceil(MAX_CHUNKS_PER_WORKER),
                "fat-unit chunk {floored} below the per-worker floor"
            );
            assert!(
                4096usize.div_ceil(floored) <= 8 * MAX_CHUNKS_PER_WORKER,
                "fat-unit chunking floods the deques"
            );
        }
        // Forced override wins (over auto and env alike) and is clamped to
        // the task count.
        set_gemm_chunk(4);
        assert_eq!(chunk_units(1024, 4 * 8192, 4), 4);
        assert_eq!(chunk_units(2, 4, 4), 2, "forced chunk clamps to total");
        set_gemm_chunk(0);
    }

    #[test]
    fn threaded_degenerate_and_tiny_shapes() {
        set_gemm_threads(4);
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 2);
        assert_eq!(matmul(&a, &b).shape(), (0, 2));
        let a1 = Matrix::from_rows(&[&[2.0]]);
        let b1 = Matrix::from_rows(&[&[3.0]]);
        assert_eq!(matmul(&a1, &b1).data(), &[6.0]);
        set_gemm_threads(0);
    }

    #[test]
    fn matmul_acc_accumulates() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(5, 6, 1.0, &mut rng);
        let b = Matrix::randn(6, 4, 1.0, &mut rng);
        let mut c = Matrix::full(5, 4, 1.0);
        matmul_acc(&mut c, &a, &b, 2.0);
        let want = naive(&a, &b).scale(2.0).add(&Matrix::full(5, 4, 1.0));
        proptest::close(c.data(), want.data(), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn matvec_variants() {
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(matvec(&a, &[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(matvec_t(&a, &[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn degenerate_shapes() {
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 2);
        assert_eq!(matmul(&a, &b).shape(), (0, 2));
        let a1 = Matrix::from_rows(&[&[2.0]]);
        let b1 = Matrix::from_rows(&[&[3.0]]);
        assert_eq!(matmul(&a1, &b1).data(), &[6.0]);
    }

    #[test]
    fn degenerate_shapes_into_variants() {
        let mut ws = Workspace::new();
        // 0×k · k×n and m×k · k×0 through every _into variant.
        let a = ws.take(0, 3);
        let b = ws.take(3, 2);
        let mut c = ws.take(0, 2);
        matmul_into(&mut c, &a, &b);
        matmul_tn_into(&mut c, &Matrix::zeros(3, 0), &b, &mut ws);
        let mut c2 = ws.take(4, 0);
        matmul_nt_into(&mut c2, &Matrix::zeros(4, 3), &Matrix::zeros(0, 3), &mut ws);
        assert_eq!(c2.shape(), (4, 0));
        let mut y: Vec<f32> = Vec::new();
        matvec_into(&mut y, &Matrix::zeros(0, 3), &[1.0, 2.0, 3.0]);
        ws.give(a);
        ws.give(b);
        ws.give(c);
        ws.give(c2);
    }

    #[test]
    fn nonfinite_values_propagate() {
        // A NaN in B must reach C even when the matching A entry is zero —
        // the old kernels skipped `av == 0` terms and silently swallowed it.
        let k = 5;
        // matmul remainder-row path: a single row, NaN at B's remainder index.
        let mut a = Matrix::zeros(1, k);
        a.set(0, 4, 0.0);
        a.set(0, 0, 1.0);
        let mut b = Matrix::full(k, 2, 1.0);
        b.set(4, 0, f32::NAN);
        let c = matmul(&a, &b);
        assert!(c.get(0, 0).is_nan(), "matmul dropped NaN behind a zero weight");
        // matmul_tn small path.
        let mut at = Matrix::zeros(k, 1);
        at.set(0, 0, 1.0); // A[4,0] = 0 stays zero
        let c = matmul_tn(&at, &b);
        assert!(c.get(0, 0).is_nan(), "matmul_tn dropped NaN behind a zero weight");
        // matvec_t with a zero x entry against a NaN row of A.
        let mut m = Matrix::full(2, 3, 1.0);
        m.set(1, 1, f32::NAN);
        let y = matvec_t(&m, &[1.0, 0.0]);
        assert!(y[1].is_nan(), "matvec_t dropped NaN behind a zero x entry");
        // Inf propagates the same way (0·Inf is NaN, so use a nonzero weight).
        a.set(0, 4, 2.0);
        b.set(4, 0, f32::INFINITY);
        let c = matmul(&a, &b);
        assert!(c.get(0, 0).is_infinite());
    }

    #[test]
    fn attn_kernels_match_naive_masked_reference() {
        let mut rng = Rng::new(31);
        let mut ws = Workspace::new();
        for (t, d) in [(1usize, 4usize), (5, 3), (8, 8), (13, 6)] {
            let a = Matrix::randn(t, d, 1.0, &mut rng);
            let b = Matrix::randn(t, d, 1.0, &mut rng);
            let v = Matrix::randn(t, d, 1.0, &mut rng);
            let alpha = 0.5f32;
            // scores: C[i,j] = alpha · a_i · b_j on the lower triangle.
            let mut c = ws.take_dirty(t, t);
            c.data_mut().fill(777.0); // sentinel for the upper triangle
            attn_scores_into(&mut c, &a, &b, alpha, &mut ws);
            for i in 0..t {
                for j in 0..t {
                    if j <= i {
                        let want: f32 = a
                            .row(i)
                            .iter()
                            .zip(b.row(j))
                            .map(|(&x, &y)| x * y)
                            .sum::<f32>()
                            * alpha;
                        assert!(
                            (c.get(i, j) - want).abs() < 1e-4,
                            "scores[{i},{j}] = {} want {want}",
                            c.get(i, j)
                        );
                    } else {
                        assert_eq!(c.get(i, j), 777.0, "upper triangle written at ({i},{j})");
                    }
                }
            }
            // Poison the upper triangle with NaN: the apply kernels must not
            // read it.
            for i in 0..t {
                for j in (i + 1)..t {
                    c.set(i, j, f32::NAN);
                }
            }
            let mut out = ws.take_dirty(t, d);
            attn_apply_into(&mut out, &c, &v);
            for i in 0..t {
                for col in 0..d {
                    let want: f32 = (0..=i).map(|j| c.get(i, j) * v.get(j, col)).sum();
                    let got = out.get(i, col);
                    assert!(got.is_finite(), "apply read the masked triangle");
                    assert!((got - want).abs() < 1e-4, "apply[{i},{col}] {got} vs {want}");
                }
            }
            let mut out_tn = ws.take_dirty(t, d);
            attn_apply_tn_into(&mut out_tn, &c, &v);
            for j in 0..t {
                for col in 0..d {
                    let want: f32 = (j..t).map(|i| c.get(i, j) * v.get(i, col)).sum();
                    let got = out_tn.get(j, col);
                    assert!(got.is_finite(), "apply_tn read the masked triangle");
                    assert!(
                        (got - want).abs() < 1e-4,
                        "apply_tn[{j},{col}] {got} vs {want}"
                    );
                }
            }
            ws.give(c);
            ws.give(out);
            ws.give(out_tn);
        }
    }

    #[test]
    fn attn_scores_scratch_recycles() {
        // The Bᵀ lease inside attn_scores_into must come back to the pool.
        let mut rng = Rng::new(32);
        let mut ws = Workspace::new();
        let a = Matrix::randn(12, 8, 1.0, &mut rng);
        let b = Matrix::randn(12, 8, 1.0, &mut rng);
        let mut c = ws.take_dirty(12, 12);
        attn_scores_into(&mut c, &a, &b, 1.0, &mut ws);
        let misses = ws.misses();
        for _ in 0..3 {
            attn_scores_into(&mut c, &a, &b, 1.0, &mut ws);
        }
        assert_eq!(ws.misses(), misses, "steady-state attn_scores allocated");
        ws.give(c);
    }

    #[test]
    fn workspace_scratch_reuse_in_transpose_variants() {
        // The Aᵀ/Bᵀ scratch leased inside matmul_tn_into / matmul_nt_into
        // must come back to the pool: repeated calls add no misses. Pinned
        // to the legacy route — the packed path packs straight out of the
        // transposed storage and never leases from `ws` at all.
        let _knob = TEST_KNOB_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_gemm_pack(1);
        let mut rng = Rng::new(9);
        let a = Matrix::randn(40, 48, 1.0, &mut rng);
        let b = Matrix::randn(40, 36, 1.0, &mut rng);
        let mut ws = Workspace::new();
        let mut c = ws.take(48, 36);
        matmul_tn_into(&mut c, &a, &b, &mut ws);
        let misses = ws.misses();
        for _ in 0..3 {
            matmul_tn_into(&mut c, &a, &b, &mut ws);
        }
        assert_eq!(ws.misses(), misses, "steady-state tn_into allocated");
        ws.give(c);
        set_gemm_pack(0);
    }

    #[test]
    fn packed_route_is_bit_identical_to_legacy_kernels() {
        // The packed driver reproduces the legacy kernels' per-element
        // accumulation order exactly (KC blocks in order, 4-group folds,
        // no FMA), so forcing either route must agree to the bit — for
        // every transpose variant, the decode-fused widening path, and any
        // worker count. This is the routing contract that lets `use_packed`
        // stay a pure speed heuristic.
        let _knob = TEST_KNOB_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Rng::new(501);
        let mut ws = Workspace::new();
        let (m, k, n) = (45usize, 70usize, 39usize);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bw = MatrixB::encode(&b, crate::tensor::dtype::Dtype::Bf16);
        set_gemm_threads(0);
        set_gemm_pack(1);
        let mm = matmul(&a, &b);
        let mut acc_legacy = Matrix::full(m, n, 0.5);
        matmul_acc(&mut acc_legacy, &a, &b, 1.5);
        let tn = matmul_tn(&a.t(), &b);
        let nt = matmul_nt(&a, &b.t());
        let mut wide = ws.take_dirty(m, n);
        matmul_wide_into(&mut wide, &a, &bw, &mut ws);
        for threads in [1usize, 2, 8] {
            set_gemm_threads(threads);
            set_gemm_pack(2);
            assert_eq!(mm.data(), matmul(&a, &b).data(), "matmul t={threads}");
            let mut acc = Matrix::full(m, n, 0.5);
            matmul_acc(&mut acc, &a, &b, 1.5);
            assert_eq!(acc_legacy.data(), acc.data(), "matmul_acc t={threads}");
            assert_eq!(tn.data(), matmul_tn(&a.t(), &b).data(), "tn t={threads}");
            assert_eq!(nt.data(), matmul_nt(&a, &b.t()).data(), "nt t={threads}");
            let mut wide_p = ws.take_dirty(m, n);
            matmul_wide_into(&mut wide_p, &a, &bw, &mut ws);
            assert_eq!(wide.data(), wide_p.data(), "wide t={threads}");
            ws.give(wide_p);
        }
        ws.give(wide);
        set_gemm_threads(0);
        set_gemm_pack(0);
    }
}
