//! Cache-blocked GEMM in all transpose variants.
//!
//! Row-major, single-threaded (the sandbox exposes one core). The `ikj` loop
//! order streams both B-rows and C-rows sequentially, which autovectorizes
//! well; blocking keeps the working set inside L2. The transpose variants
//! avoid materializing Aᵀ/Bᵀ — the subspace math (SᵀG, R·Aᵀ, SₜᵀSₜ₋₁) is
//! dominated by these.

use super::matrix::Matrix;

/// Tile edge for the k-dimension blocking.
const KC: usize = 256;
/// Tile edge for the m-dimension blocking.
const MC: usize = 64;

/// C = A·B. Shapes: (m×k)·(k×n) → m×n.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut c = Matrix::zeros(m, n);
    matmul_acc(&mut c, a, b, 1.0);
    c
}

/// C += alpha · A·B, in place.
pub fn matmul_acc(c: &mut Matrix, a: &Matrix, b: &Matrix, alpha: f32) {
    let (m, k) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul inner dims");
    assert_eq!(c.shape(), (m, n), "matmul output shape");
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for p0 in (0..k).step_by(KC) {
            let p1 = (p0 + KC).min(k);
            // 2×4 register blocking: two C rows share each streamed B row,
            // and each pass over a C row performs 4 FMAs per element. This
            // cuts C traffic 4× and B traffic 2× versus the plain axpy form
            // (measured 20 → ~30+ GFLOPS single-core AVX-512).
            let mut i = i0;
            while i + 2 <= i1 {
                let (c_lo, c_hi) = cd.split_at_mut((i + 1) * n);
                let crow0 = &mut c_lo[i * n..];
                let crow1 = &mut c_hi[..n];
                let arow0 = &ad[i * k..(i + 1) * k];
                let arow1 = &ad[(i + 1) * k..(i + 2) * k];
                let mut p = p0;
                while p + 4 <= p1 {
                    let x0 = alpha * arow0[p];
                    let x1 = alpha * arow0[p + 1];
                    let x2 = alpha * arow0[p + 2];
                    let x3 = alpha * arow0[p + 3];
                    let y0 = alpha * arow1[p];
                    let y1 = alpha * arow1[p + 1];
                    let y2 = alpha * arow1[p + 2];
                    let y3 = alpha * arow1[p + 3];
                    let b0 = &bd[p * n..(p + 1) * n];
                    let b1 = &bd[(p + 1) * n..(p + 2) * n];
                    let b2 = &bd[(p + 2) * n..(p + 3) * n];
                    let b3 = &bd[(p + 3) * n..(p + 4) * n];
                    // Zip form keeps the loops free of bounds checks so LLVM
                    // emits packed AVX-512 FMAs.
                    for (((((cv0, cv1), &v0), &v1), &v2), &v3) in crow0
                        .iter_mut()
                        .zip(crow1.iter_mut())
                        .zip(b0)
                        .zip(b1)
                        .zip(b2)
                        .zip(b3)
                    {
                        *cv0 += x0 * v0 + x1 * v1 + x2 * v2 + x3 * v3;
                        *cv1 += y0 * v0 + y1 * v1 + y2 * v2 + y3 * v3;
                    }
                    p += 4;
                }
                while p < p1 {
                    let x = alpha * arow0[p];
                    let y = alpha * arow1[p];
                    let brow = &bd[p * n..(p + 1) * n];
                    for ((cv0, cv1), &bv) in
                        crow0.iter_mut().zip(crow1.iter_mut()).zip(brow)
                    {
                        *cv0 += x * bv;
                        *cv1 += y * bv;
                    }
                    p += 1;
                }
                i += 2;
            }
            // Remainder row.
            while i < i1 {
                let arow = &ad[i * k..(i + 1) * k];
                let crow = &mut cd[i * n..(i + 1) * n];
                let mut p = p0;
                while p + 4 <= p1 {
                    let a0 = alpha * arow[p];
                    let a1 = alpha * arow[p + 1];
                    let a2 = alpha * arow[p + 2];
                    let a3 = alpha * arow[p + 3];
                    let b0 = &bd[p * n..(p + 1) * n];
                    let b1 = &bd[(p + 1) * n..(p + 2) * n];
                    let b2 = &bd[(p + 2) * n..(p + 3) * n];
                    let b3 = &bd[(p + 3) * n..(p + 4) * n];
                    for ((((cv, &v0), &v1), &v2), &v3) in
                        crow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
                    {
                        *cv += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
                    }
                    p += 4;
                }
                while p < p1 {
                    let av = alpha * arow[p];
                    if av != 0.0 {
                        let brow = &bd[p * n..(p + 1) * n];
                        for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += av * bv;
                        }
                    }
                    p += 1;
                }
                i += 1;
            }
        }
    }
}

/// C = Aᵀ·B. Shapes: (k×m)ᵀ·(k×n) → m×n. A is stored k×m (not transposed).
///
/// Beyond small shapes this transposes A once (O(k·m)) and reuses the
/// register-blocked `matmul` kernel — the strided A[p,i] access pattern of
/// the direct form caps out well below it.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let (k, m) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul_tn inner dims: {k} vs {k2}");
    if m * n >= 32 * 32 {
        return matmul(&a.t(), b);
    }
    let mut c = Matrix::zeros(m, n);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    // C[i,:] += A[p,i] * B[p,:]  — stream both A and B rows.
    for p0 in (0..k).step_by(KC) {
        let p1 = (p0 + KC).min(k);
        for p in p0..p1 {
            let arow = &ad[p * m..(p + 1) * m];
            let brow = &bd[p * n..(p + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let crow = &mut cd[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
    }
    c
}

/// C = A·Bᵀ. Shapes: (m×k)·(n×k)ᵀ → m×n. B is stored n×k (not transposed).
///
/// For anything beyond small shapes, the row-dot formulation is memory-bound
/// (each C element is an isolated k-length dot product: ~5 GFLOPS measured),
/// while transposing B once (O(n·k)) and streaming the `ikj` kernel reaches
/// ~20 GFLOPS — a 4× win on the model's `x·Wᵀ` linears. The crossover lives
/// around 32² work; below it the transpose overhead dominates.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (n, k2) = b.shape();
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    if m * n >= 32 * 32 {
        return matmul(a, &b.t());
    }
    let mut c = Matrix::zeros(m, n);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    // Small case: direct row dots (transpose not worth it).
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut cd[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &bd[j * k..(j + 1) * k];
            *cv = arow.iter().zip(brow).map(|(&x, &y)| x * y).sum();
        }
    }
    c
}

/// y = A·x (matrix-vector).
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let (m, k) = a.shape();
    assert_eq!(k, x.len(), "matvec dims");
    let ad = a.data();
    (0..m)
        .map(|i| {
            let row = &ad[i * k..(i + 1) * k];
            row.iter().zip(x).map(|(&a, &b)| a * b).sum()
        })
        .collect()
}

/// y = Aᵀ·x (A stored m×k, result length k).
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let (m, k) = a.shape();
    assert_eq!(m, x.len(), "matvec_t dims");
    let mut y = vec![0.0f32; k];
    let ad = a.data();
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = &ad[i * k..(i + 1) * k];
        for (yv, &av) in y.iter_mut().zip(row.iter()) {
            *yv += xv * av;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    /// Naive reference matmul for testing.
    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let (_, n) = b.shape();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a.get(i, p) as f64 * b.get(p, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn small_known_product() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(7, 7, 1.0, &mut rng);
        let c = matmul(&a, &Matrix::eye(7));
        proptest::close(c.data(), a.data(), 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn property_matches_naive_all_variants() {
        proptest::check(
            42,
            60,
            |rng| {
                let (m, k) = proptest::shape(rng, 33, 40);
                let n = 1 + rng.below(35);
                let a = Matrix::randn(m, k, 1.0, rng);
                let b = Matrix::randn(k, n, 1.0, rng);
                (a, b)
            },
            |(a, b)| {
                let want = naive(a, b);
                proptest::close(matmul(a, b).data(), want.data(), 1e-4, 1e-4)?;
                proptest::close(matmul_tn(&a.t(), b).data(), want.data(), 1e-4, 1e-4)?;
                proptest::close(matmul_nt(a, &b.t()).data(), want.data(), 1e-4, 1e-4)?;
                Ok(())
            },
        );
    }

    #[test]
    fn matmul_acc_accumulates() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(5, 6, 1.0, &mut rng);
        let b = Matrix::randn(6, 4, 1.0, &mut rng);
        let mut c = Matrix::full(5, 4, 1.0);
        matmul_acc(&mut c, &a, &b, 2.0);
        let want = naive(&a, &b).scale(2.0).add(&Matrix::full(5, 4, 1.0));
        proptest::close(c.data(), want.data(), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn matvec_variants() {
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(matvec(&a, &[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(matvec_t(&a, &[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn degenerate_shapes() {
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 2);
        assert_eq!(matmul(&a, &b).shape(), (0, 2));
        let a1 = Matrix::from_rows(&[&[2.0]]);
        let b1 = Matrix::from_rows(&[&[3.0]]);
        assert_eq!(matmul(&a1, &b1).data(), &[6.0]);
    }
}
