//! Persistent worker pool with a work-stealing scheduler.
//!
//! PR-2 replaced per-call `thread::scope` forks with long-lived workers, but
//! handed tasks out through one shared atomic counter and queued job copies
//! through one mutex-guarded `VecDeque` — at high core counts every GEMM row
//! block, QR panel column, and Jacobi pair claim serialized on the same
//! cache line. This revision replaces that scheduler with per-participant
//! **range deques** and Chase–Lev-style half-stealing:
//!
//! * [`run`]`(workers, n_tasks, f)` pre-splits `0..n_tasks` into one
//!   contiguous index range per participant (the calling thread plus up to
//!   `workers − 1` pool workers). Each participant claims tasks from the
//!   *front* of its own range — a private cache line, uncontended in the
//!   common case — and when its range is empty it **steals the back half**
//!   of a victim's remaining range and installs it as its own. Stealing
//!   repeats until every range is empty, so uneven task costs rebalance
//!   without any shared claim counter.
//! * Jobs are announced on a fixed board of slots, each with its **own**
//!   lock; workers claim participant *seats* (one atomic CAS per job, not
//!   per task) and then never touch shared scheduler state again until they
//!   exit. There is no global job queue, and the pool-wide condvar exists
//!   only to sleep/wake idle workers.
//! * Job state (the range slots, seat/exit counters, completion condvar) is
//!   **leased from a pre-sized free list**, so a warm [`run`] submission
//!   performs no heap allocation: deques are fixed-capacity (one range slot
//!   per possible participant, sized at pool init) and job-state misses are
//!   capped at first use — the same contract the [`Workspace`] leases carry,
//!   gated by [`job_state_misses`] in `rust/tests/zero_alloc.rs`.
//!
//! [`Workspace`]: super::workspace::Workspace
//!
//! # Execution model: what reorders, what cannot
//!
//! [`run`] executes `f(0)`, …, `f(n_tasks − 1)` **exactly once each** and
//! blocks until all of them finished (so closures may borrow stack data; the
//! borrow is lifetime-erased internally and provably outlives the run).
//! Stealing makes *placement and order* scheduling-dependent: which thread
//! runs a task, and in what sequence, varies run to run. What cannot vary is
//! the *result*: a task is claimed by exactly one participant and runs the
//! same sequential kernel wherever it lands, so kernels that make each
//! task's output depend only on its index (the bit-identical-per-row/column
//! contract every threaded kernel in this crate follows) produce
//! bit-identical results for any worker count, any chunk size, and any
//! steal schedule. Tasks must not synchronize with each other — a task that
//! blocks on another task's side effect can deadlock, because sibling tasks
//! may be queued behind it on the same participant.
//!
//! # Isolation between jobs
//!
//! Each job's tasks live only in that job's range slots: a caller drains and
//! steals exclusively within its own job, and finishing touches only its own
//! announce slot (O(1) — the old scheduler's leftover-copy reclaim scanned
//! the global queue under its lock). A caller therefore **never blocks on an
//! unrelated busy worker**: with every pool worker pinned by some long job,
//! a new caller simply drains its whole task set itself and returns
//! (`rust/tests/pool_sched.rs` regression-tests this starvation bound).
//!
//! # Nesting and the shared budget
//!
//! A task running *on* a pool worker never re-enters the pool: nested
//! [`run`] calls execute inline on that worker ([`on_worker`] guards this).
//! Combined with `gemm::run_single_threaded` (the data-parallel workers'
//! opt-out) this makes oversubscription impossible: one level of the stack
//! owns the cores at a time. Concurrent top-level callers each announce
//! their own job and share the worker set through seat claims.
//!
//! # Task-local scratch
//!
//! Tasks that need scratch buffers cannot share the caller's single-owner
//! [`Workspace`]; they lease a whole workspace per task from a pre-sized
//! `WorkspaceBank` instead (the model's per-(batch, head) attention fan-out
//! is the canonical user — see the leasing rules in
//! [`super::workspace`]). Heavier kernels running *inside* a task should
//! stay sequential: with one pool task per unit of work, the parallelism
//! already lives at the fan-out level, and nested threading would only run
//! inline anyway.
//!
//! # Scheduler modes
//!
//! [`run_mode`] exposes the scheduler choice: [`Sched::Steal`] (the default
//! behind [`run`]) and [`Sched::Counter`], which dispatches through a single
//! shared counter over the same seat/announce machinery. Counter mode exists
//! as the contention baseline for `examples/gemmbench.rs` (`gemm.sched_ms`
//! counter-vs-deque sweep) and as a cross-check oracle in the stress suite —
//! both modes execute every task exactly once with identical results.
//!
//! # Watchdog (default off)
//!
//! A hung or dead participant would otherwise block [`run`] forever: the
//! caller waits for every seat winner's exit, and a worker stuck inside a
//! task never exits. With a deadline armed (`GEMM_DEADLINE_MS` env /
//! [`set_pool_deadline_ms`], same sentinel-re-resolve idiom as the other
//! `GEMM_*` knobs; `0` = off, the default), the caller's wait turns into a
//! progress watchdog over the per-job heartbeat (a counter bumped on every
//! task completion):
//!
//! * **Dead worker** ([`PoolError::WorkerLost`]): a worker thread that dies
//!   holding a seat reports its participant index and in-flight task on the
//!   way down (a drop guard on the worker's stack). The caller re-runs the
//!   in-flight task, drains the dead participant's remaining range, credits
//!   its exit, and spawns a replacement worker — every task still runs, and
//!   [`try_run`] reports the event. Recovery may re-execute the one task
//!   the worker died inside, so tasks must be idempotent (every kernel in
//!   this crate writes a pure function of the task index to a disjoint
//!   region, so re-execution writes the same bytes). This path also works
//!   with the watchdog off: the dying thread's notification wakes the
//!   caller directly.
//! * **Hung worker** ([`PoolError::Hung`]): when no task completes for a
//!   full deadline window, the caller sets the job's cancellation flag
//!   ([`job_cancelled`], which long-running tasks should poll) and waits a
//!   few grace windows for the stuck task to cooperate. All *other* tasks
//!   still ran exactly once; only work that observed the flag and returned
//!   early is suspect, so callers must treat the job's output as invalid.
//!   A task that ignores the flag past the grace windows leaves the
//!   borrowed closure pinned forever — the process aborts loudly (the
//!   documented behavior of watchdogs over non-cooperative code; cf.
//!   collective-ops watchdogs in distributed trainers).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// A raw mutable pointer that may be shared across pool tasks.
///
/// Wrapper contract: tasks must write **disjoint** regions (row blocks,
/// column strides, pair columns) — the pool gives no other synchronization.
/// This is how kernels hand each task its slice of an output buffer without
/// borrow-splitting gymnastics at closure-capture time.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    /// The wrapped pointer. All safety obligations of raw-pointer access
    /// apply; additionally, concurrent tasks must touch disjoint elements.
    #[inline]
    pub fn get(self) -> *mut T {
        self.0
    }
}

/// Typed failure from [`try_run`] / [`try_run_mode`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolError {
    /// No task completed for a full watchdog window; the job was
    /// cooperatively cancelled. Tasks polling [`job_cancelled`] may have
    /// returned early, so the job's **output must be treated as invalid**
    /// (recompute, roll back, or abort at the caller's level).
    Hung,
    /// A worker thread died holding a seat. The caller re-ran its in-flight
    /// task and drained its remaining range, so every task still executed
    /// exactly once — the error is telemetry, the **output is valid**.
    WorkerLost,
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::Hung => write!(f, "pool job hung (no task progress within the deadline)"),
            PoolError::WorkerLost => write!(f, "pool worker died mid-job (tasks recovered)"),
        }
    }
}

impl std::error::Error for PoolError {}

/// Watchdog deadline in ms; `usize::MAX` = unresolved (read the env var on
/// first use), `0` = watchdog off.
static DEADLINE_MS: AtomicUsize = AtomicUsize::new(usize::MAX);

/// The armed watchdog deadline in milliseconds: explicit
/// [`set_pool_deadline_ms`] value, else the `GEMM_DEADLINE_MS` env var
/// (parsed once), else 0 (off). Resolved once per job at publish time.
pub fn pool_deadline_ms() -> usize {
    let n = super::gemm::env_knob(&DEADLINE_MS, "GEMM_DEADLINE_MS");
    if n == usize::MAX {
        0
    } else {
        n
    }
}

/// Arm the pool watchdog: declare a job hung when no task completes for
/// `ms` milliseconds (0 restores the `GEMM_DEADLINE_MS` env default, or off
/// when the variable is unset). The deadline bounds *progress*, not total
/// runtime — a slow job whose tasks keep completing is never killed.
pub fn set_pool_deadline_ms(ms: usize) {
    // Storing the sentinel makes the next read re-resolve the env var, so a
    // caller that restores "off" does not erase a CI-wide setting.
    DEADLINE_MS.store(if ms == 0 { usize::MAX } else { ms }, Ordering::Relaxed);
}

thread_local! {
    /// The currently executing job's cancellation flag (null outside a pool
    /// task). Installed scoped by [`participate`], so the pointer never
    /// outlives the job state it points into.
    static CANCEL: std::cell::Cell<*const AtomicBool> =
        const { std::cell::Cell::new(std::ptr::null()) };
}

/// Whether the watchdog cancelled the job the current thread is executing a
/// task for. Long-running tasks (seconds, not microseconds) should poll
/// this and return early when set; everything this crate's kernels do per
/// task is far below any sane deadline, so only deliberately-blocking tasks
/// (fault injection, external waits) need to. Always false outside a pool
/// task and in jobs that were never cancelled.
pub fn job_cancelled() -> bool {
    CANCEL.with(|c| {
        let p = c.get();
        // SAFETY: non-null only while `CancelScope` in `participate` is
        // live, and the flag it points to is owned by the job state the
        // participant borrows for at least as long.
        !p.is_null() && unsafe { (*p).load(Ordering::Acquire) }
    })
}

/// Scoped installer for the [`CANCEL`] pointer; restores the previous value
/// on drop (unwind-safe, and correct under nested inline runs).
struct CancelScope {
    prev: *const AtomicBool,
}

impl CancelScope {
    fn install(flag: &AtomicBool) -> CancelScope {
        CancelScope { prev: CANCEL.with(|c| c.replace(flag as *const AtomicBool)) }
    }
}

impl Drop for CancelScope {
    fn drop(&mut self) {
        CANCEL.with(|c| c.set(self.prev));
    }
}

/// Publisher thread armed to lose one worker on its next job (test hook for
/// the lost-worker recovery path). Keyed on the thread id so concurrently
/// running tests cannot kill each other's workers.
static SIM_LOSE: Mutex<Option<std::thread::ThreadId>> = Mutex::new(None);

/// Test hook: the next pool job *published by the calling thread* has one
/// seat-claiming worker exit its thread without running a task or doing the
/// exit protocol — exactly what a worker death looks like to the caller.
/// The caller recovers (see [`PoolError::WorkerLost`]) and a replacement
/// worker is spawned, so the pool is left at full strength.
#[doc(hidden)]
pub fn simulate_worker_loss() {
    *relock(&SIM_LOSE) = Some(std::thread::current().id());
}

/// Disarm a pending [`simulate_worker_loss`] (the hook only fires if a
/// worker claims a seat; tests disarm on paths where none did).
#[doc(hidden)]
pub fn cancel_simulated_worker_loss() {
    *relock(&SIM_LOSE) = None;
}

/// Task-dispatch strategy for [`run_mode`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sched {
    /// Per-participant range deques with half-stealing (the default).
    Steal,
    /// One shared claim counter (the pre-deque scheduler, kept as the
    /// contention baseline for benches and as a test oracle).
    Counter,
}

/// Lifetime-erased borrow of a caller's task closure. Stored as a raw fat
/// pointer so stale copies (a worker that looked at a job too late to claim
/// a seat) are never *dereferenced* — only participants that won a seat call
/// it, and the caller blocks until every such participant exited.
#[derive(Clone, Copy)]
struct TaskFn(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskFn {}
unsafe impl Sync for TaskFn {}

/// Per-run parameters, written by the caller before the job is announced
/// and read by each worker after it wins a seat (both under the mutex, so
/// publication is ordered).
struct Header {
    f: Option<TaskFn>,
    mode: Sched,
    n_participants: usize,
    n_tasks: usize,
    /// Thread that published the job (watchdog telemetry + the simulated
    /// worker-loss hook, which must only hit the arming thread's own job).
    publisher: Option<std::thread::ThreadId>,
}

/// Reusable per-job scheduler state, leased from the pool's free list.
///
/// `ranges[pid]` is participant `pid`'s deque: a `(lo, hi)` index range
/// claimed from the front by its owner and halved from the back by thieves.
/// Each slot has its own lock; a claim or steal holds exactly one lock at a
/// time (a stolen half is carried lock-free and installed into the thief's
/// own empty slot), so there is no lock-order cycle.
struct JobState {
    header: Mutex<Header>,
    /// One range slot per possible participant (`max_participants`), fixed
    /// at construction so warm runs never grow it.
    ranges: Vec<Mutex<(usize, usize)>>,
    /// Shared claim counter for [`Sched::Counter`] mode.
    counter: AtomicUsize,
    /// Unclaimed worker seats. A worker joins by CAS-decrementing this;
    /// the claimed value doubles as its participant index (1..=extra).
    /// The caller closes the job by swapping in 0.
    seats: AtomicUsize,
    /// Participants (seat winners) that have finished and released their
    /// borrow of the task closure.
    exited: AtomicUsize,
    /// Set when a participant's task panicked; re-raised on the caller.
    panicked: AtomicBool,
    /// Per-job heartbeat: bumped on every task completion. The watchdog
    /// only declares a job hung when this stops advancing for a whole
    /// deadline window, so slow-but-alive jobs are never killed.
    progress: AtomicUsize,
    /// Cooperative cancellation flag, set by the watchdog and readable from
    /// inside tasks via [`job_cancelled`].
    cancelled: AtomicBool,
    /// `in_flight[pid]` is 1 + the task index participant `pid` is
    /// currently executing (0 = none). Read by lost-worker recovery to
    /// re-run the task a dead worker was inside.
    in_flight: Vec<AtomicUsize>,
    /// Participants whose worker thread died mid-job: `(pid, in-flight
    /// task)` pushed by the worker's drop guard, drained by the caller.
    lost: Mutex<Vec<(usize, Option<usize>)>>,
    done_lock: Mutex<()>,
    done_cv: Condvar,
}

fn new_state(max_p: usize) -> Arc<JobState> {
    Arc::new(JobState {
        header: Mutex::new(Header {
            f: None,
            mode: Sched::Steal,
            n_participants: 0,
            n_tasks: 0,
            publisher: None,
        }),
        ranges: (0..max_p).map(|_| Mutex::new((0usize, 0usize))).collect(),
        counter: AtomicUsize::new(0),
        seats: AtomicUsize::new(0),
        exited: AtomicUsize::new(0),
        panicked: AtomicBool::new(false),
        progress: AtomicUsize::new(0),
        cancelled: AtomicBool::new(false),
        in_flight: (0..max_p).map(|_| AtomicUsize::new(0)).collect(),
        lost: Mutex::new(Vec::new()),
        done_lock: Mutex::new(()),
        done_cv: Condvar::new(),
    })
}

/// One entry of the announce board. `occupied` is the cheap scan filter;
/// the Arc hand-off goes through the slot's own small mutex (there is no
/// board-wide lock).
struct AnnounceSlot {
    occupied: AtomicBool,
    job: Mutex<Option<Arc<JobState>>>,
}

/// Announce-board capacity: bounds *concurrent top-level* jobs only (nested
/// runs execute inline and DP shards run on the pool itself). If ever
/// exceeded, the caller degrades to draining its tasks inline — correct,
/// just unassisted.
const ANNOUNCE_SLOTS: usize = 64;

/// Job states pre-built at pool init, so the common one-caller-at-a-time
/// pattern never allocates even on its first run.
const PREALLOC_STATES: usize = 2;

/// Lock that tolerates poisoning: a panic inside a pool task must never
/// cascade into a secondary panic (or abort) on the synchronization path.
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct Pool {
    slots: Vec<AnnounceSlot>,
    /// Leasable job states; pre-sized so warm runs pop/push without
    /// allocating.
    free_states: Mutex<Vec<Arc<JobState>>>,
    /// Fresh job-state allocations after init (the zero-alloc gate's proxy,
    /// mirroring `Workspace::misses`).
    state_misses: AtomicUsize,
    /// Total unclaimed seats across announced jobs; the only thing idle
    /// workers sleep on.
    claimable: AtomicUsize,
    sleep_lock: Mutex<()>,
    cv: Condvar,
    n_workers: usize,
}

impl Pool {
    fn lease_state(&self) -> Arc<JobState> {
        if let Some(s) = relock(&self.free_states).pop() {
            return s;
        }
        self.state_misses.fetch_add(1, Ordering::Relaxed);
        new_state(self.n_workers + 1)
    }

    fn release_state(&self, s: Arc<JobState>) {
        relock(&self.free_states).push(s);
    }

    /// Claim a free announce slot and publish the job into it. Returns the
    /// slot index, or `None` when the board is full.
    fn publish(&self, state: &Arc<JobState>) -> Option<usize> {
        for (i, slot) in self.slots.iter().enumerate() {
            if slot
                .occupied
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                *relock(&slot.job) = Some(Arc::clone(state));
                return Some(i);
            }
        }
        None
    }

    fn worker_main(pool: Arc<Pool>) {
        ON_WORKER.with(|w| w.set(true));
        loop {
            let mut participated = false;
            for slot in &pool.slots {
                if !slot.occupied.load(Ordering::Acquire) {
                    continue;
                }
                let Some(state) = relock(&slot.job).clone() else {
                    continue;
                };
                // Claim a seat: the decremented-from value is this worker's
                // participant index (extra..1 map to pids extra..1).
                let mut s = state.seats.load(Ordering::Acquire);
                let pid = loop {
                    if s == 0 {
                        break 0;
                    }
                    match state.seats.compare_exchange_weak(
                        s,
                        s - 1,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    ) {
                        Ok(_) => break s,
                        Err(cur) => s = cur,
                    }
                };
                if pid == 0 {
                    continue; // all seats gone; look at other jobs
                }
                pool.claimable.fetch_sub(1, Ordering::AcqRel);
                let (f, mode, p, n_tasks, publisher) = {
                    let h = relock(&state.header);
                    let f = h.f.expect("announced job without a task fn");
                    (f, h.mode, h.n_participants, h.n_tasks, h.publisher)
                };
                // From here until the exit protocol this worker holds a
                // claimed seat the caller waits on; if the thread dies in
                // between, the guard reports the loss so the caller can
                // recover instead of waiting forever.
                let mut watch = DeathWatch { state: Arc::clone(&state), pid, armed: true };
                let die = {
                    let mut g = relock(&SIM_LOSE);
                    if g.is_some() && *g == publisher {
                        *g = None;
                        true
                    } else {
                        false
                    }
                };
                if die {
                    // Simulated worker death: claim one task (left
                    // unfinished, as if the thread died mid-execution) and
                    // exit the thread without running it or doing the exit
                    // protocol — the `watch` drop reports the loss.
                    if mode == Sched::Steal {
                        if let Some(i) = claim_front(&state.ranges[pid]) {
                            state.in_flight[pid].store(i + 1, Ordering::Release);
                        }
                    }
                    return;
                }
                // A panicking task must not kill the worker or strand the
                // caller: record it, do the exit protocol, re-raise
                // caller-side.
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    // SAFETY: the seat claim succeeded before the caller
                    // closed the job, so the caller is blocked in
                    // `Finish::finish` until this participant's exit below —
                    // the closure borrow outlives every use here.
                    participate(&state, pid, unsafe { &*f.0 }, mode, p, n_tasks);
                }));
                if res.is_err() {
                    state.panicked.store(true, Ordering::Release);
                }
                watch.disarm();
                {
                    let _g = relock(&state.done_lock);
                    state.exited.fetch_add(1, Ordering::AcqRel);
                    state.done_cv.notify_all();
                }
                participated = true;
                break;
            }
            if participated {
                continue;
            }
            let mut g = relock(&pool.sleep_lock);
            while pool.claimable.load(Ordering::Acquire) == 0 {
                g = pool.cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
        }
    }
}

/// Records a worker thread's death while it held a claimed seat. Armed
/// between the seat claim and the exit protocol; if the thread unwinds or
/// exits in that window without disarming, the drop handler publishes the
/// loss (participant index + in-flight task) and wakes the caller.
struct DeathWatch {
    state: Arc<JobState>,
    pid: usize,
    armed: bool,
}

impl DeathWatch {
    fn disarm(&mut self) {
        self.armed = false;
    }
}

impl Drop for DeathWatch {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let task = match self.state.in_flight[self.pid].swap(0, Ordering::AcqRel) {
            0 => None,
            v => Some(v - 1),
        };
        relock(&self.state.lost).push((self.pid, task));
        let _g = relock(&self.state.done_lock);
        self.state.done_cv.notify_all();
    }
}

static POOL: OnceLock<Arc<Pool>> = OnceLock::new();

thread_local! {
    /// True on pool worker threads: nested `run` executes inline.
    static ON_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn pool() -> &'static Arc<Pool> {
    POOL.get_or_init(|| {
        let n_workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).saturating_sub(1);
        let max_p = n_workers + 1;
        let mut free = Vec::with_capacity(ANNOUNCE_SLOTS);
        for _ in 0..PREALLOC_STATES {
            free.push(new_state(max_p));
        }
        let pool = Arc::new(Pool {
            slots: (0..ANNOUNCE_SLOTS)
                .map(|_| AnnounceSlot {
                    occupied: AtomicBool::new(false),
                    job: Mutex::new(None),
                })
                .collect(),
            free_states: Mutex::new(free),
            state_misses: AtomicUsize::new(0),
            claimable: AtomicUsize::new(0),
            sleep_lock: Mutex::new(()),
            cv: Condvar::new(),
            n_workers,
        });
        for _ in 0..n_workers {
            spawn_worker(Arc::clone(&pool));
        }
        pool
    })
}

/// Spawn one pool worker thread (used at init and to replace lost workers,
/// keeping the pool at `n_workers` strength across recoveries).
fn spawn_worker(pool: Arc<Pool>) {
    std::thread::Builder::new()
        .name("subtrack-pool".into())
        .spawn(move || Pool::worker_main(pool))
        .expect("spawn pool worker");
}

/// Whether the current thread is a pool worker (used by kernels to skip
/// re-planning: nested fan-out would run inline anyway).
pub fn on_worker() -> bool {
    ON_WORKER.with(|w| w.get())
}

/// Maximum useful participant count: the caller plus every pool worker.
pub fn max_participants() -> usize {
    pool().n_workers + 1
}

/// Fresh job-state allocations since pool init: the observable proxy for
/// the warm-`run`-does-not-allocate contract (deques and job slots are
/// pre-sized; misses are capped at first use of each concurrency level),
/// mirroring `Workspace::misses` for workspace leases.
pub fn job_state_misses() -> usize {
    pool().state_misses.load(Ordering::Relaxed)
}

/// Claim the front task of a participant's own range.
#[inline]
fn claim_front(range: &Mutex<(usize, usize)>) -> Option<usize> {
    let mut r = relock(range);
    if r.0 < r.1 {
        let i = r.0;
        r.0 += 1;
        Some(i)
    } else {
        None
    }
}

/// The claim-and-run loop shared by the caller (pid 0) and seat-winning
/// workers. In steal mode: drain the front of the own range; when empty,
/// split off the back half of the first non-empty victim range (round-robin
/// scan from the next pid) and install it as the own range. Exits when every
/// range is empty — remaining in-flight tasks belong to participants that
/// will exit after finishing them.
fn participate(
    state: &JobState,
    pid: usize,
    f: &(dyn Fn(usize) + Sync),
    mode: Sched,
    p: usize,
    n_tasks: usize,
) {
    let _cancel = CancelScope::install(&state.cancelled);
    match mode {
        Sched::Counter => loop {
            let i = state.counter.fetch_add(1, Ordering::Relaxed);
            if i >= n_tasks {
                return;
            }
            run_task(state, pid, f, i);
        },
        Sched::Steal => loop {
            while let Some(i) = claim_front(&state.ranges[pid]) {
                run_task(state, pid, f, i);
            }
            let mut stolen = None;
            for off in 1..p {
                let victim = (pid + off) % p;
                let mut r = relock(&state.ranges[victim]);
                let len = r.1 - r.0;
                if len > 0 {
                    let take = len.div_ceil(2);
                    stolen = Some((r.1 - take, r.1));
                    r.1 -= take;
                    break;
                }
            }
            match stolen {
                Some(range) => {
                    // Own range is empty (only its owner refills it), so the
                    // carried half can be installed wholesale.
                    *relock(&state.ranges[pid]) = range;
                }
                None => return,
            }
        },
    }
}

/// Execute one task with the in-flight marker and the progress heartbeat
/// around it (both feed the caller's watchdog / lost-worker recovery).
#[inline]
fn run_task(state: &JobState, pid: usize, f: &(dyn Fn(usize) + Sync), i: usize) {
    state.in_flight[pid].store(i + 1, Ordering::Release);
    f(i);
    state.in_flight[pid].store(0, Ordering::Release);
    state.progress.fetch_add(1, Ordering::Relaxed);
}

/// Close-and-wait guard for the caller: stops new seat claims, retires the
/// announce slot (O(1) — no queue scan), and blocks until every seat winner
/// exited. Runs on unwind too, so the lifetime-erased closure borrow can
/// never dangle even when the caller's own task panics. The wait doubles as
/// the watchdog (progress deadline) and the lost-worker recovery site.
struct Finish<'a> {
    pool: &'a Pool,
    state: &'a JobState,
    slot_idx: usize,
    extra: usize,
    done: bool,
    /// Copy of the job's task fn, used to re-run a dead worker's tasks.
    f: TaskFn,
    mode: Sched,
    /// Watchdog deadline resolved at publish time (0 = off).
    deadline_ms: usize,
    error: Option<PoolError>,
}

impl Finish<'_> {
    fn finish(&mut self) {
        if self.done {
            return;
        }
        self.done = true;
        // Close the job: no worker can win a seat after this swap.
        let unclaimed = self.state.seats.swap(0, Ordering::AcqRel);
        if unclaimed > 0 {
            self.pool.claimable.fetch_sub(unclaimed, Ordering::AcqRel);
        }
        // Retire the announce slot. Order matters: clear the job while the
        // slot is still marked occupied so no concurrent publisher can have
        // claimed it, then free the slot.
        let slot = &self.pool.slots[self.slot_idx];
        *relock(&slot.job) = None;
        slot.occupied.store(false, Ordering::Release);
        // Wait for every participant that did win a seat. With a deadline
        // armed the wait watches the progress heartbeat; either way, a
        // worker-death notification drops us into `recover_lost`.
        let claimed = self.extra - unclaimed;
        let mut last_progress = self.state.progress.load(Ordering::Relaxed);
        let mut stalled_windows = 0u32;
        loop {
            self.recover_lost();
            let g = relock(&self.state.done_lock);
            if self.state.exited.load(Ordering::Acquire) >= claimed {
                break;
            }
            if self.deadline_ms == 0 {
                let _g = self.state.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
                continue;
            }
            let (_g, timeout) = self
                .state
                .done_cv
                .wait_timeout(g, Duration::from_millis(self.deadline_ms as u64))
                .unwrap_or_else(|e| e.into_inner());
            if !timeout.timed_out() {
                continue;
            }
            let now = self.state.progress.load(Ordering::Relaxed);
            if now != last_progress {
                last_progress = now;
                stalled_windows = 0;
                continue;
            }
            stalled_windows += 1;
            if stalled_windows == 1 {
                // First full window without a single task completion:
                // cancel the job cooperatively and grant grace windows for
                // the stuck task to observe the flag and return.
                self.state.cancelled.store(true, Ordering::Release);
                self.error.get_or_insert(PoolError::Hung);
            } else if stalled_windows >= 4 {
                // The stuck task ignored cancellation for a whole further
                // window. It still borrows the caller's stack-lifetime
                // closure, so neither unwinding past it nor leaking the
                // wait is sound — fail loudly instead of hanging forever
                // (the standard watchdog contract over non-cooperative
                // code; distributed trainers' collective watchdogs do the
                // same).
                eprintln!(
                    "fatal: pool job made no progress for {} ms after cancellation \
                     (deadline {} ms); aborting",
                    self.deadline_ms * 3,
                    self.deadline_ms
                );
                std::process::abort();
            }
        }
    }

    /// Drain worker-death reports: re-run each dead participant's in-flight
    /// task, drain what is left of its range, credit its exit, and spawn a
    /// replacement worker. After this, every task has run and the wait
    /// accounting balances again.
    fn recover_lost(&mut self) {
        loop {
            let entry = relock(&self.state.lost).pop();
            let Some((pid, task)) = entry else { return };
            self.error.get_or_insert(PoolError::WorkerLost);
            // SAFETY: same borrow argument as the worker's call — the
            // closure outlives the job, and the owning thread is dead so
            // nothing else touches this participant's slots.
            let f = unsafe { &*self.f.0 };
            if let Some(i) = task {
                f(i);
                self.state.progress.fetch_add(1, Ordering::Relaxed);
            }
            if self.mode == Sched::Steal {
                // Counter-mode ranges are set-but-unused; draining them
                // would re-run tasks the shared counter already handed out.
                while let Some(i) = claim_front(&self.state.ranges[pid]) {
                    f(i);
                    self.state.progress.fetch_add(1, Ordering::Relaxed);
                }
            }
            {
                let _g = relock(&self.state.done_lock);
                self.state.exited.fetch_add(1, Ordering::AcqRel);
            }
            spawn_worker(Arc::clone(pool()));
        }
    }
}

impl Drop for Finish<'_> {
    fn drop(&mut self) {
        self.finish();
    }
}

/// Execute `f(0..n_tasks)` with up to `workers` participants (calling thread
/// included) on the work-stealing scheduler. Falls back to a plain
/// sequential loop when the fan-out cannot help (one task, one worker,
/// already on a pool worker, or no pool workers exist). Blocks until every
/// task completed.
///
/// Failure behavior: a recovered worker loss is *transparent* here (every
/// task still ran — a note goes to stderr); a watchdog cancellation panics,
/// because the output is invalid and this signature has no error channel.
/// Callers that want the typed event use [`try_run`].
pub fn run(workers: usize, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
    run_mode(workers, n_tasks, Sched::Steal, f);
}

/// [`run`] with an explicit [`Sched`] mode (bench/test entry point — the
/// two modes are behaviorally identical, differing only in claim
/// contention).
pub fn run_mode(workers: usize, n_tasks: usize, mode: Sched, f: &(dyn Fn(usize) + Sync)) {
    match try_run_mode(workers, n_tasks, mode, f) {
        Ok(()) | Err(PoolError::WorkerLost) => {}
        Err(e @ PoolError::Hung) => panic!("pool job failed: {e}"),
    }
}

/// [`run`] returning the watchdog/recovery outcome instead of panicking:
/// `Err(Hung)` means the job was cancelled and its output is invalid;
/// `Err(WorkerLost)` means a worker died but every task was recovered (the
/// output is valid — the error is telemetry for the caller's fault
/// accounting). See the module docs' watchdog section.
pub fn try_run(
    workers: usize,
    n_tasks: usize,
    f: &(dyn Fn(usize) + Sync),
) -> Result<(), PoolError> {
    try_run_mode(workers, n_tasks, Sched::Steal, f)
}

/// [`try_run`] with an explicit [`Sched`] mode.
pub fn try_run_mode(
    workers: usize,
    n_tasks: usize,
    mode: Sched,
    f: &(dyn Fn(usize) + Sync),
) -> Result<(), PoolError> {
    if n_tasks == 0 {
        return Ok(());
    }
    let workers = workers.min(n_tasks);
    if workers <= 1 || on_worker() {
        for i in 0..n_tasks {
            f(i);
        }
        return Ok(());
    }
    let pool = pool();
    let extra = (workers - 1).min(pool.n_workers);
    if extra == 0 {
        for i in 0..n_tasks {
            f(i);
        }
        return Ok(());
    }
    let p = extra + 1;
    let state = pool.lease_state();
    // Reset per-run fields. Exclusive access: the state came off the free
    // list, and prior users only drop stale Arc clones without touching
    // fields.
    state.panicked.store(false, Ordering::Relaxed);
    state.exited.store(0, Ordering::Relaxed);
    state.counter.store(0, Ordering::Relaxed);
    state.progress.store(0, Ordering::Relaxed);
    state.cancelled.store(false, Ordering::Relaxed);
    for slot in state.in_flight.iter().take(p) {
        slot.store(0, Ordering::Relaxed);
    }
    relock(&state.lost).clear();
    let per = n_tasks.div_ceil(p);
    for pid in 0..p {
        let lo = (pid * per).min(n_tasks);
        let hi = (lo + per).min(n_tasks);
        *relock(&state.ranges[pid]) = (lo, hi);
    }
    let task_fn = TaskFn(f as *const (dyn Fn(usize) + Sync));
    {
        let mut h = relock(&state.header);
        h.f = Some(task_fn);
        h.mode = mode;
        h.n_participants = p;
        h.n_tasks = n_tasks;
        h.publisher = Some(std::thread::current().id());
    }
    let Some(slot_idx) = pool.publish(&state) else {
        // Announce board full (pathological concurrent-caller count):
        // degrade to draining inline. `seats` was never opened, so a stale
        // Arc holder cannot join this dead job.
        pool.release_state(state);
        for i in 0..n_tasks {
            f(i);
        }
        return Ok(());
    };
    // Open the seats LAST, after the claimable budget is funded: a worker
    // can reach this state through a stale Arc from an earlier run (not
    // just through the announce slot), and every successful seat claim
    // debits `claimable` — a claim before the credit would underflow it.
    // The Release store also publishes the header/range writes above to
    // stale-route claimers (their CAS acquires it).
    pool.claimable.fetch_add(extra, Ordering::AcqRel);
    state.seats.store(extra, Ordering::Release);
    // Lock round-trip before notifying so a worker between its claimable
    // check and its wait cannot miss the wake-up.
    drop(relock(&pool.sleep_lock));
    if extra == 1 {
        pool.cv.notify_one();
    } else {
        pool.cv.notify_all();
    }
    let mut fin = Finish {
        pool: &**pool,
        state: &*state,
        slot_idx,
        extra,
        done: false,
        f: task_fn,
        mode,
        deadline_ms: pool_deadline_ms(),
        error: None,
    };
    // The caller participates too — it is one of the `workers` budget.
    participate(&state, 0, f, mode, p, n_tasks);
    fin.finish();
    let error = fin.error;
    let panicked = state.panicked.load(Ordering::Acquire);
    drop(fin);
    pool.release_state(state);
    if panicked {
        panic!("worker-pool task panicked (see stderr for the original panic)");
    }
    if error == Some(PoolError::WorkerLost) {
        eprintln!("warn: pool worker died mid-job; tasks recovered, replacement spawned");
    }
    match error {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn every_task_runs_exactly_once() {
        for n_tasks in [0usize, 1, 2, 7, 64, 1000] {
            for workers in [1usize, 2, 8] {
                for mode in [Sched::Steal, Sched::Counter] {
                    let counts: Vec<AtomicU32> =
                        (0..n_tasks).map(|_| AtomicU32::new(0)).collect();
                    run_mode(workers, n_tasks, mode, &|i| {
                        counts[i].fetch_add(1, Ordering::Relaxed);
                    });
                    for (i, c) in counts.iter().enumerate() {
                        assert_eq!(
                            c.load(Ordering::Relaxed),
                            1,
                            "task {i} ran wrong count \
                             (tasks={n_tasks} workers={workers} mode={mode:?})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn borrows_stack_data_mutably_through_disjoint_tasks() {
        let mut data = vec![0u64; 128];
        let base = data.as_mut_ptr() as usize;
        run(4, 128, &|i| {
            // Each task owns element i — disjoint writes.
            unsafe { *(base as *mut u64).add(i) = i as u64 * 3 };
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64 * 3);
        }
    }

    #[test]
    fn nested_runs_execute_inline_without_deadlock() {
        let total = AtomicU32::new(0);
        run(8, 8, &|_| {
            run(8, 4, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn concurrent_top_level_callers_share_the_pool() {
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    let sum = AtomicU32::new(0);
                    run(4, 100, &|i| {
                        sum.fetch_add(i as u32, Ordering::Relaxed);
                    });
                    assert_eq!(sum.load(Ordering::Relaxed), 4950);
                });
            }
        });
    }

    #[test]
    fn uneven_task_costs_rebalance_through_stealing() {
        // Front-loaded cost: the caller's own range holds all the slow
        // tasks, so completion within the test timeout requires either the
        // caller's own drain or steals — both must preserve exactly-once.
        let n = 200usize;
        let counts: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        run(8, n, &|i| {
            if i < 8 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn scheduler_modes_agree() {
        for n in [5usize, 63, 257] {
            let run_with = |mode: Sched| {
                let acc: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
                run_mode(8, n, mode, &|i| {
                    acc[i].fetch_add(i as u32 + 1, Ordering::Relaxed);
                });
                acc.iter().map(|a| a.load(Ordering::Relaxed)).collect::<Vec<_>>()
            };
            assert_eq!(run_with(Sched::Steal), run_with(Sched::Counter), "n={n}");
        }
    }

    #[test]
    fn warm_runs_reuse_job_state() {
        // Single-caller pattern: after a couple of warm-up runs the free
        // list serves every lease. Loop-until-stable because sibling tests
        // in this binary may lease states concurrently.
        let mut prev = usize::MAX;
        let mut stable = false;
        for _ in 0..10 {
            for _ in 0..4 {
                run(8, 64, &|i| {
                    std::hint::black_box(i);
                });
            }
            let now = job_state_misses();
            if now == prev {
                stable = true;
                break;
            }
            prev = now;
        }
        assert!(stable, "warm runs kept allocating job state");
    }

    #[test]
    fn watchdog_cancels_hung_task() {
        // One task hangs until cancelled — but only when a pool worker
        // claimed it. If the caller happens to run it (steal race, or a
        // 1-core machine with no workers), nothing hangs and the job is
        // clean; the assertion is conditioned on who ran the task.
        let _knob = crate::tensor::gemm::TEST_KNOB_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        set_pool_deadline_ms(200);
        let hung_on_worker = AtomicBool::new(false);
        let res = try_run(2, 2, &|i| {
            if i == 1 && on_worker() {
                hung_on_worker.store(true, Ordering::SeqCst);
                while !job_cancelled() {
                    std::thread::sleep(Duration::from_millis(1));
                }
            } else {
                // Keep the caller busy so the worker usually claims task 1.
                std::thread::sleep(Duration::from_millis(20));
            }
        });
        set_pool_deadline_ms(0);
        if hung_on_worker.load(Ordering::SeqCst) {
            assert_eq!(res, Err(PoolError::Hung));
        } else {
            assert_eq!(res, Ok(()));
        }
    }

    #[test]
    fn lost_worker_is_recovered_and_job_completes() {
        // Arm the simulated death (keyed to this thread's next job), then
        // verify exactly-once execution survives it: the dead worker's
        // claimed-but-unrun task and leftover range are re-run by the
        // caller, and the job reports the loss instead of hanging.
        let n = 64usize;
        let counts: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        simulate_worker_loss();
        let res = try_run(2, n, &|i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        cancel_simulated_worker_loss();
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "task {i} ran wrong count");
        }
        // The hook only fires if a worker claimed a seat before the job
        // closed (guaranteed on multi-core, but not on a 1-core runner).
        assert!(res == Ok(()) || res == Err(PoolError::WorkerLost), "unexpected: {res:?}");
    }
}
