//! Persistent worker pool shared by every threaded kernel.
//!
//! PR-1's `matmul_acc` forked `std::thread::scope` workers per call; at
//! refresh-path shapes (a few hundred rows) the fork/join overhead is
//! comparable to the kernel itself. This pool spawns
//! `available_parallelism() − 1` long-lived workers once, on first use, and
//! every threaded kernel (GEMM row blocks, QR reflector columns, Jacobi
//! rotation pairs, matvec blocks) and the data-parallel trainer shards draw
//! from the same budget through [`run`].
//!
//! # Execution model
//!
//! [`run`]`(workers, n_tasks, f)` executes `f(0)`, …, `f(n_tasks − 1)`
//! exactly once each, distributed over at most `workers` participants (the
//! calling thread plus pool workers). Task indices are handed out through a
//! shared atomic counter, so *which* thread runs a task is scheduling-
//! dependent — kernels must therefore make each task's output depend only on
//! its index, which is exactly the bit-identical-per-row/column contract the
//! GEMM kernel established. The caller blocks until every task has finished,
//! so closures may borrow stack data (the borrow is lifetime-erased
//! internally and provably outlives the run).
//!
//! # Nesting and the shared budget
//!
//! A task running *on* a pool worker never re-enters the pool: nested
//! [`run`] calls execute inline on that worker ([`on_worker`] guards this).
//! Combined with `gemm::run_single_threaded` (the data-parallel workers'
//! opt-out) this makes oversubscription impossible: one level of the stack
//! owns the cores at a time. Concurrent top-level callers simply queue; the
//! job counter still guarantees exactly-once execution of every task.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A raw mutable pointer that may be shared across pool tasks.
///
/// Wrapper contract: tasks must write **disjoint** regions (row blocks,
/// column strides, pair columns) — the pool gives no other synchronization.
/// This is how kernels hand each task its slice of an output buffer without
/// borrow-splitting gymnastics at closure-capture time.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    /// The wrapped pointer. All safety obligations of raw-pointer access
    /// apply; additionally, concurrent tasks must touch disjoint elements.
    #[inline]
    pub fn get(self) -> *mut T {
        self.0
    }
}

/// One unit of fan-out: a lifetime-erased task function plus the shared
/// completion state. Cloned once per participating worker.
#[derive(Clone)]
struct Job {
    /// Erased borrow of the caller's closure. Valid for the whole job:
    /// the caller blocks in [`run`] until `remaining` hits zero.
    f: &'static (dyn Fn(usize) + Sync),
    shared: Arc<JobShared>,
}

struct JobShared {
    /// Next task index to claim.
    next: AtomicUsize,
    n_tasks: usize,
    /// Worker copies of the job still running (the caller's own
    /// participation is not counted — it knows when it finished).
    remaining: AtomicUsize,
    /// Set when a worker-side task panicked; re-raised on the caller.
    panicked: std::sync::atomic::AtomicBool,
    done_lock: Mutex<()>,
    done_cv: Condvar,
}

/// Lock that tolerates poisoning: a panic inside a pool task must never
/// cascade into a secondary panic (or abort) on the synchronization path.
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl JobShared {
    /// Claim-and-run loop shared by workers and the caller.
    fn drain(&self, f: &(dyn Fn(usize) + Sync)) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_tasks {
                return;
            }
            f(i);
        }
    }

    fn signal_done(&self) {
        let _guard = relock(&self.done_lock);
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.done_cv.notify_all();
        }
    }

    /// Block until every worker copy of the job finished. MUST run before
    /// the caller's borrow of `f` ends — including on unwind — because
    /// workers hold a lifetime-erased reference to it.
    fn wait(&self) {
        let mut guard = relock(&self.done_lock);
        while self.remaining.load(Ordering::Acquire) > 0 {
            guard = self.done_cv.wait(guard).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Drop guard: waits for outstanding workers even when the caller's own
/// task panics, so the erased closure borrow can never dangle.
struct WaitOnDrop<'a>(&'a JobShared);

impl Drop for WaitOnDrop<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// The pool: a shared job queue the long-lived workers block on.
struct Pool {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    n_workers: usize,
}

impl Pool {
    fn worker_main(pool: Arc<Pool>) {
        ON_WORKER.with(|w| w.set(true));
        loop {
            let job = {
                let mut q = relock(&pool.queue);
                loop {
                    if let Some(job) = q.pop_front() {
                        break job;
                    }
                    q = pool.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                }
            };
            // A panicking task must not kill the worker or strand the
            // caller: record it, signal completion, re-raise caller-side.
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                job.shared.drain(job.f);
            }));
            if res.is_err() {
                job.shared.panicked.store(true, Ordering::Release);
            }
            job.shared.signal_done();
        }
    }
}

static POOL: OnceLock<Arc<Pool>> = OnceLock::new();

thread_local! {
    /// True on pool worker threads: nested `run` executes inline.
    static ON_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn pool() -> &'static Arc<Pool> {
    POOL.get_or_init(|| {
        let n_workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).saturating_sub(1);
        let pool = Arc::new(Pool {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            n_workers,
        });
        for _ in 0..n_workers {
            let p = Arc::clone(&pool);
            std::thread::Builder::new()
                .name("subtrack-pool".into())
                .spawn(move || Pool::worker_main(p))
                .expect("spawn pool worker");
        }
        pool
    })
}

/// Whether the current thread is a pool worker (used by kernels to skip
/// re-planning: nested fan-out would run inline anyway).
pub fn on_worker() -> bool {
    ON_WORKER.with(|w| w.get())
}

/// Maximum useful participant count: the caller plus every pool worker.
pub fn max_participants() -> usize {
    pool().n_workers + 1
}

/// Execute `f(0..n_tasks)` with up to `workers` participants (calling thread
/// included). Falls back to a plain sequential loop when the fan-out cannot
/// help (one task, one worker, already on a pool worker, or no pool workers
/// exist). Blocks until every task completed.
pub fn run(workers: usize, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
    if n_tasks == 0 {
        return;
    }
    let workers = workers.min(n_tasks);
    if workers <= 1 || on_worker() {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let pool = pool();
    let extra = (workers - 1).min(pool.n_workers);
    if extra == 0 {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let shared = Arc::new(JobShared {
        next: AtomicUsize::new(0),
        n_tasks,
        remaining: AtomicUsize::new(extra),
        panicked: std::sync::atomic::AtomicBool::new(false),
        done_lock: Mutex::new(()),
        done_cv: Condvar::new(),
    });
    // Erase the borrow's lifetime: sound because this function does not
    // return (or unwind — see `WaitOnDrop`) until `remaining == 0`, i.e.
    // until no worker holds `f` anymore.
    let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
    };
    {
        let mut q = relock(&pool.queue);
        for _ in 0..extra {
            q.push_back(Job { f: f_static, shared: Arc::clone(&shared) });
        }
    }
    if extra == 1 {
        pool.cv.notify_one();
    } else {
        pool.cv.notify_all();
    }
    {
        // The caller participates too — it is one of the `workers` budget —
        // and waits for the workers even if its own task panics.
        let _wait = WaitOnDrop(&shared);
        shared.drain(f);
        // Reclaim job copies no worker has popped yet: every task is claimed
        // by now, so a late pop would be a no-op — but waiting for a *busy*
        // worker (occupied with an unrelated long job) to pop-and-discard it
        // would stall this caller behind work it has no part in.
        let mut q = relock(&pool.queue);
        q.retain(|job| {
            let mine = Arc::ptr_eq(&job.shared, &shared);
            if mine {
                // No worker will signal for this copy; account for it here
                // (the caller is the one about to wait, so no notify needed).
                shared.remaining.fetch_sub(1, Ordering::AcqRel);
            }
            !mine
        });
    }
    if shared.panicked.load(Ordering::Acquire) {
        panic!("worker-pool task panicked (see stderr for the original panic)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn every_task_runs_exactly_once() {
        for n_tasks in [0usize, 1, 2, 7, 64, 1000] {
            for workers in [1usize, 2, 8] {
                let counts: Vec<AtomicU32> =
                    (0..n_tasks).map(|_| AtomicU32::new(0)).collect();
                run(workers, n_tasks, &|i| {
                    counts[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, c) in counts.iter().enumerate() {
                    assert_eq!(
                        c.load(Ordering::Relaxed),
                        1,
                        "task {i} ran wrong count (tasks={n_tasks} workers={workers})"
                    );
                }
            }
        }
    }

    #[test]
    fn borrows_stack_data_mutably_through_disjoint_tasks() {
        let mut data = vec![0u64; 128];
        let base = data.as_mut_ptr() as usize;
        run(4, 128, &|i| {
            // Each task owns element i — disjoint writes.
            unsafe { *(base as *mut u64).add(i) = i as u64 * 3 };
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64 * 3);
        }
    }

    #[test]
    fn nested_runs_execute_inline_without_deadlock() {
        let total = AtomicU32::new(0);
        run(8, 8, &|_| {
            run(8, 4, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn concurrent_top_level_callers_share_the_pool() {
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    let sum = AtomicU32::new(0);
                    run(4, 100, &|i| {
                        sum.fetch_add(i as u32, Ordering::Relaxed);
                    });
                    assert_eq!(sum.load(Ordering::Relaxed), 4950);
                });
            }
        });
    }
}
