//! Persistent worker pool with a work-stealing scheduler.
//!
//! PR-2 replaced per-call `thread::scope` forks with long-lived workers, but
//! handed tasks out through one shared atomic counter and queued job copies
//! through one mutex-guarded `VecDeque` — at high core counts every GEMM row
//! block, QR panel column, and Jacobi pair claim serialized on the same
//! cache line. This revision replaces that scheduler with per-participant
//! **range deques** and Chase–Lev-style half-stealing:
//!
//! * [`run`]`(workers, n_tasks, f)` pre-splits `0..n_tasks` into one
//!   contiguous index range per participant (the calling thread plus up to
//!   `workers − 1` pool workers). Each participant claims tasks from the
//!   *front* of its own range — a private cache line, uncontended in the
//!   common case — and when its range is empty it **steals the back half**
//!   of a victim's remaining range and installs it as its own. Stealing
//!   repeats until every range is empty, so uneven task costs rebalance
//!   without any shared claim counter.
//! * Jobs are announced on a fixed board of slots, each with its **own**
//!   lock; workers claim participant *seats* (one atomic CAS per job, not
//!   per task) and then never touch shared scheduler state again until they
//!   exit. There is no global job queue, and the pool-wide condvar exists
//!   only to sleep/wake idle workers.
//! * Job state (the range slots, seat/exit counters, completion condvar) is
//!   **leased from a pre-sized free list**, so a warm [`run`] submission
//!   performs no heap allocation: deques are fixed-capacity (one range slot
//!   per possible participant, sized at pool init) and job-state misses are
//!   capped at first use — the same contract the [`Workspace`] leases carry,
//!   gated by [`job_state_misses`] in `rust/tests/zero_alloc.rs`.
//!
//! [`Workspace`]: super::workspace::Workspace
//!
//! # Execution model: what reorders, what cannot
//!
//! [`run`] executes `f(0)`, …, `f(n_tasks − 1)` **exactly once each** and
//! blocks until all of them finished (so closures may borrow stack data; the
//! borrow is lifetime-erased internally and provably outlives the run).
//! Stealing makes *placement and order* scheduling-dependent: which thread
//! runs a task, and in what sequence, varies run to run. What cannot vary is
//! the *result*: a task is claimed by exactly one participant and runs the
//! same sequential kernel wherever it lands, so kernels that make each
//! task's output depend only on its index (the bit-identical-per-row/column
//! contract every threaded kernel in this crate follows) produce
//! bit-identical results for any worker count, any chunk size, and any
//! steal schedule. Tasks must not synchronize with each other — a task that
//! blocks on another task's side effect can deadlock, because sibling tasks
//! may be queued behind it on the same participant.
//!
//! # Isolation between jobs
//!
//! Each job's tasks live only in that job's range slots: a caller drains and
//! steals exclusively within its own job, and finishing touches only its own
//! announce slot (O(1) — the old scheduler's leftover-copy reclaim scanned
//! the global queue under its lock). A caller therefore **never blocks on an
//! unrelated busy worker**: with every pool worker pinned by some long job,
//! a new caller simply drains its whole task set itself and returns
//! (`rust/tests/pool_sched.rs` regression-tests this starvation bound).
//!
//! # Nesting and the shared budget
//!
//! A task running *on* a pool worker never re-enters the pool: nested
//! [`run`] calls execute inline on that worker ([`on_worker`] guards this).
//! Combined with `gemm::run_single_threaded` (the data-parallel workers'
//! opt-out) this makes oversubscription impossible: one level of the stack
//! owns the cores at a time. Concurrent top-level callers each announce
//! their own job and share the worker set through seat claims.
//!
//! # Task-local scratch
//!
//! Tasks that need scratch buffers cannot share the caller's single-owner
//! [`Workspace`]; they lease a whole workspace per task from a pre-sized
//! `WorkspaceBank` instead (the model's per-(batch, head) attention fan-out
//! is the canonical user — see the leasing rules in
//! [`super::workspace`]). Heavier kernels running *inside* a task should
//! stay sequential: with one pool task per unit of work, the parallelism
//! already lives at the fan-out level, and nested threading would only run
//! inline anyway.
//!
//! # Scheduler modes
//!
//! [`run_mode`] exposes the scheduler choice: [`Sched::Steal`] (the default
//! behind [`run`]) and [`Sched::Counter`], which dispatches through a single
//! shared counter over the same seat/announce machinery. Counter mode exists
//! as the contention baseline for `examples/gemmbench.rs` (`gemm.sched_ms`
//! counter-vs-deque sweep) and as a cross-check oracle in the stress suite —
//! both modes execute every task exactly once with identical results.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A raw mutable pointer that may be shared across pool tasks.
///
/// Wrapper contract: tasks must write **disjoint** regions (row blocks,
/// column strides, pair columns) — the pool gives no other synchronization.
/// This is how kernels hand each task its slice of an output buffer without
/// borrow-splitting gymnastics at closure-capture time.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    /// The wrapped pointer. All safety obligations of raw-pointer access
    /// apply; additionally, concurrent tasks must touch disjoint elements.
    #[inline]
    pub fn get(self) -> *mut T {
        self.0
    }
}

/// Task-dispatch strategy for [`run_mode`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sched {
    /// Per-participant range deques with half-stealing (the default).
    Steal,
    /// One shared claim counter (the pre-deque scheduler, kept as the
    /// contention baseline for benches and as a test oracle).
    Counter,
}

/// Lifetime-erased borrow of a caller's task closure. Stored as a raw fat
/// pointer so stale copies (a worker that looked at a job too late to claim
/// a seat) are never *dereferenced* — only participants that won a seat call
/// it, and the caller blocks until every such participant exited.
#[derive(Clone, Copy)]
struct TaskFn(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskFn {}
unsafe impl Sync for TaskFn {}

/// Per-run parameters, written by the caller before the job is announced
/// and read by each worker after it wins a seat (both under the mutex, so
/// publication is ordered).
struct Header {
    f: Option<TaskFn>,
    mode: Sched,
    n_participants: usize,
    n_tasks: usize,
}

/// Reusable per-job scheduler state, leased from the pool's free list.
///
/// `ranges[pid]` is participant `pid`'s deque: a `(lo, hi)` index range
/// claimed from the front by its owner and halved from the back by thieves.
/// Each slot has its own lock; a claim or steal holds exactly one lock at a
/// time (a stolen half is carried lock-free and installed into the thief's
/// own empty slot), so there is no lock-order cycle.
struct JobState {
    header: Mutex<Header>,
    /// One range slot per possible participant (`max_participants`), fixed
    /// at construction so warm runs never grow it.
    ranges: Vec<Mutex<(usize, usize)>>,
    /// Shared claim counter for [`Sched::Counter`] mode.
    counter: AtomicUsize,
    /// Unclaimed worker seats. A worker joins by CAS-decrementing this;
    /// the claimed value doubles as its participant index (1..=extra).
    /// The caller closes the job by swapping in 0.
    seats: AtomicUsize,
    /// Participants (seat winners) that have finished and released their
    /// borrow of the task closure.
    exited: AtomicUsize,
    /// Set when a participant's task panicked; re-raised on the caller.
    panicked: AtomicBool,
    done_lock: Mutex<()>,
    done_cv: Condvar,
}

fn new_state(max_p: usize) -> Arc<JobState> {
    Arc::new(JobState {
        header: Mutex::new(Header {
            f: None,
            mode: Sched::Steal,
            n_participants: 0,
            n_tasks: 0,
        }),
        ranges: (0..max_p).map(|_| Mutex::new((0usize, 0usize))).collect(),
        counter: AtomicUsize::new(0),
        seats: AtomicUsize::new(0),
        exited: AtomicUsize::new(0),
        panicked: AtomicBool::new(false),
        done_lock: Mutex::new(()),
        done_cv: Condvar::new(),
    })
}

/// One entry of the announce board. `occupied` is the cheap scan filter;
/// the Arc hand-off goes through the slot's own small mutex (there is no
/// board-wide lock).
struct AnnounceSlot {
    occupied: AtomicBool,
    job: Mutex<Option<Arc<JobState>>>,
}

/// Announce-board capacity: bounds *concurrent top-level* jobs only (nested
/// runs execute inline and DP shards run on the pool itself). If ever
/// exceeded, the caller degrades to draining its tasks inline — correct,
/// just unassisted.
const ANNOUNCE_SLOTS: usize = 64;

/// Job states pre-built at pool init, so the common one-caller-at-a-time
/// pattern never allocates even on its first run.
const PREALLOC_STATES: usize = 2;

/// Lock that tolerates poisoning: a panic inside a pool task must never
/// cascade into a secondary panic (or abort) on the synchronization path.
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct Pool {
    slots: Vec<AnnounceSlot>,
    /// Leasable job states; pre-sized so warm runs pop/push without
    /// allocating.
    free_states: Mutex<Vec<Arc<JobState>>>,
    /// Fresh job-state allocations after init (the zero-alloc gate's proxy,
    /// mirroring `Workspace::misses`).
    state_misses: AtomicUsize,
    /// Total unclaimed seats across announced jobs; the only thing idle
    /// workers sleep on.
    claimable: AtomicUsize,
    sleep_lock: Mutex<()>,
    cv: Condvar,
    n_workers: usize,
}

impl Pool {
    fn lease_state(&self) -> Arc<JobState> {
        if let Some(s) = relock(&self.free_states).pop() {
            return s;
        }
        self.state_misses.fetch_add(1, Ordering::Relaxed);
        new_state(self.n_workers + 1)
    }

    fn release_state(&self, s: Arc<JobState>) {
        relock(&self.free_states).push(s);
    }

    /// Claim a free announce slot and publish the job into it. Returns the
    /// slot index, or `None` when the board is full.
    fn publish(&self, state: &Arc<JobState>) -> Option<usize> {
        for (i, slot) in self.slots.iter().enumerate() {
            if slot
                .occupied
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                *relock(&slot.job) = Some(Arc::clone(state));
                return Some(i);
            }
        }
        None
    }

    fn worker_main(pool: Arc<Pool>) {
        ON_WORKER.with(|w| w.set(true));
        loop {
            let mut participated = false;
            for slot in &pool.slots {
                if !slot.occupied.load(Ordering::Acquire) {
                    continue;
                }
                let Some(state) = relock(&slot.job).clone() else {
                    continue;
                };
                // Claim a seat: the decremented-from value is this worker's
                // participant index (extra..1 map to pids extra..1).
                let mut s = state.seats.load(Ordering::Acquire);
                let pid = loop {
                    if s == 0 {
                        break 0;
                    }
                    match state.seats.compare_exchange_weak(
                        s,
                        s - 1,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    ) {
                        Ok(_) => break s,
                        Err(cur) => s = cur,
                    }
                };
                if pid == 0 {
                    continue; // all seats gone; look at other jobs
                }
                pool.claimable.fetch_sub(1, Ordering::AcqRel);
                let (f, mode, p, n_tasks) = {
                    let h = relock(&state.header);
                    let f = h.f.expect("announced job without a task fn");
                    (f, h.mode, h.n_participants, h.n_tasks)
                };
                // A panicking task must not kill the worker or strand the
                // caller: record it, do the exit protocol, re-raise
                // caller-side.
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    // SAFETY: the seat claim succeeded before the caller
                    // closed the job, so the caller is blocked in
                    // `Finish::finish` until this participant's exit below —
                    // the closure borrow outlives every use here.
                    participate(&state, pid, unsafe { &*f.0 }, mode, p, n_tasks);
                }));
                if res.is_err() {
                    state.panicked.store(true, Ordering::Release);
                }
                {
                    let _g = relock(&state.done_lock);
                    state.exited.fetch_add(1, Ordering::AcqRel);
                    state.done_cv.notify_all();
                }
                participated = true;
                break;
            }
            if participated {
                continue;
            }
            let mut g = relock(&pool.sleep_lock);
            while pool.claimable.load(Ordering::Acquire) == 0 {
                g = pool.cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
        }
    }
}

static POOL: OnceLock<Arc<Pool>> = OnceLock::new();

thread_local! {
    /// True on pool worker threads: nested `run` executes inline.
    static ON_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn pool() -> &'static Arc<Pool> {
    POOL.get_or_init(|| {
        let n_workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).saturating_sub(1);
        let max_p = n_workers + 1;
        let mut free = Vec::with_capacity(ANNOUNCE_SLOTS);
        for _ in 0..PREALLOC_STATES {
            free.push(new_state(max_p));
        }
        let pool = Arc::new(Pool {
            slots: (0..ANNOUNCE_SLOTS)
                .map(|_| AnnounceSlot {
                    occupied: AtomicBool::new(false),
                    job: Mutex::new(None),
                })
                .collect(),
            free_states: Mutex::new(free),
            state_misses: AtomicUsize::new(0),
            claimable: AtomicUsize::new(0),
            sleep_lock: Mutex::new(()),
            cv: Condvar::new(),
            n_workers,
        });
        for _ in 0..n_workers {
            let p = Arc::clone(&pool);
            std::thread::Builder::new()
                .name("subtrack-pool".into())
                .spawn(move || Pool::worker_main(p))
                .expect("spawn pool worker");
        }
        pool
    })
}

/// Whether the current thread is a pool worker (used by kernels to skip
/// re-planning: nested fan-out would run inline anyway).
pub fn on_worker() -> bool {
    ON_WORKER.with(|w| w.get())
}

/// Maximum useful participant count: the caller plus every pool worker.
pub fn max_participants() -> usize {
    pool().n_workers + 1
}

/// Fresh job-state allocations since pool init: the observable proxy for
/// the warm-`run`-does-not-allocate contract (deques and job slots are
/// pre-sized; misses are capped at first use of each concurrency level),
/// mirroring `Workspace::misses` for workspace leases.
pub fn job_state_misses() -> usize {
    pool().state_misses.load(Ordering::Relaxed)
}

/// Claim the front task of a participant's own range.
#[inline]
fn claim_front(range: &Mutex<(usize, usize)>) -> Option<usize> {
    let mut r = relock(range);
    if r.0 < r.1 {
        let i = r.0;
        r.0 += 1;
        Some(i)
    } else {
        None
    }
}

/// The claim-and-run loop shared by the caller (pid 0) and seat-winning
/// workers. In steal mode: drain the front of the own range; when empty,
/// split off the back half of the first non-empty victim range (round-robin
/// scan from the next pid) and install it as the own range. Exits when every
/// range is empty — remaining in-flight tasks belong to participants that
/// will exit after finishing them.
fn participate(
    state: &JobState,
    pid: usize,
    f: &(dyn Fn(usize) + Sync),
    mode: Sched,
    p: usize,
    n_tasks: usize,
) {
    match mode {
        Sched::Counter => loop {
            let i = state.counter.fetch_add(1, Ordering::Relaxed);
            if i >= n_tasks {
                return;
            }
            f(i);
        },
        Sched::Steal => loop {
            while let Some(i) = claim_front(&state.ranges[pid]) {
                f(i);
            }
            let mut stolen = None;
            for off in 1..p {
                let victim = (pid + off) % p;
                let mut r = relock(&state.ranges[victim]);
                let len = r.1 - r.0;
                if len > 0 {
                    let take = len.div_ceil(2);
                    stolen = Some((r.1 - take, r.1));
                    r.1 -= take;
                    break;
                }
            }
            match stolen {
                Some(range) => {
                    // Own range is empty (only its owner refills it), so the
                    // carried half can be installed wholesale.
                    *relock(&state.ranges[pid]) = range;
                }
                None => return,
            }
        },
    }
}

/// Close-and-wait guard for the caller: stops new seat claims, retires the
/// announce slot (O(1) — no queue scan), and blocks until every seat winner
/// exited. Runs on unwind too, so the lifetime-erased closure borrow can
/// never dangle even when the caller's own task panics.
struct Finish<'a> {
    pool: &'a Pool,
    state: &'a JobState,
    slot_idx: usize,
    extra: usize,
    done: bool,
}

impl Finish<'_> {
    fn finish(&mut self) {
        if self.done {
            return;
        }
        self.done = true;
        // Close the job: no worker can win a seat after this swap.
        let unclaimed = self.state.seats.swap(0, Ordering::AcqRel);
        if unclaimed > 0 {
            self.pool.claimable.fetch_sub(unclaimed, Ordering::AcqRel);
        }
        // Retire the announce slot. Order matters: clear the job while the
        // slot is still marked occupied so no concurrent publisher can have
        // claimed it, then free the slot.
        let slot = &self.pool.slots[self.slot_idx];
        *relock(&slot.job) = None;
        slot.occupied.store(false, Ordering::Release);
        // Wait for every participant that did win a seat.
        let claimed = self.extra - unclaimed;
        let mut g = relock(&self.state.done_lock);
        while self.state.exited.load(Ordering::Acquire) < claimed {
            g = self.state.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl Drop for Finish<'_> {
    fn drop(&mut self) {
        self.finish();
    }
}

/// Execute `f(0..n_tasks)` with up to `workers` participants (calling thread
/// included) on the work-stealing scheduler. Falls back to a plain
/// sequential loop when the fan-out cannot help (one task, one worker,
/// already on a pool worker, or no pool workers exist). Blocks until every
/// task completed.
pub fn run(workers: usize, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
    run_mode(workers, n_tasks, Sched::Steal, f);
}

/// [`run`] with an explicit [`Sched`] mode (bench/test entry point — the
/// two modes are behaviorally identical, differing only in claim
/// contention).
pub fn run_mode(workers: usize, n_tasks: usize, mode: Sched, f: &(dyn Fn(usize) + Sync)) {
    if n_tasks == 0 {
        return;
    }
    let workers = workers.min(n_tasks);
    if workers <= 1 || on_worker() {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let pool = pool();
    let extra = (workers - 1).min(pool.n_workers);
    if extra == 0 {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let p = extra + 1;
    let state = pool.lease_state();
    // Reset per-run fields. Exclusive access: the state came off the free
    // list, and prior users only drop stale Arc clones without touching
    // fields.
    state.panicked.store(false, Ordering::Relaxed);
    state.exited.store(0, Ordering::Relaxed);
    state.counter.store(0, Ordering::Relaxed);
    let per = n_tasks.div_ceil(p);
    for pid in 0..p {
        let lo = (pid * per).min(n_tasks);
        let hi = (lo + per).min(n_tasks);
        *relock(&state.ranges[pid]) = (lo, hi);
    }
    {
        let mut h = relock(&state.header);
        h.f = Some(TaskFn(f as *const (dyn Fn(usize) + Sync)));
        h.mode = mode;
        h.n_participants = p;
        h.n_tasks = n_tasks;
    }
    let Some(slot_idx) = pool.publish(&state) else {
        // Announce board full (pathological concurrent-caller count):
        // degrade to draining inline. `seats` was never opened, so a stale
        // Arc holder cannot join this dead job.
        pool.release_state(state);
        for i in 0..n_tasks {
            f(i);
        }
        return;
    };
    // Open the seats LAST, after the claimable budget is funded: a worker
    // can reach this state through a stale Arc from an earlier run (not
    // just through the announce slot), and every successful seat claim
    // debits `claimable` — a claim before the credit would underflow it.
    // The Release store also publishes the header/range writes above to
    // stale-route claimers (their CAS acquires it).
    pool.claimable.fetch_add(extra, Ordering::AcqRel);
    state.seats.store(extra, Ordering::Release);
    // Lock round-trip before notifying so a worker between its claimable
    // check and its wait cannot miss the wake-up.
    drop(relock(&pool.sleep_lock));
    if extra == 1 {
        pool.cv.notify_one();
    } else {
        pool.cv.notify_all();
    }
    let mut fin = Finish { pool: &**pool, state: &*state, slot_idx, extra, done: false };
    // The caller participates too — it is one of the `workers` budget.
    participate(&state, 0, f, mode, p, n_tasks);
    fin.finish();
    let panicked = state.panicked.load(Ordering::Acquire);
    drop(fin);
    pool.release_state(state);
    if panicked {
        panic!("worker-pool task panicked (see stderr for the original panic)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn every_task_runs_exactly_once() {
        for n_tasks in [0usize, 1, 2, 7, 64, 1000] {
            for workers in [1usize, 2, 8] {
                for mode in [Sched::Steal, Sched::Counter] {
                    let counts: Vec<AtomicU32> =
                        (0..n_tasks).map(|_| AtomicU32::new(0)).collect();
                    run_mode(workers, n_tasks, mode, &|i| {
                        counts[i].fetch_add(1, Ordering::Relaxed);
                    });
                    for (i, c) in counts.iter().enumerate() {
                        assert_eq!(
                            c.load(Ordering::Relaxed),
                            1,
                            "task {i} ran wrong count \
                             (tasks={n_tasks} workers={workers} mode={mode:?})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn borrows_stack_data_mutably_through_disjoint_tasks() {
        let mut data = vec![0u64; 128];
        let base = data.as_mut_ptr() as usize;
        run(4, 128, &|i| {
            // Each task owns element i — disjoint writes.
            unsafe { *(base as *mut u64).add(i) = i as u64 * 3 };
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64 * 3);
        }
    }

    #[test]
    fn nested_runs_execute_inline_without_deadlock() {
        let total = AtomicU32::new(0);
        run(8, 8, &|_| {
            run(8, 4, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn concurrent_top_level_callers_share_the_pool() {
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    let sum = AtomicU32::new(0);
                    run(4, 100, &|i| {
                        sum.fetch_add(i as u32, Ordering::Relaxed);
                    });
                    assert_eq!(sum.load(Ordering::Relaxed), 4950);
                });
            }
        });
    }

    #[test]
    fn uneven_task_costs_rebalance_through_stealing() {
        // Front-loaded cost: the caller's own range holds all the slow
        // tasks, so completion within the test timeout requires either the
        // caller's own drain or steals — both must preserve exactly-once.
        let n = 200usize;
        let counts: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        run(8, n, &|i| {
            if i < 8 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn scheduler_modes_agree() {
        for n in [5usize, 63, 257] {
            let run_with = |mode: Sched| {
                let acc: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
                run_mode(8, n, mode, &|i| {
                    acc[i].fetch_add(i as u32 + 1, Ordering::Relaxed);
                });
                acc.iter().map(|a| a.load(Ordering::Relaxed)).collect::<Vec<_>>()
            };
            assert_eq!(run_with(Sched::Steal), run_with(Sched::Counter), "n={n}");
        }
    }

    #[test]
    fn warm_runs_reuse_job_state() {
        // Single-caller pattern: after a couple of warm-up runs the free
        // list serves every lease. Loop-until-stable because sibling tests
        // in this binary may lease states concurrently.
        let mut prev = usize::MAX;
        let mut stable = false;
        for _ in 0..10 {
            for _ in 0..4 {
                run(8, 64, &|i| {
                    std::hint::black_box(i);
                });
            }
            let now = job_state_misses();
            if now == prev {
                stable = true;
                break;
            }
            prev = now;
        }
        assert!(stable, "warm runs kept allocating job state");
    }
}
